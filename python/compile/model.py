"""Layer-2 JAX compute graphs for RC-FED.

Defines the federated learning models the paper evaluates (S5):

* ``mlp``  — a dense classifier used for the SynthCifar task (the paper's
  ResNet-18 is substituted per DESIGN.md: gradient statistics and the
  compression mechanics are dimension-independent).
* ``cnn``  — the paper's FEMNIST architecture verbatim in spirit: two conv
  layers followed by two fully-connected layers.

Every exported graph is a pure function over explicit parameter lists (no
pytree magic on the wire): the rust coordinator feeds parameters in the
manifest order and receives gradients in the same order. Graphs are lowered
once by ``aot.py`` to HLO text; Python never runs on the request path.

The gradient-compression hot path (``quantize_chunk`` etc.) lives in the
Layer-1 Pallas kernels and is exported as its own HLO so the rust client
can run compress/decompress without re-tracing the model.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import quantize as qk

# ---------------------------------------------------------------------------
# Model configurations
# ---------------------------------------------------------------------------


class ModelSpec:
    """Static description of one exported model variant."""

    def __init__(self, name, kind, input_shape, num_classes, batch, **kw):
        self.name = name
        self.kind = kind                  # "mlp" | "cnn"
        self.input_shape = tuple(input_shape)
        self.num_classes = num_classes
        self.batch = batch
        self.kw = kw

    # -- parameter inventory -------------------------------------------------

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        if self.kind == "mlp":
            dims = [int(math.prod(self.input_shape))] + list(
                self.kw.get("hidden", (256, 128))
            ) + [self.num_classes]
            specs = []
            for i in range(len(dims) - 1):
                specs.append((f"w{i}", (dims[i], dims[i + 1])))
                specs.append((f"b{i}", (dims[i + 1],)))
            return specs
        if self.kind == "cnn":
            h, w, cin = self.input_shape
            c1 = self.kw.get("c1", 8)
            c2 = self.kw.get("c2", 16)
            fc = self.kw.get("fc", 128)
            # two 3x3 SAME convs, each followed by 2x2 max-pool
            flat = (h // 4) * (w // 4) * c2
            return [
                ("conv1_w", (3, 3, cin, c1)), ("conv1_b", (c1,)),
                ("conv2_w", (3, 3, c1, c2)), ("conv2_b", (c2,)),
                ("fc1_w", (flat, fc)), ("fc1_b", (fc,)),
                ("fc2_w", (fc, self.num_classes)), ("fc2_b", (self.num_classes,)),
            ]
        raise ValueError(f"unknown model kind {self.kind!r}")

    def num_params(self) -> int:
        return sum(int(math.prod(s)) for _, s in self.param_specs())

    # -- init ----------------------------------------------------------------

    def init_params(self, seed: int = 0) -> List[jnp.ndarray]:
        key = jax.random.PRNGKey(seed)
        params = []
        for pname, shape in self.param_specs():
            key, sub = jax.random.split(key)
            if pname.endswith("_b") or pname.startswith("b"):
                params.append(jnp.zeros(shape, jnp.float32))
            else:
                fan_in = int(math.prod(shape[:-1])) if len(shape) > 1 else shape[0]
                scale = math.sqrt(2.0 / max(fan_in, 1))
                params.append(scale * jax.random.normal(sub, shape, jnp.float32))
        return params


MODELS = {
    # SynthCifar substitute for the paper's CIFAR-10/ResNet-18 run:
    # K=10 clients, Dirichlet beta=0.5, batch 64 (S5).
    "mlp_synthcifar": ModelSpec(
        "mlp_synthcifar", "mlp", (768,), 10, 64, hidden=(256, 128)),
    # FEMNIST model from the paper: 2 conv + 2 fc, 62 classes, batch 32.
    "cnn_synthfemnist": ModelSpec(
        "cnn_synthfemnist", "cnn", (28, 28, 1), 62, 32, c1=8, c2=16, fc=128),
    # Tiny variant for fast integration tests / quickstart.
    "mlp_tiny": ModelSpec("mlp_tiny", "mlp", (32,), 4, 16, hidden=(32,)),
}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _mlp_logits(spec: ModelSpec, params: Sequence[jnp.ndarray], x):
    h = x.reshape(x.shape[0], -1)
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = h @ w + b
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def _max_pool_2x2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _cnn_logits(spec: ModelSpec, params: Sequence[jnp.ndarray], x):
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    dn = lax.conv_dimension_numbers(x.shape, c1w.shape, ("NHWC", "HWIO", "NHWC"))
    h = lax.conv_general_dilated(x, c1w, (1, 1), "SAME", dimension_numbers=dn)
    h = jax.nn.relu(h + c1b)
    h = _max_pool_2x2(h)
    dn = lax.conv_dimension_numbers(h.shape, c2w.shape, ("NHWC", "HWIO", "NHWC"))
    h = lax.conv_general_dilated(h, c2w, (1, 1), "SAME", dimension_numbers=dn)
    h = jax.nn.relu(h + c2b)
    h = _max_pool_2x2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ f1w + f1b)
    return h @ f2w + f2b


def logits_fn(spec: ModelSpec, params, x):
    if spec.kind == "mlp":
        return _mlp_logits(spec, params, x)
    return _cnn_logits(spec, params, x)


# ---------------------------------------------------------------------------
# Exported graphs
# ---------------------------------------------------------------------------


def loss_fn(spec: ModelSpec, params, x, y):
    """Mean softmax cross-entropy over a mini-batch (labels int32)."""
    lg = logits_fn(spec, params, x)
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def make_train_step(spec: ModelSpec):
    """(params..., x, y) -> (grads..., loss). The client-side local step."""

    def train_step(*args):
        n = len(spec.param_specs())
        params, x, y = list(args[:n]), args[n], args[n + 1]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(spec, p, x, y))(params)
        return tuple(grads) + (loss,)

    return train_step


def make_eval_step(spec: ModelSpec):
    """(params..., x, y) -> correct-prediction count over the batch."""

    def eval_step(*args):
        n = len(spec.param_specs())
        params, x, y = list(args[:n]), args[n], args[n + 1]
        lg = logits_fn(spec, params, x)
        return (jnp.sum((jnp.argmax(lg, axis=-1) == y).astype(jnp.int32)),)

    return eval_step


def make_quantize_chunk(num_levels: int, chunk: int, block: int):
    """(g, mu, sigma, bounds, levels) -> (deq, idx) via the Pallas kernel."""

    def quantize(g, mu, sigma, bounds, levels):
        deq, idx = qk.quantize_chunk(g, mu, sigma, bounds, levels, block=block)
        return deq, idx

    return quantize


def make_moments_chunk(chunk: int, block: int):
    """(g,) -> per-block (sum, sumsq) partials via the Pallas kernel."""

    def moments(g):
        s, ss = qk.moments_chunk(g, block=block)
        return s, ss

    return moments


def make_dequantize_chunk(num_levels: int, chunk: int, block: int):
    """(idx, mu, sigma, levels) -> deq via the Pallas kernel (PS side)."""

    def deq(idx, mu, sigma, levels):
        return (qk.dequantize_chunk(idx, mu, sigma, levels, block=block),)

    return deq
