"""AOT compile path: lower every Layer-2 graph to HLO *text* + manifest.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per exported graph plus ``manifest.json``
describing parameter inventories and I/O shapes, which the rust runtime
(`rust/src/runtime/artifacts.rs`) parses to drive PJRT execution.

HLO **text** is the interchange format, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Gradient chunks stream through the Pallas quantize kernel in fixed-size
# pieces; the rust side zero-pads the final chunk. 65536 f32 = 256 KiB.
CHUNK = 65536
BLOCK = 8192
# Bit widths exported for the quantizer graphs (paper tests b in {3, 6};
# the rate-distortion bench sweeps wider).
BITS = (2, 3, 4, 6)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(dt):
    return {jnp.float32: "f32", jnp.int32: "i32"}[dt]


def export_entry(out_dir, name, fn, in_specs, manifest):
    lowered = jax.jit(fn).lower(*[_spec(s, d) for s, d in in_specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = [
        {"shape": list(s.shape), "dtype": _dtype_name_from(s.dtype)}
        for s in jax.eval_shape(fn, *[_spec(s, d) for s, d in in_specs])
    ]
    manifest["artifacts"][name] = {
        "file": fname,
        "inputs": [{"shape": list(s), "dtype": _dtype_name(d)}
                   for s, d in in_specs],
        "outputs": out_shapes,
    }
    print(f"  wrote {fname} ({len(text)} chars)")


def _dtype_name_from(dt):
    s = jnp.dtype(dt).name
    return {"float32": "f32", "int32": "i32"}[s]


def build_manifest_models(manifest):
    for name, spec in M.MODELS.items():
        manifest["models"][name] = {
            "kind": spec.kind,
            "input_shape": list(spec.input_shape),
            "num_classes": spec.num_classes,
            "batch": spec.batch,
            "num_params": spec.num_params(),
            "params": [{"name": n, "shape": list(s)}
                       for n, s in spec.param_specs()],
            "train": f"train_{name}",
            "eval": f"eval_{name}",
        }


def main() -> int:
    ap = argparse.ArgumentParser(description="RC-FED AOT export")
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifest")
    ap.add_argument("--models", default=",".join(M.MODELS),
                    help="comma-separated model names to export")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "chunk": CHUNK,
        "block": BLOCK,
        "bits": list(BITS),
        "artifacts": {},
        "models": {},
    }
    build_manifest_models(manifest)

    # ---- model graphs -----------------------------------------------------
    for name in args.models.split(","):
        spec = M.MODELS[name]
        pin = [(s, F32) for _, s in spec.param_specs()]
        xin = (spec.batch,) + spec.input_shape
        yin = (spec.batch,)
        print(f"[aot] model {name}: {spec.num_params()} params")
        export_entry(out_dir, f"train_{name}", M.make_train_step(spec),
                     pin + [(xin, F32), (yin, I32)], manifest)
        export_entry(out_dir, f"eval_{name}", M.make_eval_step(spec),
                     pin + [(xin, F32), (yin, I32)], manifest)

    # ---- compression graphs (Layer-1 Pallas, shared by all models) -------
    for b in BITS:
        nl = 1 << b
        print(f"[aot] quantize b={b} ({nl} levels, chunk={CHUNK})")
        export_entry(
            out_dir, f"quantize_b{b}",
            M.make_quantize_chunk(nl, CHUNK, BLOCK),
            [((CHUNK,), F32), ((1,), F32), ((1,), F32),
             ((nl - 1,), F32), ((nl,), F32)], manifest)
        export_entry(
            out_dir, f"dequantize_b{b}",
            M.make_dequantize_chunk(nl, CHUNK, BLOCK),
            [((CHUNK,), I32), ((1,), F32), ((1,), F32), ((nl,), F32)],
            manifest)
    export_entry(out_dir, "moments", M.make_moments_chunk(CHUNK, BLOCK),
                 [((CHUNK,), F32)], manifest)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest.json + {len(manifest['artifacts'])} artifacts -> {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
