"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis in ``python/tests/test_kernel.py``). They intentionally use the
most literal jnp formulation (searchsorted / direct reductions) rather than
mirroring the kernels' blocked structure.
"""

from __future__ import annotations

import jax.numpy as jnp

SIGMA_FLOOR = 1e-8


def quantize_ref(g, mu, sigma, bounds, levels):
    """Reference fused normalize + bucketize + dequantize.

    idx[i] = searchsorted(bounds, z[i], side='left')  (i.e. #{j: z_i > u_j})
    deq[i] = levels[idx[i]] * sigma + mu
    """
    sigma = jnp.maximum(sigma, SIGMA_FLOOR)
    z = (g - mu) / sigma
    idx = jnp.searchsorted(bounds, z, side="left").astype(jnp.int32)
    deq = levels[idx] * sigma + mu
    return deq, idx


def moments_ref(g, block):
    """Reference per-block (sum, sumsq) partials."""
    gb = g.reshape(-1, block)
    return jnp.sum(gb, axis=1), jnp.sum(gb * gb, axis=1)


def dequantize_ref(idx, mu, sigma, levels):
    sigma = jnp.maximum(sigma, SIGMA_FLOOR)
    return levels[idx] * sigma + mu
