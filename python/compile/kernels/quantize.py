"""Layer-1 Pallas kernels for the RC-FED gradient-compression hot spot.

The paper's per-coordinate pipeline (Algorithm 1, client side) is

    z      = (g - mu) / sigma          # statistics-aware normalization (S3.1)
    idx    = bucketize(z, boundaries)  # scalar quantization Q*(.)   (S3.2)
    deq    = levels[idx] * sigma + mu  # PS-side reconstruction      (S3.4)

fused into a single memory-bound kernel. On TPU this is a pure VPU
(vector-unit) workload: the 2^b <= 64-entry codebook is replicated into
VMEM next to every gradient block, and bucketize is a branch-free
compare-and-accumulate against the sorted boundary vector, i.e.

    idx[i] = sum_j [ z[i] > u_j ]

which vectorizes perfectly and needs no MXU. Blocks of BLOCK coordinates
stream HBM->VMEM via the BlockSpec grid; the op is roofline-bound on HBM
bandwidth (see DESIGN.md SS Hardware-Adaptation).

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin that
the rust runtime drives cannot execute Mosaic custom-calls. Correctness is
pinned against the pure-jnp oracle in ``ref.py`` by ``python/tests``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: 8192 f32 = 32 KiB per input block in VMEM; with the output
# block, the int32 index block and a <=64-entry codebook this is ~100 KiB,
# leaving ample VMEM for double buffering on a real TPU core.
DEFAULT_BLOCK = 8192

# Guard against degenerate (constant) gradient blocks: sigma is clamped so
# normalization never divides by ~0. Matches ref.py and the rust pipeline.
SIGMA_FLOOR = 1e-8


def _quantize_block_kernel(g_ref, mu_ref, sigma_ref, bounds_ref, levels_ref,
                           deq_ref, idx_ref):
    """Fused normalize + bucketize + dequantize over one VMEM block."""
    g = g_ref[...]
    mu = mu_ref[0]
    sigma = jnp.maximum(sigma_ref[0], SIGMA_FLOOR)
    z = (g - mu) / sigma
    # Branch-free bucketize: idx[i] = #{j : z[i] > u_j}. bounds is sorted
    # ascending, so this equals searchsorted(bounds, z, side='left').
    cmp = z[:, None] > bounds_ref[...][None, :]
    idx = jnp.sum(cmp.astype(jnp.int32), axis=-1)
    idx_ref[...] = idx
    # Reconstruction the PS will compute, eq. (11): sigma * Qi*(s_idx) + mu.
    deq_ref[...] = jnp.take(levels_ref[...], idx) * sigma + mu


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_chunk(g, mu, sigma, bounds, levels, *, block=DEFAULT_BLOCK):
    """Quantize a 1-D f32 chunk against a (levels, bounds) codebook.

    Args:
      g:      f32[d] gradient chunk; d must be a multiple of ``block``.
      mu:     f32[1] client-round gradient mean (side information).
      sigma:  f32[1] client-round gradient std (side information).
      bounds: f32[2^b - 1] sorted decision boundaries u_1..u_{2^b-1}.
      levels: f32[2^b] reconstruction levels s_0..s_{2^b-1}.

    Returns:
      (deq, idx): f32[d] de-normalized reconstruction and i32[d] symbol ids.
    """
    (d,) = g.shape
    if d % block != 0:
        raise ValueError(f"chunk length {d} not a multiple of block {block}")
    nb = bounds.shape[0]
    nl = levels.shape[0]
    grid = (d // block,)
    return pl.pallas_call(
        _quantize_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),       # stream g blocks
            pl.BlockSpec((1,), lambda i: (0,)),           # replicate mu
            pl.BlockSpec((1,), lambda i: (0,)),           # replicate sigma
            pl.BlockSpec((nb,), lambda i: (0,)),          # replicate codebook
            pl.BlockSpec((nl,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.int32),
        ],
        interpret=True,
    )(g, mu, sigma, bounds, levels)


def _moments_block_kernel(g_ref, sum_ref, sumsq_ref):
    """Per-block partial sums for the two-pass (mu, sigma) reduction."""
    g = g_ref[...]
    sum_ref[0] = jnp.sum(g)
    sumsq_ref[0] = jnp.sum(g * g)


@functools.partial(jax.jit, static_argnames=("block",))
def moments_chunk(g, *, block=DEFAULT_BLOCK):
    """Per-block (sum, sum of squares) partials of a 1-D f32 chunk.

    The combine step (across blocks and across chunks) is a cheap host-side
    scalar reduction done by the rust coordinator; splitting it this way
    keeps the kernel a single streaming pass over HBM.
    """
    (d,) = g.shape
    if d % block != 0:
        raise ValueError(f"chunk length {d} not a multiple of block {block}")
    nblk = d // block
    return pl.pallas_call(
        _moments_block_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
        ],
        interpret=True,
    )(g)


def _dequantize_block_kernel(idx_ref, mu_ref, sigma_ref, levels_ref, out_ref):
    """PS-side reconstruction (11): out = sigma * levels[idx] + mu."""
    mu = mu_ref[0]
    sigma = jnp.maximum(sigma_ref[0], SIGMA_FLOOR)
    out_ref[...] = jnp.take(levels_ref[...], idx_ref[...]) * sigma + mu


@functools.partial(jax.jit, static_argnames=("block",))
def dequantize_chunk(idx, mu, sigma, levels, *, block=DEFAULT_BLOCK):
    """Reconstruct a chunk from symbol ids (the PS half of the pipeline)."""
    (d,) = idx.shape
    if d % block != 0:
        raise ValueError(f"chunk length {d} not a multiple of block {block}")
    nl = levels.shape[0]
    return pl.pallas_call(
        _dequantize_block_kernel,
        grid=(d // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((nl,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(idx, mu, sigma, levels)
