"""AOT export path: HLO text well-formedness + manifest integrity."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--models", "mlp_tiny"],
        cwd=ROOT, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    return out


def test_manifest_structure(art_dir):
    man = json.loads((art_dir / "manifest.json").read_text())
    assert man["version"] == 1
    assert man["chunk"] % man["block"] == 0
    assert set(man["bits"]) == {2, 3, 4, 6}
    assert "train_mlp_tiny" in man["artifacts"]
    assert "quantize_b3" in man["artifacts"]
    assert "moments" in man["artifacts"]
    for name, art in man["artifacts"].items():
        assert (art_dir / art["file"]).exists(), name
        assert art["inputs"] and art["outputs"], name


def test_hlo_text_is_parseable_hlo(art_dir):
    man = json.loads((art_dir / "manifest.json").read_text())
    for name, art in man["artifacts"].items():
        text = (art_dir / art["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_quantize_artifact_io_shapes(art_dir):
    man = json.loads((art_dir / "manifest.json").read_text())
    chunk = man["chunk"]
    for b in man["bits"]:
        art = man["artifacts"][f"quantize_b{b}"]
        shapes = [tuple(i["shape"]) for i in art["inputs"]]
        assert shapes == [(chunk,), (1,), (1,), ((1 << b) - 1,), (1 << b,)]
        out = [tuple(o["shape"]) for o in art["outputs"]]
        assert out == [(chunk,), (chunk,)]
        dt = [o["dtype"] for o in art["outputs"]]
        assert dt == ["f32", "i32"]


def test_model_manifest_param_inventory(art_dir):
    man = json.loads((art_dir / "manifest.json").read_text())
    m = man["models"]["mlp_tiny"]
    total = sum(int(_prod(p["shape"])) for p in m["params"])
    assert total == m["num_params"]
    art = man["artifacts"][m["train"]]
    # train inputs = params + x + y; outputs = grads + loss
    assert len(art["inputs"]) == len(m["params"]) + 2
    assert len(art["outputs"]) == len(m["params"]) + 1


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out
