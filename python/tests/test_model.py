"""Layer-2 correctness: model graphs (shapes, gradients, learnability)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def batch_for(spec, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((spec.batch,) + spec.input_shape).astype(np.float32)
    y = rng.integers(0, spec.num_classes, spec.batch).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", list(M.MODELS))
class TestShapes:
    def test_param_specs_consistent(self, name):
        spec = M.MODELS[name]
        params = spec.init_params(0)
        assert len(params) == len(spec.param_specs())
        for p, (_, s) in zip(params, spec.param_specs()):
            assert p.shape == tuple(s)
        assert spec.num_params() == sum(int(np.prod(p.shape)) for p in params)

    def test_train_step_shapes(self, name):
        spec = M.MODELS[name]
        params = spec.init_params(0)
        x, y = batch_for(spec)
        out = M.make_train_step(spec)(*params, x, y)
        assert len(out) == len(params) + 1
        for g, p in zip(out[:-1], params):
            assert g.shape == p.shape
        assert out[-1].shape == ()
        assert np.isfinite(float(out[-1]))

    def test_eval_step_counts(self, name):
        spec = M.MODELS[name]
        params = spec.init_params(0)
        x, y = batch_for(spec)
        (correct,) = M.make_eval_step(spec)(*params, x, y)
        assert 0 <= int(correct) <= spec.batch


class TestGradients:
    def test_mlp_grad_matches_finite_difference(self):
        spec = M.MODELS["mlp_tiny"]
        params = spec.init_params(1)
        x, y = batch_for(spec, 1)
        out = M.make_train_step(spec)(*params, x, y)
        grads = out[:-1]
        # perturb a handful of coordinates of w0 and compare fd vs autodiff
        eps = 1e-3
        rng = np.random.default_rng(0)
        w0 = np.asarray(params[0])
        for _ in range(5):
            i, j = rng.integers(0, w0.shape[0]), rng.integers(0, w0.shape[1])
            pp = [p for p in params]
            bump = np.zeros_like(w0)
            bump[i, j] = eps
            pp[0] = jnp.asarray(w0 + bump)
            lp = float(M.loss_fn(spec, pp, x, y))
            pp[0] = jnp.asarray(w0 - bump)
            lm = float(M.loss_fn(spec, pp, x, y))
            fd = (lp - lm) / (2 * eps)
            ad = float(np.asarray(grads[0])[i, j])
            np.testing.assert_allclose(fd, ad, rtol=5e-2, atol=5e-4)

    def test_loss_decreases_under_sgd(self):
        spec = M.MODELS["mlp_tiny"]
        params = spec.init_params(2)
        x, y = batch_for(spec, 2)
        step = jax.jit(M.make_train_step(spec))
        losses = []
        for _ in range(30):
            out = step(*params, x, y)
            grads, loss = out[:-1], float(out[-1])
            losses.append(loss)
            params = [p - 0.1 * g for p, g in zip(params, grads)]
        assert losses[-1] < losses[0] * 0.7

    def test_gradients_roughly_gaussian(self):
        # Premise of S3.1 (refs [17,18]): large-model gradient coordinates
        # are approximately Gaussian. Sanity-check skew/kurtosis are mild.
        spec = M.MODELS["mlp_synthcifar"]
        params = spec.init_params(3)
        x, y = batch_for(spec, 3)
        out = M.make_train_step(spec)(*params, x, y)
        g = np.concatenate([np.asarray(t).ravel() for t in out[:-1]])
        z = (g - g.mean()) / (g.std() + 1e-12)
        assert abs(float(np.mean(z ** 3))) < 2.0
