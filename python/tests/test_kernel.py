"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compression hot path: hypothesis
sweeps chunk lengths, block sizes, bit widths, codebooks and gradient
statistics, asserting exact index agreement and allclose dequantization.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile.kernels import quantize as qk
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("kernels")


def make_codebook(b, spread=2.0):
    """A monotone Lloyd-like codebook: 2^b levels, 2^b - 1 boundaries."""
    nl = 1 << b
    levels = np.linspace(-spread, spread, nl).astype(np.float32)
    bounds = ((levels[1:] + levels[:-1]) / 2).astype(np.float32)
    return jnp.asarray(bounds), jnp.asarray(levels)


def run_both(g, mu, sigma, bounds, levels, block):
    deq_k, idx_k = qk.quantize_chunk(
        jnp.asarray(g), jnp.asarray([mu], jnp.float32),
        jnp.asarray([sigma], jnp.float32), bounds, levels, block=block)
    deq_r, idx_r = ref.quantize_ref(
        jnp.asarray(g), jnp.float32(mu), jnp.float32(sigma), bounds, levels)
    return (np.asarray(deq_k), np.asarray(idx_k),
            np.asarray(deq_r), np.asarray(idx_r))


class TestQuantizeKernel:
    @given(
        nblk=st.integers(1, 4),
        block=st.sampled_from([128, 256, 1024]),
        b=st.sampled_from([1, 2, 3, 4, 6]),
        mu=st.floats(-3, 3),
        sigma=st.floats(0.05, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, nblk, block, b, mu, sigma, seed):
        rng = np.random.default_rng(seed)
        d = nblk * block
        g = (mu + sigma * rng.standard_normal(d)).astype(np.float32)
        bounds, levels = make_codebook(b)
        deq_k, idx_k, deq_r, idx_r = run_both(g, mu, sigma, bounds, levels, block)
        np.testing.assert_array_equal(idx_k, idx_r)
        np.testing.assert_allclose(deq_k, deq_r, rtol=1e-6, atol=1e-6)

    @given(b=st.sampled_from([2, 3, 6]), seed=st.integers(0, 1000))
    def test_indices_in_range(self, b, seed):
        rng = np.random.default_rng(seed)
        g = rng.standard_normal(512).astype(np.float32) * 10  # heavy tails
        bounds, levels = make_codebook(b)
        _, idx, _, _ = run_both(g, 0.0, 1.0, bounds, levels, 256)
        assert idx.min() >= 0 and idx.max() < (1 << b)

    def test_exact_boundary_goes_to_lower_cell(self):
        # Paper S3.2: Q(z) = s_l if u_l < z <= u_{l+1} — a value exactly on
        # a boundary belongs to the lower cell.
        bounds, levels = make_codebook(3)
        nb = np.asarray(bounds).shape[0]
        g = np.pad(np.asarray(bounds), (0, 128 - nb)).astype(np.float32)
        _, idx, _, _ = run_both(g, 0.0, 1.0, bounds, levels, 128)
        np.testing.assert_array_equal(idx[:nb], np.arange(nb))

    def test_degenerate_sigma_is_clamped(self):
        bounds, levels = make_codebook(3)
        g = np.full(128, 0.25, np.float32)
        deq_k, idx_k, deq_r, idx_r = run_both(g, 0.25, 0.0, bounds, levels, 128)
        np.testing.assert_array_equal(idx_k, idx_r)
        assert np.isfinite(deq_k).all()

    def test_monotonicity(self):
        # Larger inputs never get a smaller symbol.
        bounds, levels = make_codebook(4)
        g = np.sort(np.random.default_rng(0).standard_normal(256)).astype(np.float32)
        _, idx, _, _ = run_both(g, 0.0, 1.0, bounds, levels, 256)
        assert (np.diff(idx) >= 0).all()

    def test_reconstruction_error_bounded_by_cell_width(self):
        bounds, levels = make_codebook(6)
        rng = np.random.default_rng(1)
        g = rng.standard_normal(1024).astype(np.float32)
        deq, idx, _, _ = run_both(g, 0.0, 1.0, bounds, levels, 256)
        inner = (idx > 0) & (idx < 63)
        width = np.diff(np.asarray(levels)).max()
        assert np.abs(deq[inner] - g[inner]).max() <= width

    def test_bad_block_raises(self):
        bounds, levels = make_codebook(2)
        with pytest.raises(ValueError):
            qk.quantize_chunk(jnp.zeros(100), jnp.zeros(1), jnp.ones(1),
                              bounds, levels, block=64)


class TestMomentsKernel:
    @given(
        nblk=st.integers(1, 6),
        block=st.sampled_from([64, 256, 1024]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, nblk, block, seed):
        rng = np.random.default_rng(seed)
        g = rng.standard_normal(nblk * block).astype(np.float32)
        s_k, ss_k = qk.moments_chunk(jnp.asarray(g), block=block)
        s_r, ss_r = ref.moments_ref(jnp.asarray(g), block)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ss_k), np.asarray(ss_r), rtol=1e-5)

    def test_combined_mean_std(self):
        # Host-side combine of the partials reproduces global mu/sigma.
        rng = np.random.default_rng(7)
        g = (3.0 + 0.5 * rng.standard_normal(4096)).astype(np.float32)
        s, ss = qk.moments_chunk(jnp.asarray(g), block=512)
        n = g.size
        mu = float(np.sum(np.asarray(s))) / n
        var = float(np.sum(np.asarray(ss))) / n - mu * mu
        np.testing.assert_allclose(mu, g.mean(), rtol=1e-5)
        np.testing.assert_allclose(np.sqrt(var), g.std(), rtol=1e-4)


class TestDequantizeKernel:
    @given(
        b=st.sampled_from([2, 3, 4, 6]),
        mu=st.floats(-2, 2),
        sigma=st.floats(0.1, 3.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, mu, sigma, seed):
        rng = np.random.default_rng(seed)
        nl = 1 << b
        idx = rng.integers(0, nl, 512).astype(np.int32)
        _, levels = make_codebook(b)
        out_k = qk.dequantize_chunk(
            jnp.asarray(idx), jnp.asarray([mu], jnp.float32),
            jnp.asarray([sigma], jnp.float32), levels, block=256)
        out_r = ref.dequantize_ref(
            jnp.asarray(idx), jnp.float32(mu), jnp.float32(sigma), levels)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-6, atol=1e-6)

    def test_roundtrip_quantize_dequantize(self):
        bounds, levels = make_codebook(3)
        rng = np.random.default_rng(3)
        g = rng.standard_normal(1024).astype(np.float32)
        mu, sigma = jnp.asarray([0.0]), jnp.asarray([1.0])
        deq, idx = qk.quantize_chunk(jnp.asarray(g), mu, sigma, bounds,
                                     levels, block=256)
        deq2 = qk.dequantize_chunk(idx, mu, sigma, levels, block=256)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(deq2),
                                   rtol=1e-6, atol=1e-6)
