//! End-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Runs the full three-layer stack on a real workload: the SynthCifar
//! federation (K=10, Dirichlet β=0.5) training the AOT-compiled JAX MLP
//! (230k params, with the Pallas quantization kernels in the same
//! artifact set) through the PJRT runtime, compressed with RC-FED —
//! Algorithm 1 end to end, logging the loss curve and the uplink ledger.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --rounds 100 \
//!         --backend pjrt --lambda 0.05
//!
//! The default uses the PJRT backend for fidelity; `--backend native`
//! runs the layout-identical rust MLP (cross-validated in
//! rust/tests/pjrt_roundtrip.rs) for speed.

use rcfed::coordinator::experiment::{
    run_experiment, BackendChoice, ExperimentConfig,
};
use rcfed::fl::compression::CompressionScheme;
use rcfed::quant::rcq::LengthModel;
use rcfed::util::cli::Args;

fn main() {
    rcfed::util::log::init_from_env();
    let args = Args::from_env().unwrap();
    let rounds = args.usize_or("rounds", 60).unwrap();
    let lambda = args.f64_or("lambda", 0.05).unwrap();
    let bits = args.usize_or("bits", 3).unwrap() as u32;
    let backend = args.str_or("backend", "pjrt");
    let out = args.str_or("out", "results/quickstart.csv");
    args.finish().unwrap();

    let mut cfg = ExperimentConfig::synth_cifar();
    cfg.rounds = rounds;
    cfg.eval_every = 5;
    cfg.scheme = CompressionScheme::RcFed {
        bits,
        lambda,
        length_model: LengthModel::Huffman,
    };
    cfg.backend = match backend.as_str() {
        "pjrt" => BackendChoice::Pjrt("mlp_synthcifar".into()),
        _ => BackendChoice::Native,
    };

    println!("=== RC-FED quickstart ===");
    println!(
        "dataset=synthcifar K={} rounds={rounds} scheme={} backend={backend}",
        cfg.dataset.num_clients,
        cfg.scheme.label()
    );
    let report = run_experiment(&cfg).expect("experiment failed");

    println!("\nround  train_loss  test_acc   cum_uplink_Mb");
    for r in &report.metrics.rounds {
        if !r.test_accuracy.is_nan() {
            println!(
                "{:>5}  {:>10.4}  {:>8.4}  {:>12.3}",
                r.round, r.train_loss, r.test_accuracy,
                r.bits_cum as f64 / 1e6
            );
        }
    }
    println!(
        "\nfinal accuracy      : {:.4} (best {:.4})",
        report.final_accuracy, report.best_accuracy
    );
    println!("model parameters    : {}", report.num_params);
    println!(
        "total uplink        : {:.4} Gb ({:.2} bits/coord/round/client)",
        report.uplink_gigabits(),
        report.total_bits as f64
            / (report.num_params as f64
                * report.metrics.rounds.len() as f64
                * cfg.dataset.num_clients as f64)
    );
    println!("wallclock           : {:.1}s", report.wall_secs);
    report.metrics.write_csv(&out, &report.label).unwrap();
    println!("loss curve written  : {out}");

    // sanity for CI-style usage
    let first = report.metrics.rounds.first().unwrap().train_loss;
    let last = report.metrics.rounds.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}
