//! Rate–distortion anatomy of the RC quantizer (paper §3.2).
//!
//! Shows, for a N(0,1) source:
//!   1. the λ-sweep trade-off curve (MSE vs encoded rate) against the
//!      Lloyd-Max / NQFL / uniform operating points;
//!   2. the boundary shift of eq. (10) vs the plain Lloyd midpoints —
//!      "intervals associated with longer codewords become smaller";
//!   3. the high-rate law of eq. (20): MSE ≈ (1/12)·2^{2h(Z)}·2^{−2R}.
//!
//!     cargo run --release --example rate_distortion

use rcfed::quant::lloyd::{midpoints, LloydMax};
use rcfed::quant::nqfl::nqfl_codebook;
use rcfed::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use rcfed::quant::uniform::uniform_codebook;
use rcfed::quant::evaluate;
use rcfed::coding::huffman::HuffmanCode;
use rcfed::stats::entropy::entropy_bits;
use rcfed::stats::gaussian::{differential_entropy_bits, StdGaussian};

fn main() {
    let b = 3u32;
    println!("=== RC-FED quantizer anatomy (N(0,1), b={b}) ===\n");

    // 1. trade-off curve
    println!("{:>8} {:>10} {:>10} {:>10}", "lambda", "MSE", "H(Q)", "E[huff]");
    for lam in [0.0, 0.02, 0.04, 0.06, 0.08, 0.1, 0.2, 0.4] {
        let rc = RateConstrainedQuantizer {
            lambda: lam,
            length_model: LengthModel::Huffman,
            ..Default::default()
        };
        let (_, rep) = rc.design(&StdGaussian, b).unwrap();
        println!(
            "{lam:>8.3} {:>10.5} {:>10.4} {:>10.4}",
            rep.mse, rep.entropy_bits, rep.huffman_rate
        );
    }
    println!("\nbaseline operating points:");
    let (_, lloyd) = LloydMax::default().design(&StdGaussian, b).unwrap();
    println!("  lloyd-max : MSE={:.5} E[huff]={:.4}", lloyd.mse,
             lloyd.huffman_rate);
    for (name, cb) in [
        ("nqfl", nqfl_codebook(b).unwrap()),
        ("uniform", uniform_codebook(b, 4.0).unwrap()),
    ] {
        let (mse, probs) = evaluate(&StdGaussian, &cb);
        let code = HuffmanCode::from_probs(&probs).unwrap();
        println!(
            "  {name:<9} : MSE={mse:.5} E[huff]={:.4}",
            code.expected_length(&probs)
        );
    }

    // 2. boundary shift anatomy
    let rc = RateConstrainedQuantizer {
        lambda: 0.08,
        length_model: LengthModel::Huffman,
        ..Default::default()
    };
    let (cb, rep) = rc.design(&StdGaussian, b).unwrap();
    let code = HuffmanCode::from_probs(&rep.probs).unwrap();
    let levels: Vec<f64> = cb.levels.iter().map(|&x| x as f64).collect();
    let mids = midpoints(&levels);
    println!("\nboundary shifts at λ=0.08 (eq. 10):");
    println!(
        "{:>3} {:>9} {:>9} {:>8} {:>6} {:>6}",
        "l", "midpoint", "u_l", "shift", "ℓ_l-1", "ℓ_l"
    );
    for l in 1..levels.len() {
        println!(
            "{l:>3} {:>9.4} {:>9.4} {:>+8.4} {:>6} {:>6}",
            mids[l - 1],
            cb.bounds[l - 1],
            cb.bounds[l - 1] as f64 - mids[l - 1],
            code.lengths()[l - 1],
            code.lengths()[l]
        );
    }
    println!("(positive shift toward the longer-codeword side shrinks \
              rare cells)");

    // 3. high-rate law
    println!("\nhigh-rate law check, MSE vs (1/12)·2^(2h)·2^(−2R):");
    let h = differential_entropy_bits(1.0);
    println!("{:>4} {:>10} {:>12} {:>8}", "b", "MSE", "eq20", "ratio");
    for bb in [2u32, 3, 4, 6] {
        let rc = RateConstrainedQuantizer {
            lambda: 0.01,
            length_model: LengthModel::Ideal,
            ..Default::default()
        };
        let (_, rep) = rc.design(&StdGaussian, bb).unwrap();
        let predicted = (1.0 / 12.0)
            * 2f64.powf(2.0 * h)
            * 2f64.powf(-2.0 * rep.entropy_bits);
        println!(
            "{bb:>4} {:>10.6} {predicted:>12.6} {:>8.3}",
            rep.mse,
            rep.mse / predicted
        );
    }
    let _ = entropy_bits(&rep.probs);
}
