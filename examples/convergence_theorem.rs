//! Theorem-1 harness (paper §4): quantized DSGD on an L-smooth,
//! ρ-strongly-convex quadratic federation with the schedule
//! `η_t = 2/(ρ(t+γ))`, `γ = max{8L/ρ, e} − 1`.
//!
//! Prints the measured optimality gap `Δ_t = f(θ_t) − f(θ*)` against the
//! theorem's `O(1/t)` envelope with the constant C of eq. (12), for
//! several local-iteration counts `e`. Writes `results/convergence.csv`.
//!
//!     cargo run --release --example convergence_theorem

use rcfed::csv_row;
use rcfed::model::convex::QuadraticFederation;
use rcfed::quant::rcq::RateConstrainedQuantizer;
use rcfed::stats::gaussian::StdGaussian;
use rcfed::stats::moments::mean_std;
use rcfed::util::cli::Args;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;

fn main() {
    let args = Args::from_env().unwrap();
    let dim = args.usize_or("dim", 64).unwrap();
    let clients = args.usize_or("clients", 10).unwrap();
    let rounds = args.usize_or("rounds", 500).unwrap();
    let bits = args.usize_or("bits", 3).unwrap() as u32;
    args.finish().unwrap();

    let fed = QuadraticFederation::new(dim, clients, 1.0, 4.0, 0.6, 0.05, 11);
    let f_star = fed.global_loss(&fed.optimum());
    let rc = RateConstrainedQuantizer::new(0.05);
    let (cb, rep) = rc.design(&StdGaussian, bits).unwrap();
    println!("=== Theorem 1 convergence harness ===");
    println!(
        "d={dim} K={clients} rho={} L={} Γ={:.4} R_Q*={:.3} bits",
        fed.rho, fed.l_smooth, fed.heterogeneity_gap(), rep.huffman_rate
    );

    let mut w = CsvWriter::create(
        "results/convergence.csv",
        &["e", "t", "gap", "bound"],
    )
    .unwrap();

    for e in [1usize, 2, 4] {
        let gamma = (8.0 * fed.l_smooth / fed.rho).max(e as f64) - 1.0;
        let mut theta = vec![1.5f32; dim];
        let theta0_dist: f64 = theta
            .iter()
            .zip(&fed.optimum())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        // Theorem constants: σ²_k,t ≈ per-client gradient variance at θ0,
        // ζ²_k from the gradient norm bound over the trajectory start.
        let mut g = vec![0f32; dim];
        let zeta_sq: f64 = (0..clients)
            .map(|k| {
                fed.local_grad(k, &theta, None, &mut g);
                g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            })
            .fold(0.0, f64::max);
        let sigma_sq: f64 = (0..clients)
            .map(|k| {
                fed.local_grad(k, &theta, None, &mut g);
                let (_, s) = mean_std(&g);
                (s as f64).powi(2)
            })
            .sum::<f64>()
            / clients as f64;
        let c = fed.theorem_c(rep.huffman_rate, e, sigma_sq, zeta_sq);
        let bound_scale = (4.0 * c / (fed.rho * fed.rho))
            .max((gamma + 1.0) * theta0_dist);

        let mut rng = Rng::new(100 + e as u64);
        println!("\n-- e={e} (γ={gamma:.1}, C={c:.3}) --");
        println!("{:>6} {:>12} {:>12}", "t", "gap", "bound");
        for t in 0..rounds {
            let eta = (2.0 / (fed.rho * (t as f64 + gamma))) as f32;
            let mut agg = vec![0f32; dim];
            for k in 0..fed.num_clients() {
                // e local iterations
                let mut local = theta.clone();
                for _ in 0..e {
                    fed.local_grad(k, &local, Some(&mut rng), &mut g);
                    for (p, &gv) in local.iter_mut().zip(&g) {
                        *p -= eta * gv;
                    }
                }
                // effective gradient, RC-FED compressed
                let eff: Vec<f32> = theta
                    .iter()
                    .zip(&local)
                    .map(|(&a, &b)| (a - b) / eta)
                    .collect();
                let (mu, sigma) = mean_std(&eff);
                let mut sym = Vec::new();
                cb.quantize_normalized(&eff, mu, sigma, &mut sym);
                cb.dequantize_accumulate(&sym, mu, sigma, &mut agg);
            }
            for (th, &gv) in theta.iter_mut().zip(&agg) {
                *th -= eta * gv / clients as f32;
            }
            let gap = fed.global_loss(&theta) - f_star;
            let bound =
                fed.l_smooth / (2.0 * (t as f64 + gamma)) * bound_scale;
            csv_row!(w, e, t, gap, bound).unwrap();
            if t % (rounds / 10).max(1) == 0 || t + 1 == rounds {
                println!("{t:>6} {gap:>12.6} {bound:>12.6}");
            }
        }
    }
    w.flush().unwrap();
    println!("\nwrote results/convergence.csv");
    println!(
        "expected shape: gap ≲ bound everywhere, ~1/t decay until the\n\
         deterministic-quantizer bias floor (see EXPERIMENTS.md E4)."
    );
}
