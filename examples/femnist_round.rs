//! FEMNIST-style federation (paper §5, second workload).
//!
//! Demonstrates the scale axis of the paper's evaluation: a large device
//! population (default 355, paper-faithful 3550 with `--devices 3550`),
//! per-round sampling of K devices, e=2 local iterations, batch 32, and
//! the 62-class CNN/MLP task. Shows per-device non-IID class subsets and
//! the uplink ledger across sampled cohorts.
//!
//!     cargo run --release --example femnist_round
//!     cargo run --release --example femnist_round -- --devices 3550 \
//!         --sample 500 --rounds 100        # paper-scale
//!     cargo run --release --example femnist_round -- --backend pjrt \
//!         --rounds 3                       # CNN through PJRT

use rcfed::coordinator::experiment::{
    run_experiment, BackendChoice, ExperimentConfig,
};
use rcfed::data::FederatedDataset;
use rcfed::fl::compression::CompressionScheme;
use rcfed::quant::rcq::LengthModel;
use rcfed::util::cli::Args;

fn main() {
    rcfed::util::log::init_from_env();
    let args = Args::from_env().unwrap();
    let devices = args.usize_or("devices", 355).unwrap();
    let sample = args.usize_or("sample", 50).unwrap();
    let rounds = args.usize_or("rounds", 30).unwrap();
    let lambda = args.f64_or("lambda", 0.05).unwrap();
    let backend = args.str_or("backend", "native");
    args.finish().unwrap();

    let mut cfg = ExperimentConfig::synth_femnist();
    cfg.dataset.num_clients = devices;
    cfg.clients_per_round = sample;
    cfg.rounds = rounds;
    cfg.eval_every = 5;
    cfg.scheme = CompressionScheme::RcFed {
        bits: 3,
        lambda,
        length_model: LengthModel::Huffman,
    };
    if backend == "pjrt" {
        cfg.backend = BackendChoice::Pjrt("cnn_synthfemnist".into());
    }

    // show the non-IID structure before training
    let ds = FederatedDataset::build(&cfg.dataset);
    println!("=== FEMNIST-style federation ===");
    println!(
        "{} devices, {} sampled/round, e={} local iters, batch {}",
        ds.num_clients(), sample, cfg.local_iters, cfg.batch
    );
    let mut class_counts: Vec<usize> = ds
        .shards
        .iter()
        .map(|s| s.label_counts(62).iter().filter(|&&c| c > 0).count())
        .collect();
    class_counts.sort_unstable();
    println!(
        "classes per device: min={} median={} max={} (62 classes total)",
        class_counts[0],
        class_counts[class_counts.len() / 2],
        class_counts[class_counts.len() - 1]
    );

    let report = run_experiment(&cfg).expect("experiment failed");
    println!("\nround  train_loss  test_acc  cum_uplink_Mb");
    for r in &report.metrics.rounds {
        if !r.test_accuracy.is_nan() {
            println!(
                "{:>5}  {:>10.4}  {:>8.4}  {:>12.3}",
                r.round, r.train_loss, r.test_accuracy,
                r.bits_cum as f64 / 1e6
            );
        }
    }
    println!(
        "\nfinal acc {:.4}, uplink {:.4} Gb across {} sampled \
         client-rounds ({} params)",
        report.final_accuracy,
        report.uplink_gigabits(),
        rounds * sample,
        report.num_params
    );
}
