//! Differential suite pinning the fast quantizer kernels byte-identical
//! to their scalar references.
//!
//! The apply-path kernels in `quant::codebook` (blocked compare-sum for
//! small alphabets, design-time binned lookup for wide ones, and the
//! premultiplied dequantize tables) are performance rewrites of the
//! per-coordinate scalar semantics `Q((g − μ)/max(σ, floor))` and
//! `σ·s_l + μ`. They carry `*_reference` twins that state those
//! semantics with none of the machinery; every test here drives both
//! over the same inputs and compares **bit patterns**, not tolerances —
//! the speed tier claims byte-identity, so approximate agreement is a
//! failure.

use rcfed::fl::compression::{designed_codebook, CompressionScheme};
use rcfed::quant::codebook::{Codebook, SIGMA_FLOOR, SMALL_MAX_BOUNDS};
use rcfed::util::rng::Rng;

/// One ulp toward +∞ (finite inputs; bit-level, no std feature gates).
fn ulp_up(x: f32) -> f32 {
    let b = x.to_bits();
    if x == 0.0 {
        f32::from_bits(1)
    } else if b >> 31 == 0 {
        f32::from_bits(b + 1)
    } else {
        f32::from_bits(b - 1)
    }
}

/// One ulp toward −∞.
fn ulp_down(x: f32) -> f32 {
    -ulp_up(-x)
}

/// Designed books covering both apply paths: b ∈ 1..=4 stays on the
/// small compare-sum path (≤ 15 boundaries), b ∈ 5..=8 crosses
/// `SMALL_MAX_BOUNDS` onto the binned path.
fn designed_books() -> Vec<(u32, Codebook)> {
    (1..=8)
        .map(|bits| {
            let (cb, _) =
                designed_codebook(CompressionScheme::Lloyd { bits }).unwrap();
            (bits, cb)
        })
        .collect()
}

/// A book too wide for the u8 bin table: exercises the binary-search
/// fallback (no `bins`, still must match the reference).
fn oversized_book() -> Codebook {
    let levels: Vec<f64> =
        (0..300).map(|i| (i as f64 - 149.5) / 40.0).collect();
    let bounds: Vec<f64> =
        levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
    Codebook::from_f64(&levels, &bounds).unwrap()
}

/// Adversarial input battery for one (codebook, μ, σ) triple.
fn input_battery(cb: &Codebook, mu: f32, sigma: f32, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut g = vec![0f32; 8192];
    rng.fill_normal_f32(&mut g, mu, sigma);
    // non-finite + extreme magnitudes
    g.extend_from_slice(&[
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        -1e30,
        1e30,
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        mu,
    ]);
    // boundary-exact raw inputs: the normalized value lands on (or one
    // ulp around) each interior boundary — the `u_l < z ≤ u_{l+1}`
    // lower-cell rule must agree across paths
    let s = sigma.max(SIGMA_FLOOR);
    for &u in &cb.bounds {
        let raw = (u as f64 * s as f64 + mu as f64) as f32;
        g.push(raw);
        g.push(ulp_up(raw));
        g.push(ulp_down(raw));
    }
    g
}

fn assert_symbols_match(cb: &Codebook, g: &[f32], mu: f32, sigma: f32, tag: &str) {
    let (mut fast, mut slow) = (Vec::new(), Vec::new());
    cb.quantize_normalized(g, mu, sigma, &mut fast);
    cb.quantize_normalized_reference(g, mu, sigma, &mut slow);
    assert_eq!(fast.len(), g.len(), "{tag}: output length");
    for (i, (&f, &s)) in fast.iter().zip(&slow).enumerate() {
        assert_eq!(f, s, "{tag}: symbol diverged at i={i} (x={})", g[i]);
    }
}

fn assert_dequant_matches(cb: &Codebook, sym: &[u8], mu: f32, sigma: f32, tag: &str) {
    let mut fast = vec![0f32; sym.len()];
    let mut slow = vec![0f32; sym.len()];
    cb.dequantize_into(sym, mu, sigma, &mut fast);
    cb.dequantize_into_reference(sym, mu, sigma, &mut slow);
    for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
        assert_eq!(
            f.to_bits(),
            s.to_bits(),
            "{tag}: dequantize_into diverged at i={i}"
        );
    }
    // accumulate twins, folded onto a non-trivial accumulator
    let mut afast: Vec<f32> = (0..sym.len()).map(|i| i as f32 * 0.25 - 3.0).collect();
    let mut aslow = afast.clone();
    cb.dequantize_accumulate(sym, mu, sigma, &mut afast);
    cb.dequantize_accumulate_reference(sym, mu, sigma, &mut aslow);
    for (i, (f, s)) in afast.iter().zip(&aslow).enumerate() {
        assert_eq!(
            f.to_bits(),
            s.to_bits(),
            "{tag}: dequantize_accumulate diverged at i={i}"
        );
    }
}

#[test]
fn quantize_fast_paths_match_reference_across_widths() {
    for (bits, cb) in designed_books() {
        if bits <= 4 {
            assert!(
                cb.bounds.len() <= SMALL_MAX_BOUNDS,
                "b={bits} expected on the small path"
            );
        } else {
            assert!(
                cb.bounds.len() > SMALL_MAX_BOUNDS,
                "b={bits} expected on the binned path"
            );
        }
        for (mu, sigma) in [(0.0f32, 1.0f32), (0.3, 1.7), (-2.5, 0.04)] {
            let g = input_battery(&cb, mu, sigma, 0xC0DE + bits as u64);
            assert_symbols_match(&cb, &g, mu, sigma, &format!("b={bits}"));
        }
    }
}

#[test]
fn quantize_degenerate_sigma_matches_reference() {
    // σ = 0 engages the SIGMA_FLOOR: normalized magnitudes explode, so
    // every path must saturate identically (and identically handle the
    // exactly-μ coordinate, which normalizes to 0)
    for (bits, cb) in designed_books() {
        let g = input_battery(&cb, 1.25, 0.0, 0xF100D + bits as u64);
        assert_symbols_match(&cb, &g, 1.25, 0.0, &format!("b={bits} σ=0"));
    }
}

#[test]
fn quantize_empty_and_degenerate_inputs() {
    for (bits, cb) in designed_books() {
        let mut out = vec![7u8; 3];
        cb.quantize_normalized(&[], 0.0, 1.0, &mut out);
        assert!(out.is_empty(), "b={bits}: empty input must clear output");
        // single coordinate, all paths
        assert_symbols_match(&cb, &[0.5], 0.0, 1.0, &format!("b={bits} d=1"));
    }
}

#[test]
fn oversized_book_uses_search_fallback_and_matches() {
    let cb = oversized_book();
    assert!(cb.bounds.len() > u8::MAX as usize);
    for (mu, sigma) in [(0.0f32, 1.0f32), (0.7, 2.2)] {
        let g = input_battery(&cb, mu, sigma, 0xB16);
        assert_symbols_match(&cb, &g, mu, sigma, "oversized");
    }
}

#[test]
fn dequantize_tables_match_reference_across_widths() {
    let mut rng = Rng::new(0xDEC0DE);
    for (bits, cb) in designed_books() {
        let n = cb.levels.len() as u64;
        // cover every symbol plus a long random tail (256 levels for
        // b = 8: `i as u8` wraps exactly once around the alphabet)
        let mut sym: Vec<u8> = (0..cb.levels.len()).map(|i| i as u8).collect();
        sym.extend((0..4099).map(|_| (rng.next_u64() % n) as u8));
        for (mu, sigma) in [(0.0f32, 1.0f32), (0.25, 2.5), (3.0, 0.0)] {
            assert_dequant_matches(&cb, &sym, mu, sigma, &format!("b={bits}"));
        }
    }
}

#[test]
fn quantize_dequantize_roundtrip_is_fixed_point() {
    // quantizing an already-reconstructed vector must be stable: the
    // symbols of recon(symbols) equal the original symbols (levels lie
    // strictly inside their cells) — a joint sanity check that the fast
    // quantize and the premultiplied dequantize agree about the affine
    // map, on both the small and the binned path
    for bits in [3u32, 6] {
        let (cb, _) =
            designed_codebook(CompressionScheme::Lloyd { bits }).unwrap();
        let (mu, sigma) = (0.4f32, 1.9f32);
        let mut rng = Rng::new(0x57AB1E + bits as u64);
        let mut g = vec![0f32; 2048];
        rng.fill_normal_f32(&mut g, mu, sigma);
        let mut sym = Vec::new();
        cb.quantize_normalized(&g, mu, sigma, &mut sym);
        let mut rec = vec![0f32; g.len()];
        cb.dequantize_into(&sym, mu, sigma, &mut rec);
        let mut sym2 = Vec::new();
        cb.quantize_normalized(&rec, mu, sigma, &mut sym2);
        assert_eq!(sym, sym2, "b={bits}: roundtrip not a fixed point");
    }
}
