//! Golden end-to-end regression: fixed-seed tiny runs pinned to exact
//! uplink-bit totals and final training loss, so wire-format or
//! accounting changes cannot drift silently.
//!
//! Two layers of protection:
//!
//! 1. **Closed-form exactness** — fp32 bit totals are fully predictable
//!    from the wire format (`HEADER_BITS + 32·d` per packet), no
//!    snapshot needed.
//! 2. **Snapshot** — data-dependent schemes (RC-FED, Lloyd, QSGD) are
//!    pinned to `tests/golden/e2e_tiny.golden`. On first run (or with
//!    `RCFED_UPDATE_GOLDEN=1`) the file is (re)written and the test
//!    passes with a notice; once the file is committed, any drift in
//!    total bits (exact) or final loss (1e-6) fails the suite. Commit
//!    the generated file to lock the behavior in.

use std::fmt::Write as _;

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::fl::compression::CompressionScheme;
use rcfed::fl::packet::HEADER_BITS;
use rcfed::quant::rcq::LengthModel;

fn tiny(scheme: CompressionScheme) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.scheme = scheme;
    cfg.rounds = 10;
    cfg.eval_every = 5;
    cfg
}

#[test]
fn fp32_uplink_bits_match_the_wire_format_exactly() {
    let cfg = tiny(CompressionScheme::Fp32);
    let rep = run_experiment(&cfg).unwrap();
    let clients = cfg.dataset.num_clients as u64;
    let d = rep.num_params as u64;
    let per_packet = HEADER_BITS + 32 * d; // no side info, no table
    assert_eq!(
        rep.total_bits,
        cfg.rounds as u64 * clients * per_packet,
        "fp32 accounting must be exactly rounds × clients × packet bits \
         (d={d}, clients={clients})"
    );
}

fn golden_schemes() -> Vec<(&'static str, CompressionScheme)> {
    vec![
        (
            "rcfed_b3_l0.05",
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
        ),
        ("lloyd_b3", CompressionScheme::Lloyd { bits: 3 }),
        ("qsgd_b3", CompressionScheme::Qsgd { bits: 3 }),
    ]
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/e2e_tiny.golden")
}

#[test]
fn fixed_seed_runs_match_the_committed_snapshot() {
    let mut current = String::new();
    for (name, scheme) in golden_schemes() {
        let rep = run_experiment(&tiny(scheme)).unwrap();
        let final_loss = rep.metrics.rounds.last().unwrap().train_loss;
        // `{}` on floats is the shortest exact-roundtrip representation,
        // so the snapshot carries full precision
        writeln!(
            current,
            "{name} total_bits={} final_loss={final_loss} final_acc={}",
            rep.total_bits, rep.final_accuracy
        )
        .unwrap();
    }

    let path = golden_path();
    let update = std::env::var("RCFED_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        // A missing snapshot must never *silently* pass in CI: a
        // self-bootstrapped file trivially equals itself, so the
        // regression gate would be a no-op on every fresh checkout.
        // Locally the bootstrap is a convenience (generate → commit);
        // under GitHub Actions it is a hard failure. (Keyed on
        // GITHUB_ACTIONS rather than the generic CI variable so
        // non-Actions harnesses that export CI=1 keep the seed
        // behavior of bootstrapping on first run.)
        if !update && std::env::var("GITHUB_ACTIONS").is_ok() {
            panic!(
                "golden snapshot {} is missing in CI — a self-bootstrapped \
                 snapshot cannot gate anything. Run `cargo test -q` locally \
                 (or RCFED_UPDATE_GOLDEN=1) and commit the generated file.",
                path.display()
            );
        }
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &current).unwrap();
        eprintln!(
            "golden_e2e: wrote snapshot {} — commit it to pin these values",
            path.display()
        );
        return;
    }

    let committed = std::fs::read_to_string(&path).unwrap();
    for (have, want) in current.lines().zip(committed.lines()) {
        let parse = |line: &str| -> (String, u64, f32, f64) {
            let mut it = line.split_whitespace();
            let name = it.next().unwrap().to_string();
            let field = |tok: &str, key: &str| -> String {
                tok.strip_prefix(key)
                    .unwrap_or_else(|| panic!("bad golden line: {line}"))
                    .to_string()
            };
            let bits = field(it.next().unwrap(), "total_bits=");
            let loss = field(it.next().unwrap(), "final_loss=");
            let acc = field(it.next().unwrap(), "final_acc=");
            (
                name,
                bits.parse().unwrap(),
                loss.parse().unwrap(),
                acc.parse().unwrap(),
            )
        };
        let (hn, hb, hl, ha) = parse(have);
        let (wn, wb, wl, wa) = parse(want);
        assert_eq!(hn, wn, "scheme order changed");
        assert_eq!(
            hb, wb,
            "{hn}: total uplink bits drifted from golden \
             (have {hb}, golden {wb}) — if intentional, rerun with \
             RCFED_UPDATE_GOLDEN=1 and commit"
        );
        assert!(
            (hl - wl).abs() <= 1e-6,
            "{hn}: final loss drifted: have {hl}, golden {wl}"
        );
        assert!(
            (ha - wa).abs() <= 1e-6,
            "{hn}: final accuracy drifted: have {ha}, golden {wa}"
        );
    }
    assert_eq!(
        current.lines().count(),
        committed.lines().count(),
        "snapshot line count changed"
    );
}
