//! Integration tests for the direction-agnostic downlink codec: the
//! version protocol across rounds a client sits out, the joint up+down
//! budget against a charged fp32 broadcast, and wire-level stale-delta
//! rejection.

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::fl::compression::{
    CompressionScheme, DeltaCodec, Direction, RateTarget, WireCoder,
};
use rcfed::fl::packet::{Packet, HEADER_BITS};
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn rcfed_scheme() -> CompressionScheme {
    CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    }
}

#[test]
fn laggards_resync_instead_of_decoding_stale_deltas() {
    // population ≫ cohort: most clients sit out most rounds, so their
    // acked model version falls behind and the coordinator must unicast
    // a full resync instead of the incremental delta. With an fp32
    // downlink the accounting is closed-form: an incremental broadcast
    // share costs HEADER + 32 (version word) + 32·d, a resync unicast
    // HEADER + 32·d — so any resync pulls the ledger strictly below the
    // all-incremental total.
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.dataset.num_clients = 64;
    cfg.clients_per_round = 8;
    cfg.down_scheme = Some(CompressionScheme::Fp32);
    let rep = run_experiment(&cfg).unwrap();
    let d = rep.num_params as u64;
    let per_incremental = HEADER_BITS + 32 + 32 * d;
    let all_incremental = cfg.rounds as u64
        * cfg.clients_per_round as u64
        * per_incremental;
    assert!(rep.downlink_bits > 0, "downlink never charged");
    assert!(
        rep.downlink_bits < all_incremental,
        "no resync ever happened: {} vs all-incremental {}",
        rep.downlink_bits,
        all_incremental
    );
    assert!(rep.final_accuracy.is_finite());
    assert_eq!(rep.metrics.down_trace().len(), cfg.rounds);
    assert!(rep.down_bpc().is_finite());
    // the version protocol is deterministic: same seed, same ledger
    let again = run_experiment(&cfg).unwrap();
    assert_eq!(again.downlink_bits, rep.downlink_bits);
    assert_eq!(
        again.final_accuracy.to_bits(),
        rep.final_accuracy.to_bits()
    );
}

#[test]
fn joint_budget_beats_a_charged_fp32_broadcast() {
    // the acceptance check: at a joint up+down budget, total
    // communication must come in below the charged fp32-broadcast
    // baseline without giving up the tiny-task accuracy
    let mut base = ExperimentConfig::tiny();
    base.rounds = 30;
    base.eval_every = 10;
    base.scheme = rcfed_scheme();
    base.down_scheme = Some(CompressionScheme::Fp32);
    let fp32_broadcast = run_experiment(&base).unwrap();

    let mut joint = base.clone();
    joint.rate_target = RateTarget::Joint {
        total_bpc: 4.0,
        split: 0.625,
        adapt_every: 5,
    };
    joint.down_scheme = Some(rcfed_scheme());
    let budgeted = run_experiment(&joint).unwrap();

    assert!(
        budgeted.total_comm_bits() < fp32_broadcast.total_comm_bits(),
        "joint budget {} bits vs fp32 broadcast {} bits",
        budgeted.total_comm_bits(),
        fp32_broadcast.total_comm_bits()
    );
    // equal-accuracy within a generous tiny-task tolerance
    assert!(
        budgeted.final_accuracy >= fp32_broadcast.final_accuracy - 0.2,
        "accuracy collapsed: {} vs {}",
        budgeted.final_accuracy,
        fp32_broadcast.final_accuracy
    );
}

#[test]
fn stale_broadcasts_reject_recoverably_through_the_wire() {
    // replaying last round's broadcast bytes must surface a recoverable
    // error that leaves the reconstruction untouched; the current
    // broadcast must still decode afterwards
    let d = 80usize;
    let mut codec = DeltaCodec::design(
        Direction::Downlink,
        rcfed_scheme(),
        WireCoder::Huffman,
        d,
    )
    .unwrap();
    let mut rng = Rng::new(11);
    let mut params = vec![0f32; d];
    let mut stale: Option<Packet> = None;
    for round in 0..4u32 {
        for (i, p) in params.iter_mut().enumerate() {
            *p += ((i as f32) * 0.13 + round as f32).sin() * 0.05;
        }
        let pkt = codec.encode_round(&params, round, &mut rng).unwrap();
        let wire = Packet::parse(&pkt.to_bytes()).unwrap();
        if let Some(old) = &stale {
            let before = codec.reference().to_vec();
            let err = codec.decode_current(old).unwrap_err();
            assert!(err.to_string().contains("stale"), "{err}");
            assert_eq!(
                codec.reference(),
                &before[..],
                "a rejected delta must not touch the reference"
            );
        }
        codec.decode_current(&wire).unwrap();
        stale = Some(wire);
    }
    assert_eq!(codec.version(), 4);
}
