//! Cross-module integration tests: full federated runs, the paper's
//! qualitative claims on small workloads, failure injection, and the
//! wire format end to end.

use rcfed::coordinator::experiment::{
    run_experiment, ExperimentConfig,
};
use rcfed::fl::compression::{CompressionScheme, Compressor, WireCoder};
use rcfed::fl::packet::Packet;
use rcfed::model::convex::QuadraticFederation;
use rcfed::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use rcfed::quant::lloyd::LloydMax;
use rcfed::stats::empirical::EmpiricalPdf;
use rcfed::stats::gaussian::StdGaussian;
use rcfed::util::rng::Rng;

// ---------------------------------------------------------------------
// E2E training behaviour
// ---------------------------------------------------------------------

#[test]
fn all_schemes_complete_a_run_and_learn() {
    let schemes = [
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        },
        CompressionScheme::Lloyd { bits: 3 },
        CompressionScheme::Nqfl { bits: 3 },
        CompressionScheme::Qsgd { bits: 3 },
        CompressionScheme::Uniform { bits: 3, clip: 4.0 },
        CompressionScheme::Fp32,
    ];
    for scheme in schemes {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 30;
        cfg.scheme = scheme;
        let rep = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert!(
            rep.final_accuracy > 0.45,
            "{scheme:?}: acc {}",
            rep.final_accuracy
        );
    }
}

#[test]
fn compressed_bits_ordering_matches_theory() {
    // at b=3: RC-FED(λ>0) < Lloyd ≈ NQFL < fp32; all well below 32 b/coord
    let mut base = ExperimentConfig::tiny();
    base.rounds = 6;
    base.eval_every = 0;
    let bits_of = |scheme| {
        let mut cfg = base.clone();
        cfg.scheme = scheme;
        run_experiment(&cfg).unwrap().total_bits as f64
    };
    let rc = bits_of(CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.1,
        length_model: LengthModel::Huffman,
    });
    let lloyd = bits_of(CompressionScheme::Lloyd { bits: 3 });
    let fp32 = bits_of(CompressionScheme::Fp32);
    assert!(rc < lloyd, "rc {rc} vs lloyd {lloyd}");
    assert!(lloyd < fp32 / 8.0, "lloyd {lloyd} vs fp32 {fp32}");
}

#[test]
fn lambda_sweep_is_monotone_in_bits() {
    // larger λ ⇒ fewer uplink bits (the Fig. 1 x-axis direction)
    let mut base = ExperimentConfig::tiny();
    base.rounds = 5;
    base.eval_every = 0;
    let mut last = u64::MAX;
    for lam in [0.0, 0.05, 0.15, 0.4] {
        let mut cfg = base.clone();
        cfg.scheme = CompressionScheme::RcFed {
            bits: 3,
            lambda: lam,
            length_model: LengthModel::Huffman,
        };
        let rep = run_experiment(&cfg).unwrap();
        assert!(
            rep.total_bits <= last,
            "λ={lam}: {} > previous {last}",
            rep.total_bits
        );
        last = rep.total_bits;
    }
}

// ---------------------------------------------------------------------
// Theorem-1 convergence harness (quick version of bench E4)
// ---------------------------------------------------------------------

#[test]
fn quantized_dsgd_converges_at_one_over_t_on_quadratic() {
    let fed = QuadraticFederation::new(32, 8, 1.0, 4.0, 0.8, 0.05, 7);
    let opt = fed.optimum();
    let f_star = fed.global_loss(&opt);
    let rc = RateConstrainedQuantizer::new(0.05);
    let (cb, _rep) = rc.design(&StdGaussian, 3).unwrap();
    let gamma = 8.0 * fed.l_smooth / fed.rho; // e = 1
    let mut theta = vec![2.0f32; fed.dim];
    let mut rng = Rng::new(9);
    let mut grad = vec![0f32; fed.dim];
    let mut gaps = Vec::new();
    for t in 0..400 {
        let eta = (2.0 / (fed.rho * (t as f64 + gamma))) as f32;
        let mut agg = vec![0f32; fed.dim];
        for k in 0..fed.num_clients() {
            fed.local_grad(k, &theta, Some(&mut rng), &mut grad);
            // RC-FED pipeline: normalize → quantize → dequantize
            let (mu, sigma) = rcfed::stats::moments::mean_std(&grad);
            let mut sym = Vec::new();
            cb.quantize_normalized(&grad, mu, sigma, &mut sym);
            cb.dequantize_accumulate(&sym, mu, sigma, &mut agg);
        }
        for (th, &g) in theta.iter_mut().zip(&agg) {
            *th -= eta * g / fed.num_clients() as f32;
        }
        gaps.push(fed.global_loss(&theta) - f_star);
    }
    // Δ_t decays ~1/t until the deterministic-quantizer bias floor
    // (the paper's Lemma 2 treats quantization error as zero-mean noise;
    // a deterministic scalar quantizer leaves a small bias floor, which
    // bench E4 plots explicitly). Check the 1/t regime before the floor:
    let c_fit = gaps[50] * (50.0 + gamma);
    for &t in &[100usize, 200] {
        let bound = 4.0 * c_fit / (t as f64 + gamma);
        assert!(
            gaps[t] <= bound,
            "gap at t={t}: {} > {bound} (no 1/t decay)",
            gaps[t]
        );
    }
    assert!(
        gaps[399] < gaps[10] / 3.0,
        "insufficient decay: {} -> {}", gaps[10], gaps[399]
    );
}

// ---------------------------------------------------------------------
// Universal-design property (§3.1)
// ---------------------------------------------------------------------

#[test]
fn universal_gaussian_design_matches_per_client_empirical_designs() {
    // Normalized gradients from *different* client distributions are all
    // ~N(0,1), so the universal codebook's rate/MSE is close to what a
    // personalized empirical design would achieve — the justification for
    // dropping hyperparameter exchange.
    let mut rng = Rng::new(41);
    let universal = LloydMax::default().design(&StdGaussian, 3).unwrap().1;
    for (mu, sigma) in [(0.0f32, 1.0f32), (5.0, 0.01), (-3.0, 2.5)] {
        let mut g = vec![0f32; 60_000];
        rng.fill_normal_f32(&mut g, mu, sigma);
        let (m, s) = rcfed::stats::moments::mean_std(&g);
        let z: Vec<f32> = g.iter().map(|&x| (x - m) / s).collect();
        let emp = EmpiricalPdf::from_samples(&z);
        let personalized = LloydMax::default().design(&emp, 3).unwrap().1;
        assert!(
            (universal.mse - personalized.mse).abs() < 0.01,
            "mu={mu} sigma={sigma}: {} vs {}",
            universal.mse,
            personalized.mse
        );
        assert!(
            (universal.entropy_bits - personalized.entropy_bits).abs() < 0.1
        );
    }
}

// ---------------------------------------------------------------------
// Wire format through real bytes
// ---------------------------------------------------------------------

#[test]
fn packet_survives_the_wire_byte_for_byte() {
    let c = Compressor::design(
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        },
        WireCoder::Huffman,
    )
    .unwrap();
    let mut rng = Rng::new(51);
    let mut g = vec![0f32; 5000];
    rng.fill_normal_f32(&mut g, 0.002, 0.01);
    let pkt = c.compress(4, 17, &g, &mut rng).unwrap();
    // serialize → parse → decode (the real uplink path)
    let wire = pkt.to_bytes();
    let parsed = Packet::from_bytes(&wire).unwrap();
    let mut acc1 = vec![0f32; g.len()];
    let mut acc2 = vec![0f32; g.len()];
    c.decompress_accumulate(&pkt, &mut acc1).unwrap();
    c.decompress_accumulate(&parsed, &mut acc2).unwrap();
    assert_eq!(acc1, acc2);
}

#[test]
fn corrupted_packets_fail_loud_not_wrong() {
    let c = Compressor::design(
        CompressionScheme::Qsgd { bits: 3 },
        WireCoder::Huffman,
    )
    .unwrap();
    let mut rng = Rng::new(52);
    let g = vec![0.5f32; 100];
    let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
    // truncate the payload below the table size
    let mut bad = pkt.clone();
    bad.payload.truncate(2);
    let mut acc = vec![0f32; g.len()];
    assert!(c.decompress_accumulate(&bad, &mut acc).is_err());
    // wrong dimension
    let mut acc_small = vec![0f32; 50];
    assert!(c.decompress_accumulate(&pkt, &mut acc_small).is_err());
}

// ---------------------------------------------------------------------
// Rate-distortion sanity across the whole quantizer zoo
// ---------------------------------------------------------------------

#[test]
fn rcfed_dominates_baselines_in_rate_distortion() {
    // For every baseline operating point (MSE, rate), the RC-FED curve
    // at the same b offers an operating point with rate ≤ baseline rate
    // and MSE within a hair — i.e. the constrained design is on or below
    // the baselines. (Quantitative Fig. 1 shape is in bench E3.)
    let baselines = [
        CompressionScheme::Lloyd { bits: 3 },
        CompressionScheme::Nqfl { bits: 3 },
    ];
    let mut rc_points = Vec::new();
    for lam in [0.0, 0.01, 0.02, 0.05, 0.1, 0.2, 0.4] {
        let (_, rep) = RateConstrainedQuantizer {
            lambda: lam,
            length_model: LengthModel::Huffman,
            ..Default::default()
        }
        .design(&StdGaussian, 3)
        .unwrap();
        rc_points.push((rep.huffman_rate, rep.mse));
    }
    for b in baselines {
        let c = Compressor::design(b, WireCoder::Huffman).unwrap();
        let (b_rate, b_mse) =
            (c.design_rate.unwrap(), c.design_mse.unwrap());
        let dominated = rc_points.iter().any(|&(r, m)| {
            r <= b_rate + 1e-9 && m <= b_mse * 1.02
        });
        assert!(dominated, "{b:?} at ({b_rate:.3}, {b_mse:.4}) not dominated \
                 by RC curve {rc_points:?}");
    }
}
