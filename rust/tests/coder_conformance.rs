//! Coder conformance suite: one shared battery of symbol streams run
//! through every entropy coder (Huffman, Arithmetic, LZW, Block),
//! asserting `decode(encode(x)) == x` on each, plus the guarantee that
//! `message_bits` is *exactly* the bit length `encode` produces — for
//! the baseline Huffman coder and for the block coder's self-framing
//! payloads (the RC design loop and the uplink ledger both depend on
//! that number being honest).

use rcfed::coding::arithmetic::ArithmeticCoder;
use rcfed::coding::bitio::BitWriter;
use rcfed::coding::block::{BlockCoder, DEFAULT_BLOCK_LEN};
use rcfed::coding::huffman::HuffmanCode;
use rcfed::coding::lz::Lzw;
use rcfed::coding::EntropyCoder;
use rcfed::util::rng::Rng;

/// One battery case: an alphabet size and a symbol stream over it.
struct Case {
    name: &'static str,
    nsym: usize,
    stream: Vec<u8>,
}

/// The shared battery. Covers the regimes the quantizers actually
/// produce: skewed Gaussian-cell distributions, uniform symbols, the
/// degenerate single-symbol regime (RC-FED at large λ), empty and
/// near-empty messages, and the full 256-symbol alphabet.
fn battery() -> Vec<Case> {
    let mut rng = Rng::new(0xC0DE);
    let mut cases = Vec::new();

    cases.push(Case { name: "empty", nsym: 4, stream: Vec::new() });
    cases.push(Case { name: "one_symbol", nsym: 4, stream: vec![2] });
    cases.push(Case { name: "two_symbols", nsym: 4, stream: vec![3, 0] });
    cases.push(Case {
        name: "single_symbol_run",
        nsym: 8,
        stream: vec![5; 4096],
    });

    // uniform over a small alphabet
    cases.push(Case {
        name: "uniform_8",
        nsym: 8,
        stream: (0..5000).map(|_| rng.below(8) as u8).collect(),
    });

    // zipf-skewed over 64 symbols (the b=6 quantizer alphabet)
    let probs: Vec<f64> =
        (0..64).map(|i| 1.0 / (1.0 + i as f64).powi(2)).collect();
    cases.push(Case {
        name: "zipf_64",
        nsym: 64,
        stream: (0..5000).map(|_| rng.categorical(&probs) as u8).collect(),
    });

    // heavily skewed binary (worst case for Huffman's 1-bit floor)
    let bin = [0.97, 0.03];
    cases.push(Case {
        name: "skewed_binary",
        nsym: 2,
        stream: (0..8000).map(|_| rng.categorical(&bin) as u8).collect(),
    });

    // full 256-symbol alphabet, uniform
    cases.push(Case {
        name: "uniform_256",
        nsym: 256,
        stream: (0..4096).map(|_| rng.below(256) as u8).collect(),
    });

    // full alphabet with exponential skew (forces Huffman length
    // limiting and the wide-alphabet code paths)
    let skew: Vec<f64> = (0..256).map(|i| 0.97f64.powi(i)).collect();
    cases.push(Case {
        name: "skewed_256",
        nsym: 256,
        stream: (0..4096).map(|_| rng.categorical(&skew) as u8).collect(),
    });

    cases
}

/// Histogram of `stream` over `nsym` symbols, floored to 1 so every
/// alphabet symbol is encodable by the model-based coders.
fn hist(nsym: usize, stream: &[u8]) -> Vec<u64> {
    let mut h = vec![1u64; nsym];
    for &s in stream {
        h[s as usize] += 1;
    }
    h
}

#[test]
fn every_coder_roundtrips_the_battery() {
    for case in battery() {
        let freqs = hist(case.nsym, &case.stream);
        let huffman = HuffmanCode::from_freqs(&freqs).unwrap();
        let arith = ArithmeticCoder::from_freqs(&freqs).unwrap();
        let lzw = Lzw;
        let block = BlockCoder::new(case.nsym).unwrap();
        let coders: [&dyn EntropyCoder; 4] =
            [&huffman, &arith, &lzw, &block];
        for coder in coders {
            let payload = coder.encode(&case.stream).unwrap_or_else(|e| {
                panic!("{}/{}: encode failed: {e}", coder.name(), case.name)
            });
            let back =
                coder.decode(&payload, case.stream.len()).unwrap_or_else(
                    |e| {
                        panic!(
                            "{}/{}: decode failed: {e}",
                            coder.name(),
                            case.name
                        )
                    },
                );
            assert_eq!(
                back, case.stream,
                "{}/{}: roundtrip mismatch",
                coder.name(),
                case.name
            );
        }
    }
}

#[test]
fn huffman_message_bits_is_exactly_what_encode_produces() {
    for case in battery() {
        let code = HuffmanCode::from_freqs(&hist(case.nsym, &case.stream))
            .unwrap();
        let claimed = code.message_bits(&case.stream);
        // measure the real bit length through the writer
        let mut w = BitWriter::new();
        code.encode_into(&case.stream, &mut w).unwrap();
        assert_eq!(
            w.bit_len(),
            claimed,
            "{}: message_bits lied about the wire cost",
            case.name
        );
        // and the byte payload is the claimed bits, byte-padded
        let payload = code.encode(&case.stream).unwrap();
        assert_eq!(
            payload.len() as u64,
            claimed.div_ceil(8),
            "{}: payload padding",
            case.name
        );
    }
}

#[test]
fn block_message_bits_is_exactly_what_encode_produces() {
    // the ledger-honesty contract extended to the throughput tier:
    // `message_bits` must equal the bits `encode` emits *including*
    // every block's self-framing table refresh, at the default block
    // length and at small lengths that force multi-block streams,
    // boundary-straddling tails and degenerate single-symbol blocks
    for case in battery() {
        for block_len in [DEFAULT_BLOCK_LEN, 64, 1000] {
            let coder =
                BlockCoder::with_block_len(case.nsym, block_len).unwrap();
            let claimed = coder.message_bits(&case.stream).unwrap();
            let (payload, bits) = coder.encode_counted(&case.stream).unwrap();
            assert_eq!(
                bits, claimed,
                "{}/block_len={block_len}: message_bits lied about the \
                 wire cost",
                case.name
            );
            assert_eq!(
                payload.len() as u64,
                claimed.div_ceil(8),
                "{}/block_len={block_len}: payload padding",
                case.name
            );
            // and the exact-accounting decode closes the loop
            let back = coder
                .decode_exact(&payload, case.stream.len(), claimed)
                .unwrap();
            assert_eq!(
                back, case.stream,
                "{}/block_len={block_len}: roundtrip mismatch",
                case.name
            );
        }
    }
}

#[test]
fn block_boundary_symbols_survive_every_alignment() {
    // streams sized exactly at, one under and one over a block boundary
    // — the tail block carries fewer symbols than block_len and must
    // still frame, cost and decode exactly
    let mut rng = Rng::new(0xB10C);
    for block_len in [1usize, 2, 7, 64] {
        for n in [block_len.saturating_sub(1), block_len, block_len + 1, 3 * block_len]
        {
            let stream: Vec<u8> =
                (0..n).map(|_| rng.below(8) as u8).collect();
            let coder = BlockCoder::with_block_len(8, block_len).unwrap();
            let claimed = coder.message_bits(&stream).unwrap();
            let (payload, bits) = coder.encode_counted(&stream).unwrap();
            assert_eq!(bits, claimed, "block_len={block_len} n={n}");
            let back = coder.decode_exact(&payload, n, claimed).unwrap();
            assert_eq!(back, stream, "block_len={block_len} n={n}");
        }
    }
}

#[test]
fn decoders_reject_or_zero_fill_truncated_payloads_without_panicking() {
    // conformance for the channel-corruption path: a truncated payload
    // must never panic any decoder — wrong symbols or Err are both
    // acceptable, UB/panic is not
    for case in battery() {
        if case.stream.is_empty() {
            continue;
        }
        let freqs = hist(case.nsym, &case.stream);
        let huffman = HuffmanCode::from_freqs(&freqs).unwrap();
        let arith = ArithmeticCoder::from_freqs(&freqs).unwrap();
        let lzw = Lzw;
        let block = BlockCoder::new(case.nsym).unwrap();
        let coders: [&dyn EntropyCoder; 4] =
            [&huffman, &arith, &lzw, &block];
        for coder in coders {
            let payload = coder.encode(&case.stream).unwrap();
            for cut in [payload.len() / 2, 1, 0] {
                let _ = coder.decode(&payload[..cut], case.stream.len());
            }
        }
        // the exact-accounting block path goes further: truncation is a
        // recoverable Err, never a zero-filled accept
        let (payload, bits) = block.encode_counted(&case.stream).unwrap();
        for cut in [payload.len() / 2, 1, 0] {
            if (cut as u64 * 8) < bits {
                assert!(
                    block
                        .decode_exact(&payload[..cut], case.stream.len(), bits)
                        .is_err(),
                    "{}: truncated block payload accepted at {cut} bytes",
                    case.name
                );
            }
        }
    }
}
