//! Byte-identity of the streamed round loop vs. the resident one.
//!
//! The streaming executor (lazy shard materialization + spilled
//! per-client state + sharded cohort workers) is the default, and it is
//! allowed to be the default only because these tests pin it to the
//! resident `Vec<Client>` path *bit for bit*: same ledger, same
//! survivor sets, same accuracy bits, same metrics CSV bytes — across
//! schemes, transforms, controllers and lossy channels.

use rcfed::coordinator::experiment::{
    run_experiment, ExecutionMode, ExperimentConfig, ExperimentReport,
};
use rcfed::coordinator::network::ChannelSpec;
use rcfed::fl::compression::{
    CompressionScheme, RateAllocation, RateTarget, TransformCfg, WireCoder,
};
use rcfed::quant::rcq::LengthModel;

/// Fast base: tiny dataset, few rounds, eval every other round so the
/// accuracy column carries both NaN and real entries.
fn base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 6;
    cfg.eval_every = 2;
    cfg
}

fn run_mode(cfg: &ExperimentConfig, mode: ExecutionMode) -> ExperimentReport {
    let mut cfg = cfg.clone();
    cfg.mode = mode;
    run_experiment(&cfg).unwrap()
}

/// Everything simulation-determined must match bitwise; wall clock and
/// RSS are measurement noise and excluded by construction.
fn assert_identical(tag: &str, a: &ExperimentReport, b: &ExperimentReport) {
    assert_eq!(a.label, b.label, "{tag}: label");
    assert_eq!(
        a.final_accuracy.to_bits(),
        b.final_accuracy.to_bits(),
        "{tag}: final accuracy {} vs {}",
        a.final_accuracy,
        b.final_accuracy
    );
    assert_eq!(
        a.best_accuracy.to_bits(),
        b.best_accuracy.to_bits(),
        "{tag}: best accuracy"
    );
    assert_eq!(a.num_params, b.num_params, "{tag}: num_params");
    assert_eq!(a.total_bits, b.total_bits, "{tag}: uplink ledger");
    assert_eq!(a.downlink_bits, b.downlink_bits, "{tag}: downlink ledger");
    assert_eq!(a.channel, b.channel, "{tag}: channel stats/survivors");
    assert_eq!(a.alloc_hist, b.alloc_hist, "{tag}: allocation histogram");
    assert_eq!(
        a.metrics.rounds.len(),
        b.metrics.rounds.len(),
        "{tag}: round count"
    );
    for (ra, rb) in a.metrics.rounds.iter().zip(b.metrics.rounds.iter()) {
        assert_eq!(ra.round, rb.round, "{tag}: round index");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{tag}: round {} train loss",
            ra.round
        );
        assert_eq!(
            ra.test_accuracy.to_bits(),
            rb.test_accuracy.to_bits(),
            "{tag}: round {} accuracy",
            ra.round
        );
        assert_eq!(ra.bits_up, rb.bits_up, "{tag}: round {} bits", ra.round);
        assert_eq!(
            ra.bits_cum, rb.bits_cum,
            "{tag}: round {} cumulative bits",
            ra.round
        );
    }
    // the exported artifact must be byte-identical, not just field-wise
    // equal — the CSV is what downstream plots and goldens consume
    let dir = std::env::temp_dir();
    let pa = dir.join(format!("rcfed_ident_{tag}_a.csv"));
    let pb = dir.join(format!("rcfed_ident_{tag}_b.csv"));
    a.metrics.write_csv(pa.to_str().unwrap(), &a.label).unwrap();
    b.metrics.write_csv(pb.to_str().unwrap(), &b.label).unwrap();
    let bytes_a = std::fs::read(&pa).unwrap();
    let bytes_b = std::fs::read(&pb).unwrap();
    let _ = std::fs::remove_file(&pa);
    let _ = std::fs::remove_file(&pb);
    assert!(!bytes_a.is_empty(), "{tag}: empty CSV");
    assert_eq!(bytes_a, bytes_b, "{tag}: metrics CSV bytes diverged");
}

fn check(tag: &str, cfg: &ExperimentConfig) {
    let resident = run_mode(cfg, ExecutionMode::Resident);
    let streamed = run_mode(cfg, ExecutionMode::Streamed);
    assert_identical(tag, &resident, &streamed);
}

#[test]
fn rcfed_ideal_channel() {
    check("rcfed", &base());
}

#[test]
fn lloyd_with_topk_and_error_feedback() {
    // the transform satellite: EF residuals are durable per-client
    // state, exactly what the ClientStore spills between rounds
    let mut cfg = base();
    cfg.scheme = CompressionScheme::Lloyd { bits: 3 };
    cfg.transform = TransformCfg::topk(0.25).with_ef();
    check("lloyd_topk_ef", &cfg);
}

#[test]
fn rate_targeted_rcfed() {
    let mut cfg = base();
    cfg.scheme = CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    };
    cfg.rate_target =
        RateTarget::Track { bits_per_coord: 2.5, adapt_every: 2 };
    check("rate_target", &cfg);
}

#[test]
fn waterfill_allocation_over_heterogeneous_bandwidth() {
    // exercises per-client codebook versions + moment estimates (spilled
    // allocator state) and the keyed bandwidth-factor derivation
    let mut cfg = base();
    cfg.scheme = CompressionScheme::Lloyd { bits: 3 };
    cfg.alloc = RateAllocation::WaterFill {
        budget_bpc: 2.5,
        adapt_every: 2,
        min_bits: 1,
        max_bits: 6,
    };
    cfg.channel = ChannelSpec {
        uplink_bps: 1e6,
        bandwidth_spread: 0.4,
        ..ChannelSpec::ideal()
    };
    check("waterfill", &cfg);
}

#[test]
fn lossy_channel_survivor_sets() {
    // loss + availability + corruption: the survivor set (and therefore
    // every downstream aggregate) depends on the exact order of channel
    // RNG draws — the strictest identity requirement the streamed path
    // must meet
    let mut cfg = base();
    cfg.rounds = 8;
    cfg.channel = ChannelSpec {
        loss: 0.2,
        availability: 0.85,
        corrupt: 0.1,
        ..ChannelSpec::ideal()
    };
    check("lossy", &cfg);
}

#[test]
fn block_wire_coder() {
    // the throughput tier rides the same streamed/resident split as the
    // paper coder: per-block tables and the exact-accounting decode must
    // not perturb the ledger or the trajectory on either side
    let mut cfg = base();
    cfg.wire = WireCoder::Block;
    check("wblock", &cfg);
}

#[test]
fn block_wire_coder_under_corruption() {
    // corruption exercises the strict bit-accounting rejects (truncated
    // or mutated block payloads) — accept/reject decisions must be
    // identical across execution modes
    let mut cfg = base();
    cfg.rounds = 8;
    cfg.wire = WireCoder::Block;
    cfg.channel = ChannelSpec {
        loss: 0.15,
        corrupt: 0.15,
        ..ChannelSpec::ideal()
    };
    check("wblock_lossy", &cfg);
}

#[test]
fn compressed_downlink_with_laggards() {
    // the direction-agnostic delta codec: per-client acked versions are
    // durable state (resident field vs. store entry), and a population
    // larger than the cohort forces the resync path for clients that sat
    // out rounds — both executors must charge the same ledger
    let mut cfg = base();
    cfg.rounds = 8;
    cfg.dataset.num_clients = 64;
    cfg.clients_per_round = 8;
    cfg.down_scheme = Some(CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    });
    check("downlink", &cfg);
}

#[test]
fn joint_rate_budget_runs_both_controllers() {
    // joint up+down budget: the uplink dual ascent and the downlink
    // delta-codec controller both adapt mid-run — window state, λ
    // trajectories and republication charges must match across executors
    let mut cfg = base();
    cfg.scheme = CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    };
    cfg.rate_target = RateTarget::Joint {
        total_bpc: 4.0,
        split: 0.625,
        adapt_every: 2,
    };
    cfg.down_scheme = Some(CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    });
    check("joint", &cfg);
}

#[test]
fn sign_scheme_on_both_directions() {
    // the 1-bit sign kernel as uplink scheme and downlink codec at once
    let mut cfg = base();
    cfg.scheme = CompressionScheme::Sign;
    cfg.down_scheme = Some(CompressionScheme::Sign);
    check("sign", &cfg);
}

#[test]
fn population_larger_than_cohort() {
    // the streaming configuration the executor exists for: sample a
    // small cohort out of a larger population every round
    let mut cfg = base();
    cfg.dataset.num_clients = 64;
    cfg.clients_per_round = 8;
    check("big_population", &cfg);
}

#[test]
fn decode_thread_count_does_not_change_results() {
    // the parallel server decode-accumulate (and the client worker pool
    // that shares the same `threads` knob) is a throughput lever, never
    // a results lever: `threads = 1` drives the serial reference decode
    // loop, everything else fans packet decodes out and replays them in
    // delivery order — byte-identical by construction, pinned here
    // across the three paths with distinct decode planes (plain shared
    // codebook under a lossy channel, per-client allocated codebooks,
    // sparse top-k + error feedback)
    let lossy = {
        let mut cfg = base();
        cfg.rounds = 8;
        cfg.channel = ChannelSpec {
            loss: 0.2,
            availability: 0.85,
            corrupt: 0.1,
            ..ChannelSpec::ideal()
        };
        cfg
    };
    let allocated = {
        let mut cfg = base();
        cfg.scheme = CompressionScheme::Lloyd { bits: 3 };
        cfg.alloc = RateAllocation::WaterFill {
            budget_bpc: 2.5,
            adapt_every: 2,
            min_bits: 1,
            max_bits: 6,
        };
        cfg.channel = ChannelSpec {
            uplink_bps: 1e6,
            bandwidth_spread: 0.4,
            ..ChannelSpec::ideal()
        };
        cfg
    };
    let sparse = {
        let mut cfg = base();
        cfg.scheme = CompressionScheme::Lloyd { bits: 3 };
        cfg.transform = TransformCfg::topk(0.25).with_ef();
        cfg
    };
    for (tag, cfg) in
        [("lossy", lossy), ("alloc", allocated), ("sparse", sparse)]
    {
        let mut cfg = cfg;
        cfg.threads = 1;
        let reference = run_experiment(&cfg).unwrap();
        for threads in [0usize, 2, 3] {
            cfg.threads = threads;
            let got = run_experiment(&cfg).unwrap();
            assert_identical(
                &format!("threads_{tag}_{threads}"),
                &reference,
                &got,
            );
        }
    }
}

#[test]
fn shard_count_does_not_change_results() {
    // the worker-pool shard count is a throughput knob, never a results
    // knob: any sharding must reduce to the same ordered stream
    let mut cfg = base();
    cfg.dataset.num_clients = 16;
    cfg.clients_per_round = 6;
    cfg.mode = ExecutionMode::Streamed;
    cfg.round_shards = 1;
    let reference = run_experiment(&cfg).unwrap();
    for shards in [0, 2, 5] {
        cfg.round_shards = shards;
        let got = run_experiment(&cfg).unwrap();
        assert_identical(&format!("shards{shards}"), &reference, &got);
    }
}
