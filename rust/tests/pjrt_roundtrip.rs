//! Three-layer integration: the AOT JAX/Pallas artifacts executed through
//! the rust PJRT runtime, cross-validated against the rust-native
//! implementations.
//!
//! Requires `make artifacts` (skips cleanly with a message otherwise —
//! CI runs `make test`, which builds them first).

use std::rc::Rc;

use rcfed::model::native::NativeMlp;
use rcfed::model::pjrt::PjrtModel;
use rcfed::model::Backend;
use rcfed::quant::codebook::Codebook;
use rcfed::runtime::host::HostTensor;
use rcfed::runtime::{Engine, Manifest};
use rcfed::stats::moments::{combine_partials, mean_std};
use rcfed::util::rng::Rng;

fn engine() -> Option<Rc<Engine>> {
    let dir = rcfed::runtime::artifacts::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(Rc::new(Engine::new(m).expect("engine"))),
        Err(e) => {
            eprintln!("SKIP pjrt tests: {e}");
            None
        }
    }
}

/// A deterministic Lloyd-ish codebook matching the b-bit artifacts.
fn codebook(bits: u32) -> Codebook {
    let n = 1usize << bits;
    let levels: Vec<f64> = (0..n)
        .map(|l| -2.5 + 5.0 * (l as f64 + 0.5) / n as f64)
        .collect();
    let bounds: Vec<f64> =
        levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
    Codebook::from_f64(&levels, &bounds).unwrap()
}

#[test]
fn quantize_kernel_matches_rust_codebook() {
    let Some(eng) = engine() else { return };
    let man = eng.manifest().clone();
    let chunk = man.chunk;
    let mut rng = Rng::new(17);
    for &bits in &[3usize, 6] {
        let cb = codebook(bits as u32);
        let mut g = vec![0f32; chunk];
        rng.fill_normal_f32(&mut g, 0.3, 1.7);
        let (mu, sigma) = mean_std(&g);
        let out = eng
            .run(
                &format!("quantize_b{bits}"),
                &[
                    HostTensor::F32(g.clone(), vec![chunk]),
                    HostTensor::F32(vec![mu], vec![1]),
                    HostTensor::F32(vec![sigma], vec![1]),
                    HostTensor::F32(cb.bounds.clone(), vec![cb.bounds.len()]),
                    HostTensor::F32(cb.levels.clone(), vec![cb.levels.len()]),
                ],
            )
            .unwrap();
        let deq = out[0].as_f32().unwrap();
        let idx = out[1].as_i32().unwrap();
        // rust-native mirror
        let mut sym = Vec::new();
        cb.quantize_normalized(&g, mu, sigma, &mut sym);
        let mut rec = vec![0f32; chunk];
        cb.dequantize_into(&sym, mu, sigma, &mut rec);
        let mut mismatches = 0;
        for i in 0..chunk {
            if idx[i] != sym[i] as i32 {
                mismatches += 1;
            }
        }
        // f32 normalization rounding can flip coordinates sitting exactly
        // on a boundary; must be vanishingly rare
        assert!(
            mismatches < chunk / 10_000 + 2,
            "b={bits}: {mismatches} index mismatches"
        );
        for i in 0..chunk {
            if idx[i] == sym[i] as i32 {
                assert!(
                    (deq[i] - rec[i]).abs() < 1e-5,
                    "b={bits} i={i}: {} vs {}", deq[i], rec[i]
                );
            }
        }
    }
}

#[test]
fn moments_kernel_matches_rust() {
    let Some(eng) = engine() else { return };
    let man = eng.manifest().clone();
    let (chunk, block) = (man.chunk, man.block);
    let mut rng = Rng::new(23);
    let mut g = vec![0f32; chunk];
    rng.fill_normal_f32(&mut g, -0.7, 2.2);
    let out = eng
        .run("moments", &[HostTensor::F32(g.clone(), vec![chunk])])
        .unwrap();
    let sums = out[0].as_f32().unwrap();
    let sumsqs = out[1].as_f32().unwrap();
    assert_eq!(sums.len(), chunk / block);
    let (mu_k, sd_k) = combine_partials(sums, sumsqs, chunk);
    let (mu_r, sd_r) = mean_std(&g);
    assert!((mu_k - mu_r).abs() < 1e-3, "{mu_k} vs {mu_r}");
    assert!((sd_k - sd_r).abs() < 1e-3, "{sd_k} vs {sd_r}");
}

#[test]
fn dequantize_kernel_roundtrip() {
    let Some(eng) = engine() else { return };
    let man = eng.manifest().clone();
    let chunk = man.chunk;
    let cb = codebook(3);
    let mut rng = Rng::new(29);
    let idx: Vec<i32> = (0..chunk).map(|_| rng.below(8) as i32).collect();
    let (mu, sigma) = (0.4f32, 1.3f32);
    let out = eng
        .run(
            "dequantize_b3",
            &[
                HostTensor::I32(idx.clone(), vec![chunk]),
                HostTensor::F32(vec![mu], vec![1]),
                HostTensor::F32(vec![sigma], vec![1]),
                HostTensor::F32(cb.levels.clone(), vec![8]),
            ],
        )
        .unwrap();
    let deq = out[0].as_f32().unwrap();
    for i in 0..chunk {
        let want = sigma * cb.levels[idx[i] as usize] + mu;
        assert!((deq[i] - want).abs() < 1e-6);
    }
}

#[test]
fn jax_mlp_gradient_matches_native_mlp() {
    // The core L2↔L3 cross-validation: identical parameters and batch
    // through the AOT JAX graph and the rust-native MLP must produce the
    // same loss and gradients (both implement x@w+b / relu / mean-CE).
    let Some(eng) = engine() else { return };
    let pjrt = PjrtModel::new(eng, "mlp_tiny").unwrap();
    let native = NativeMlp::tiny();
    assert_eq!(pjrt.num_params(), native.num_params());
    let params = native.init_params(77);
    let b = pjrt.batch_size();
    let mut rng = Rng::new(31);
    let mut xs = vec![0f32; b * 32];
    rng.fill_normal_f32(&mut xs, 0.0, 1.0);
    let ys: Vec<i32> = (0..b).map(|_| rng.below(4) as i32).collect();
    let mut g_pjrt = vec![0f32; pjrt.num_params()];
    let mut g_nat = vec![0f32; native.num_params()];
    let loss_p = pjrt.grad(&params, &xs, &ys, &mut g_pjrt).unwrap();
    let loss_n = native.grad(&params, &xs, &ys, &mut g_nat).unwrap();
    assert!((loss_p - loss_n).abs() < 1e-4, "loss {loss_p} vs {loss_n}");
    let mut max_err = 0f32;
    for (a, b) in g_pjrt.iter().zip(&g_nat) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-4, "max grad err {max_err}");
    // eval agreement
    let c_p = pjrt.eval(&params, &xs, &ys).unwrap();
    let c_n = native.eval(&params, &xs, &ys).unwrap();
    assert_eq!(c_p, c_n);
}

#[test]
fn executable_cache_compiles_once() {
    let Some(eng) = engine() else { return };
    let chunk = eng.manifest().chunk;
    let g = vec![0f32; chunk];
    let input = [HostTensor::F32(g, vec![chunk])];
    eng.run("moments", &input).unwrap();
    let after_first = eng.compiled_count();
    for _ in 0..3 {
        eng.run("moments", &input).unwrap();
    }
    assert_eq!(eng.compiled_count(), after_first);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(eng) = engine() else { return };
    let err = eng
        .run("moments", &[HostTensor::F32(vec![0.0; 7], vec![7])])
        .unwrap_err();
    assert!(err.to_string().contains("mismatch"), "{err}");
    let err = eng.run("moments", &[]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
    assert!(eng.run("nonexistent", &[]).is_err());
}

#[test]
fn end_to_end_experiment_on_pjrt_backend() {
    // Full Algorithm 1 with the three-layer stack: JAX/Pallas compute,
    // rust compression/aggregation. Small but real.
    use rcfed::coordinator::experiment::{
        run_experiment, BackendChoice, ExperimentConfig,
    };
    use rcfed::fl::compression::CompressionScheme;
    use rcfed::quant::rcq::LengthModel;
    if engine().is_none() {
        return;
    }
    let mut cfg = ExperimentConfig::tiny();
    cfg.backend = BackendChoice::Pjrt("mlp_tiny".into());
    cfg.rounds = 12;
    cfg.eval_every = 4;
    cfg.scheme = CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    };
    let report = run_experiment(&cfg).unwrap();
    assert!(report.final_accuracy > 0.3, "acc={}", report.final_accuracy);
    let first = report.metrics.rounds[0].train_loss;
    let last = report.metrics.rounds.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
}
