//! Closed-loop rate-controller properties on the tiny config.
//!
//! The acceptance bar of the rate-targeted pipeline:
//!
//! * `RateTarget::Off` reproduces the static `Compressor` behavior
//!   bit-for-bit — packets byte-identical, full-run reports unchanged
//!   (the committed golden snapshot in `tests/golden_e2e.rs` pins the
//!   same property against the pre-pipeline values);
//! * with a target set, the controller brings the *measured* uplink
//!   bits/coordinate (ledger bits over transmitted coordinates) within
//!   5% of the target while accuracy stays close to the fixed-λ run;
//! * reported communication totals include the downlink codebook
//!   broadcasts the adaptation paid for.

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::fl::compression::{
    CompressionPipeline, CompressionScheme, Compressor, RateTarget,
    WireCoder,
};
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn rcfed() -> CompressionScheme {
    CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    }
}

#[test]
fn off_reproduces_the_static_compressor_bit_for_bit() {
    // packet level: same gradient, same rng seed, identical wire bytes
    let stat = Compressor::design(rcfed(), WireCoder::Huffman).unwrap();
    let pipe = CompressionPipeline::design(
        rcfed(), WireCoder::Huffman, RateTarget::Off)
    .unwrap();
    let mut g = vec![0f32; 2000];
    Rng::new(3).fill_normal_f32(&mut g, 0.001, 0.02);
    let p_stat = stat.compress(2, 7, &g, &mut Rng::new(4)).unwrap();
    let p_pipe = pipe.compress(2, 7, &g, &mut Rng::new(4)).unwrap();
    assert_eq!(p_stat.to_bytes(), p_pipe.to_bytes());
    assert_eq!(p_stat.total_bits(), p_pipe.total_bits());

    // run level: an explicit Off equals the default, pays no downlink,
    // records no controller trace, and replays bit-exactly
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 8;
    let a = run_experiment(&cfg).unwrap();
    cfg.rate_target = RateTarget::Off;
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.downlink_bits, 0);
    assert_eq!(b.downlink_bits, 0);
    assert!(a.metrics.rate_trace().is_empty());
    for (ra, rb) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
        assert_eq!(ra.bits_up, rb.bits_up);
    }
}

#[test]
fn controller_converges_within_5_percent_of_target() {
    let target = 2.0;
    let adapt_every = 2usize;
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 80;
    cfg.eval_every = 10;
    cfg.rate_target = RateTarget::Track {
        bits_per_coord: target,
        adapt_every,
    };
    let rep = run_experiment(&cfg).unwrap();

    // one trace row per round; realized_bpc is refreshed on the rounds
    // that close a window — average the last few closed windows so a
    // single window's jitter cannot flake the property
    let trace = rep.metrics.rate_trace();
    assert_eq!(trace.len(), cfg.rounds);
    let window_rates: Vec<f64> = trace
        .iter()
        .enumerate()
        .filter(|(r, _)| (r + 1) % adapt_every == 0)
        .map(|(_, t)| t.realized_bpc)
        .filter(|x| x.is_finite())
        .collect();
    assert!(window_rates.len() >= 10, "controller never closed windows");
    let tail = &window_rates[window_rates.len() - 5..];
    let realized = tail.iter().sum::<f64>() / tail.len() as f64;
    assert!(
        (realized - target).abs() <= 0.05 * target,
        "realized {realized:.3} b/coord not within 5% of target {target} \
         (window tail {tail:?})"
    );

    // the controller actually moved λ off its initial value
    let lambdas: Vec<f64> = trace.iter().map(|t| t.lambda).collect();
    assert!(
        (lambdas.last().unwrap() - lambdas.first().unwrap()).abs() > 1e-4,
        "λ never moved: {:?}",
        &lambdas[..4.min(lambdas.len())]
    );

    // honest totals: downlink broadcasts are counted and reported
    assert!(rep.downlink_bits > 0, "no codebook broadcast charged");
    assert_eq!(rep.total_comm_bits(), rep.total_bits + rep.downlink_bits);
    assert_eq!(rep.metrics.total_downlink_bits(), rep.downlink_bits);

    // accuracy does not collapse relative to the fixed-λ reference
    let mut fixed = cfg.clone();
    fixed.rate_target = RateTarget::Off;
    let reference = run_experiment(&fixed).unwrap();
    assert!(
        rep.final_accuracy >= reference.final_accuracy - 0.05,
        "adaptive acc {} vs fixed-λ acc {}",
        rep.final_accuracy,
        reference.final_accuracy
    );
}

#[test]
fn loose_target_relaxes_lambda_to_zero_cost() {
    // a target far above the λ=0 rate: dual ascent must push λ to (or
    // near) zero and keep the realized rate at the unconstrained level,
    // never above the target
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 30;
    cfg.eval_every = 0;
    cfg.rate_target =
        RateTarget::Track { bits_per_coord: 8.0, adapt_every: 2 };
    let rep = run_experiment(&cfg).unwrap();
    let realized = rep.realized_bpc();
    assert!(realized.is_finite());
    assert!(
        realized < 8.0,
        "unconstrained 3-bit rate {realized} above the loose target"
    );
    let final_lambda = rep.metrics.rate_trace().last().unwrap().lambda;
    assert!(
        final_lambda < 0.05,
        "λ should relax toward 0 under a loose target, got {final_lambda}"
    );
}

#[test]
fn dual_ascent_moves_lambda_toward_the_target() {
    // realized >> target must raise lambda (cheaper codebook); a later
    // window with realized << target must lower it again
    let target = RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 };
    let mut pipe =
        CompressionPipeline::design(rcfed(), WireCoder::Huffman, target)
            .unwrap();
    let mut g = vec![0f32; 16_384];
    Rng::new(75).fill_normal_f32(&mut g, 0.0, 1.0);
    let sample = pipe.grad_sample(&g);
    let lam0 = pipe.lambda();
    pipe.observe_samples(&sample);
    pipe.observe_round(4 * 16_384, 16_384); // 4 bits/coord measured
    pipe.end_round(0).unwrap();
    assert!((pipe.last_realized() - 4.0).abs() < 1e-9);
    let lam1 = pipe.lambda();
    assert!(lam1 > lam0, "lambda must rise: {lam0} -> {lam1}");
    pipe.observe_samples(&sample);
    pipe.observe_round(16_384 / 2, 16_384); // 0.5 bits/coord measured
    pipe.end_round(1).unwrap();
    assert!(
        pipe.lambda() < lam1,
        "lambda must fall: {lam1} -> {}",
        pipe.lambda()
    );
    // lambda is a Lagrange multiplier: never negative
    for round in 2..30 {
        pipe.observe_samples(&sample);
        pipe.observe_round(1, 16_384);
        pipe.end_round(round).unwrap();
        assert!(pipe.lambda() >= 0.0);
    }
}
