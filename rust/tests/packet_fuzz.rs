//! Wire-format fuzzing: `Packet::parse` and the full
//! `parse → decompress_accumulate` pipeline must return `Err` on
//! malformed input — never panic, never over-read, never accumulate a
//! partial gradient. This is the contract the channel model's
//! corruption injection relies on.

use rcfed::fl::compression::{
    CompressionPipeline, CompressionScheme, Compressor, RateAllocation,
    RateTarget, TransformCfg, WireCoder,
};
use rcfed::fl::packet::Packet;
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn sample_packet() -> Packet {
    Packet {
        client_id: 7,
        round: 3,
        scheme: rcfed::fl::packet::SchemeTag::RcFed,
        bits_per_symbol: 3,
        d: 64,
        side_info: vec![0.25, 1.5],
        payload: vec![0xA5; 24],
        payload_bits: 24 * 8 - 3,
        table_bits: 0,
        index_bits: 0,
    }
}

#[test]
fn parse_rejects_every_strict_prefix() {
    let bytes = sample_packet().to_bytes();
    for cut in 0..bytes.len() {
        assert!(
            Packet::parse(&bytes[..cut]).is_err(),
            "prefix of {cut} bytes parsed"
        );
    }
    // the full buffer still parses
    assert!(Packet::parse(&bytes).is_ok());
}

#[test]
fn parse_rejects_bad_scheme_tags() {
    let bytes = sample_packet().to_bytes();
    for tag in 6u8..=255 {
        let mut bad = bytes.clone();
        bad[8] = tag;
        assert!(Packet::parse(&bad).is_err(), "tag {tag} accepted");
    }
}

#[test]
fn parse_rejects_length_field_mismatches() {
    let p = sample_packet();
    // payload_bits claiming more bits than the payload carries
    let mut bytes = p.to_bytes();
    let lie = (p.payload.len() as u64 * 8 + 1).to_le_bytes();
    bytes[14..20].copy_from_slice(&lie[..6]);
    assert!(Packet::parse(&bytes).is_err());
    // side-info count promising values the buffer does not have
    let mut bytes = p.to_bytes();
    bytes[20..22].copy_from_slice(&u16::MAX.to_le_bytes());
    assert!(Packet::parse(&bytes).is_err());
    // a count that swallows the whole payload then runs short
    let mut bytes = p.to_bytes();
    let n = ((bytes.len() - 22) / 4 + 1) as u16;
    bytes[20..22].copy_from_slice(&n.to_le_bytes());
    assert!(Packet::parse(&bytes).is_err());
}

#[test]
fn parse_survives_random_garbage() {
    let mut rng = Rng::new(0xFADE);
    for len in 0..96usize {
        for _ in 0..64 {
            let buf: Vec<u8> =
                (0..len).map(|_| rng.next_u64() as u8).collect();
            // must return (Ok or Err) without panicking or over-reading
            let _ = Packet::parse(&buf);
        }
    }
}

fn compressors() -> Vec<Compressor> {
    vec![
        Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Huffman,
        )
        .unwrap(),
        Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Arithmetic,
        )
        .unwrap(),
        Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Block,
        )
        .unwrap(),
        Compressor::design(CompressionScheme::Lloyd { bits: 3 }, WireCoder::Huffman)
            .unwrap(),
        Compressor::design(CompressionScheme::Lloyd { bits: 3 }, WireCoder::Block)
            .unwrap(),
        Compressor::design(CompressionScheme::Qsgd { bits: 3 }, WireCoder::Huffman)
            .unwrap(),
        Compressor::design(CompressionScheme::Fp32, WireCoder::Huffman)
            .unwrap(),
    ]
}

#[test]
fn decompress_never_panics_on_mutated_wire_bytes() {
    let mut rng = Rng::new(0xBEEF);
    let d = 600; // > one QSGD bucket so the norms path is exercised
    let mut grad = vec![0f32; d];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    for c in compressors() {
        let pkt = c.compress(1, 0, &grad, &mut rng).unwrap();
        let clean = pkt.to_bytes();
        for trial in 0..400 {
            let mut bytes = clean.clone();
            match trial % 3 {
                0 => {
                    // truncate anywhere
                    let cut = rng.below(bytes.len());
                    bytes.truncate(cut);
                }
                1 => {
                    // flip a handful of random bits
                    for _ in 0..8 {
                        let bit = rng.below(bytes.len() * 8);
                        bytes[bit / 8] ^= 1 << (bit % 8);
                    }
                }
                _ => {
                    // stomp a whole random field region
                    let start = rng.below(bytes.len());
                    let end = (start + 1 + rng.below(8)).min(bytes.len());
                    for b in &mut bytes[start..end] {
                        *b = rng.next_u64() as u8;
                    }
                }
            }
            // parse may fail (good); if it succeeds, decode must return
            // a Result too — wrong values are channel noise, panics are
            // bugs
            if let Ok(parsed) = Packet::parse(&bytes) {
                let mut acc = vec![0f32; d];
                let _ = c.decompress_accumulate(&parsed, &mut acc);
            }
        }
    }
}

#[test]
fn truncated_payloads_are_recoverable_rejects_for_every_wire() {
    // the zero-fill bugfix battery: a payload physically shorter than
    // the bit length its header declares must come back as a
    // recoverable Err from every coded wire path — never a panic and
    // never a silent zero-filled accept that corrupts the aggregate
    let mut rng = Rng::new(0x7105);
    let d = 600;
    let mut grad = vec![0f32; d];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    for c in compressors() {
        let pkt = c.compress(2, 1, &grad, &mut rng).unwrap();
        if pkt.payload.is_empty() {
            continue;
        }
        for keep in [0, 1, pkt.payload.len() / 2, pkt.payload.len() - 1] {
            if keep >= pkt.payload.len() {
                continue;
            }
            // cut bytes but keep the header's bit claim: the struct-level
            // lie `ensure_covers`/exact decode must catch
            let mut cut = pkt.clone();
            cut.payload.truncate(keep);
            let mut acc = vec![0f32; d];
            assert!(
                c.decompress_accumulate(&cut, &mut acc).is_err(),
                "{} bytes of a {}-byte payload accepted",
                keep,
                pkt.payload.len()
            );
            assert!(acc.iter().all(|&x| x == 0.0), "partial accumulation");
        }
        // a wire image whose declared bit length exceeds the payload is
        // already dead at parse (the header-level guard)
        let mut bytes = pkt.to_bytes();
        let lie = (pkt.payload.len() as u64 * 8 + 1).to_le_bytes();
        bytes[14..20].copy_from_slice(&lie[..6]);
        assert!(Packet::parse(&bytes).is_err());
        // the intact packet still decodes after the battery
        let mut acc = vec![0f32; d];
        c.decompress_accumulate(&pkt, &mut acc).unwrap();
    }
}

#[test]
fn block_header_mutation_never_panics() {
    // the block wire carries self-framing headers (kind bit, MTF flag,
    // 4-bit length tables) *inside* the payload — stomp them directly:
    // Kraft violations, empty tables, out-of-alphabet constant blocks
    // and truncated tails must all surface as Err or as channel noise,
    // never as a panic or over-read
    let c = Compressor::design(
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        },
        WireCoder::Block,
    )
    .unwrap();
    let mut rng = Rng::new(0xB10C);
    let d = 900;
    let mut grad = vec![0f32; d];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    let pkt = c.compress(0, 0, &grad, &mut rng).unwrap();
    let clean = pkt.to_bytes();
    let payload_start = clean.len() - pkt.payload.len();
    for trial in 0..800 {
        let mut bytes = clean.clone();
        match trial % 4 {
            0 => {
                // stomp the first payload bytes — that's the first
                // block's kind/flag/table header
                let end = (payload_start + 1 + rng.below(6)).min(bytes.len());
                for b in &mut bytes[payload_start..end] {
                    *b = rng.next_u64() as u8;
                }
            }
            1 => {
                // flip single bits anywhere in the payload region
                for _ in 0..4 {
                    let bit = payload_start * 8
                        + rng.below((bytes.len() - payload_start) * 8);
                    bytes[bit / 8] ^= 1 << (bit % 8);
                }
            }
            2 => {
                // truncate inside the payload
                let cut = payload_start + rng.below(pkt.payload.len());
                bytes.truncate(cut);
            }
            _ => {
                // stomp a random span anywhere (headers included)
                let start = rng.below(bytes.len());
                let end = (start + 1 + rng.below(12)).min(bytes.len());
                for b in &mut bytes[start..end] {
                    *b = rng.next_u64() as u8;
                }
            }
        }
        if let Ok(parsed) = Packet::parse(&bytes) {
            let mut acc = vec![0f32; d];
            let _ = c.decompress_accumulate(&parsed, &mut acc);
        }
    }
    // the untouched packet still decodes
    let mut acc = vec![0f32; d];
    c.decompress_accumulate(&Packet::parse(&clean).unwrap(), &mut acc)
        .unwrap();
}

#[test]
fn decompress_rejects_missing_or_bogus_side_info() {
    let mut rng = Rng::new(0x51DE);
    let mut grad = vec![0f32; 128];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    let c = Compressor::design(
        CompressionScheme::Lloyd { bits: 3 },
        WireCoder::Huffman,
    )
    .unwrap();
    let pkt = c.compress(0, 0, &grad, &mut rng).unwrap();
    let mut acc = vec![0f32; 128];
    // no side info at all
    let mut bad = pkt.clone();
    bad.side_info.clear();
    assert!(c.decompress_accumulate(&bad, &mut acc).is_err());
    // wrong count
    let mut bad = pkt.clone();
    bad.side_info = vec![0.0; 5];
    assert!(c.decompress_accumulate(&bad, &mut acc).is_err());
    // non-finite (μ, σ)
    let mut bad = pkt.clone();
    bad.side_info = vec![f32::NAN, 1.0];
    assert!(c.decompress_accumulate(&bad, &mut acc).is_err());
    let mut bad = pkt;
    bad.side_info = vec![0.0, f32::INFINITY];
    assert!(c.decompress_accumulate(&bad, &mut acc).is_err());
    // nothing accumulated by any rejected packet
    assert!(acc.iter().all(|&x| x == 0.0));
}

#[test]
fn side_version_fuzz_is_recoverable_on_the_adaptive_pipeline() {
    // PRs 3–4 added a third side-info word (codebook/allocation version)
    // — fuzz it: stale, malformed and byte-stomped versions must come
    // back as recoverable Errs, never panics or silent accepts.
    let mut pipe = CompressionPipeline::design(
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        },
        WireCoder::Huffman,
        RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 },
    )
    .unwrap();
    let mut rng = Rng::new(0xC0DE);
    let d = 1024;
    let mut grad = vec![0f32; d];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    let v0 = pipe.compress(0, 0, &grad, &mut rng).unwrap();
    assert_eq!(v0.side_info.len(), 3);
    // malformed version words are rejected up front
    for bad in [f32::NAN, f32::INFINITY, -1.0, 2.5, 4.3e9] {
        let mut forged = v0.clone();
        forged.side_info[2] = bad;
        let mut acc = vec![0f32; d];
        assert!(
            pipe.decompress_accumulate(&forged, &mut acc).is_err(),
            "version {bad} accepted"
        );
        assert!(acc.iter().all(|&x| x == 0.0), "partial accumulation");
    }
    // drive one adaptation window so the live version moves to 1
    let sample = pipe.grad_sample(&grad);
    pipe.observe_samples(&sample);
    pipe.observe_round(v0.total_bits(), v0.d as u64);
    pipe.end_round(0).unwrap();
    assert_eq!(pipe.version(), 1);
    // the v0 packet is now stale: recoverable reject, nothing written
    let mut acc = vec![0f32; d];
    assert!(pipe.decompress_accumulate(&v0, &mut acc).is_err());
    assert!(acc.iter().all(|&x| x == 0.0));
    // byte-stomp the version word (bytes 30..34 of the wire image) of a
    // fresh packet: parse may fail, decode must never panic
    let fresh = pipe.compress(0, 1, &grad, &mut rng).unwrap();
    let clean = fresh.to_bytes();
    for trial in 0..512 {
        let mut bytes = clean.clone();
        for (i, b) in bytes[30..34].iter_mut().enumerate() {
            *b = (trial as u8).wrapping_mul(37).wrapping_add(i as u8 * 101);
        }
        if let Ok(parsed) = Packet::parse(&bytes) {
            let mut acc = vec![0f32; d];
            let _ = pipe.decompress_accumulate(&parsed, &mut acc);
        }
    }
    // the untouched fresh packet still decodes
    pipe.decompress_accumulate(&fresh, &mut acc).unwrap();
}

#[test]
fn width_header_fuzz_is_recoverable_on_the_allocated_pipeline() {
    // the allocator decodes against the width claimed in the header —
    // every forged width must be a recoverable reject, never a panic or
    // an out-of-ladder index
    let mut pipe = CompressionPipeline::design_alloc(
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        },
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::WaterFill {
            budget_bpc: 2.5,
            adapt_every: 1,
            min_bits: 1,
            max_bits: 6,
        },
    )
    .unwrap();
    pipe.bind_clients(2, &[1.0, 1.0]).unwrap();
    let mut rng = Rng::new(0xF00D);
    let d = 600;
    let mut grad = vec![0f32; d];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    let pkt = pipe.compress(0, 0, &grad, &mut rng).unwrap();
    let assigned = pkt.bits_per_symbol;
    for width in 0u8..=255 {
        let mut forged = pkt.clone();
        forged.bits_per_symbol = width;
        let mut acc = vec![0f32; d];
        let result = pipe.decompress_accumulate(&forged, &mut acc);
        if width == assigned {
            assert!(result.is_ok(), "assigned width rejected");
        } else {
            assert!(result.is_err(), "forged width {width} accepted");
            assert!(acc.iter().all(|&x| x == 0.0), "partial accumulation");
        }
    }
    // the width byte through the real wire image (offset 9): parse
    // succeeds (any u8 is a legal header value), decode must reject
    let clean = pkt.to_bytes();
    for width in 0u8..=255 {
        if width == assigned {
            continue;
        }
        let mut bytes = clean.clone();
        bytes[9] = width;
        let parsed = Packet::parse(&bytes).unwrap();
        let mut acc = vec![0f32; d];
        assert!(pipe.decompress_accumulate(&parsed, &mut acc).is_err());
    }
    // stomping the version word on the allocated path is recoverable too
    let mut forged = pkt.clone();
    for bad in [f32::NAN, -2.0, 0.5, 7.0] {
        forged.side_info[2] = bad;
        let mut acc = vec![0f32; d];
        assert!(pipe.decompress_accumulate(&forged, &mut acc).is_err());
    }
}

#[test]
fn sparse_topk_packets_survive_mutation_without_panicking() {
    // the top-k index block is attacker-controlled bytes at the payload
    // head: every mutation must parse/decode to Ok or Err, never panic,
    // never scatter out of bounds
    let c = Compressor::design_with_transform(
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        },
        WireCoder::Huffman,
        TransformCfg::topk(0.1),
    )
    .unwrap();
    let mut rng = Rng::new(0x70CC);
    let d = 800;
    let mut grad = vec![0f32; d];
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    let pkt = c.compress(1, 0, &grad, &mut rng).unwrap();
    assert!(pkt.index_bits > 0);
    let clean = pkt.to_bytes();
    for trial in 0..600 {
        let mut bytes = clean.clone();
        match trial % 3 {
            0 => {
                let cut = rng.below(bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                for _ in 0..8 {
                    let bit = rng.below(bytes.len() * 8);
                    bytes[bit / 8] ^= 1 << (bit % 8);
                }
            }
            _ => {
                let start = rng.below(bytes.len());
                let end = (start + 1 + rng.below(8)).min(bytes.len());
                for b in &mut bytes[start..end] {
                    *b = rng.next_u64() as u8;
                }
            }
        }
        if let Ok(parsed) = Packet::parse(&bytes) {
            let mut acc = vec![0f32; d];
            let _ = c.decompress_accumulate(&parsed, &mut acc);
        }
    }
    // the clean packet still decodes after all that
    let mut acc = vec![0f32; d];
    c.decompress_accumulate(&Packet::parse(&clean).unwrap(), &mut acc)
        .unwrap();
}

#[test]
fn decompress_rejects_short_fp32_payloads() {
    let c = Compressor::design(CompressionScheme::Fp32, WireCoder::Huffman)
        .unwrap();
    let mut rng = Rng::new(2);
    let pkt = c.compress(0, 0, &[1.0f32; 32], &mut rng).unwrap();
    let mut bad = pkt.clone();
    bad.payload.truncate(32 * 4 - 1);
    bad.payload_bits = bad.payload.len() as u64 * 8;
    let mut acc = vec![0f32; 32];
    assert!(c.decompress_accumulate(&bad, &mut acc).is_err());
    assert!(acc.iter().all(|&x| x == 0.0), "partial accumulation");
    // the intact packet still decodes
    assert!(c.decompress_accumulate(&pkt, &mut acc).is_ok());
}
