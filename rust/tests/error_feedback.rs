//! Error-feedback + top-k sparsification scenarios through the staged
//! `fl/codec` subsystem: EF-compressed aggregates must converge to the
//! uncompressed sum, a dropped packet must leave the client residual
//! intact, and sparse packets must charge their index bits honestly.

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::coordinator::network::ChannelSpec;
use rcfed::fl::compression::{
    CompressionPipeline, CompressionScheme, RateAllocation, RateTarget,
    Transform, TransformCfg, TransformState, WireCoder,
};
use rcfed::fl::packet::Packet;
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn rcfed3() -> CompressionScheme {
    CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    }
}

fn pipe(transform: TransformCfg) -> CompressionPipeline {
    CompressionPipeline::design_full(
        rcfed3(),
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::Uniform,
        transform,
    )
    .unwrap()
}

fn gaussian(n: usize, mu: f32, sigma: f32, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut g = vec![0f32; n];
    rng.fill_normal_f32(&mut g, mu, sigma);
    g
}

fn l2_diff(sum: &[f32], truth: &[f64]) -> f64 {
    sum.iter()
        .zip(truth)
        .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
        .sum::<f64>()
        .sqrt()
}

/// With a deterministic (repeated) gradient stream, the plain quantizer
/// repeats the same error every round — the aggregate drifts linearly —
/// while EF banks the error and re-injects it, so the EF aggregate
/// tracks the uncompressed sum to within the final residual norm.
#[test]
fn ef_aggregate_converges_to_the_uncompressed_sum() {
    let d = 4096;
    let rounds = 25u32;
    let g = gaussian(d, 0.01, 0.3, 1);
    let ef = pipe(TransformCfg::identity().with_ef());
    let plain = pipe(TransformCfg::identity());
    let mut state = TransformState::new();
    let mut sum_true = vec![0f64; d];
    let mut sum_ef = vec![0f32; d];
    let mut sum_plain = vec![0f32; d];
    for t in 0..rounds {
        let mut rng = Rng::new(99);
        let p = ef.compress_with(&mut state, 0, t, &g, &mut rng).unwrap();
        ef.decompress_accumulate(&p, &mut sum_ef).unwrap();
        let q = plain.compress(0, t, &g, &mut rng).unwrap();
        plain.decompress_accumulate(&q, &mut sum_plain).unwrap();
        for (s, &x) in sum_true.iter_mut().zip(&g) {
            *s += x as f64;
        }
    }
    let e_ef = l2_diff(&sum_ef, &sum_true);
    let e_plain = l2_diff(&sum_plain, &sum_true);
    // exact invariant: Σ decoded = Σ true − residual_T, so the EF error
    // equals the final residual norm (up to f32 accumulation noise)
    let r_norm = state.last_ef_norm;
    assert!(r_norm.is_finite() && r_norm > 0.0);
    assert!(
        (e_ef - r_norm).abs() < 1e-2 * (1.0 + r_norm),
        "EF aggregate error {e_ef} != residual norm {r_norm}"
    );
    // and the plain aggregate drifts ~rounds× further
    assert!(
        e_ef * 3.0 < e_plain,
        "EF error {e_ef} not clearly below plain error {e_plain}"
    );
}

/// A packet lost in the channel must not touch the client-side residual:
/// the error banked at compress time rides into the next round whether
/// or not the server ever saw the packet.
#[test]
fn dropped_packet_leaves_the_residual_intact() {
    let d = 1024;
    let g = gaussian(d, 0.0, 0.5, 7);
    let ef = pipe(TransformCfg::identity().with_ef());
    let mut state = TransformState::new();
    let mut rng = Rng::new(8);
    let _lost = ef.compress_with(&mut state, 0, 0, &g, &mut rng).unwrap();
    let residual_after_loss: Vec<f32> = state.residual().to_vec();
    assert!(
        residual_after_loss.iter().any(|&r| r != 0.0),
        "3-bit quantization must leave a nonzero residual"
    );
    // the "loss": nothing decodes the packet, nothing else runs — the
    // state the next round sees is exactly the banked residual
    assert_eq!(state.residual(), &residual_after_loss[..]);
    // the next round's packet carries the banked error: its decoded
    // reconstruction approximates g + residual, so subtracting g leaves
    // a vector correlated with the residual
    let p1 = ef.compress_with(&mut state, 0, 1, &g, &mut rng).unwrap();
    let mut recon = vec![0f32; d];
    ef.decompress_accumulate(&p1, &mut recon).unwrap();
    let carried: Vec<f64> = recon
        .iter()
        .zip(&g)
        .map(|(&r, &x)| (r - x) as f64)
        .collect();
    let dot: f64 = carried
        .iter()
        .zip(&residual_after_loss)
        .map(|(&a, &b)| a * b as f64)
        .sum();
    assert!(dot > 0.0, "round-1 packet does not carry the residual");
}

/// End-to-end: an EF run over a lossy channel is deterministic, records
/// the transform trace, and survives without touching accuracy plumbing.
#[test]
fn ef_run_is_deterministic_under_packet_loss() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 12;
    cfg.transform = TransformCfg::identity().with_ef();
    cfg.channel = ChannelSpec { loss: 0.3, ..ChannelSpec::ideal() };
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert!(a.channel.lost > 0, "loss 0.3 never fired");
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.metrics.transform_trace().len(), 12);
    let last = a.metrics.transform_trace().last().unwrap();
    assert!((last.sparsity - 1.0).abs() < 1e-12, "dense EF is not sparse");
    assert!(
        last.ef_residual_norm.is_finite() && last.ef_residual_norm > 0.0,
        "residual norm missing from the trace"
    );
    assert_eq!(a.label, "rcfed_b3_l0.050_ef");
}

/// Top-k packets: index bits charged to the ledger, fewer total bits
/// than dense at small ratios, and scatter-decode through real wire
/// bytes touching only the kept coordinates.
#[test]
fn topk_charges_index_bits_and_beats_dense_uplink() {
    let d = 4096;
    let g = gaussian(d, 0.0, 1.0, 11);
    let dense = pipe(TransformCfg::identity());
    let sparse = pipe(TransformCfg::topk(0.1));
    let mut rng = Rng::new(12);
    let pd = dense.compress(0, 0, &g, &mut rng).unwrap();
    let ps = sparse.compress(0, 0, &g, &mut rng).unwrap();
    let k = 410; // ceil(0.1 · 4096)
    assert!(ps.index_bits > 0, "index bits not charged");
    assert_eq!(pd.index_bits, 0, "dense packets must not charge indices");
    assert!(
        ps.total_bits() < pd.total_bits(),
        "topk0.1 {} >= dense {}",
        ps.total_bits(),
        pd.total_bits()
    );
    let parsed = Packet::parse(&ps.to_bytes()).unwrap();
    let mut acc = vec![0f32; d];
    sparse.decompress_accumulate(&parsed, &mut acc).unwrap();
    let touched = acc.iter().filter(|&&x| x != 0.0).count();
    assert!(touched <= k, "sparse decode touched {touched} > k={k}");
    assert!(touched > k / 2, "sparse decode touched only {touched}");
    // the kept coordinates align with the gradient's largest entries
    let dot: f64 = g.iter().zip(&acc).map(|(&a, &b)| (a * b) as f64).sum();
    assert!(dot > 0.0);
}

/// The acceptance scenario: `--scheme topk0.1 --ef` end-to-end, with the
/// Track controller measuring the index+value bits in `realized_bpc`.
#[test]
fn topk_ef_runs_end_to_end_with_rate_tracking() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 9;
    cfg.eval_every = 3;
    cfg.transform = TransformCfg::topk(0.1).with_ef();
    cfg.rate_target = RateTarget::Track { bits_per_coord: 1.0, adapt_every: 3 };
    let rep = run_experiment(&cfg).unwrap();
    assert_eq!(rep.label, "rcfed_b3_l0.050_topk0.1_ef");
    assert!(rep.realized_bpc().is_finite(), "realized_bpc missing");
    assert_eq!(rep.metrics.transform_trace().len(), 9);
    let last = rep.metrics.transform_trace().last().unwrap();
    assert!(last.sparsity > 0.0 && last.sparsity <= 0.11,
            "sparsity {} off the 0.1 ratio", last.sparsity);
    assert!(last.ef_residual_norm > 0.0);
    // the same protocol without sparsification pays more uplink (both
    // static, so the comparison is free of controller drift)
    let mut sparse_static = cfg.clone();
    sparse_static.rate_target = RateTarget::Off;
    let mut dense_static = sparse_static.clone();
    dense_static.transform = TransformCfg::identity().with_ef();
    let sparse_rep = run_experiment(&sparse_static).unwrap();
    let dense_rep = run_experiment(&dense_static).unwrap();
    assert!(
        sparse_rep.total_bits < dense_rep.total_bits,
        "topk {} >= dense {}",
        sparse_rep.total_bits,
        dense_rep.total_bits
    );
    // deterministic replay, transform and all
    let again = run_experiment(&cfg).unwrap();
    assert_eq!(rep.total_bits, again.total_bits);
    assert_eq!(rep.final_accuracy, again.final_accuracy);
}

/// topk+ef under the closed loop: the staged sampler feeds the
/// controller a working-set sample, versioned sparse packets roundtrip,
/// and a window end still broadcasts.
#[test]
fn transform_composes_with_the_track_controller() {
    let target = RateTarget::Track { bits_per_coord: 1.0, adapt_every: 1 };
    let mut pipe = CompressionPipeline::design_full(
        rcfed3(),
        WireCoder::Huffman,
        target,
        RateAllocation::Uniform,
        TransformCfg::topk(0.1).with_ef(),
    )
    .unwrap();
    let g = gaussian(8192, 0.0, 1.0, 83);
    let mut rng = Rng::new(84);
    let mut state = TransformState::new();
    // stateless compress is a config error under EF
    assert!(pipe.compress(0, 0, &g, &mut rng).is_err());
    let pkt = pipe.compress_with(&mut state, 0, 0, &g, &mut rng).unwrap();
    assert_eq!(pkt.side_info.len(), 3, "version word missing");
    assert!(pkt.index_bits > 0);
    let sample = state.take_sample().expect("staged sampler must fire");
    assert!(!sample.is_empty());
    assert!(sample.len() <= 8192 / 8 + 1, "sample of the kept set only");
    let mut acc = vec![0f32; g.len()];
    pipe.decompress_accumulate(&pkt, &mut acc).unwrap();
    pipe.observe_samples(&sample);
    pipe.observe_round(pkt.total_bits(), pkt.d as u64);
    match pipe.end_round(0).unwrap() {
        rcfed::fl::compression::RoundAdaptation::Broadcast {
            bits_per_client,
        } => {
            assert!(bits_per_client > 0);
        }
        other => panic!("expected a broadcast, got {other:?}"),
    }
    assert_eq!(pipe.version(), 1);
    // stale sparse packets are rejected like dense ones
    assert!(pipe.decompress_accumulate(&pkt, &mut acc).is_err());
    let fresh = pipe.compress_with(&mut state, 0, 1, &g, &mut rng).unwrap();
    pipe.decompress_accumulate(&fresh, &mut acc).unwrap();
}

/// Config errors stay config errors: EF through the stateless entry
/// point, bad ratios, and topk × qsgd are rejected up front.
#[test]
fn transform_misconfigurations_are_rejected() {
    let ef = pipe(TransformCfg::identity().with_ef());
    let g = gaussian(64, 0.0, 1.0, 21);
    let mut rng = Rng::new(22);
    assert!(ef.compress(0, 0, &g, &mut rng).is_err(),
            "stateless EF compress must be a config error");
    assert!(CompressionPipeline::design_full(
        rcfed3(),
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::Uniform,
        TransformCfg::topk(0.0),
    )
    .is_err());
    assert!(CompressionPipeline::design_full(
        CompressionScheme::Qsgd { bits: 3 },
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::Uniform,
        TransformCfg::topk(0.5),
    )
    .is_err());
    // qsgd + EF is allowed (dense, unbiased reconstruction exists)
    assert!(CompressionPipeline::design_full(
        CompressionScheme::Qsgd { bits: 3 },
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::Uniform,
        TransformCfg { kind: Transform::Identity, error_feedback: true },
    )
    .is_ok());
}
