//! Integration tests for the fault-injecting channel: corruption flows
//! through the real `Packet::parse` → `decompress_accumulate` path and
//! is handled as a recoverable `Err` (client skipped, aggregate
//! reweighted over survivors); fixed seeds replay whole lossy sweeps
//! bit-exactly.

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::coordinator::network::{ChannelSpec, Delivery, SimulatedNetwork};
use rcfed::coordinator::sweep::{run_sweep, SweepGrid};
use rcfed::fl::compression::{CompressionScheme, Compressor, WireCoder};
use rcfed::fl::server::{LrSchedule, Server};
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn rcfed_scheme() -> CompressionScheme {
    CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    }
}

/// The acceptance path: a corrupting channel between real compressed
/// gradients and a real server. Every corrupted packet goes through
/// `Packet::parse` → `decompress_accumulate`; failures skip the client
/// and the surviving aggregate equals the plain mean over survivors.
#[test]
fn corrupt_packets_are_recoverable_and_survivors_reweight() {
    let d = 256usize;
    let clients = 4u32;
    let spec = ChannelSpec { corrupt: 1.0, ..ChannelSpec::ideal() };
    let compressor =
        Compressor::design(rcfed_scheme(), WireCoder::Huffman).unwrap();
    let mut network = SimulatedNetwork::with_spec(clients as usize, spec, 77);
    let mut server = Server::new(vec![0.0; d], LrSchedule::Const(0.1));
    let mut rng = Rng::new(123);

    let mut total_decode_errors = 0u64;
    for round in 0..6u32 {
        network.begin_round();
        server.begin_round();
        // per-survivor reference decodes, to check the aggregate against
        let mut reference = vec![0f32; d];
        let mut survivors = 0usize;
        for c in 0..clients {
            let mut grad = vec![0f32; d];
            rng.fill_normal_f32(&mut grad, 0.01 * c as f32, 1.0);
            let pkt = compressor.compress(c, round, &grad, &mut rng).unwrap();
            match network.deliver(&pkt) {
                Delivery::Corrupted { bytes, .. } => {
                    // THE path under test: real wire bytes → parse →
                    // decompress; Err is recoverable, never a panic
                    match server.receive_bytes(&compressor, &bytes) {
                        Ok(()) => {
                            survivors += 1;
                            // mirror what the server just accumulated
                            let p = rcfed::fl::packet::Packet::parse(&bytes)
                                .unwrap();
                            compressor
                                .decompress_accumulate(&p, &mut reference)
                                .unwrap();
                        }
                        Err(_) => {
                            network.note_decode_error();
                            total_decode_errors += 1;
                        }
                    }
                }
                other => panic!("corrupt=1.0 produced {other:?}"),
            }
        }
        assert_eq!(server.received(), survivors);
        if survivors > 0 {
            // unbiased over survivors: mean = acc / received
            let mean = server.aggregated_gradient();
            for (m, r) in mean.iter().zip(&reference) {
                let want = r / survivors as f32;
                // undetected bit flips can blow single coordinates up to
                // ±inf; both sides compute identically, so only compare
                // where the value is meaningful
                if !want.is_finite() {
                    continue;
                }
                assert!(
                    (m - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "aggregate not reweighted over survivors: {m} vs {want}"
                );
            }
            server.step().unwrap();
        } else {
            server.skip_round();
        }
    }
    assert!(
        total_decode_errors > 0,
        "no corruption was caught as a decode Err in 24 packets"
    );
    assert_eq!(network.stats.decode_errors, total_decode_errors);
    assert_eq!(network.stats.corrupted, 24);
    assert_eq!(server.round, 6, "every round must advance");
}

#[test]
fn corrupting_experiment_completes_end_to_end() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 8;
    cfg.eval_every = 0;
    cfg.scheme = rcfed_scheme();
    cfg.channel = ChannelSpec { corrupt: 1.0, ..ChannelSpec::ideal() };
    let rep = run_experiment(&cfg).unwrap();
    assert_eq!(rep.channel.delivered, 0);
    assert_eq!(
        rep.channel.corrupted,
        8 * cfg.dataset.num_clients as u64,
        "every packet must pass through the corruptor"
    );
    assert!(
        rep.channel.decode_errors > 0,
        "corruption never surfaced as a decode Err: {:?}",
        rep.channel
    );
    // the ledger still charges every transmission
    assert!(rep.total_bits > 0);
}

#[test]
fn lossy_sweep_replays_bit_exactly() {
    let run = || {
        let mut base = ExperimentConfig::tiny();
        base.rounds = 6;
        base.eval_every = 3;
        let mut grid = SweepGrid::new(base)
            .scheme(rcfed_scheme())
            .channel(ChannelSpec::ideal())
            .loss_axis(&[0.3])
            .deadline_axis(1e6, 0.5, &[2e-3]);
        grid.threads = 1;
        run_sweep(&grid).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.cells.len(), 3);
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.channel, y.channel);
        assert_eq!(x.report.total_bits, y.report.total_bits);
        assert_eq!(x.report.final_accuracy, y.report.final_accuracy);
        assert_eq!(x.report.channel, y.report.channel, "survivor replay");
        let bits_x: Vec<u64> =
            x.report.metrics.rounds.iter().map(|r| r.bits_up).collect();
        let bits_y: Vec<u64> =
            y.report.metrics.rounds.iter().map(|r| r.bits_up).collect();
        assert_eq!(bits_x, bits_y, "per-round ledger replay");
    }
    // the loss cell lost packets, the deadline cell straggled some
    assert!(a.cells[1].report.channel.lost > 0);
    assert_eq!(a.cells[0].report.channel.faults(), 0);
}
