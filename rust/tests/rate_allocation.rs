//! Heterogeneity-aware per-client rate allocation: acceptance tests.
//!
//! The bar set by the allocation issue:
//!
//! * `RateAllocation::Uniform` (the default) is byte-identical to the
//!   pre-allocator pipeline on the tiny config — same per-round bits,
//!   same accuracy, no downlink, no extra columns (the committed golden
//!   snapshot in `tests/golden_e2e.rs` pins the same property against
//!   absolute values);
//! * a `WaterFill` run under a heterogeneous `ChannelSpec` achieves
//!   strictly lower aggregate distortion than `Uniform` while spending
//!   no more measured uplink bits: the budget buys the energetic
//!   clients wide codebooks and parks the quiescent ones on cheap
//!   narrow ones.

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::coordinator::network::{ChannelSpec, SimulatedNetwork};
use rcfed::fl::compression::{
    designed_codebook, CompressionPipeline, CompressionScheme,
    RateAllocation, RateTarget, RoundAdaptation, TransformCfg, WireCoder,
};
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn rcfed() -> CompressionScheme {
    CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    }
}

/// Deterministic per-(client, round) gradient with client-specific
/// energy — the heterogeneity the allocator exploits.
fn client_grad(client: usize, round: usize, sigma: f32, d: usize) -> Vec<f32> {
    let mut g = vec![0f32; d];
    let seed = 7_000 + 31 * client as u64 + 977 * round as u64;
    Rng::new(seed).fill_normal_f32(&mut g, 0.0, sigma);
    g
}

/// Compress + decode every client once; returns (total uplink bits,
/// aggregate squared reconstruction error).
fn run_round(
    pipe: &mut CompressionPipeline,
    sigmas: &[f32],
    round: usize,
    d: usize,
) -> (u64, f64) {
    let mut rng = Rng::new(55);
    let mut bits = 0u64;
    let mut dist = 0f64;
    for (c, &sigma) in sigmas.iter().enumerate() {
        let g = client_grad(c, round, sigma, d);
        let pkt = pipe.compress(c as u32, round as u32, &g, &mut rng).unwrap();
        bits += pkt.total_bits();
        let mut acc = vec![0f32; d];
        pipe.decompress_accumulate(&pkt, &mut acc).unwrap();
        dist += g
            .iter()
            .zip(&acc)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>();
        pipe.observe_delivery(&pkt, &[]);
    }
    (bits, dist)
}

#[test]
fn waterfill_beats_uniform_distortion_at_no_more_bits() {
    let d = 16_384usize;
    // strongly heterogeneous gradient energies across 8 clients
    let sigmas: [f32; 8] = [0.01, 0.02, 0.05, 0.1, 0.3, 0.6, 1.2, 2.5];

    // heterogeneous channel: per-client bandwidth factors drawn by the
    // deterministic channel model
    let spec = ChannelSpec {
        uplink_bps: 1e6,
        bandwidth_spread: 0.4,
        ..ChannelSpec::ideal()
    };
    let network = SimulatedNetwork::with_spec(sigmas.len(), spec, 17);
    let factors: Vec<f64> = (0..sigmas.len())
        .map(|c| network.client_bandwidth_factor(c))
        .collect();

    // the budget: slightly under the uniform b=3 design rate, so the
    // water-filled assignment is constrained to *no more* encoded bits
    // than the shared-codebook baseline spends
    let (_, rep) = designed_codebook(rcfed()).unwrap();
    let budget = 0.97 * rep.huffman_rate;

    let mut uniform = CompressionPipeline::design(
        rcfed(), WireCoder::Huffman, RateTarget::Off)
    .unwrap();
    let mut wf = CompressionPipeline::design_alloc(
        rcfed(),
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::WaterFill {
            budget_bpc: budget,
            adapt_every: 1,
            min_bits: 1,
            max_bits: 6,
        },
    )
    .unwrap();
    wf.bind_clients(sigmas.len(), &factors).unwrap();

    // window 1: both pipelines see identical gradients; the allocator
    // observes the per-client energies and re-solves at the window end
    run_round(&mut uniform, &sigmas, 0, d);
    run_round(&mut wf, &sigmas, 0, d);
    assert_eq!(uniform.end_round(0).unwrap(), RoundAdaptation::None);
    match wf.end_round(0).unwrap() {
        RoundAdaptation::PerClient { publications } => {
            assert!(!publications.is_empty(), "allocation never moved");
        }
        other => panic!("expected per-client publications, got {other:?}"),
    }
    // energy-aware assignment: the most energetic client out-bids the
    // most quiescent one
    let w_lo = wf.client_width(0).unwrap();
    let w_hi = wf.client_width(sigmas.len() - 1).unwrap();
    assert!(w_hi > w_lo, "widths {w_lo} vs {w_hi}");

    // window 2 is the measurement: same gradients through both
    let (uni_bits, uni_dist) = run_round(&mut uniform, &sigmas, 1, d);
    let (wf_bits, wf_dist) = run_round(&mut wf, &sigmas, 1, d);
    assert!(
        wf_bits <= uni_bits,
        "water-filling exceeded the uniform spend: {wf_bits} vs {uni_bits}"
    );
    assert!(
        wf_dist < 0.5 * uni_dist,
        "no distortion win at equal bits: wf {wf_dist} vs uniform {uni_dist}"
    );
}

#[test]
fn uniform_allocation_replays_the_tiny_config_bit_for_bit() {
    // run level: the default (no alloc field touched) and an explicit
    // Uniform produce identical ledgers and metrics, and neither pays
    // downlink — the committed golden snapshot pins the same trajectory
    // against absolute values
    let base = ExperimentConfig::tiny();
    assert_eq!(base.alloc, RateAllocation::Uniform);
    let a = run_experiment(&base).unwrap();
    let mut explicit = base.clone();
    explicit.alloc = RateAllocation::Uniform;
    let b = run_experiment(&explicit).unwrap();
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.downlink_bits, 0);
    assert_eq!(b.downlink_bits, 0);
    for (ra, rb) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
        assert_eq!(ra.bits_up, rb.bits_up);
    }
    assert!(a.metrics.alloc_trace().is_empty());
    assert!(a.alloc_hist.is_empty());
}

#[test]
fn waterfill_experiment_end_to_end_under_heterogeneous_channel() {
    // the full round loop: allocation bound to the channel's bandwidth
    // factors, per-client publications charged to the downlink ledger,
    // deterministic replay
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 10;
    cfg.eval_every = 5;
    cfg.channel = ChannelSpec {
        uplink_bps: 1e6,
        bandwidth_spread: 0.5,
        ..ChannelSpec::ideal()
    };
    cfg.alloc = RateAllocation::WaterFill {
        budget_bpc: 2.4,
        adapt_every: 2,
        min_bits: 1,
        max_bits: 6,
    };
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.downlink_bits, b.downlink_bits);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.metrics.alloc_trace().len(), cfg.rounds);
    let covered: usize = a.alloc_hist.iter().map(|&(_, n)| n).sum();
    assert_eq!(covered, cfg.dataset.num_clients);
    // the run still learns through per-client codebooks
    assert!(a.final_accuracy > 0.3, "acc collapsed: {}", a.final_accuracy);
    assert_eq!(a.total_comm_bits(), a.total_bits + a.downlink_bits);
}

#[test]
fn waterfill_respects_the_budget_and_bandwidth_priors() {
    let mut pipe = CompressionPipeline::design_alloc(
        CompressionScheme::Lloyd { bits: 3 },
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::WaterFill {
            budget_bpc: 3.0,
            adapt_every: 1,
            min_bits: 1,
            max_bits: 6,
        },
    )
    .unwrap();
    // strongly heterogeneous bandwidths, flat energies: the initial
    // allocation must already skew toward the fast clients
    pipe.bind_clients(4, &[0.2, 0.2, 1.0, 2.6]).unwrap();
    let w: Vec<u32> = (0..4).map(|c| pipe.client_width(c).unwrap()).collect();
    assert!(w[3] >= w[2] && w[2] >= w[0], "{w:?}");
    assert!(w[3] > w[0], "bandwidth prior ignored: {w:?}");
    // the mean *encoded design rate* of the assignment stays within the
    // budget
    let rate_of = |width: u32| {
        let (_, rep) =
            designed_codebook(CompressionScheme::Lloyd { bits: width })
                .unwrap();
        rep.huffman_rate
    };
    let mean_rate: f64 =
        w.iter().map(|&b| rate_of(b)).sum::<f64>() / w.len() as f64;
    assert!(
        mean_rate <= 3.0 + 1e-9,
        "assignment {w:?} breaks the budget: {mean_rate}"
    );
}

#[test]
fn allocated_topk_packets_roundtrip_with_version_and_indices() {
    let mut pipe = CompressionPipeline::design_full(
        rcfed(),
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::WaterFill {
            budget_bpc: 2.5,
            adapt_every: 1,
            min_bits: 1,
            max_bits: 6,
        },
        TransformCfg::topk(0.2),
    )
    .unwrap();
    pipe.bind_clients(2, &[1.0, 1.0]).unwrap();
    let d = 2000;
    let mut g = vec![0f32; d];
    Rng::new(95).fill_normal_f32(&mut g, 0.0, 1.0);
    let mut rng = Rng::new(96);
    let pkt = pipe.compress(0, 0, &g, &mut rng).unwrap();
    assert_eq!(pkt.side_info.len(), 3, "version word missing");
    assert!(pkt.index_bits > 0, "index bits not charged");
    let mut acc = vec![0f32; d];
    pipe.decompress_accumulate(&pkt, &mut acc).unwrap();
    let nonzero = acc.iter().filter(|&&x| x != 0.0).count();
    assert!(nonzero <= 400, "sparse decode touched {nonzero} coords");
    // sparse packets honor the stale-version rejection too
    let mut forged = pkt.clone();
    forged.side_info[2] = 7.0;
    assert!(pipe.decompress_accumulate(&forged, &mut acc).is_err());
}

#[test]
fn allocation_validation() {
    let waterfill = |budget: f64| RateAllocation::WaterFill {
        budget_bpc: budget,
        adapt_every: 1,
        min_bits: 1,
        max_bits: 6,
    };
    let rc = rcfed();
    let off = RateTarget::Off;
    assert!(RateAllocation::Uniform.validate(&rc, &off).is_ok());
    assert!(waterfill(2.5).validate(&rc, &off).is_ok());
    assert!(waterfill(2.5)
        .validate(&CompressionScheme::Lloyd { bits: 3 }, &off)
        .is_ok());
    // QSGD/Fp32 have no designed codebook to allocate
    assert!(waterfill(2.5)
        .validate(&CompressionScheme::Qsgd { bits: 3 }, &off)
        .is_err());
    assert!(waterfill(2.5).validate(&CompressionScheme::Fp32, &off).is_err());
    // both controllers at once is a config error
    let track = RateTarget::Track { bits_per_coord: 2.0, adapt_every: 2 };
    assert!(waterfill(2.5).validate(&rc, &track).is_err());
    assert!(RateAllocation::Uniform.validate(&rc, &track).is_ok());
    // nonsense budgets / ranges
    assert!(waterfill(0.0).validate(&rc, &off).is_err());
    assert!(waterfill(f64::NAN).validate(&rc, &off).is_err());
    let bad_range = RateAllocation::WaterFill {
        budget_bpc: 2.0,
        adapt_every: 1,
        min_bits: 4,
        max_bits: 3,
    };
    assert!(bad_range.validate(&rc, &off).is_err());
    // a budget below the min-width encoded rate passes validate but is
    // rejected at design time
    let starved = RateAllocation::WaterFill {
        budget_bpc: 0.5,
        adapt_every: 1,
        min_bits: 2,
        max_bits: 4,
    };
    assert!(starved.validate(&rc, &off).is_ok());
    assert!(CompressionPipeline::design_alloc(
        rc,
        WireCoder::Huffman,
        off,
        starved
    )
    .is_err());
    assert_eq!(RateAllocation::Uniform.label(), "uniform");
    assert_eq!(waterfill(2.5).label(), "wf2.5w1b1-6");
}
