//! Heterogeneity-aware per-client rate allocation: acceptance tests.
//!
//! The bar set by the allocation issue:
//!
//! * `RateAllocation::Uniform` (the default) is byte-identical to the
//!   pre-allocator pipeline on the tiny config — same per-round bits,
//!   same accuracy, no downlink, no extra columns (the committed golden
//!   snapshot in `tests/golden_e2e.rs` pins the same property against
//!   absolute values);
//! * a `WaterFill` run under a heterogeneous `ChannelSpec` achieves
//!   strictly lower aggregate distortion than `Uniform` while spending
//!   no more measured uplink bits: the budget buys the energetic
//!   clients wide codebooks and parks the quiescent ones on cheap
//!   narrow ones.

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::coordinator::network::{ChannelSpec, SimulatedNetwork};
use rcfed::fl::compression::{
    designed_codebook, CompressionPipeline, CompressionScheme,
    RateAllocation, RateTarget, RoundAdaptation, WireCoder,
};
use rcfed::quant::rcq::LengthModel;
use rcfed::util::rng::Rng;

fn rcfed() -> CompressionScheme {
    CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    }
}

/// Deterministic per-(client, round) gradient with client-specific
/// energy — the heterogeneity the allocator exploits.
fn client_grad(client: usize, round: usize, sigma: f32, d: usize) -> Vec<f32> {
    let mut g = vec![0f32; d];
    let seed = 7_000 + 31 * client as u64 + 977 * round as u64;
    Rng::new(seed).fill_normal_f32(&mut g, 0.0, sigma);
    g
}

/// Compress + decode every client once; returns (total uplink bits,
/// aggregate squared reconstruction error).
fn run_round(
    pipe: &mut CompressionPipeline,
    sigmas: &[f32],
    round: usize,
    d: usize,
) -> (u64, f64) {
    let mut rng = Rng::new(55);
    let mut bits = 0u64;
    let mut dist = 0f64;
    for (c, &sigma) in sigmas.iter().enumerate() {
        let g = client_grad(c, round, sigma, d);
        let pkt = pipe.compress(c as u32, round as u32, &g, &mut rng).unwrap();
        bits += pkt.total_bits();
        let mut acc = vec![0f32; d];
        pipe.decompress_accumulate(&pkt, &mut acc).unwrap();
        dist += g
            .iter()
            .zip(&acc)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>();
        pipe.observe_delivery(&pkt, &[]);
    }
    (bits, dist)
}

#[test]
fn waterfill_beats_uniform_distortion_at_no_more_bits() {
    let d = 16_384usize;
    // strongly heterogeneous gradient energies across 8 clients
    let sigmas: [f32; 8] = [0.01, 0.02, 0.05, 0.1, 0.3, 0.6, 1.2, 2.5];

    // heterogeneous channel: per-client bandwidth factors drawn by the
    // deterministic channel model
    let spec = ChannelSpec {
        uplink_bps: 1e6,
        bandwidth_spread: 0.4,
        ..ChannelSpec::ideal()
    };
    let network = SimulatedNetwork::with_spec(sigmas.len(), spec, 17);
    let factors: Vec<f64> = (0..sigmas.len())
        .map(|c| network.client_bandwidth_factor(c))
        .collect();

    // the budget: slightly under the uniform b=3 design rate, so the
    // water-filled assignment is constrained to *no more* encoded bits
    // than the shared-codebook baseline spends
    let (_, rep) = designed_codebook(rcfed()).unwrap();
    let budget = 0.97 * rep.huffman_rate;

    let mut uniform = CompressionPipeline::design(
        rcfed(), WireCoder::Huffman, RateTarget::Off)
    .unwrap();
    let mut wf = CompressionPipeline::design_alloc(
        rcfed(),
        WireCoder::Huffman,
        RateTarget::Off,
        RateAllocation::WaterFill {
            budget_bpc: budget,
            adapt_every: 1,
            min_bits: 1,
            max_bits: 6,
        },
    )
    .unwrap();
    wf.bind_clients(sigmas.len(), &factors).unwrap();

    // window 1: both pipelines see identical gradients; the allocator
    // observes the per-client energies and re-solves at the window end
    run_round(&mut uniform, &sigmas, 0, d);
    run_round(&mut wf, &sigmas, 0, d);
    assert_eq!(uniform.end_round(0).unwrap(), RoundAdaptation::None);
    match wf.end_round(0).unwrap() {
        RoundAdaptation::PerClient { publications } => {
            assert!(!publications.is_empty(), "allocation never moved");
        }
        other => panic!("expected per-client publications, got {other:?}"),
    }
    // energy-aware assignment: the most energetic client out-bids the
    // most quiescent one
    let w_lo = wf.client_width(0).unwrap();
    let w_hi = wf.client_width(sigmas.len() - 1).unwrap();
    assert!(w_hi > w_lo, "widths {w_lo} vs {w_hi}");

    // window 2 is the measurement: same gradients through both
    let (uni_bits, uni_dist) = run_round(&mut uniform, &sigmas, 1, d);
    let (wf_bits, wf_dist) = run_round(&mut wf, &sigmas, 1, d);
    assert!(
        wf_bits <= uni_bits,
        "water-filling exceeded the uniform spend: {wf_bits} vs {uni_bits}"
    );
    assert!(
        wf_dist < 0.5 * uni_dist,
        "no distortion win at equal bits: wf {wf_dist} vs uniform {uni_dist}"
    );
}

#[test]
fn uniform_allocation_replays_the_tiny_config_bit_for_bit() {
    // run level: the default (no alloc field touched) and an explicit
    // Uniform produce identical ledgers and metrics, and neither pays
    // downlink — the committed golden snapshot pins the same trajectory
    // against absolute values
    let base = ExperimentConfig::tiny();
    assert_eq!(base.alloc, RateAllocation::Uniform);
    let a = run_experiment(&base).unwrap();
    let mut explicit = base.clone();
    explicit.alloc = RateAllocation::Uniform;
    let b = run_experiment(&explicit).unwrap();
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.downlink_bits, 0);
    assert_eq!(b.downlink_bits, 0);
    for (ra, rb) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
        assert_eq!(ra.bits_up, rb.bits_up);
    }
    assert!(a.metrics.alloc_trace().is_empty());
    assert!(a.alloc_hist.is_empty());
}

#[test]
fn waterfill_experiment_end_to_end_under_heterogeneous_channel() {
    // the full round loop: allocation bound to the channel's bandwidth
    // factors, per-client publications charged to the downlink ledger,
    // deterministic replay
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 10;
    cfg.eval_every = 5;
    cfg.channel = ChannelSpec {
        uplink_bps: 1e6,
        bandwidth_spread: 0.5,
        ..ChannelSpec::ideal()
    };
    cfg.alloc = RateAllocation::WaterFill {
        budget_bpc: 2.4,
        adapt_every: 2,
        min_bits: 1,
        max_bits: 6,
    };
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.total_bits, b.total_bits);
    assert_eq!(a.downlink_bits, b.downlink_bits);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.metrics.alloc_trace().len(), cfg.rounds);
    let covered: usize = a.alloc_hist.iter().map(|&(_, n)| n).sum();
    assert_eq!(covered, cfg.dataset.num_clients);
    // the run still learns through per-client codebooks
    assert!(a.final_accuracy > 0.3, "acc collapsed: {}", a.final_accuracy);
    assert_eq!(a.total_comm_bits(), a.total_bits + a.downlink_bits);
}
