//! L3 model-compute micro-bench: blocked forward/grad kernels and the
//! fused sgd step vs their scalar reference twins, at the three
//! manifest shapes (`tiny`, `synth_femnist`, `synth_cifar`).
//!
//! Throughput unit is **Mcoord/s** where a "coordinate" is one
//! weight-MAC of the forward pass (`batch · Σ_l dims[l]·dims[l+1]`) —
//! the same unit for forward and grad so the grad rows honestly show
//! the backward pass costing ~3× a forward at the same rate. The
//! `speedup` column is fast-kernel throughput over the reference-twin
//! throughput at the identical accumulation tree; CI floors the grad
//! rows at tiny/femnist (see `.github/workflows/ci.yml`).
//!
//!     cargo bench --bench model_throughput
//!
//! Rep counts are auto-scaled so every closure does a comparable amount
//! of work regardless of shape; there is no `RCFED_BENCH_N` knob — the
//! shapes themselves are the size axis and the defaults are already
//! smoke-sized.

use rcfed::csv_row;
use rcfed::model::kernels;
use rcfed::model::native::NativeMlp;
use rcfed::model::{Backend, ModelScratch};
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;
use rcfed::util::timer::{bench, report};

/// Weight-MACs of one forward pass at batch size `batch`.
fn coords_per_pass(dims: &[usize], batch: usize) -> usize {
    batch
        * dims
            .windows(2)
            .map(|w| w[0] * w[1])
            .sum::<usize>()
}

/// Inner-loop repetitions targeting ~8M coords of work per timed
/// closure, so tiny shapes are not dominated by call overhead and cifar
/// reference rows stay smoke-sized.
fn reps_for(work: usize) -> usize {
    (8_000_000 / work.max(1)).clamp(1, 512)
}

/// Standalone forward pass through the public kernels (fast or
/// reference twin) — the bench-local equivalent of the model's private
/// `forward_into`, so the forward rows isolate matvec throughput from
/// the argmax/loss tails of `eval`/`grad`.
fn forward(
    m: &NativeMlp,
    params: &[f32],
    xs: &[f32],
    batch: usize,
    acts: &mut [Vec<f32>],
    reference: bool,
) {
    let nl = m.dims.len() - 1;
    let mut off = 0;
    for l in 0..nl {
        let (i, o) = (m.dims[l], m.dims[l + 1]);
        let w = &params[off..off + i * o];
        let b = &params[off + i * o..off + i * o + o];
        off += i * o + o;
        let (prev, rest) = acts.split_at_mut(l);
        let h_in: &[f32] = if l == 0 { xs } else { &prev[l - 1] };
        let h = &mut rest[0];
        h.resize(batch * o, 0.0);
        if reference {
            kernels::matvec_bias_reference(w, b, h_in, batch, i, o, h);
        } else {
            kernels::matvec_bias(w, b, h_in, batch, i, o, h);
        }
        if l < nl - 1 {
            for x in h.iter_mut() {
                if *x < 0.0 {
                    *x = 0.0;
                }
            }
        }
    }
}

fn main() {
    let shapes: [(&str, NativeMlp); 3] = [
        ("tiny", NativeMlp::tiny()),
        ("femnist", NativeMlp::synth_femnist()),
        ("cifar", NativeMlp::synth_cifar()),
    ];
    let mut w = CsvWriter::create(
        "results/model.csv",
        &["op", "shape", "mcoord_per_s", "examples_per_s", "speedup"],
    )
    .unwrap();

    println!("== model-compute throughput (single thread) ==");
    let mut rng = Rng::new(42);
    for (name, m) in &shapes {
        let batch = m.batch_size();
        let d = m.num_params();
        let classes = *m.dims.last().unwrap();
        let params = m.init_params(7);
        let mut xs = vec![0f32; batch * m.dims[0]];
        rng.fill_normal_f32(&mut xs, 0.0, 1.0);
        let ys: Vec<i32> =
            (0..batch).map(|k| (k % classes) as i32).collect();
        let coords = coords_per_pass(&m.dims, batch);
        let reps = reps_for(coords);
        let work = (reps * coords) as f64;
        let ex = (reps * batch) as f64;
        println!(
            "-- {name}: dims {:?}, batch {batch}, d {d}, {reps} reps/iter",
            m.dims
        );

        // forward: matvec chain only, fast vs reference twin
        let mut acts: Vec<Vec<f32>> =
            vec![Vec::new(); m.dims.len() - 1];
        let mut fwd = |reference: bool| {
            bench(1, 5, || {
                for _ in 0..reps {
                    forward(m, &params, &xs, batch, &mut acts, reference);
                    std::hint::black_box(acts.last().unwrap().as_slice());
                }
            })
        };
        let f_fast = fwd(false);
        let f_ref = fwd(true);
        let tput = work / f_fast.median() / 1e6;
        let tput_ref = work / f_ref.median() / 1e6;
        let speedup = tput / tput_ref.max(1e-12);
        report(&format!("forward/{name}"), &f_fast, work);
        report(&format!("forward_reference/{name}"), &f_ref, work);
        println!("   forward speedup {speedup:.2}x");
        csv_row!(w, "forward", *name, tput, ex / f_fast.median(), speedup)
            .unwrap();
        csv_row!(
            w,
            "forward_reference",
            *name,
            tput_ref,
            ex / f_ref.median(),
            1.0f64
        )
        .unwrap();

        // grad: full forward+backward through the Backend entry points
        let mut grad_out = vec![0f32; d];
        let mut scratch = ModelScratch::new();
        let g_fast = bench(1, 5, || {
            for _ in 0..reps {
                let loss = m
                    .grad_with(&params, &xs, &ys, &mut grad_out, &mut scratch)
                    .unwrap();
                std::hint::black_box(loss);
            }
        });
        let g_ref = bench(1, 5, || {
            for _ in 0..reps {
                let loss = m
                    .grad_reference(
                        &params, &xs, &ys, &mut grad_out, &mut scratch,
                    )
                    .unwrap();
                std::hint::black_box(loss);
            }
        });
        let tput = work / g_fast.median() / 1e6;
        let tput_ref = work / g_ref.median() / 1e6;
        let speedup = tput / tput_ref.max(1e-12);
        report(&format!("grad/{name}"), &g_fast, work);
        report(&format!("grad_reference/{name}"), &g_ref, work);
        println!("   grad speedup {speedup:.2}x");
        csv_row!(w, "grad", *name, tput, ex / g_fast.median(), speedup)
            .unwrap();
        csv_row!(
            w,
            "grad_reference",
            *name,
            tput_ref,
            ex / g_ref.median(),
            1.0f64
        )
        .unwrap();

        // sgd_step over the flat parameter vector: coords here are
        // parameter updates, examples_per_s counts whole steps
        let mut p = params.clone();
        let sgd_reps = reps_for(d);
        let sgd_work = (sgd_reps * d) as f64;
        let s_fast = bench(1, 5, || {
            for _ in 0..sgd_reps {
                kernels::sgd_step(&mut p, &grad_out, 1e-7);
            }
            std::hint::black_box(p.as_slice());
        });
        let s_ref = bench(1, 5, || {
            for _ in 0..sgd_reps {
                kernels::sgd_step_reference(&mut p, &grad_out, 1e-7);
            }
            std::hint::black_box(p.as_slice());
        });
        let tput = sgd_work / s_fast.median() / 1e6;
        let tput_ref = sgd_work / s_ref.median() / 1e6;
        let speedup = tput / tput_ref.max(1e-12);
        report(&format!("sgd_step/{name}"), &s_fast, sgd_work);
        report(&format!("sgd_step_reference/{name}"), &s_ref, sgd_work);
        println!("   sgd_step speedup {speedup:.2}x");
        csv_row!(
            w,
            "sgd_step",
            *name,
            tput,
            sgd_reps as f64 / s_fast.median(),
            speedup
        )
        .unwrap();
        csv_row!(
            w,
            "sgd_step_reference",
            *name,
            tput_ref,
            sgd_reps as f64 / s_ref.median(),
            1.0f64
        )
        .unwrap();
    }
    w.flush().unwrap();
    println!("wrote results/model.csv");
}
