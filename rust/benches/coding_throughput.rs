//! E6 — entropy-coder bench: (i) rate vs the Shannon bound `H(Q(Z))`
//! (the premise of §2's "Source-encoded Transmission"), (ii) encode /
//! decode throughput of the wire coders on realistic quantized-gradient
//! symbol streams, including the block-coding speed tier and its
//! speedup over the baseline Huffman coder.
//!
//!     cargo bench --bench coding_throughput
//!
//! Symbols are one byte each, so Msym/s and MB/s coincide; the CSV
//! carries both names for downstream plots.

use rcfed::coding::arithmetic::ArithmeticCoder;
use rcfed::coding::block::BlockCoder;
use rcfed::coding::huffman::HuffmanCode;
use rcfed::coding::lz::Lzw;
use rcfed::coding::EntropyCoder;
use rcfed::csv_row;
use rcfed::fl::compression::{designed_codebook, CompressionScheme};
use rcfed::quant::rcq::LengthModel;
use rcfed::stats::entropy::entropy_bits;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;
use rcfed::util::timer::{bench, report};

fn symbol_stream(bits: u32, lambda: f64, n: usize, seed: u64) -> (Vec<u8>, Vec<f64>) {
    // realistic stream: quantize N(0,1) "gradients" with the RC codebook
    // (design served from the process-wide cache)
    let (cb, rep) = designed_codebook(CompressionScheme::RcFed {
        bits,
        lambda,
        length_model: LengthModel::Huffman,
    })
    .unwrap();
    let mut rng = Rng::new(seed);
    let mut g = vec![0f32; n];
    rng.fill_normal_f32(&mut g, 0.0, 1.0);
    let mut sym = Vec::new();
    cb.quantize_normalized(&g, 0.0, 1.0, &mut sym);
    (sym, rep.probs)
}

fn main() {
    let n: usize = std::env::var("RCFED_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let mut w = CsvWriter::create(
        "results/coding.csv",
        &["coder", "bits", "lambda", "bits_per_sym", "entropy",
          "enc_msyms_per_s", "dec_msyms_per_s", "enc_mbytes_per_s",
          "dec_mbytes_per_s", "speedup_vs_huffman"],
    )
    .unwrap();

    println!("=== E6: entropy coders on quantized gradient streams ===\n");
    // 3/6-bit grids match E1–E5; the 8-bit row is the block tier's
    // acceptance stream (256-cell alphabet, worst-case table refresh)
    for (bits, lambda) in [(3u32, 0.05), (6, 0.05), (8, 0.05)] {
        let (sym, probs) = symbol_stream(bits, lambda, n, 7);
        let h = entropy_bits(&probs);
        println!("-- b={bits} λ={lambda} H(Q(Z))={h:.4} bits/sym --");

        let huff = HuffmanCode::from_probs(&probs).unwrap();
        let arith = ArithmeticCoder::from_probs(&probs).unwrap();
        let lzw = Lzw;
        let block = BlockCoder::new(probs.len()).unwrap();

        // ledger-honesty check on the bench stream itself: the block
        // coder's self-framing payload is exactly what it claims, and it
        // never costs more than the baseline plus its table refreshes
        let huff_bits = huff.message_bits(&sym);
        let (block_payload, block_bits) = block.encode_counted(&sym).unwrap();
        assert_eq!(
            block_bits,
            block.message_bits(&sym).unwrap(),
            "block message_bits drifted from the encoded length"
        );
        assert_eq!(block_payload.len() as u64, block_bits.div_ceil(8));
        let refreshes =
            (n as u64).div_ceil(block.block_len() as u64) * block.table_bits();
        assert!(
            block_bits <= huff_bits + refreshes,
            "block tier spent {block_bits} bits > huffman {huff_bits} + \
             {refreshes} table overhead"
        );

        let coders: Vec<(&str, &dyn EntropyCoder)> = vec![
            ("huffman", &huff),
            ("arithmetic", &arith),
            ("lzw", &lzw),
            ("block", &block),
        ];
        let mut huff_enc = f64::NAN;
        let mut huff_dec = f64::NAN;
        for (name, coder) in coders {
            let payload = coder.encode(&sym).unwrap();
            let bps = payload.len() as f64 * 8.0 / n as f64;
            let enc_stats = bench(1, 5, || {
                std::hint::black_box(coder.encode(&sym).unwrap());
            });
            let dec_stats = bench(1, 5, || {
                std::hint::black_box(coder.decode(&payload, n).unwrap());
            });
            let enc_tput = n as f64 / enc_stats.median() / 1e6;
            let dec_tput = n as f64 / dec_stats.median() / 1e6;
            if name == "huffman" {
                huff_enc = enc_tput;
                huff_dec = dec_tput;
            }
            // one symbol = one byte, so MB/s tracks Msym/s exactly
            let speedup = if huff_enc.is_finite() && huff_dec.is_finite() {
                (enc_tput + dec_tput) / (huff_enc + huff_dec)
            } else {
                f64::NAN
            };
            println!(
                "  {name:<11} {bps:.4} bits/sym (H+{:+.4})  enc {enc_tput:8.1} \
                 MB/s  dec {dec_tput:8.1} MB/s  ({speedup:.2}x huffman)",
                bps - h
            );
            csv_row!(w, name, bits as usize, lambda, bps, h, enc_tput,
                     dec_tput, enc_tput, dec_tput, speedup)
                .unwrap();
            report(
                &format!("{name}_b{bits}_encode"),
                &enc_stats,
                n as f64,
            );
            report(
                &format!("{name}_b{bits}_decode"),
                &dec_stats,
                n as f64,
            );
        }
        println!();
    }
    w.flush().unwrap();
    println!("expected shape: arithmetic ≈ H, huffman ∈ [H, H+1), LZW \
              between; the block tier trades ≤ table_bits/block_len \
              bits/sym of rate for the largest enc+dec throughput.\n\
              wrote results/coding.csv");
}
