//! L3 hot-path micro-bench: quantizer apply (normalize→bucketize) and
//! dequantize-accumulate throughput, plus design-time cost of every
//! scheme. The apply path is the per-coordinate work Fig. 1 multiplies
//! by d·K·T — §Perf target ≥ 500 Mcoord/s/core for b ≤ 4.
//!
//!     cargo bench --bench quantizer_throughput

use rcfed::csv_row;
use rcfed::fl::compression::{
    design_cache_stats, designed_codebook, CompressionScheme,
};
use rcfed::quant::lloyd::LloydMax;
use rcfed::quant::nqfl::nqfl_codebook;
use rcfed::quant::qsgd::Qsgd;
use rcfed::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use rcfed::stats::gaussian::StdGaussian;
use rcfed::stats::moments::mean_std;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;
use rcfed::util::timer::{bench, report, Timer};

fn main() {
    let n = 4_000_000usize;
    let mut rng = Rng::new(3);
    let mut g = vec![0f32; n];
    rng.fill_normal_f32(&mut g, 0.01, 0.002);
    let (mu, sigma) = mean_std(&g);
    let mut w = CsvWriter::create(
        "results/quantizer_throughput.csv",
        &["op", "bits", "mcoord_per_s"],
    )
    .unwrap();

    println!("=== quantizer hot-path throughput (d = {n}) ===\n");
    for bits in [2u32, 3, 4, 6] {
        // cache-served design (the apply path is what's being measured)
        let (cb, _) =
            designed_codebook(CompressionScheme::Lloyd { bits }).unwrap();
        let mut sym = Vec::with_capacity(n);
        let stats = bench(1, 5, || {
            cb.quantize_normalized(&g, mu, sigma, &mut sym);
            std::hint::black_box(&sym);
        });
        let tput = n as f64 / stats.median() / 1e6;
        report(&format!("quantize_normalized_b{bits}"), &stats, n as f64);
        csv_row!(w, "quantize", bits as usize, tput).unwrap();

        let mut acc = vec![0f32; n];
        let stats = bench(1, 5, || {
            cb.dequantize_accumulate(&sym, mu, sigma, &mut acc);
            std::hint::black_box(&acc);
        });
        let tput = n as f64 / stats.median() / 1e6;
        report(&format!("dequantize_accumulate_b{bits}"), &stats, n as f64);
        csv_row!(w, "dequantize", bits as usize, tput).unwrap();
    }

    // QSGD stochastic encode
    let q = Qsgd::new(3);
    let mut qrng = Rng::new(9);
    let stats = bench(1, 3, || {
        std::hint::black_box(q.encode(&g, &mut qrng));
    });
    report("qsgd_encode_b3", &stats, n as f64);
    csv_row!(w, "qsgd_encode", 3usize, n as f64 / stats.median() / 1e6)
        .unwrap();

    // moments (two-pass) — the normalization statistics
    let stats = bench(1, 5, || {
        std::hint::black_box(mean_std(&g));
    });
    report("mean_std", &stats, n as f64);
    csv_row!(w, "mean_std", 0usize, n as f64 / stats.median() / 1e6).unwrap();

    // design-time cost (done once per training run — §3.1). Direct
    // designer calls give the honest uncached cost; the cached path
    // below shows what repeated sweep cells actually pay.
    println!("\ndesign-time cost (once per run):");
    for bits in [3u32, 6] {
        let t = Timer::start();
        let rc = RateConstrainedQuantizer {
            lambda: 0.05,
            length_model: LengthModel::Huffman,
            ..Default::default()
        };
        let (_, rep) = rc.design(&StdGaussian, bits).unwrap();
        println!(
            "  rcfed  b={bits}: {:>8.2} ms ({} iters)",
            t.secs() * 1e3, rep.iterations
        );
        let t = Timer::start();
        LloydMax::default().design(&StdGaussian, bits).unwrap();
        println!("  lloyd  b={bits}: {:>8.2} ms", t.secs() * 1e3);
        let t = Timer::start();
        nqfl_codebook(bits).unwrap();
        println!("  nqfl   b={bits}: {:>8.2} ms", t.secs() * 1e3);
    }

    // cached design cost: the second lookup of the same operating point
    // is a hashmap hit, not a Lloyd/RC alternation
    println!("\ncached design cost (sweep steady state):");
    let scheme = CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    };
    designed_codebook(scheme).unwrap(); // warm the key
    let before = design_cache_stats();
    let t = Timer::start();
    designed_codebook(scheme).unwrap();
    let cached_ms = t.secs() * 1e3;
    let cache = design_cache_stats().since(&before);
    println!(
        "  rcfed  b=3 λ=0.05: {cached_ms:>8.4} ms ({} hit(s))",
        cache.hits
    );

    w.flush().unwrap();
    println!("\nwrote results/quantizer_throughput.csv");
}
