//! L3 hot-path micro-bench: quantizer apply (normalize→bucketize) and
//! dequantize-accumulate throughput, plus design-time cost of every
//! scheme. The apply path is the per-coordinate work Fig. 1 multiplies
//! by d·K·T — §Perf target ≥ 500 Mcoord/s/core for b ≤ 4.
//!
//! The wide-alphabet (b ≥ 5) apply is additionally benchmarked against
//! the pre-speed-tier baseline that rebuilt the 2048-bin lookup table on
//! every call, at *packet scale* (small d), where the rebuild is not
//! amortized away — that before/after pair is the speed tier's headline
//! row (`apply_speedup_pkt`).
//!
//!     cargo bench --bench quantizer_throughput
//!
//! `RCFED_BENCH_N` scales the bulk-vector size (CI smoke uses a small
//! value; the 4M default is the paper-scale measurement).

use rcfed::csv_row;
use rcfed::fl::compression::{
    design_cache_stats, designed_codebook, CompressionScheme,
};
use rcfed::quant::codebook::{Codebook, SIGMA_FLOOR};
use rcfed::quant::lloyd::LloydMax;
use rcfed::quant::nqfl::nqfl_codebook;
use rcfed::quant::qsgd::Qsgd;
use rcfed::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use rcfed::stats::gaussian::StdGaussian;
use rcfed::stats::moments::mean_std;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;
use rcfed::util::timer::{bench, report, Timer};

/// Faithful reimplementation of the pre-speed-tier wide-alphabet apply:
/// normalize the boundaries into the raw domain, then rebuild the
/// 2048-bin lookup table **per call** before the per-coordinate loop.
/// Lives only in this bench — production code builds the table once at
/// design time ([`Codebook::new`]).
fn baseline_rebuild_apply(
    cb: &Codebook,
    g: &[f32],
    mu: f32,
    sigma: f32,
    out: &mut Vec<u8>,
) {
    const BINS: usize = 2048;
    let s = sigma.max(SIGMA_FLOOR);
    out.clear();
    out.resize(g.len(), 0);
    let raw: Vec<f32> = cb
        .bounds
        .iter()
        .map(|&u| (u as f64 * s as f64 + mu as f64) as f32)
        .collect();
    let n = raw.len();
    let lo = raw[0];
    let hi = raw[n - 1];
    let span = (hi - lo).max(f32::MIN_POSITIVE);
    let scale = BINS as f32 / span;
    let mut bins = Vec::with_capacity(BINS);
    for k in 0..BINS {
        let start = lo + k as f32 / scale;
        let end = lo + (k + 1) as f32 / scale;
        let min_c = raw.partition_point(|&u| u < start) as u8;
        let max_c = if k == BINS - 1 {
            n as u8
        } else {
            raw.partition_point(|&u| u < end) as u8
        };
        bins.push((min_c, max_c));
    }
    for (o, &x) in out.iter_mut().zip(g) {
        let k =
            (((x - lo) * scale) as i32).clamp(0, BINS as i32 - 1) as usize;
        let (min_c, max_c) = bins[k];
        let mut c = min_c;
        for j in min_c..max_c {
            c += (raw[j as usize] < x) as u8;
        }
        *o = c;
    }
}

fn main() {
    let n = std::env::var("RCFED_BENCH_N")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4_000_000)
        .max(1);
    let mut rng = Rng::new(3);
    let mut g = vec![0f32; n];
    rng.fill_normal_f32(&mut g, 0.01, 0.002);
    let (mu, sigma) = mean_std(&g);
    let mut w = CsvWriter::create(
        "results/quantizer_throughput.csv",
        &["op", "bits", "mcoord_per_s"],
    )
    .unwrap();

    println!("=== quantizer hot-path throughput (d = {n}) ===\n");
    for bits in [2u32, 3, 4, 5, 6, 8] {
        // cache-served design (the apply path is what's being measured)
        let (cb, _) =
            designed_codebook(CompressionScheme::Lloyd { bits }).unwrap();
        let mut sym = Vec::with_capacity(n);
        let stats = bench(1, 5, || {
            cb.quantize_normalized(&g, mu, sigma, &mut sym);
            std::hint::black_box(&sym);
        });
        let tput = n as f64 / stats.median() / 1e6;
        report(&format!("quantize_normalized_b{bits}"), &stats, n as f64);
        csv_row!(w, "quantize", bits as usize, tput).unwrap();

        let mut acc = vec![0f32; n];
        let stats = bench(1, 5, || {
            cb.dequantize_accumulate(&sym, mu, sigma, &mut acc);
            std::hint::black_box(&acc);
        });
        let tput = n as f64 / stats.median() / 1e6;
        report(&format!("dequantize_accumulate_b{bits}"), &stats, n as f64);
        csv_row!(w, "dequantize", bits as usize, tput).unwrap();
    }

    // design-time bin cache vs per-call rebuild, at packet scale: the
    // update vectors the round loop actually quantizes are small enough
    // that a per-call table rebuild (2048 partition-points + two
    // allocations) is a constant cost comparable to the coordinate loop
    // itself. The cached path must clear 2× here — the speed tier's
    // acceptance row.
    println!("\nwide-alphabet apply, cached bins vs per-call rebuild:");
    let d_pkt = 8192.min(n);
    let g_pkt = &g[..d_pkt];
    for bits in [5u32, 6, 8] {
        let (cb, _) =
            designed_codebook(CompressionScheme::Lloyd { bits }).unwrap();
        let mut sym = Vec::with_capacity(d_pkt);
        let stats = bench(2, 9, || {
            cb.quantize_normalized(g_pkt, mu, sigma, &mut sym);
            std::hint::black_box(&sym);
        });
        let cached = d_pkt as f64 / stats.median() / 1e6;
        report(&format!("apply_cached_pkt_b{bits}"), &stats, d_pkt as f64);
        csv_row!(w, "apply_cached_pkt", bits as usize, cached).unwrap();

        let stats = bench(2, 9, || {
            baseline_rebuild_apply(&cb, g_pkt, mu, sigma, &mut sym);
            std::hint::black_box(&sym);
        });
        let rebuild = d_pkt as f64 / stats.median() / 1e6;
        report(&format!("apply_rebuild_pkt_b{bits}"), &stats, d_pkt as f64);
        csv_row!(w, "apply_rebuild_pkt", bits as usize, rebuild).unwrap();

        let speedup = cached / rebuild.max(1e-12);
        println!(
            "  b={bits} d={d_pkt}: cached {cached:>8.1} vs rebuild \
             {rebuild:>8.1} Mcoord/s  ({speedup:.2}x)"
        );
        csv_row!(w, "apply_speedup_pkt", bits as usize, speedup).unwrap();
    }

    // QSGD stochastic encode
    let q = Qsgd::new(3);
    let mut qrng = Rng::new(9);
    let stats = bench(1, 3, || {
        std::hint::black_box(q.encode(&g, &mut qrng));
    });
    report("qsgd_encode_b3", &stats, n as f64);
    csv_row!(w, "qsgd_encode", 3usize, n as f64 / stats.median() / 1e6)
        .unwrap();

    // moments (two-pass) — the normalization statistics
    let stats = bench(1, 5, || {
        std::hint::black_box(mean_std(&g));
    });
    report("mean_std", &stats, n as f64);
    csv_row!(w, "mean_std", 0usize, n as f64 / stats.median() / 1e6).unwrap();

    // design-time cost (done once per training run — §3.1). Direct
    // designer calls give the honest uncached cost; the cached path
    // below shows what repeated sweep cells actually pay.
    println!("\ndesign-time cost (once per run):");
    for bits in [3u32, 6] {
        let t = Timer::start();
        let rc = RateConstrainedQuantizer {
            lambda: 0.05,
            length_model: LengthModel::Huffman,
            ..Default::default()
        };
        let (_, rep) = rc.design(&StdGaussian, bits).unwrap();
        println!(
            "  rcfed  b={bits}: {:>8.2} ms ({} iters)",
            t.secs() * 1e3, rep.iterations
        );
        let t = Timer::start();
        LloydMax::default().design(&StdGaussian, bits).unwrap();
        println!("  lloyd  b={bits}: {:>8.2} ms", t.secs() * 1e3);
        let t = Timer::start();
        nqfl_codebook(bits).unwrap();
        println!("  nqfl   b={bits}: {:>8.2} ms", t.secs() * 1e3);
    }

    // cached design cost: the second lookup of the same operating point
    // is a hashmap hit, not a Lloyd/RC alternation
    println!("\ncached design cost (sweep steady state):");
    let scheme = CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    };
    designed_codebook(scheme).unwrap(); // warm the key
    let before = design_cache_stats();
    let t = Timer::start();
    designed_codebook(scheme).unwrap();
    let cached_ms = t.secs() * 1e3;
    let cache = design_cache_stats().since(&before);
    println!(
        "  rcfed  b=3 λ=0.05: {cached_ms:>8.4} ms ({} hit(s))",
        cache.hits
    );

    w.flush().unwrap();
    println!("\nwrote results/quantizer_throughput.csv");
}
