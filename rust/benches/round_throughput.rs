//! Streamed round-loop throughput: clients/sec through the cohort
//! pipeline (materialize → train → compress → deliver → accumulate) as
//! the *population* grows with the per-round cohort held fixed.
//!
//! The claim under test is the streaming executor's scaling contract:
//! wall-clock per round and resident memory follow the active cohort,
//! not the population — a 1M-client federation with a 1k cohort runs on
//! a laptop. The resident executor rides along at small populations as
//! the baseline (it materializes every client up front, so it is
//! excluded from the large-population legs by construction).
//!
//!     cargo bench --bench round_throughput
//!
//! Scale the heavyweight leg up with RCFED_BENCH_POP (population of the
//! largest streamed leg, default 1_000_000).

use rcfed::coordinator::experiment::{
    run_experiment, ExecutionMode, ExperimentConfig,
};
use rcfed::csv_row;
use rcfed::util::csv::CsvWriter;

struct Leg {
    mode: ExecutionMode,
    population: usize,
    cohort: usize,
    shards: usize,
}

fn config_for(leg: &Leg) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.dataset.num_clients = leg.population;
    cfg.clients_per_round = leg.cohort;
    cfg.rounds = 4;
    // keep the measurement about the round loop, not the eval pass
    cfg.eval_every = cfg.rounds;
    cfg.mode = leg.mode;
    cfg.round_shards = leg.shards;
    cfg
}

fn main() {
    rcfed::util::log::init_from_env();
    let top_pop: usize = std::env::var("RCFED_BENCH_POP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let mut w = CsvWriter::create(
        "results/round_throughput.csv",
        &[
            "mode",
            "population",
            "cohort",
            "round_shards",
            "rounds",
            "clients_per_sec",
            "wall_secs",
            "peak_rss_kb",
        ],
    )
    .unwrap();
    println!("=== streamed round throughput (tiny model) ===\n");

    let legs = [
        // resident baseline: the whole population lives in memory
        Leg {
            mode: ExecutionMode::Resident,
            population: 1_000,
            cohort: 256,
            shards: 0,
        },
        // streamed at the same scale — parity check
        Leg {
            mode: ExecutionMode::Streamed,
            population: 1_000,
            cohort: 256,
            shards: 0,
        },
        // population grows 100×, cohort fixed: throughput and RSS
        // should hold roughly flat
        Leg {
            mode: ExecutionMode::Streamed,
            population: 100_000,
            cohort: 256,
            shards: 0,
        },
        // the ISSUE target: ~1M clients, 1k per round, laptop-sized
        Leg {
            mode: ExecutionMode::Streamed,
            population: top_pop,
            cohort: 1_000,
            shards: 0,
        },
    ];

    for leg in &legs {
        let cfg = config_for(leg);
        let report = run_experiment(&cfg).unwrap();
        let served = (cfg.rounds * leg.cohort) as f64;
        let cps = served / report.wall_secs.max(1e-9);
        println!(
            "{:<9?} population={:<9} cohort={:<5} shards={} \
             {:>9.1} clients/s  wall={:.2}s  peak_rss={} kB",
            leg.mode,
            leg.population,
            leg.cohort,
            leg.shards,
            cps,
            report.wall_secs,
            report.peak_rss_kb,
        );
        csv_row!(
            w,
            format!("{:?}", leg.mode),
            leg.population,
            leg.cohort,
            leg.shards,
            cfg.rounds,
            cps,
            report.wall_secs,
            report.peak_rss_kb
        )
        .unwrap();
    }
    w.flush().unwrap();
    println!("\nwrote results/round_throughput.csv");
}
