//! E9 — lossy-uplink scenario sweep: how RC-FED and Lloyd-Max degrade
//! when the channel is imperfect. The grid crosses two schemes with an
//! ideal channel, an i.i.d. loss axis, a Gilbert–Elliott burst channel,
//! a corrupting channel, and a straggler-deadline channel over
//! heterogeneous client bandwidths.
//!
//! Everything is deterministic in the seed: rerunning the bench replays
//! the same survivor sets and the same CSV. Expected shape: accuracy
//! degrades gracefully with loss (the survivor-reweighted aggregate
//! stays unbiased), lost packets still pay uplink bits, and the
//! deadline channel is the only one that *reduces* bits on the wire.
//!
//!     cargo bench --bench lossy_uplink

use rcfed::coordinator::experiment::ExperimentConfig;
use rcfed::coordinator::network::ChannelSpec;
use rcfed::coordinator::sweep::{run_sweep, SweepGrid};
use rcfed::fl::compression::CompressionScheme;
use rcfed::model::Backend;
use rcfed::quant::rcq::LengthModel;

fn main() {
    rcfed::util::log::init_from_env();
    let full = std::env::var("RCFED_FULL").is_ok();
    let rounds = if full { 100 } else { 20 };

    let mut base = ExperimentConfig::synth_cifar();
    base.rounds = rounds;
    base.eval_every = 5;

    // Deadline calibrated to the model size: the mean client (≈3 bits
    // per coordinate at b=3) finishes right at the deadline, so with a
    // ±60% bandwidth spread roughly the slower half straggles.
    let d = rcfed::model::native::NativeMlp::synth_cifar().num_params();
    let mean_bps = 2e6;
    let deadline = 3.0 * d as f64 / mean_bps;

    let burst = ChannelSpec {
        loss: 0.02,
        burst_loss: 0.8,
        burst_enter: 0.05,
        burst_exit: 0.3,
        ..ChannelSpec::ideal()
    };
    let corrupting = ChannelSpec { corrupt: 0.1, ..ChannelSpec::ideal() };

    let grid = SweepGrid::new(base)
        .scheme(CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        })
        .scheme(CompressionScheme::Lloyd { bits: 3 })
        .channel(ChannelSpec::ideal())
        .loss_axis(&[0.05, 0.1, 0.2])
        .channel(burst)
        .channel(corrupting)
        .deadline_axis(mean_bps, 0.6, &[deadline]);

    println!("=== E9 — lossy uplink, SynthCifar, {rounds} rounds ===");
    let report = run_sweep(&grid).expect("sweep failed");

    println!(
        "{:<16} {:<22} {:>9} {:>12}  {}",
        "channel", "scheme", "final_acc", "uplink_Gb", "survivors"
    );
    for cell in &report.cells {
        println!(
            "{:<16} {:<22} {:>9.4} {:>12.5}  {}",
            cell.channel,
            cell.label,
            cell.report.final_accuracy,
            cell.report.uplink_gigabits(),
            cell.report.channel
        );
    }
    report.write_csv("results/lossy_uplink.csv").expect("csv");
    report
        .write_json("results/lossy_uplink.json")
        .expect("json");
    println!("{}", report.summary());
    println!("wrote results/lossy_uplink.csv, results/lossy_uplink.json");
}
