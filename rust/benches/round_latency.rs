//! End-to-end round-latency breakdown: where the wallclock of one
//! federated round goes (gradient compute vs moments vs quantize vs
//! entropy-encode vs decode+aggregate). §Perf target: the compression
//! side (everything but the gradient) ≤ 10% of gradient compute.
//!
//! Runs both backends when artifacts are available: native MLP and the
//! three-layer PJRT path (whose quantize step is the Pallas kernel).
//!
//!     cargo bench --bench round_latency

use std::rc::Rc;

use rcfed::coding::huffman::HuffmanCode;
use rcfed::csv_row;
use rcfed::data::{DatasetConfig, FederatedDataset};
use rcfed::fl::compression::{designed_codebook, CompressionScheme};
use rcfed::model::native::NativeMlp;
use rcfed::model::pjrt::PjrtModel;
use rcfed::model::Backend;
use rcfed::stats::moments::mean_std;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;
use rcfed::util::timer::Timer;

struct Breakdown {
    grad: f64,
    moments: f64,
    quantize: f64,
    encode: f64,
    decode: f64,
    aggregate: f64,
}

fn profile_backend<B: Backend + ?Sized>(
    backend: &B,
    ds: &FederatedDataset,
    iters: usize,
) -> Breakdown {
    // served from the process-wide design cache (shared with the sweeps)
    let (cb, rep) =
        designed_codebook(CompressionScheme::Lloyd { bits: 3 }).unwrap();
    let code = HuffmanCode::from_probs(&rep.probs).unwrap();
    let params = backend.init_params(1);
    let d = backend.num_params();
    let mut rng = Rng::new(5);
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    let mut grad = vec![0f32; d];
    let mut sym = Vec::with_capacity(d);
    let mut acc = vec![0f32; d];
    let mut bd = Breakdown {
        grad: 0.0,
        moments: 0.0,
        quantize: 0.0,
        encode: 0.0,
        decode: 0.0,
        aggregate: 0.0,
    };
    for _ in 0..iters {
        ds.shards[0].sample_batch(
            &mut rng, backend.batch_size(), &mut xs, &mut ys);
        let t = Timer::start();
        backend.grad(&params, &xs, &ys, &mut grad).unwrap();
        bd.grad += t.secs();

        let t = Timer::start();
        let (mu, sigma) = mean_std(&grad);
        bd.moments += t.secs();

        let t = Timer::start();
        cb.quantize_normalized(&grad, mu, sigma, &mut sym);
        bd.quantize += t.secs();

        let t = Timer::start();
        let payload = code.encode(&sym).unwrap();
        bd.encode += t.secs();

        let t = Timer::start();
        let back = code.decode(&payload, d).unwrap();
        bd.decode += t.secs();

        let t = Timer::start();
        cb.dequantize_accumulate(&back, mu, sigma, &mut acc);
        bd.aggregate += t.secs();
    }
    bd
}

fn show(label: &str, bd: &Breakdown, iters: usize, d: usize,
        w: &mut CsvWriter) {
    let n = iters as f64;
    let comp = bd.moments + bd.quantize + bd.encode;
    let ps = bd.decode + bd.aggregate;
    println!("-- {label} (d={d}) --");
    println!("  gradient compute : {:>9.3} ms", bd.grad / n * 1e3);
    println!("  moments (μ,σ)    : {:>9.3} ms", bd.moments / n * 1e3);
    println!("  quantize         : {:>9.3} ms", bd.quantize / n * 1e3);
    println!("  huffman encode   : {:>9.3} ms", bd.encode / n * 1e3);
    println!("  huffman decode   : {:>9.3} ms", bd.decode / n * 1e3);
    println!("  dequant+aggregate: {:>9.3} ms", bd.aggregate / n * 1e3);
    println!(
        "  client compression overhead: {:.1}% of gradient compute",
        100.0 * comp / bd.grad.max(1e-12)
    );
    println!(
        "  PS-side per client          : {:.3} ms\n",
        ps / n * 1e3
    );
    for (op, v) in [
        ("grad", bd.grad), ("moments", bd.moments),
        ("quantize", bd.quantize), ("encode", bd.encode),
        ("decode", bd.decode), ("aggregate", bd.aggregate),
    ] {
        csv_row!(w, label, op, v / n * 1e3).unwrap();
    }
}

fn main() {
    rcfed::util::log::init_from_env();
    let mut w = CsvWriter::create(
        "results/round_latency.csv",
        &["backend", "op", "ms_per_round"],
    )
    .unwrap();
    println!("=== round-latency breakdown (per client-round) ===\n");

    let ds = FederatedDataset::build(&DatasetConfig::synth_cifar());
    let native = NativeMlp::synth_cifar();
    let bd = profile_backend(&native, &ds, 10);
    show("native_mlp_synthcifar", &bd, 10, native.num_params(), &mut w);

    match rcfed::runtime::Engine::from_default_dir() {
        Ok(engine) => {
            let engine = Rc::new(engine);
            let pjrt = PjrtModel::new(engine, "mlp_synthcifar").unwrap();
            let bd = profile_backend(&pjrt, &ds, 10);
            show("pjrt_mlp_synthcifar", &bd, 10, pjrt.num_params(), &mut w);
        }
        Err(e) => println!("(pjrt backend skipped: {e})"),
    }
    w.flush().unwrap();
    println!("wrote results/round_latency.csv");
}
