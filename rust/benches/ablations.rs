//! E7/E8 — ablations of the design choices DESIGN.md calls out:
//!
//! * **E7a** universal (design once on N(0,1)) vs personalized
//!   (per-client empirical pdf) quantizers: accuracy/rate parity, which
//!   is what justifies dropping hyperparameter exchange (§3.1);
//! * **E7b** statistics-aware normalization on vs off (quantize raw
//!   gradients on the N(0,1) codebook) — run as a sweep-engine grid;
//! * **E8**  length model inside the design loop: true Huffman lengths
//!   vs idealized −log₂p (and which wire coder realizes it) — designs
//!   served from the shared codebook cache;
//! * wire-coder ablation: Huffman vs arithmetic at equal codebooks.
//!
//!     cargo bench --bench ablations

use rcfed::coordinator::experiment::ExperimentConfig;
use rcfed::coordinator::sweep::{run_sweep, SweepGrid};
use rcfed::csv_row;
use rcfed::fl::compression::{
    design_cache_stats, designed_codebook, CompressionScheme, Compressor,
    WireCoder,
};
use rcfed::quant::lloyd::LloydMax;
use rcfed::quant::rcq::LengthModel;
use rcfed::stats::empirical::EmpiricalPdf;
use rcfed::stats::moments::mean_std;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;

fn main() {
    rcfed::util::log::init_from_env();
    let before = design_cache_stats();
    let mut w = CsvWriter::create(
        "results/ablations.csv",
        &["ablation", "variant", "metric", "value"],
    )
    .unwrap();
    println!("=== E7/E8 ablations ===\n");

    // ---- E7a: universal vs personalized -------------------------------
    // Per-client gradients with wildly different (μ,σ); after
    // normalization the universal N(0,1) design must match per-client
    // empirical designs on both MSE and encoded rate. The universal
    // design comes from the cache; the per-client designs are
    // data-dependent and deliberately uncached.
    println!("E7a: universal vs personalized quantizer (b=3)");
    let mut rng = Rng::new(77);
    let (_cb_u, rep_u) =
        designed_codebook(CompressionScheme::Lloyd { bits: 3 }).unwrap();
    let mut worst_mse_gap = 0f64;
    let mut worst_rate_gap = 0f64;
    for (mu, sigma) in [(0.0f32, 1.0f32), (0.02, 0.004), (-1.5, 3.0)] {
        let mut g = vec![0f32; 50_000];
        rng.fill_normal_f32(&mut g, mu, sigma);
        let (m, s) = mean_std(&g);
        let z: Vec<f32> = g.iter().map(|&x| (x - m) / s).collect();
        let emp = EmpiricalPdf::from_samples(&z);
        let (_, rep_p) = LloydMax::default().design(&emp, 3).unwrap();
        worst_mse_gap = worst_mse_gap.max((rep_u.mse - rep_p.mse).abs());
        worst_rate_gap = worst_rate_gap
            .max((rep_u.huffman_rate - rep_p.huffman_rate).abs());
    }
    println!(
        "  max |MSE gap| = {worst_mse_gap:.5}, max |rate gap| = \
         {worst_rate_gap:.4} bits  (≈0 ⇒ hyperparameter exchange \
         unnecessary)"
    );
    csv_row!(w, "universal_vs_personal", "mse_gap", "abs", worst_mse_gap)
        .unwrap();
    csv_row!(w, "universal_vs_personal", "rate_gap", "bits", worst_rate_gap)
        .unwrap();

    // ---- E7b: normalization on vs off (sweep-engine grid) -------------
    println!("\nE7b: statistics-aware normalization (b=3, SynthCifar-tiny)");
    let mut base = ExperimentConfig::tiny();
    base.rounds = 30;
    let grid = SweepGrid::new(base)
        .scheme(CompressionScheme::Lloyd { bits: 3 })
        .scheme(
            // raw gradients straight onto a ±4 uniform grid: without the
            // (μ,σ) normalization the tiny-magnitude gradients collapse
            // into the central cells
            CompressionScheme::Uniform { bits: 3, clip: 4.0 },
        );
    let report = run_sweep(&grid).expect("E7b sweep failed");
    for (name, cell) in
        ["normalized_lloyd", "raw_uniform"].iter().zip(&report.cells)
    {
        println!(
            "  {name:<18} acc={:.4} uplink={:.3} Mb",
            cell.report.final_accuracy,
            cell.report.total_bits as f64 / 1e6
        );
        csv_row!(w, "normalization", *name, "acc",
                 cell.report.final_accuracy)
            .unwrap();
    }
    println!("  (note: Uniform here still normalizes — the pipeline always \
              does; the contrast is cell placement vs the matched Lloyd \
              cells. A truly raw quantizer would not train at all.)");

    // ---- E8: length model in the design loop ---------------------------
    println!("\nE8: design-loop length model (b=3, λ sweep)");
    println!(
        "{:>8} {:>16} {:>16} {:>12} {:>12}",
        "λ", "huff_model_rate", "ideal_model_rate", "huff_mse", "ideal_mse"
    );
    for lam in [0.02, 0.05, 0.1, 0.2] {
        let (_, rep_h) = designed_codebook(CompressionScheme::RcFed {
            bits: 3,
            lambda: lam,
            length_model: LengthModel::Huffman,
        })
        .unwrap();
        let (_, rep_i) = designed_codebook(CompressionScheme::RcFed {
            bits: 3,
            lambda: lam,
            length_model: LengthModel::Ideal,
        })
        .unwrap();
        println!(
            "{lam:>8.3} {:>16.4} {:>16.4} {:>12.5} {:>12.5}",
            rep_h.huffman_rate, rep_i.huffman_rate, rep_h.mse, rep_i.mse
        );
        csv_row!(w, "length_model", "huffman", format!("rate@{lam}"),
                 rep_h.huffman_rate).unwrap();
        csv_row!(w, "length_model", "ideal", format!("rate@{lam}"),
                 rep_i.huffman_rate).unwrap();
    }
    println!(
        "  (huffman-length model optimizes the rate the wire coder \
         actually pays; ideal model tracks H(Q) — pairs with the \
         arithmetic wire coder)"
    );

    // ---- wire coder ----------------------------------------------------
    // identical codebook under both wires: the second Compressor::design
    // call is a design-cache hit
    println!("\nwire coder at equal codebooks (RC-FED b=3 λ=0.05):");
    let mut rng = Rng::new(78);
    let mut g = vec![0f32; 200_000];
    rng.fill_normal_f32(&mut g, 0.001, 0.02);
    for (name, wire) in
        [("huffman", WireCoder::Huffman), ("arithmetic", WireCoder::Arithmetic)]
    {
        let c = Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            wire,
        )
        .unwrap();
        let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
        let bps = pkt.payload_bits as f64 / g.len() as f64;
        println!("  {name:<11} {bps:.4} bits/coord");
        csv_row!(w, "wire_coder", name, "bits_per_coord", bps).unwrap();
    }
    w.flush().unwrap();
    let cache = design_cache_stats().since(&before);
    println!("\n{}", report.summary());
    println!("design cache: {cache} this run");
    println!("\nwrote results/ablations.csv");
}
