//! E4 — Theorem 1: measured optimality gap Δ_t vs the O(1/t) envelope
//! with constant C (eq. 12), across local-iteration counts e ∈ {1,2,4}
//! and rates b ∈ {2,3,6}. Verifies (i) gap ≤ bound, (ii) 1/t decay in
//! the pre-floor regime, (iii) the C-vs-rate dependence 2^{−2R}.
//!
//! The (bits × e) grid is executed through the sweep engine's worker
//! pool (`parallel_map`), and quantizer designs come from the shared
//! design cache — the (b=3, e=1) cell appears in both sweeps, so its
//! second design is a cache hit.
//!
//!     cargo bench --bench convergence

use rcfed::coordinator::sweep::parallel_map;
use rcfed::csv_row;
use rcfed::fl::compression::{design_cache_stats, designed_codebook};
use rcfed::fl::compression::CompressionScheme;
use rcfed::model::convex::QuadraticFederation;
use rcfed::quant::rcq::LengthModel;
use rcfed::stats::moments::mean_std;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;

/// One grid cell: (bits, local iterations).
#[derive(Clone, Copy)]
struct Cell {
    bits: u32,
    e: usize,
}

/// Per-cell output: gap trajectory, per-symbol rate, CSV rows.
struct CellResult {
    gaps: Vec<f64>,
    rate: f64,
    rows: Vec<(u32, usize, usize, f64)>,
}

fn run(fed: &QuadraticFederation, cell: Cell, rounds: usize) -> CellResult {
    let Cell { bits, e } = cell;
    let f_star = fed.global_loss(&fed.optimum());
    // λ=0 (pure Lloyd limit) so the per-symbol rate R grows with b and
    // the C ∝ 2^{−2R} dependence is visible across the b sweep
    let (cb, rep) = designed_codebook(CompressionScheme::RcFed {
        bits,
        lambda: 0.0,
        length_model: LengthModel::Huffman,
    })
    .unwrap();
    let gamma = (8.0 * fed.l_smooth / fed.rho).max(e as f64) - 1.0;
    let dim = fed.dim;
    let clients = fed.num_clients();
    let mut theta = vec![1.5f32; dim];
    let mut rng = Rng::new(999 + bits as u64 * 17 + e as u64);
    let mut g = vec![0f32; dim];
    let mut gaps = Vec::with_capacity(rounds);
    let mut rows = Vec::new();
    for t in 0..rounds {
        let eta = (2.0 / (fed.rho * (t as f64 + gamma))) as f32;
        let mut agg = vec![0f32; dim];
        for k in 0..clients {
            let mut local = theta.clone();
            for _ in 0..e {
                fed.local_grad(k, &local, Some(&mut rng), &mut g);
                for (p, &gv) in local.iter_mut().zip(&g) {
                    *p -= eta * gv;
                }
            }
            let eff: Vec<f32> = theta
                .iter()
                .zip(&local)
                .map(|(&a, &b)| (a - b) / eta)
                .collect();
            let (mu, sigma) = mean_std(&eff);
            let mut sym = Vec::new();
            cb.quantize_normalized(&eff, mu, sigma, &mut sym);
            cb.dequantize_accumulate(&sym, mu, sigma, &mut agg);
        }
        for (th, &gv) in theta.iter_mut().zip(&agg) {
            *th -= eta * gv / clients as f32;
        }
        let gap = fed.global_loss(&theta) - f_star;
        gaps.push(gap);
        if t % 25 == 0 {
            rows.push((bits, e, t, gap));
        }
    }
    CellResult { gaps, rate: rep.huffman_rate, rows }
}

fn main() {
    let fed = QuadraticFederation::new(64, 10, 1.0, 4.0, 0.6, 0.05, 11);
    let rounds = 600;
    let mut w = CsvWriter::create(
        "results/convergence_bench.csv",
        &["bits", "e", "t", "gap"],
    )
    .unwrap();

    println!("=== E4: Theorem-1 convergence (quadratic federation) ===");
    println!("d=64 K=10 ρ=1 L=4 Γ={:.4}\n", fed.heterogeneity_gap());

    // the full grid: e-sweep at b=3, then rate-sweep at e=1 (the (3,1)
    // duplicate is intentional — its quantizer design is a cache hit and
    // the run itself is deterministic, so both sections agree)
    let cells = [
        Cell { bits: 3, e: 1 },
        Cell { bits: 3, e: 2 },
        Cell { bits: 3, e: 4 },
        Cell { bits: 2, e: 1 },
        Cell { bits: 3, e: 1 },
        Cell { bits: 6, e: 1 },
    ];
    let before = design_cache_stats();
    let results =
        parallel_map(&cells, 0, |_, &cell| run(&fed, cell, rounds));
    let cache = design_cache_stats().since(&before);
    for r in &results {
        for &(bits, e, t, gap) in &r.rows {
            csv_row!(w, bits as usize, e, t, gap).unwrap();
        }
    }

    println!("1/t decay across local iterations (b=3):");
    println!("{:>3} {:>12} {:>12} {:>12} {:>10}", "e", "gap@50", "gap@200",
             "gap@599", "t·gap@200/t·gap@50");
    for (i, e) in [1usize, 2, 4].into_iter().enumerate() {
        let gaps = &results[i].gaps;
        let ratio =
            (200.0 * gaps[200]) / (50.0 * gaps[50]); // ≈1 under 1/t decay
        println!(
            "{e:>3} {:>12.5} {:>12.5} {:>12.5} {ratio:>10.3}",
            gaps[50], gaps[200], gaps[599]
        );
    }

    println!("\nquantization-rate dependence of the floor (e=1):");
    println!("{:>3} {:>10} {:>14}", "b", "R (bits)", "gap floor@599");
    let mut floors = Vec::new();
    for (i, b) in [2u32, 3, 6].into_iter().enumerate() {
        let r = &results[3 + i];
        println!("{b:>3} {:>10.3} {:>14.6}", r.rate, r.gaps[599]);
        floors.push((r.rate, r.gaps[599]));
    }
    println!(
        "(Theorem 1: the quantization term of C scales as 2^(−2R) — the\n \
         floor must drop sharply with b; paper shape: monotone decrease)"
    );
    assert!(floors[0].1 > floors[2].1, "floor did not drop with rate");
    w.flush().unwrap();
    println!("\ndesign cache: {cache} this run");
    println!("wrote results/convergence_bench.csv");
}
