//! E4 — Theorem 1: measured optimality gap Δ_t vs the O(1/t) envelope
//! with constant C (eq. 12), across local-iteration counts e ∈ {1,2,4}
//! and rates b ∈ {2,3,6}. Verifies (i) gap ≤ bound, (ii) 1/t decay in
//! the pre-floor regime, (iii) the C-vs-rate dependence 2^{−2R}.
//!
//!     cargo bench --bench convergence

use rcfed::csv_row;
use rcfed::model::convex::QuadraticFederation;
use rcfed::quant::rcq::RateConstrainedQuantizer;
use rcfed::stats::gaussian::StdGaussian;
use rcfed::stats::moments::mean_std;
use rcfed::util::csv::CsvWriter;
use rcfed::util::rng::Rng;

fn run(
    fed: &QuadraticFederation,
    bits: u32,
    e: usize,
    rounds: usize,
    w: &mut CsvWriter,
) -> (Vec<f64>, f64) {
    let f_star = fed.global_loss(&fed.optimum());
    // λ=0 (pure Lloyd limit) so the per-symbol rate R grows with b and
    // the C ∝ 2^{−2R} dependence is visible across the b sweep
    let rc = RateConstrainedQuantizer::new(0.0);
    let (cb, rep) = rc.design(&StdGaussian, bits).unwrap();
    let gamma = (8.0 * fed.l_smooth / fed.rho).max(e as f64) - 1.0;
    let dim = fed.dim;
    let clients = fed.num_clients();
    let mut theta = vec![1.5f32; dim];
    let mut rng = Rng::new(999 + bits as u64 * 17 + e as u64);
    let mut g = vec![0f32; dim];
    let mut gaps = Vec::with_capacity(rounds);
    for t in 0..rounds {
        let eta = (2.0 / (fed.rho * (t as f64 + gamma))) as f32;
        let mut agg = vec![0f32; dim];
        for k in 0..clients {
            let mut local = theta.clone();
            for _ in 0..e {
                fed.local_grad(k, &local, Some(&mut rng), &mut g);
                for (p, &gv) in local.iter_mut().zip(&g) {
                    *p -= eta * gv;
                }
            }
            let eff: Vec<f32> = theta
                .iter()
                .zip(&local)
                .map(|(&a, &b)| (a - b) / eta)
                .collect();
            let (mu, sigma) = mean_std(&eff);
            let mut sym = Vec::new();
            cb.quantize_normalized(&eff, mu, sigma, &mut sym);
            cb.dequantize_accumulate(&sym, mu, sigma, &mut agg);
        }
        for (th, &gv) in theta.iter_mut().zip(&agg) {
            *th -= eta * gv / clients as f32;
        }
        let gap = fed.global_loss(&theta) - f_star;
        gaps.push(gap);
        if t % 25 == 0 {
            csv_row!(w, bits as usize, e, t, gap).unwrap();
        }
    }
    (gaps, rep.huffman_rate)
}

fn main() {
    let fed = QuadraticFederation::new(64, 10, 1.0, 4.0, 0.6, 0.05, 11);
    let rounds = 600;
    let mut w = CsvWriter::create(
        "results/convergence_bench.csv",
        &["bits", "e", "t", "gap"],
    )
    .unwrap();

    println!("=== E4: Theorem-1 convergence (quadratic federation) ===");
    println!("d=64 K=10 ρ=1 L=4 Γ={:.4}\n", fed.heterogeneity_gap());

    println!("1/t decay across local iterations (b=3):");
    println!("{:>3} {:>12} {:>12} {:>12} {:>10}", "e", "gap@50", "gap@200",
             "gap@599", "t·gap@200/t·gap@50");
    for e in [1usize, 2, 4] {
        let (gaps, _) = run(&fed, 3, e, rounds, &mut w);
        let ratio =
            (200.0 * gaps[200]) / (50.0 * gaps[50]); // ≈1 under 1/t decay
        println!(
            "{e:>3} {:>12.5} {:>12.5} {:>12.5} {ratio:>10.3}",
            gaps[50], gaps[200], gaps[599]
        );
    }

    println!("\nquantization-rate dependence of the floor (e=1):");
    println!("{:>3} {:>10} {:>14}", "b", "R (bits)", "gap floor@599");
    let mut floors = Vec::new();
    for b in [2u32, 3, 6] {
        let (gaps, rate) = run(&fed, b, 1, rounds, &mut w);
        println!("{b:>3} {rate:>10.3} {:>14.6}", gaps[599]);
        floors.push((rate, gaps[599]));
    }
    println!(
        "(Theorem 1: the quantization term of C scales as 2^(−2R) — the\n \
         floor must drop sharply with b; paper shape: monotone decrease)"
    );
    assert!(floors[0].1 > floors[2].1, "floor did not drop with rate");
    w.flush().unwrap();
    println!("\nwrote results/convergence_bench.csv");
}
