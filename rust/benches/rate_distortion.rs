//! E3 — the rate–distortion claims of §3.2: the λ trade-off curve, the
//! baseline operating points, the boundary-shift mechanism and the
//! high-rate law (20). Pure quantizer-design bench (no training).
//!
//! The per-bit-width operating-point grid is declared as a `DesignGrid`
//! and executed by the sweep engine: designs run in parallel and are
//! served from the process-wide codebook design cache, so overlapping
//! points (e.g. the boundary-shift section reusing b=3 λ=0.08) are
//! designed once.
//!
//!     cargo bench --bench rate_distortion

use rcfed::coding::huffman::HuffmanCode;
use rcfed::coordinator::sweep::{run_design_sweep, DesignGrid};
use rcfed::csv_row;
use rcfed::fl::compression::{design_cache_stats, designed_codebook};
use rcfed::fl::compression::CompressionScheme;
use rcfed::quant::lloyd::midpoints;
use rcfed::quant::rcq::LengthModel;
use rcfed::stats::gaussian::differential_entropy_bits;
use rcfed::util::csv::CsvWriter;

const LAMBDAS: [f64; 10] =
    [0.0, 0.01, 0.02, 0.04, 0.06, 0.08, 0.1, 0.15, 0.2, 0.3];

/// (series name, λ column) for the CSV.
fn series_of(scheme: &CompressionScheme) -> (&'static str, f64) {
    match *scheme {
        CompressionScheme::RcFed { lambda, .. } => ("rcfed", lambda),
        CompressionScheme::Lloyd { .. } => ("lloyd", 0.0),
        CompressionScheme::Nqfl { .. } => ("nqfl", 0.0),
        CompressionScheme::Uniform { .. } => ("uniform", 0.0),
        _ => ("other", 0.0),
    }
}

fn main() {
    let before = design_cache_stats();
    let mut w = CsvWriter::create(
        "results/rate_distortion.csv",
        &["series", "bits", "lambda", "rate_bits", "mse"],
    )
    .unwrap();

    println!("=== E3: rate–distortion curves (N(0,1) source) ===\n");
    for b in [2u32, 3, 4, 6] {
        let mut schemes: Vec<CompressionScheme> = LAMBDAS
            .iter()
            .map(|&lambda| CompressionScheme::RcFed {
                bits: b,
                lambda,
                length_model: LengthModel::Huffman,
            })
            .collect();
        schemes.push(CompressionScheme::Lloyd { bits: b });
        schemes.push(CompressionScheme::Nqfl { bits: b });
        schemes.push(CompressionScheme::Uniform { bits: b, clip: 4.0 });
        let cells = run_design_sweep(&DesignGrid { schemes, threads: 0 })
            .expect("design sweep failed");

        println!("-- b={b} --");
        println!("{:<12} {:>8} {:>10} {:>10}", "series", "λ", "E[huff]",
                 "MSE");
        for cell in &cells {
            let (series, lambda) = series_of(&cell.scheme);
            match series {
                "rcfed" => println!(
                    "{:<12} {lambda:>8.3} {:>10.4} {:>10.6}",
                    series, cell.report.huffman_rate, cell.report.mse
                ),
                _ => println!(
                    "{series:<12} {:>8} {:>10.4} {:>10.6}",
                    "-", cell.report.huffman_rate, cell.report.mse
                ),
            }
            csv_row!(w, series, b as usize, lambda,
                     cell.report.huffman_rate, cell.report.mse)
                .unwrap();
        }
        println!();
    }

    // boundary-shift mechanism at b=3 (cache hit: designed above)
    let (cb, rep) = designed_codebook(CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.08,
        length_model: LengthModel::Huffman,
    })
    .unwrap();
    let code = HuffmanCode::from_probs(&rep.probs).unwrap();
    let levels: Vec<f64> = cb.levels.iter().map(|&x| x as f64).collect();
    let mids = midpoints(&levels);
    println!("boundary shifts (b=3, λ=0.08): u_l − midpoint, Δℓ:");
    let mut agree = 0;
    let mut informative = 0;
    for l in 1..levels.len() {
        let shift = cb.bounds[l - 1] as f64 - mids[l - 1];
        let dl = code.lengths()[l] as i64 - code.lengths()[l - 1] as i64;
        println!("  l={l}: shift={shift:+.4} Δℓ={dl:+}");
        if dl != 0 && shift.abs() > 1e-9 {
            informative += 1;
            if (shift > 0.0) == (dl > 0) {
                agree += 1;
            }
        }
    }
    println!(
        "shift direction matches longer-codeword rule on {agree}/{informative} \
         informative boundaries (paper: all)\n"
    );

    // high-rate law (eq. 20)
    println!("high-rate law: MSE / [(1/12)·2^(2h)·2^(−2R)]");
    let h = differential_entropy_bits(1.0);
    for b in [3u32, 4, 6] {
        let (_, rep) = designed_codebook(CompressionScheme::RcFed {
            bits: b,
            lambda: 0.005,
            length_model: LengthModel::Ideal,
        })
        .unwrap();
        let pred = (1.0 / 12.0) * 2f64.powf(2.0 * h)
            * 2f64.powf(-2.0 * rep.entropy_bits);
        println!("  b={b}: ratio={:.3} (→1 as b grows)", rep.mse / pred);
    }
    w.flush().unwrap();
    let cache = design_cache_stats().since(&before);
    println!("\ndesign cache: {cache} this run");
    println!("wrote results/rate_distortion.csv");
}
