//! E3 — the rate–distortion claims of §3.2: the λ trade-off curve, the
//! baseline operating points, the boundary-shift mechanism and the
//! high-rate law (20). Pure quantizer-design bench (no training).
//!
//!     cargo bench --bench rate_distortion

use rcfed::coding::huffman::HuffmanCode;
use rcfed::csv_row;
use rcfed::quant::evaluate;
use rcfed::quant::lloyd::{midpoints, LloydMax};
use rcfed::quant::nqfl::nqfl_codebook;
use rcfed::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use rcfed::quant::uniform::uniform_codebook;
use rcfed::stats::gaussian::{differential_entropy_bits, StdGaussian};
use rcfed::util::csv::CsvWriter;

fn main() {
    let mut w = CsvWriter::create(
        "results/rate_distortion.csv",
        &["series", "bits", "lambda", "rate_bits", "mse"],
    )
    .unwrap();

    println!("=== E3: rate–distortion curves (N(0,1) source) ===\n");
    for b in [2u32, 3, 4, 6] {
        println!("-- b={b} --");
        println!("{:<12} {:>8} {:>10} {:>10}", "series", "λ", "E[huff]", "MSE");
        for lam in [0.0, 0.01, 0.02, 0.04, 0.06, 0.08, 0.1, 0.15, 0.2, 0.3] {
            let rc = RateConstrainedQuantizer {
                lambda: lam,
                length_model: LengthModel::Huffman,
                ..Default::default()
            };
            let (_, rep) = rc.design(&StdGaussian, b).unwrap();
            println!(
                "{:<12} {lam:>8.3} {:>10.4} {:>10.6}",
                "rcfed", rep.huffman_rate, rep.mse
            );
            csv_row!(w, "rcfed", b as usize, lam, rep.huffman_rate, rep.mse)
                .unwrap();
        }
        let (_, lrep) = LloydMax::default().design(&StdGaussian, b).unwrap();
        println!(
            "{:<12} {:>8} {:>10.4} {:>10.6}",
            "lloyd", "-", lrep.huffman_rate, lrep.mse
        );
        csv_row!(w, "lloyd", b as usize, 0.0, lrep.huffman_rate, lrep.mse)
            .unwrap();
        for (name, cb) in [
            ("nqfl", nqfl_codebook(b).unwrap()),
            ("uniform", uniform_codebook(b, 4.0).unwrap()),
        ] {
            let (mse, probs) = evaluate(&StdGaussian, &cb);
            let rate = HuffmanCode::from_probs(&probs)
                .unwrap()
                .expected_length(&probs);
            println!("{name:<12} {:>8} {rate:>10.4} {mse:>10.6}", "-");
            csv_row!(w, name, b as usize, 0.0, rate, mse).unwrap();
        }
        println!();
    }

    // boundary-shift mechanism at b=3
    let rc = RateConstrainedQuantizer {
        lambda: 0.08,
        length_model: LengthModel::Huffman,
        ..Default::default()
    };
    let (cb, rep) = rc.design(&StdGaussian, 3).unwrap();
    let code = HuffmanCode::from_probs(&rep.probs).unwrap();
    let levels: Vec<f64> = cb.levels.iter().map(|&x| x as f64).collect();
    let mids = midpoints(&levels);
    println!("boundary shifts (b=3, λ=0.08): u_l − midpoint, Δℓ:");
    let mut agree = 0;
    let mut informative = 0;
    for l in 1..levels.len() {
        let shift = cb.bounds[l - 1] as f64 - mids[l - 1];
        let dl = code.lengths()[l] as i64 - code.lengths()[l - 1] as i64;
        println!("  l={l}: shift={shift:+.4} Δℓ={dl:+}");
        if dl != 0 && shift.abs() > 1e-9 {
            informative += 1;
            if (shift > 0.0) == (dl > 0) {
                agree += 1;
            }
        }
    }
    println!(
        "shift direction matches longer-codeword rule on {agree}/{informative} \
         informative boundaries (paper: all)\n"
    );

    // high-rate law (eq. 20)
    println!("high-rate law: MSE / [(1/12)·2^(2h)·2^(−2R)]");
    let h = differential_entropy_bits(1.0);
    for b in [3u32, 4, 6] {
        let rc = RateConstrainedQuantizer {
            lambda: 0.005,
            length_model: LengthModel::Ideal,
            ..Default::default()
        };
        let (_, rep) = rc.design(&StdGaussian, b).unwrap();
        let pred = (1.0 / 12.0) * 2f64.powf(2.0 * h)
            * 2f64.powf(-2.0 * rep.entropy_bits);
        println!("  b={b}: ratio={:.3} (→1 as b grows)", rep.mse / pred);
    }
    w.flush().unwrap();
    println!("\nwrote results/rate_distortion.csv");
}
