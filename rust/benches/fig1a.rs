//! E1 — regenerates Fig. 1a: test accuracy vs total uplink (Gb) on the
//! SynthCifar task (CIFAR-10 substitute; K=10, Dirichlet β=0.5, e=1,
//! batch 64, η=0.01).
//!
//! Series: RC-FED λ ∈ {0.02..0.1} at b=3 (the paper's curve) and the
//! baselines QSGD / Lloyd-Max / NQFL at b ∈ {3, 6}, all Huffman-coded.
//!
//! Default scale is CPU-budget friendly (40 rounds, 512 examples/client);
//! set `RCFED_FULL=1` for the paper's 100 rounds. Expected *shape*
//! (paper-vs-measured details in EXPERIMENTS.md): the RC-FED curve
//! Pareto-dominates — for any baseline point there is an RC-FED point
//! with ≥ accuracy at ≤ Gb; b=6 baselines cost ≈2× b=3.
//!
//!     cargo bench --bench fig1a

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::csv_row;
use rcfed::fl::compression::CompressionScheme;
use rcfed::quant::rcq::LengthModel;
use rcfed::util::csv::CsvWriter;

fn main() {
    rcfed::util::log::init_from_env();
    let full = std::env::var("RCFED_FULL").is_ok();
    let rounds = if full { 100 } else { 40 };

    let mut schemes: Vec<CompressionScheme> = Vec::new();
    for lam in [0.02, 0.04, 0.06, 0.08, 0.10] {
        schemes.push(CompressionScheme::RcFed {
            bits: 3,
            lambda: lam,
            length_model: LengthModel::Huffman,
        });
    }
    for b in [3u32, 6] {
        schemes.push(CompressionScheme::Qsgd { bits: b });
        schemes.push(CompressionScheme::Lloyd { bits: b });
        schemes.push(CompressionScheme::Nqfl { bits: b });
    }

    let mut w = CsvWriter::create(
        "results/fig1a.csv",
        &["scheme", "final_acc", "best_acc", "gigabits", "wall_secs"],
    )
    .unwrap();
    println!("=== Fig. 1a — SynthCifar, {rounds} rounds ===");
    println!(
        "{:<22} {:>9} {:>9} {:>12} {:>8}",
        "scheme", "final_acc", "best_acc", "uplink_Gb", "wall_s"
    );
    let mut results = Vec::new();
    for scheme in schemes {
        let mut cfg = ExperimentConfig::synth_cifar();
        cfg.rounds = rounds;
        cfg.eval_every = 5;
        cfg.scheme = scheme;
        let rep = run_experiment(&cfg).expect("run failed");
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>12.5} {:>8.1}",
            rep.label,
            rep.final_accuracy,
            rep.best_accuracy,
            rep.uplink_gigabits(),
            rep.wall_secs
        );
        csv_row!(
            w,
            rep.label.clone(),
            rep.final_accuracy,
            rep.best_accuracy,
            rep.uplink_gigabits(),
            rep.wall_secs
        )
        .unwrap();
        results.push((
            rep.label.clone(),
            rep.final_accuracy,
            rep.uplink_gigabits(),
        ));
    }
    w.flush().unwrap();

    // Pareto-dominance check (the paper's headline claim)
    let rc: Vec<_> =
        results.iter().filter(|r| r.0.starts_with("rcfed")).collect();
    let mut dominated = 0;
    let mut total = 0;
    for base in results.iter().filter(|r| !r.0.starts_with("rcfed")) {
        total += 1;
        if rc.iter().any(|p| p.1 >= base.1 - 0.01 && p.2 <= base.2) {
            dominated += 1;
        }
    }
    println!(
        "\nPareto check: RC-FED dominates {dominated}/{total} baseline \
         points (paper shape: all)"
    );
    println!("wrote results/fig1a.csv");
}
