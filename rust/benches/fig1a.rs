//! E1 — regenerates Fig. 1a: test accuracy vs total uplink (Gb) on the
//! SynthCifar task (CIFAR-10 substitute; K=10, Dirichlet β=0.5, e=1,
//! batch 64, η=0.01).
//!
//! Series: RC-FED λ ∈ {0.02..0.1} at b=3 (the paper's curve) and the
//! baselines QSGD / Lloyd-Max / NQFL at b ∈ {3, 6}, all Huffman-coded.
//! The grid is declared once and executed by the sweep engine
//! (`rcfed::coordinator::sweep`): cells fan out across a scoped worker
//! pool and codebook designs are served from the process-wide cache.
//!
//! Default scale is CPU-budget friendly (40 rounds, 512 examples/client);
//! set `RCFED_FULL=1` for the paper's 100 rounds. Expected *shape*
//! (paper-vs-measured details in EXPERIMENTS.md): the RC-FED curve
//! Pareto-dominates — for any baseline point there is an RC-FED point
//! with ≥ accuracy at ≤ Gb; b=6 baselines cost ≈2× b=3.
//!
//!     cargo bench --bench fig1a

use rcfed::coordinator::experiment::ExperimentConfig;
use rcfed::coordinator::sweep::{run_sweep, SweepGrid};

fn main() {
    rcfed::util::log::init_from_env();
    let full = std::env::var("RCFED_FULL").is_ok();
    let rounds = if full { 100 } else { 40 };

    let mut base = ExperimentConfig::synth_cifar();
    base.rounds = rounds;
    base.eval_every = 5;
    let grid = SweepGrid::new(base)
        .rcfed_lambda_curve(3, &[0.02, 0.04, 0.06, 0.08, 0.10])
        .baselines(&[3, 6]);

    println!("=== Fig. 1a — SynthCifar, {rounds} rounds ===");
    let report = run_sweep(&grid).expect("sweep failed");

    println!(
        "{:<22} {:>9} {:>9} {:>12} {:>8}",
        "scheme", "final_acc", "best_acc", "uplink_Gb", "wall_s"
    );
    for cell in &report.cells {
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>12.5} {:>8.1}",
            cell.label,
            cell.report.final_accuracy,
            cell.report.best_accuracy,
            cell.report.uplink_gigabits(),
            cell.report.wall_secs
        );
    }
    report.write_csv("results/fig1a.csv").expect("csv");

    // Pareto-dominance check (the paper's headline claim)
    let (dominated, total) = report.pareto_dominance("rcfed", 0.01);
    println!(
        "\nPareto check: RC-FED dominates {dominated}/{total} baseline \
         points (paper shape: all)"
    );
    println!("{}", report.summary());
    println!("wrote results/fig1a.csv");
}
