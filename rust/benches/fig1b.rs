//! E2 — regenerates Fig. 1b: test accuracy vs total uplink (Gb) on the
//! SynthFemnist task (FEMNIST substitute; per-device class subsets,
//! K devices sampled per round, e=2 local iterations, batch 32).
//!
//! Default scale: 355 devices / 50 sampled / 30 rounds (a 10× scale-down
//! of the paper's 3550/500/100 recorded in EXPERIMENTS.md; uplink is
//! reported per sampled-client-round so the comparison is scale-free).
//! `RCFED_FULL=1` runs the paper-faithful sizes. The grid runs through
//! the sweep engine (parallel cells + shared codebook design cache).
//!
//!     cargo bench --bench fig1b

use rcfed::coordinator::experiment::ExperimentConfig;
use rcfed::coordinator::sweep::{run_sweep, SweepGrid};
use rcfed::util::csv::CsvField;

fn main() {
    rcfed::util::log::init_from_env();
    let full = std::env::var("RCFED_FULL").is_ok();
    let (devices, sample, rounds) =
        if full { (3550, 500, 100) } else { (355, 50, 30) };

    let mut base = ExperimentConfig::synth_femnist();
    base.dataset.num_clients = devices;
    base.clients_per_round = sample;
    base.rounds = rounds;
    base.eval_every = 5;
    let grid = SweepGrid::new(base)
        .rcfed_lambda_curve(3, &[0.02, 0.04, 0.06, 0.08, 0.10])
        .baselines(&[3, 6]);

    println!(
        "=== Fig. 1b — SynthFemnist, {devices} devices, {sample}/round, \
         {rounds} rounds, e=2 ==="
    );
    let report = run_sweep(&grid).expect("sweep failed");

    println!(
        "{:<22} {:>9} {:>9} {:>12} {:>14} {:>8}",
        "scheme", "final_acc", "best_acc", "uplink_Gb", "Mb/client-rnd",
        "wall_s"
    );
    let per_client =
        |total_bits: u64| total_bits as f64 / (rounds * sample) as f64 / 1e6;
    for cell in &report.cells {
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>12.5} {:>14.4} {:>8.1}",
            cell.label,
            cell.report.final_accuracy,
            cell.report.best_accuracy,
            cell.report.uplink_gigabits(),
            per_client(cell.report.total_bits),
            cell.report.wall_secs
        );
    }
    report
        .write_csv_with(
            "results/fig1b.csv",
            &["scheme", "final_acc", "best_acc", "gigabits",
              "bits_per_client_round", "wall_secs"],
            |c| {
                vec![
                    CsvField::from(c.label.clone()),
                    CsvField::from(c.report.final_accuracy),
                    CsvField::from(c.report.best_accuracy),
                    CsvField::from(c.report.uplink_gigabits()),
                    CsvField::from(per_client(c.report.total_bits)),
                    CsvField::from(c.report.wall_secs),
                ]
            },
        )
        .expect("csv");

    let (dominated, total) = report.pareto_dominance("rcfed", 0.01);
    println!(
        "\nPareto check: RC-FED dominates {dominated}/{total} baseline \
         points (paper shape: all)"
    );
    println!("{}", report.summary());
    println!("wrote results/fig1b.csv");
}
