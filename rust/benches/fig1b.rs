//! E2 — regenerates Fig. 1b: test accuracy vs total uplink (Gb) on the
//! SynthFemnist task (FEMNIST substitute; per-device class subsets,
//! K devices sampled per round, e=2 local iterations, batch 32).
//!
//! Default scale: 355 devices / 50 sampled / 30 rounds (a 10× scale-down
//! of the paper's 3550/500/100 recorded in EXPERIMENTS.md; uplink is
//! reported per sampled-client-round so the comparison is scale-free).
//! `RCFED_FULL=1` runs the paper-faithful sizes.
//!
//!     cargo bench --bench fig1b

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::csv_row;
use rcfed::fl::compression::CompressionScheme;
use rcfed::quant::rcq::LengthModel;
use rcfed::util::csv::CsvWriter;

fn main() {
    rcfed::util::log::init_from_env();
    let full = std::env::var("RCFED_FULL").is_ok();
    let (devices, sample, rounds) =
        if full { (3550, 500, 100) } else { (355, 50, 30) };

    let mut schemes: Vec<CompressionScheme> = Vec::new();
    for lam in [0.02, 0.04, 0.06, 0.08, 0.10] {
        schemes.push(CompressionScheme::RcFed {
            bits: 3,
            lambda: lam,
            length_model: LengthModel::Huffman,
        });
    }
    for b in [3u32, 6] {
        schemes.push(CompressionScheme::Qsgd { bits: b });
        schemes.push(CompressionScheme::Lloyd { bits: b });
        schemes.push(CompressionScheme::Nqfl { bits: b });
    }

    let mut w = CsvWriter::create(
        "results/fig1b.csv",
        &["scheme", "final_acc", "best_acc", "gigabits",
          "bits_per_client_round", "wall_secs"],
    )
    .unwrap();
    println!(
        "=== Fig. 1b — SynthFemnist, {devices} devices, {sample}/round, \
         {rounds} rounds, e=2 ==="
    );
    println!(
        "{:<22} {:>9} {:>9} {:>12} {:>14} {:>8}",
        "scheme", "final_acc", "best_acc", "uplink_Gb", "Mb/client-rnd",
        "wall_s"
    );
    let mut results = Vec::new();
    for scheme in schemes {
        let mut cfg = ExperimentConfig::synth_femnist();
        cfg.dataset.num_clients = devices;
        cfg.clients_per_round = sample;
        cfg.rounds = rounds;
        cfg.eval_every = 5;
        cfg.scheme = scheme;
        let rep = run_experiment(&cfg).expect("run failed");
        let per_client =
            rep.total_bits as f64 / (rounds * sample) as f64 / 1e6;
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>12.5} {:>14.4} {:>8.1}",
            rep.label,
            rep.final_accuracy,
            rep.best_accuracy,
            rep.uplink_gigabits(),
            per_client,
            rep.wall_secs
        );
        csv_row!(
            w,
            rep.label.clone(),
            rep.final_accuracy,
            rep.best_accuracy,
            rep.uplink_gigabits(),
            per_client,
            rep.wall_secs
        )
        .unwrap();
        results.push((
            rep.label.clone(),
            rep.final_accuracy,
            rep.uplink_gigabits(),
        ));
    }
    w.flush().unwrap();

    let rc: Vec<_> =
        results.iter().filter(|r| r.0.starts_with("rcfed")).collect();
    let mut dominated = 0;
    let mut total = 0;
    for base in results.iter().filter(|r| !r.0.starts_with("rcfed")) {
        total += 1;
        if rc.iter().any(|p| p.1 >= base.1 - 0.01 && p.2 <= base.2) {
            dominated += 1;
        }
    }
    println!(
        "\nPareto check: RC-FED dominates {dominated}/{total} baseline \
         points (paper shape: all)"
    );
    println!("wrote results/fig1b.csv");
}
