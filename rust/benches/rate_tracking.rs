//! E10 — rate-targeted compression: the closed-loop pipeline driven at
//! several bits/coordinate targets, against the static fixed-λ design.
//!
//! Expected shape: each Track cell's realized uplink bits/coordinate
//! converges onto its target (the controller trace printed for the tiny
//! config shows λ marching monotonically, then bracketing), accuracy
//! stays in the fixed-λ band, and the downlink column shows the honest
//! price of the re-designs — a few hundred bits per window, orders of
//! magnitude below the uplink savings.
//!
//!     cargo bench --bench rate_tracking

use rcfed::coordinator::experiment::{run_experiment, ExperimentConfig};
use rcfed::coordinator::sweep::{run_sweep, SweepGrid};
use rcfed::fl::compression::{CompressionScheme, RateTarget, TransformCfg};
use rcfed::quant::rcq::LengthModel;

fn main() {
    rcfed::util::log::init_from_env();
    let full = std::env::var("RCFED_FULL").is_ok();
    let rounds = if full { 100 } else { 40 };

    let mut base = ExperimentConfig::synth_cifar();
    base.rounds = rounds;
    base.eval_every = 10;
    let rcfed = CompressionScheme::RcFed {
        bits: 3,
        lambda: 0.05,
        length_model: LengthModel::Huffman,
    };

    let grid = SweepGrid::new(base)
        .scheme(rcfed)
        .rate_target(RateTarget::Off)
        .rate_target_axis(&[2.5, 2.0, 1.5], 5);

    println!(
        "=== E10 — rate-targeted compression, SynthCifar, {rounds} rounds \
         ==="
    );
    let report = run_sweep(&grid).expect("sweep failed");
    println!(
        "{:<22} {:<10} {:>9} {:>12} {:>12} {:>12}",
        "scheme", "target", "final_acc", "uplink_Gb", "downlink_Gb",
        "realized_bpc"
    );
    for cell in &report.cells {
        println!(
            "{:<22} {:<10} {:>9.4} {:>12.5} {:>12.6} {:>12.3}",
            cell.label,
            cell.rate,
            cell.report.final_accuracy,
            cell.report.uplink_gigabits(),
            cell.report.downlink_bits as f64 / 1e9,
            cell.report.realized_bpc()
        );
    }
    report.write_csv("results/rate_tracking.csv").expect("csv");
    report.write_json("results/rate_tracking.json").expect("json");

    // per-round controller trace on the tiny config: small enough to
    // eyeball the dual-ascent trajectory window by window
    let mut tiny = ExperimentConfig::tiny();
    tiny.rounds = rounds;
    tiny.eval_every = 0;
    tiny.rate_target =
        RateTarget::Track { bits_per_coord: 2.0, adapt_every: 2 };
    let rep = run_experiment(&tiny).expect("tiny trace run");
    println!("\ncontroller trace (tiny, target 2.0 b/coord, window 2):");
    println!(
        "{:>5} {:>9} {:>13} {:>10}",
        "round", "lambda", "realized_bpc", "bits_down"
    );
    for (r, t) in rep.metrics.rate_trace().iter().enumerate() {
        println!(
            "{r:>5} {:>9.4} {:>13.3} {:>10}",
            t.lambda, t.realized_bpc, t.bits_down
        );
    }
    println!(
        "tiny: realized {:.3} b/coord, uplink {:.5} Gb + downlink {:.6} Gb",
        rep.realized_bpc(),
        rep.uplink_gigabits(),
        rep.downlink_bits as f64 / 1e9
    );
    println!("{}", report.summary());
    println!("wrote results/rate_tracking.csv, results/rate_tracking.json");

    // E11 — transform axis: dense vs error-feedback vs topk+ef at a
    // fixed quantizer, through the same sweep engine (the `transform`
    // and `sparsity` columns are gated in, everything else unchanged)
    let mut tbase = ExperimentConfig::tiny();
    tbase.rounds = rounds;
    tbase.eval_every = 10;
    let tgrid = SweepGrid::new(tbase)
        .scheme(rcfed)
        .transform(TransformCfg::identity())
        .transform(TransformCfg::identity().with_ef())
        .topk_axis(&[0.2, 0.1, 0.05], true);
    println!("\n=== E11 — transform stage: error feedback + top-k ===");
    let treport = run_sweep(&tgrid).expect("transform sweep failed");
    println!(
        "{:<32} {:<12} {:>9} {:>12} {:>9}",
        "scheme", "transform", "final_acc", "uplink_Gb", "sparsity"
    );
    for cell in &treport.cells {
        println!(
            "{:<32} {:<12} {:>9.4} {:>12.5} {:>9.3}",
            cell.label,
            cell.transform,
            cell.report.final_accuracy,
            cell.report.uplink_gigabits(),
            cell.report.metrics.final_sparsity()
        );
    }
    treport.write_csv("results/transform_stage.csv").expect("csv");
    println!("wrote results/transform_stage.csv");
}
