//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust runtime. Parsed with the in-tree JSON codec.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::{Error, Result};

/// Dtype of a tensor crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(Error::Artifact(format!("unknown dtype {other:?}"))),
        }
    }
}

/// Shape + dtype of one executable input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: v.req("shape")?.usize_array()?,
            dtype: Dtype::parse(v.req("dtype")?.as_str()?)?,
        })
    }
}

/// One AOT-compiled graph.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifact dir
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One parameter tensor of a model (name + shape, manifest order).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model inventory: parameter list (in wire order) + graph names.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub kind: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub batch: usize,
    pub num_params: usize,
    pub params: Vec<ParamSpec>,
    pub train: String,
    pub eval: String,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// gradient chunk length fed to the quantize kernels
    pub chunk: usize,
    /// Pallas block size inside a chunk
    pub block: usize,
    /// exported quantizer bit-widths
    pub bits: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let version = v.req("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported")));
        }
        let mut artifacts = BTreeMap::new();
        for (name, art) in v.req("artifacts")?.as_obj()? {
            let inputs = art
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = art
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: art.req("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj()? {
            let params = m
                .req("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req("name")?.as_str()?.to_string(),
                        shape: p.req("shape")?.usize_array()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    kind: m.req("kind")?.as_str()?.to_string(),
                    input_shape: m.req("input_shape")?.usize_array()?,
                    num_classes: m.req("num_classes")?.as_usize()?,
                    batch: m.req("batch")?.as_usize()?,
                    num_params: m.req("num_params")?.as_usize()?,
                    params,
                    train: m.req("train")?.as_str()?.to_string(),
                    eval: m.req("eval")?.as_str()?.to_string(),
                },
            );
        }
        Ok(Manifest { dir, chunk: v.req("chunk")?.as_usize()?,
                      block: v.req("block")?.as_usize()?,
                      bits: v.req("bits")?.usize_array()?,
                      artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            Error::Artifact(format!("unknown artifact {name:?}"))
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown model {name:?}")))
    }

    /// Absolute path of an artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Consistency check: every artifact file exists, every model's
    /// train/eval graph is present and has the right arity.
    pub fn validate(&self) -> Result<()> {
        for (name, art) in &self.artifacts {
            let p = self.dir.join(&art.file);
            if !p.exists() {
                return Err(Error::Artifact(format!(
                    "{name}: missing file {}", p.display())));
            }
        }
        for (name, m) in &self.models {
            let total: usize = m.params.iter().map(|p| p.numel()).sum();
            if total != m.num_params {
                return Err(Error::Artifact(format!(
                    "{name}: param inventory {total} != {}", m.num_params)));
            }
            let train = self.artifact(&m.train)?;
            if train.inputs.len() != m.params.len() + 2
                || train.outputs.len() != m.params.len() + 1
            {
                return Err(Error::Artifact(format!(
                    "{name}: train graph arity mismatch")));
            }
            self.artifact(&m.eval)?;
        }
        if self.chunk % self.block != 0 {
            return Err(Error::Artifact("chunk % block != 0".into()));
        }
        Ok(())
    }
}

/// Default artifact directory: `$RCFED_ARTIFACTS` or `artifacts/` relative
/// to the workspace root (where `cargo run`/tests execute).
pub fn default_dir() -> PathBuf {
    std::env::var_os("RCFED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The real manifest produced by `make artifacts` (tests run from the
    /// workspace root).
    fn load_real() -> Option<Manifest> {
        Manifest::load(default_dir()).ok()
    }

    #[test]
    fn parses_and_validates_real_manifest() {
        let Some(man) = load_real() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        man.validate().unwrap();
        assert!(man.chunk >= man.block);
        assert!(man.bits.contains(&3) && man.bits.contains(&6));
        assert!(man.artifacts.contains_key("moments"));
        for b in &man.bits {
            assert!(man.artifacts.contains_key(&format!("quantize_b{b}")));
            assert!(man.artifacts.contains_key(&format!("dequantize_b{b}")));
        }
    }

    #[test]
    fn quantize_artifact_shapes_consistent() {
        let Some(man) = load_real() else { return };
        for &b in &man.bits {
            let art = man.artifact(&format!("quantize_b{b}")).unwrap();
            assert_eq!(art.inputs[0].shape, vec![man.chunk]);
            assert_eq!(art.inputs[3].shape, vec![(1 << b) - 1]);
            assert_eq!(art.inputs[4].shape, vec![1 << b]);
            assert_eq!(art.outputs[0].dtype, Dtype::F32);
            assert_eq!(art.outputs[1].dtype, Dtype::I32);
        }
    }

    #[test]
    fn model_manifests_have_param_inventories() {
        let Some(man) = load_real() else { return };
        for (name, m) in &man.models {
            assert!(!m.params.is_empty(), "{name}");
            assert!(m.num_params > 0);
            assert!(man.artifacts.contains_key(&m.train), "{name}");
        }
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
