//! PJRT execution engine.
//!
//! Wraps the `xla` crate's CPU PJRT client: loads HLO **text** artifacts
//! (`HloModuleProto::from_text_file` — jax≥0.5 serialized protos are
//! rejected by xla_extension 0.5.1, see DESIGN.md), compiles each once,
//! and caches the loaded executable keyed by artifact name. All graphs
//! are lowered with `return_tuple=True`, so outputs are unpacked from a
//! single tuple literal.

use std::collections::HashMap;
use std::sync::Arc;

use crate::runtime::artifacts::Manifest;
use crate::runtime::host::HostTensor;
// The real `xla` crate cannot be vendored on this image; the stub
// type-checks the same API and errors cleanly at Engine construction.
use crate::runtime::xla_stub as xla;
use crate::util::{Error, Result};

/// Compiled-executable cache over one PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine over the given artifact directory.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        manifest.validate()?;
        let client = xla::PjRtClient::cpu()?;
        crate::debug!(
            "pjrt engine: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, cache: Default::default() })
    }

    /// Load from the default artifact dir (`$RCFED_ARTIFACTS` or
    /// `artifacts/`).
    pub fn from_default_dir() -> Result<Engine> {
        Engine::new(Manifest::load(crate::runtime::artifacts::default_dir())?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling and caching on first use) an executable.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let t = crate::util::timer::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        crate::debug!("compiled {name} in {:.2}s", t.secs());
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for tests/diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute an artifact with host tensors; inputs are validated against
    /// the manifest and outputs unpacked from the result tuple.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: {} inputs given, {} expected",
                inputs.len(), spec.inputs.len())));
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            t.check(s)?;
        }
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Artifact(format!("{name}: empty result")))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: {} outputs returned, {} expected",
                parts.len(), spec.outputs.len())));
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| HostTensor::from_literal(lit, s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests live in `rust/tests/pjrt_roundtrip.rs` (they need the
    //! built artifacts and a PJRT client, which is process-global state
    //! best exercised from integration tests).
}
