//! Minimal in-tree stand-in for the `xla` crate (the `xla_extension`
//! PJRT bindings).
//!
//! The build image has no network registry, so the real bindings cannot
//! be vendored as a dependency. This stub keeps the [`crate::runtime`]
//! layer compiling with **zero external crates**: it mirrors exactly the
//! API surface `runtime::{pjrt, host}` touches, and fails at *runtime*
//! from the first constructor ([`PjRtClient::cpu`]) with a clear
//! "PJRT unavailable" error. Every PJRT call site already handles
//! `Engine` construction errors (benches print a skip message, the
//! experiment runner propagates `Err`), so the native backend — the path
//! all figure sweeps use — is unaffected.
//!
//! When the real bindings are available, delete this module and the
//! `use crate::runtime::xla_stub as xla;` aliases in `runtime::pjrt`,
//! `runtime::host` and `util`, and add `xla` to `Cargo.toml`.

use std::path::Path;

/// String-backed error mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

// Kept here (not in `util`) so the standard-library-only base layer does
// not depend on the runtime layer.
impl From<Error> for crate::util::Error {
    fn from(e: Error) -> Self {
        crate::util::Error::Xla(e.to_string())
    }
}

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: the `xla` crate is not part of this \
         zero-dependency build (use --backend native)"
            .to_string(),
    )
}

/// Stub of `xla::PjRtClient`; construction always fails, making the
/// unavailability visible at [`crate::runtime::Engine`] creation time.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(
        _path: P,
    ) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }

    #[test]
    fn engine_surfaces_the_stub_error() {
        // Engine::from_default_dir fails on the missing manifest first;
        // with a fabricated manifest it would fail at PjRtClient::cpu.
        // Here we only check the stub's Display path used by util::Error.
        let e: crate::util::Error = unavailable().into();
        assert!(e.to_string().contains("xla error"));
    }
}
