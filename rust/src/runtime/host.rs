//! Host-side tensor plumbing: flatten/unflatten model parameters against
//! the manifest order and build/unpack `xla::Literal`s.

use crate::runtime::artifacts::{Dtype, ModelManifest, TensorSpec};
use crate::runtime::xla_stub as xla;
use crate::util::{Error, Result};

/// A host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(Error::Artifact("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v, _) => Ok(v),
            _ => Err(Error::Artifact("expected i32 tensor".into())),
        }
    }

    /// Build an `xla::Literal` with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
            HostTensor::I32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read a literal back into a host tensor matching `spec`.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::F32(lit.to_vec::<f32>()?, spec.shape.clone()),
            Dtype::I32 => HostTensor::I32(lit.to_vec::<i32>()?, spec.shape.clone()),
        })
    }

    /// Validate against an expected spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() || self.dtype() != spec.dtype {
            return Err(Error::Artifact(format!(
                "tensor mismatch: got {:?}/{:?}, want {:?}/{:?}",
                self.shape(), self.dtype(), spec.shape, spec.dtype)));
        }
        Ok(())
    }
}

/// Model parameters as per-tensor f32 buffers in manifest order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<Vec<f32>>,
    shapes: Vec<Vec<usize>>,
}

impl ParamSet {
    /// Zero-initialized parameter set matching `model`.
    pub fn zeros(model: &ModelManifest) -> ParamSet {
        ParamSet {
            tensors: model.params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            shapes: model.params.iter().map(|p| p.shape.clone()).collect(),
        }
    }

    /// He/Kaiming-style init matching `python/compile/model.py` in spirit
    /// (weights ~ N(0, 2/fan_in), biases zero). Seeds are deterministic.
    pub fn he_init(model: &ModelManifest, seed: u64) -> ParamSet {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut out = ParamSet::zeros(model);
        for (t, p) in out.tensors.iter_mut().zip(&model.params) {
            let is_bias = p.shape.len() == 1;
            if is_bias {
                continue;
            }
            let fan_in: usize =
                p.shape[..p.shape.len() - 1].iter().product::<usize>().max(1);
            let scale = (2.0 / fan_in as f64).sqrt() as f32;
            rng.fill_normal_f32(t, 0.0, scale);
        }
        out
    }

    pub fn num_tensors(&self) -> usize {
        self.tensors.len()
    }

    pub fn total_len(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Concatenate all tensors into one flat gradient/parameter vector
    /// (the order the compression pipeline and manifest agree on).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_len());
        for t in &self.tensors {
            out.extend_from_slice(t);
        }
        out
    }

    /// Inverse of [`flatten`].
    pub fn unflatten_from(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.total_len() {
            return Err(Error::Artifact(format!(
                "flat length {} != param total {}",
                flat.len(), self.total_len())));
        }
        let mut off = 0;
        for t in self.tensors.iter_mut() {
            let n = t.len();
            t.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        Ok(())
    }

    /// SGD step: `p ← p − lr * g` over flat gradients.
    pub fn sgd_step(&mut self, flat_grad: &[f32], lr: f32) -> Result<()> {
        if flat_grad.len() != self.total_len() {
            return Err(Error::Artifact("gradient/param length mismatch".into()));
        }
        let mut off = 0;
        for t in self.tensors.iter_mut() {
            let n = t.len();
            crate::model::kernels::sgd_step(t, &flat_grad[off..off + n], lr);
            off += n;
        }
        Ok(())
    }

    /// As PJRT inputs (in manifest order).
    pub fn to_host_tensors(&self) -> Vec<HostTensor> {
        self.tensors
            .iter()
            .zip(&self.shapes)
            .map(|(t, s)| HostTensor::F32(t.clone(), s.clone()))
            .collect()
    }
}

/// Pad `v` to a multiple of `chunk` with zeros; returns (padded, orig_len).
pub fn pad_to_chunks(v: &[f32], chunk: usize) -> (Vec<f32>, usize) {
    let n = v.len();
    let padded_len = n.div_ceil(chunk) * chunk;
    let mut out = Vec::with_capacity(padded_len);
    out.extend_from_slice(v);
    out.resize(padded_len, 0.0);
    (out, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ParamSpec;

    fn fake_model() -> ModelManifest {
        ModelManifest {
            name: "m".into(),
            kind: "mlp".into(),
            input_shape: vec![4],
            num_classes: 2,
            batch: 8,
            num_params: 4 * 3 + 3,
            params: vec![
                ParamSpec { name: "w0".into(), shape: vec![4, 3] },
                ParamSpec { name: "b0".into(), shape: vec![3] },
            ],
            train: "t".into(),
            eval: "e".into(),
        }
    }

    #[test]
    fn flatten_roundtrip() {
        let m = fake_model();
        let mut p = ParamSet::he_init(&m, 7);
        let flat = p.flatten();
        assert_eq!(flat.len(), 15);
        let mut p2 = ParamSet::zeros(&m);
        p2.unflatten_from(&flat).unwrap();
        assert_eq!(p2.flatten(), flat);
        // biases stay zero under he_init
        assert!(p.tensors[1].iter().all(|&x| x == 0.0));
        // weights are non-trivial
        assert!(p.tensors[0].iter().any(|&x| x != 0.0));
        p.unflatten_from(&vec![1.0; 15]).unwrap();
        assert!(p.tensors[0].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn sgd_step_applies() {
        let m = fake_model();
        let mut p = ParamSet::zeros(&m);
        let g = vec![2.0f32; 15];
        p.sgd_step(&g, 0.5).unwrap();
        assert!(p.flatten().iter().all(|&x| (x + 1.0).abs() < 1e-7));
        assert!(p.sgd_step(&[0.0; 3], 0.5).is_err());
    }

    #[test]
    fn pad_to_chunks_works() {
        let (p, n) = pad_to_chunks(&[1.0, 2.0, 3.0], 4);
        assert_eq!(n, 3);
        assert_eq!(p, vec![1.0, 2.0, 3.0, 0.0]);
        let (p, _) = pad_to_chunks(&[1.0; 8], 4);
        assert_eq!(p.len(), 8);
        let (p, _) = pad_to_chunks(&[], 4);
        assert!(p.is_empty());
    }

    #[test]
    fn host_tensor_checks() {
        let t = HostTensor::F32(vec![0.0; 6], vec![2, 3]);
        assert_eq!(t.numel(), 6);
        t.check(&TensorSpec { shape: vec![2, 3], dtype: Dtype::F32 }).unwrap();
        assert!(t
            .check(&TensorSpec { shape: vec![3, 2], dtype: Dtype::F32 })
            .is_err());
        assert!(t
            .check(&TensorSpec { shape: vec![2, 3], dtype: Dtype::I32 })
            .is_err());
        assert!(t.as_f32().is_ok() && t.as_i32().is_err());
    }
}
