//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) that
//! `python/compile/aot.py` produced and executes them from the rust hot
//! path. Python never runs at request time; the [`Engine`] is the only
//! bridge between the coordinator and the compiled L1/L2 graphs.

pub mod artifacts;
pub mod host;
pub mod pjrt;
pub mod xla_stub;

pub use artifacts::{ArtifactSpec, Manifest, ModelManifest, TensorSpec};
pub use pjrt::Engine;
