//! `rcfed` — the RC-FED launcher.
//!
//! Subcommands:
//!
//! * `run`      — one federated training run (any scheme/backend)
//! * `sweep`    — the Fig. 1 sweep: RC-FED λ-curve + all baselines
//! * `design`   — design a quantizer and print its codebook + report
//! * `info`     — inspect the artifact manifest
//!
//! Examples:
//!
//! ```text
//! rcfed run --dataset cifar --scheme rcfed --bits 3 --lambda 0.05 \
//!           --rounds 100 --out results/run.csv
//! rcfed run --dataset cifar --backend pjrt --model mlp_synthcifar --rounds 5
//! rcfed sweep --dataset cifar --rounds 100 --out results/fig1a.csv
//! rcfed design --scheme rcfed --bits 3 --lambda 0.05
//! ```

use rcfed::coordinator::experiment::{
    run_experiment, BackendChoice, ExecutionMode, ExperimentConfig,
};
use rcfed::coordinator::network::ChannelSpec;
use rcfed::coordinator::sweep::{run_sweep, DownlinkCell, SweepGrid};
use rcfed::data::DatasetKind;
use rcfed::fl::compression::{
    designed_codebook, CompressionScheme, RateAllocation, RateTarget,
    Transform, TransformCfg, WireCoder,
};
use rcfed::fl::server::LrSchedule;
use rcfed::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use rcfed::stats::gaussian::StdGaussian;
use rcfed::util::cli::Args;
use rcfed::util::{Error, Result};

fn main() {
    rcfed::util::log::init_from_env();
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("design") => cmd_design(&args),
        Some("info") => cmd_info(&args),
        Some(other) => Err(Error::Config(format!(
            "unknown subcommand {other:?} (try run|sweep|design|info)"))),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "rcfed — rate-constrained quantization for federated learning\n\n\
         usage: rcfed <run|sweep|design|info> [--key value ...]\n\n\
         run    --dataset cifar|femnist|tiny --scheme \
         rcfed|lloyd|nqfl|qsgd|uniform|fp32|sign|topk{{ratio}}\n       \
         [--bits 3] [--lambda 0.05] [--rounds 100] [--clients-per-round 0]\n       \
         [--local-iters 1] [--batch 64] [--lr 0.01] [--seed 42]\n       \
         [--backend native|pjrt] [--model mlp_synthcifar] [--out file.csv]\n       \
         [--wire huffman|arithmetic|block] (block = per-block-table\n       \
         throughput tier)\n       \
         streaming round loop (the default executor):\n       \
         [--population N] (alias of --clients) [--cohort K] (alias of\n       \
         --clients-per-round) [--round-shards S] [--resident]\n       \
         transform stage: [--topk ratio] [--ef]  (e.g. --scheme topk0.1 --ef)\n       \
         closed-loop rate control (rcfed only):\n       \
         [--rate-target bits_per_coord] [--adapt-every 5]\n       \
         compressed downlink (direction-agnostic codec):\n       \
         [--down-scheme rcfed|lloyd|nqfl|uniform|fp32|sign]\n       \
         [--down-target bits_per_coord] (joins --rate-target into one\n       \
         up+down budget; downlink defaults to rcfed)\n       \
         per-client rate allocation (codebook schemes):\n       \
         [--alloc uniform|waterfill] [--budget bits_per_coord]\n       \
         [--min-bits 1] [--max-bits 6] [--adapt-every 5]\n\
         sweep  same dataset flags; runs the full Fig. 1 grid through the\n       \
         sweep engine [--lambdas l1,l2] [--bits-list 3,6] [--seeds s1,s2]\n       \
         [--scheme-list rcfed,lloyd,fp32] [--sweep-threads 0] [--json file.json]\n       \
         scenario axes: [--loss-list p1,p2] [--deadline-list s1,s2]\n       \
         [--rate-target-list r1,r2 [--adapt-every 5]]\n       \
         [--down-target-list d1,d2 [--down-scheme rcfed]] (joint up+down\n       \
         budgets: crosses every --rate-target-list uplink share)\n       \
         [--budget-list b1,b2 [--min-bits 1 --max-bits 6]]\n       \
         [--topk-list r1,r2 [--ef]]\n\n\
         channel model (run + sweep; all default off/ideal):\n       \
         [--loss p] [--burst-loss p --burst-enter p --burst-exit p]\n       \
         [--corrupt p] [--corrupt-bits n] [--deadline secs]\n       \
         [--bps bits_per_sec] [--bw-spread h] [--latency secs]\n       \
         [--availability p]\n\n\
         design --scheme rcfed|lloyd --bits b [--lambda l] [--target-rate r]\n\
         info   [--artifacts dir]"
    );
}

/// Shared scheme-name resolution for `--scheme` and `--scheme-list`.
fn scheme_by_name(
    name: &str,
    bits: u32,
    lambda: f64,
    lm: LengthModel,
    clip: f64,
) -> Result<CompressionScheme> {
    Ok(match name {
        "rcfed" => CompressionScheme::RcFed { bits, lambda, length_model: lm },
        "lloyd" => CompressionScheme::Lloyd { bits },
        "nqfl" => CompressionScheme::Nqfl { bits },
        "qsgd" => CompressionScheme::Qsgd { bits },
        "uniform" => CompressionScheme::Uniform { bits, clip },
        "fp32" => CompressionScheme::Fp32,
        "sign" => CompressionScheme::Sign,
        other => return Err(Error::Config(format!("bad scheme {other:?}"))),
    })
}

/// The shared `--length-model` flag (run + sweep).
fn parse_length_model(args: &Args) -> Result<LengthModel> {
    match args.str_or("length-model", "huffman").as_str() {
        "huffman" => Ok(LengthModel::Huffman),
        "ideal" => Ok(LengthModel::Ideal),
        other => Err(Error::Config(format!("bad --length-model {other:?}"))),
    }
}

/// Parse `--scheme` plus its hyper-parameter flags. A `topk{ratio}`
/// token (e.g. `--scheme topk0.1`) selects top-k sparsification over
/// the default rcfed quantizer; plain names keep the identity transform
/// (override with `--topk`).
fn parse_scheme(args: &Args) -> Result<(CompressionScheme, Transform)> {
    let bits = args.usize_or("bits", 3)? as u32;
    let lambda = args.f64_or("lambda", 0.05)?;
    let clip = args.f64_or("clip", 4.0)?;
    let lm = parse_length_model(args)?;
    let tok = args.str_or("scheme", "rcfed");
    if let Some(ratio) = tok.strip_prefix("topk") {
        let ratio: f64 = ratio.parse().map_err(|_| {
            Error::Config(format!("bad topk ratio in --scheme {tok:?}"))
        })?;
        let scheme = scheme_by_name("rcfed", bits, lambda, lm, clip)?;
        return Ok((scheme, Transform::TopK { ratio }));
    }
    Ok((
        scheme_by_name(&tok, bits, lambda, lm, clip)?,
        Transform::Identity,
    ))
}

/// Channel-model flags shared by `run` and `sweep`. Everything defaults
/// to the ideal channel, so existing invocations behave identically.
fn parse_channel(args: &Args) -> Result<ChannelSpec> {
    let mut ch = ChannelSpec::ideal();
    ch.uplink_bps = args.f64_or("bps", ch.uplink_bps)?;
    ch.bandwidth_spread = args.f64_or("bw-spread", ch.bandwidth_spread)?;
    ch.base_latency_s = args.f64_or("latency", ch.base_latency_s)?;
    ch.loss = args.f64_or("loss", ch.loss)?;
    ch.burst_loss = args.f64_or("burst-loss", ch.burst_loss)?;
    ch.burst_enter = args.f64_or("burst-enter", ch.burst_enter)?;
    ch.burst_exit = args.f64_or("burst-exit", ch.burst_exit)?;
    ch.corrupt = args.f64_or("corrupt", ch.corrupt)?;
    ch.corrupt_bits =
        args.usize_or("corrupt-bits", ch.corrupt_bits as usize)? as u32;
    ch.deadline_s = args.f64_or("deadline", ch.deadline_s)?;
    ch.availability = args.f64_or("availability", ch.availability)?;
    // burst-model consistency (absorbing state, no-op burst-loss) is
    // checked inside validate(), shared with library users
    ch.validate()?;
    Ok(ch)
}

fn parse_config(args: &Args) -> Result<ExperimentConfig> {
    let kind = DatasetKind::parse(&args.str_or("dataset", "cifar"))?;
    let mut cfg = match kind {
        DatasetKind::SynthCifar => ExperimentConfig::synth_cifar(),
        DatasetKind::SynthFemnist => ExperimentConfig::synth_femnist(),
        DatasetKind::Tiny => ExperimentConfig::tiny(),
    };
    let (scheme, mut transform_kind) = parse_scheme(args)?;
    cfg.scheme = scheme;
    // transform stage: --topk composes with any --scheme (and overrides
    // a topk scheme token); --ef banks the quantization error in a
    // per-client residual
    let topk = args.f64_or("topk", f64::NAN)?;
    if !topk.is_nan() {
        transform_kind = Transform::TopK { ratio: topk };
    }
    cfg.transform = TransformCfg {
        kind: transform_kind,
        error_feedback: args.has_flag("ef"),
    };
    cfg.transform.validate(&cfg.scheme)?;
    cfg.channel = parse_channel(args)?;
    cfg.rounds = args.usize_or("rounds", cfg.rounds)?;
    cfg.clients_per_round =
        args.usize_or("clients-per-round", cfg.clients_per_round)?;
    cfg.local_iters = args.usize_or("local-iters", cfg.local_iters)?;
    cfg.batch = args.usize_or("batch", cfg.batch)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    cfg.eval_batches = args.usize_or("eval-batches", cfg.eval_batches)?;
    cfg.threads = args.usize_or("threads", 0)?;
    cfg.dataset.num_clients =
        args.usize_or("clients", cfg.dataset.num_clients)?;
    // streaming vocabulary: --population/--cohort are aliases of
    // --clients/--clients-per-round that read naturally at federated
    // scale (millions of clients, a small cohort per round)
    cfg.dataset.num_clients =
        args.usize_or("population", cfg.dataset.num_clients)?;
    cfg.clients_per_round =
        args.usize_or("cohort", cfg.clients_per_round)?;
    cfg.round_shards = args.usize_or("round-shards", cfg.round_shards)?;
    if args.has_flag("resident") {
        cfg.mode = ExecutionMode::Resident;
    }
    cfg.dataset.examples_per_client = args.usize_or(
        "examples-per-client", cfg.dataset.examples_per_client)?;
    let lr = args.f64_or("lr", f64::NAN)?;
    if !lr.is_nan() {
        cfg.lr = LrSchedule::Const(lr as f32);
    }
    cfg.wire = match args.str_or("wire", "huffman").as_str() {
        "huffman" => WireCoder::Huffman,
        "arithmetic" => WireCoder::Arithmetic,
        "block" => WireCoder::Block,
        other => return Err(Error::Config(format!("bad --wire {other:?}"))),
    };
    // closed-loop rate targeting: --rate-target turns the controller on
    // (rcfed only, validated by the pipeline); --adapt-every sets the
    // window length in rounds
    let rate_target = args.f64_or("rate-target", f64::NAN)?;
    let adapt_every = args.usize_or("adapt-every", 5)?;
    if !rate_target.is_nan() {
        cfg.rate_target = RateTarget::Track {
            bits_per_coord: rate_target,
            adapt_every,
        };
        cfg.rate_target.validate(&cfg.scheme)?;
    }
    // direction-agnostic downlink: --down-scheme compresses the server
    // broadcast through the same stage graph (versioned model deltas
    // against a server-owned EF residual); --down-target joins it with
    // --rate-target into one budget split across the two directions
    let down_target = args.f64_or("down-target", f64::NAN)?;
    if let Some(tok) = args.get("down-scheme").map(|s| s.to_string()) {
        let bits = args.usize_or("bits", 3)? as u32;
        let lambda = args.f64_or("lambda", 0.05)?;
        let clip = args.f64_or("clip", 4.0)?;
        let lm = parse_length_model(args)?;
        cfg.down_scheme = Some(scheme_by_name(&tok, bits, lambda, lm, clip)?);
    }
    if !down_target.is_nan() {
        let RateTarget::Track { bits_per_coord, adapt_every } =
            cfg.rate_target
        else {
            return Err(Error::Config(
                "--down-target is the downlink share of a joint budget; \
                 set the uplink share with --rate-target"
                    .into(),
            ));
        };
        let total = bits_per_coord + down_target;
        cfg.rate_target = RateTarget::Joint {
            total_bpc: total,
            split: bits_per_coord / total,
            adapt_every,
        };
        cfg.rate_target.validate(&cfg.scheme)?;
        if cfg.down_scheme.is_none() {
            // the joint loop drives the downlink λ, so default the
            // broadcast codec to rcfed at the run's operating point
            let bits = args.usize_or("bits", 3)? as u32;
            let lambda = args.f64_or("lambda", 0.05)?;
            cfg.down_scheme = Some(CompressionScheme::RcFed {
                bits,
                lambda,
                length_model: parse_length_model(args)?,
            });
        }
    }
    // per-client rate allocation: --budget (encoded bits/coordinate,
    // averaged over the round's clients) turns water-filling on; --alloc
    // makes the mode explicit. Shares --adapt-every with the rate
    // controller (the two are mutually exclusive, validated below).
    let budget = args.f64_or("budget", f64::NAN)?;
    let min_bits = args.usize_or("min-bits", 1)? as u32;
    let max_bits = args.usize_or("max-bits", 6)? as u32;
    let alloc_mode = args.str_or("alloc", "uniform");
    match alloc_mode.as_str() {
        "waterfill" | "wf" => {
            if budget.is_nan() {
                return Err(Error::Config(
                    "--alloc waterfill needs --budget bits_per_coord".into(),
                ));
            }
            cfg.alloc = RateAllocation::WaterFill {
                budget_bpc: budget,
                adapt_every,
                min_bits,
                max_bits,
            };
        }
        "uniform" => {
            // a budget alone implies water-filling — but an *explicit*
            // --alloc uniform is a requested baseline and must win, so
            // only the defaulted mode is promoted
            if !budget.is_nan() && args.get("alloc").is_none() {
                cfg.alloc = RateAllocation::WaterFill {
                    budget_bpc: budget,
                    adapt_every,
                    min_bits,
                    max_bits,
                };
            }
        }
        other => {
            return Err(Error::Config(format!("bad --alloc {other:?}")))
        }
    }
    cfg.alloc.validate(&cfg.scheme, &cfg.rate_target)?;
    cfg.backend = match args.str_or("backend", "native").as_str() {
        "native" => BackendChoice::Native,
        "pjrt" => BackendChoice::Pjrt(args.str_or(
            "model",
            match kind {
                DatasetKind::SynthCifar => "mlp_synthcifar",
                DatasetKind::SynthFemnist => "cnn_synthfemnist",
                DatasetKind::Tiny => "mlp_tiny",
            },
        )),
        other => {
            return Err(Error::Config(format!("bad --backend {other:?}")))
        }
    };
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = parse_config(args)?;
    let out = args.get("out").map(|s| s.to_string());
    args.finish()?;
    let report = run_experiment(&cfg)?;
    println!(
        "{:<22} d={:<8} rounds={:<4} acc={:.4} best={:.4} uplink={:.5} Gb \
         wall={:.1}s",
        report.label,
        report.num_params,
        cfg.rounds,
        report.final_accuracy,
        report.best_accuracy,
        report.uplink_gigabits(),
        report.wall_secs
    );
    // flat-memory evidence for the streamed executor; the CI smoke run
    // greps this line and asserts a ceiling on peak_rss_kb (0 on
    // platforms without procfs ⇒ nothing to report)
    if report.peak_rss_kb > 0 {
        println!(
            "memory    mode={:?} peak_rss_kb={} population={} cohort={}",
            cfg.mode,
            report.peak_rss_kb,
            cfg.dataset.num_clients,
            cfg.clients_per_round,
        );
    }
    if cfg.channel.is_faulty() {
        println!("channel {:<14} {}", cfg.channel.label(), report.channel);
    }
    if cfg.rate_target.is_on() {
        println!(
            "rate target {:<10} realized={:.3} b/coord downlink={:.6} Gb \
             total={:.5} Gb",
            cfg.rate_target.label(),
            report.realized_bpc(),
            report.downlink_bits as f64 / 1e9,
            report.total_comm_bits() as f64 / 1e9
        );
    }
    if let Some(down) = cfg.down_scheme {
        println!(
            "downlink {:<13} down_bpc={:.3} b/coord downlink={:.6} Gb \
             total={:.5} Gb",
            down.label(),
            report.down_bpc(),
            report.downlink_bits as f64 / 1e9,
            report.total_comm_bits() as f64 / 1e9
        );
    }
    if cfg.transform.is_active() {
        let trace = report.metrics.transform_trace().last();
        println!(
            "transform {:<13} sparsity={:.3} ef_norm={:.5} \
             index+value bits on the uplink ledger",
            cfg.transform.label(),
            trace.map(|t| t.sparsity).unwrap_or(f64::NAN),
            trace.map(|t| t.ef_residual_norm).unwrap_or(f64::NAN),
        );
    }
    if cfg.alloc.is_on() {
        let hist: Vec<String> = report
            .alloc_hist
            .iter()
            .map(|&(b, n)| format!("b{b}:{n}"))
            .collect();
        println!(
            "allocation {:<14} gini={:.3} widths=[{}] downlink={:.6} Gb \
             total={:.5} Gb",
            cfg.alloc.label(),
            report.alloc_gini(),
            hist.join(" "),
            report.downlink_bits as f64 / 1e9,
            report.total_comm_bits() as f64 / 1e9
        );
    }
    if let Some(path) = out {
        report.metrics.write_csv(&path, &report.label)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = parse_config(args)?;
    let lambdas =
        args.f64_list_or("lambdas", &[0.02, 0.04, 0.06, 0.08, 0.1])?;
    let bits = args.usize_list_or("bits-list", &[3, 6])?;
    let seeds = args.usize_list_or("seeds", &[])?;
    let loss_list = args.f64_list_or("loss-list", &[])?;
    let deadline_list = args.f64_list_or("deadline-list", &[])?;
    let rate_target_list = args.f64_list_or("rate-target-list", &[])?;
    let down_target_list = args.f64_list_or("down-target-list", &[])?;
    let budget_list = args.f64_list_or("budget-list", &[])?;
    let topk_list = args.f64_list_or("topk-list", &[])?;
    let scheme_list = args.get("scheme-list").map(|s| s.to_string());
    // scheme-list hyper-parameter knobs (shared with parse_scheme)
    let list_clip = args.f64_or("clip", 4.0)?;
    let list_lm = parse_length_model(args)?;
    let adapt_every = args.usize_or("adapt-every", 5)?;
    let down_scheme_tok = args.str_or("down-scheme", "rcfed");
    let min_bits = args.usize_or("min-bits", 1)? as u32;
    let max_bits = args.usize_or("max-bits", 6)? as u32;
    let sweep_threads = args.usize_or("sweep-threads", 0)?;
    let out = args.str_or("out", "results/sweep.csv");
    let json_out = args.get("json").map(|s| s.to_string());
    args.finish()?;
    let base_channel = base.channel;
    let base_ef = base.transform.error_feedback;
    // either the axis or a base-level --rate-target puts the sweep in
    // closed-loop mode; both only steer rcfed cells
    let rate_axis = !rate_target_list.is_empty() || base.rate_target.is_on();
    // a compressed downlink (joint targets or a base-level --down-scheme)
    // puts the sweep in bidirectional mode
    let down_axis = !down_target_list.is_empty() || base.down_scheme.is_some();
    if !down_target_list.is_empty() && rate_target_list.is_empty() {
        return Err(Error::Config(
            "--down-target-list is the downlink share of joint budgets; \
             set the uplink shares with --rate-target-list"
                .into(),
        ));
    }
    // likewise for the per-client allocation axis
    let alloc_axis = !budget_list.is_empty() || base.alloc.is_on();
    // and for the transform axis (a base-level --topk/--ef counts too)
    let transform_axis = !topk_list.is_empty() || base.transform.is_active();
    // qsgd cannot host the sparsifying transform (validated per cell)
    let sparse_axis = !topk_list.is_empty() || base.transform.is_sparse();
    // the two controllers are mutually exclusive per cell; crossing the
    // axes would fill a third of the grid with cells that can only fail
    // validation, so reject the combination up front
    if rate_axis && alloc_axis {
        return Err(Error::Config(
            "--rate-target[-list] and --alloc/--budget[-list] cannot be \
             combined; run one controller at a time"
                .into(),
        ));
    }

    // declarative grid: RC-FED λ-curve + baselines (or an explicit
    // --scheme-list), expanded and executed by the sweep engine across a
    // scoped worker pool with the shared codebook design cache.
    let rc_bits = *bits.first().unwrap_or(&3) as u32;
    // --threads controls the scheduler *inside* each cell; the engine
    // defaults it to 1 so sweep workers don't oversubscribe the machine.
    let inner_threads = base.threads;
    let mut grid = SweepGrid::new(base).threads(sweep_threads);
    if inner_threads > 1 {
        grid.inner_threads = inner_threads;
        if sweep_threads == 0 {
            // keep total parallelism ≈ the machine: workers × inner ≤ cores
            let cores = std::thread::available_parallelism()
                .map(|x| x.get())
                .unwrap_or(1);
            grid.threads = (cores / inner_threads).max(1);
        }
    }
    if let Some(list) = &scheme_list {
        // explicit scheme axis: the named schemes crossed with
        // --bits-list, and rcfed entries additionally with --lambdas —
        // the same knobs the default grid honors, so nothing the user
        // passed is silently dropped
        for tok in list.split(',') {
            let tok = tok.trim();
            if tok.starts_with("topk") {
                return Err(Error::Config(
                    "sparsification is a transform axis, not a scheme: \
                     use --topk-list instead of a topk entry in \
                     --scheme-list"
                        .into(),
                ));
            }
            // axis compatibility up front: the default grid silently
            // drops schemes an active controller cannot steer, but an
            // *explicitly named* scheme deserves a hard error instead of
            // a grid of cells that can only fail validation
            if rate_axis && tok != "rcfed" {
                return Err(Error::Config(format!(
                    "rate-target sweeps steer rcfed only; remove \
                     {tok:?} from --scheme-list or drop the rate axis"
                )));
            }
            if alloc_axis && matches!(tok, "qsgd" | "fp32" | "sign") {
                return Err(Error::Config(format!(
                    "allocation sweeps need a designed-codebook scheme; \
                     remove {tok:?} from --scheme-list or drop \
                     --budget-list"
                )));
            }
            if sparse_axis && tok == "qsgd" {
                return Err(Error::Config(
                    "qsgd cannot host top-k sparsification; remove it \
                     from --scheme-list or drop --topk-list"
                        .into(),
                ));
            }
            match tok {
                "rcfed" => {
                    for &b in &bits {
                        grid = grid.rcfed_lambda_curve(b as u32, &lambdas);
                    }
                }
                // fp32/sign have no width axis: one cell, not one per
                // --bits entry
                "fp32" => {
                    grid = grid.scheme(CompressionScheme::Fp32);
                }
                "sign" => {
                    grid = grid.scheme(CompressionScheme::Sign);
                }
                _ => {
                    for &b in &bits {
                        grid = grid.scheme(scheme_by_name(
                            tok, b as u32, 0.0, list_lm, list_clip)?);
                    }
                }
            }
        }
    } else {
        grid = grid.rcfed_lambda_curve(rc_bits, &lambdas);
        // the rate-target axis only steers rcfed (λ is the control
        // variable), so a rate sweep drops the baseline schemes instead
        // of crossing them into cells that can only fail validation; the
        // allocation axis steers any designed-codebook scheme and the
        // sparsifying transform any non-qsgd scheme, so those two only
        // drop QSGD
        if !rate_axis {
            for &b in &bits {
                grid = grid
                    .scheme(CompressionScheme::Lloyd { bits: b as u32 })
                    .scheme(CompressionScheme::Nqfl { bits: b as u32 });
                if !alloc_axis && !sparse_axis {
                    grid = grid
                        .scheme(CompressionScheme::Qsgd { bits: b as u32 });
                }
            }
        }
    }
    let replicated = !seeds.is_empty();
    if replicated {
        let seeds: Vec<u64> = seeds.iter().map(|&s| s as u64).collect();
        grid = grid.seeds(&seeds);
    }
    // scenario axes: each listed loss/deadline value becomes a channel
    // built on top of the base channel flags; validated up front so a
    // bad axis is a CLI error, not a sweep of failed cells
    let channel_axis = !loss_list.is_empty() || !deadline_list.is_empty();
    if channel_axis {
        for &p in &loss_list {
            let spec = ChannelSpec { loss: p, ..base_channel };
            spec.validate()?;
            grid = grid.channel(spec);
        }
        for &dl in &deadline_list {
            let spec = ChannelSpec { deadline_s: dl, ..base_channel };
            spec.validate()?;
            grid = grid.channel(spec);
        }
    }
    // rate-target axis: the static reference cell rides along so the
    // closed-loop rows always have an off-row to compare against
    if !rate_target_list.is_empty() {
        if down_target_list.is_empty() {
            grid = grid
                .rate_target(RateTarget::Off)
                .rate_target_axis(&rate_target_list, adapt_every.max(1));
        } else {
            // joint up+down budgets: a joint cell carries its own
            // RateTarget, so the whole closed loop lives on the downlink
            // axis (crossing a separate rate axis would duplicate every
            // joint cell) — plus an uncompressed baseline and one
            // uplink-only reference per uplink share
            if down_scheme_tok != "rcfed" {
                return Err(Error::Config(format!(
                    "a joint budget drives the downlink λ, which requires \
                     the rcfed down-scheme; got {down_scheme_tok:?}"
                )));
            }
            let down_scheme = scheme_by_name(
                &down_scheme_tok,
                rc_bits,
                0.05,
                list_lm,
                list_clip,
            )?;
            grid = grid.down(DownlinkCell::off());
            for &u in &rate_target_list {
                grid = grid
                    .down(DownlinkCell {
                        scheme: None,
                        rate_target: Some(RateTarget::Track {
                            bits_per_coord: u,
                            adapt_every: adapt_every.max(1),
                        }),
                    })
                    .down_target_axis(
                        u,
                        &down_target_list,
                        adapt_every.max(1),
                        down_scheme,
                    );
            }
        }
    }
    // allocation axis: the uniform reference cell rides along so budget
    // rows always have a shared-codebook row to compare against
    if !budget_list.is_empty() {
        grid = grid.alloc(RateAllocation::Uniform).budget_axis(
            &budget_list,
            adapt_every.max(1),
            min_bits,
            max_bits,
        );
    }
    // transform axis: a dense identity cell rides along so sparse rows
    // always have a dense row to compare against (--ef applies to the
    // whole axis, reference cell included)
    if !topk_list.is_empty() {
        grid = grid
            .transform(TransformCfg {
                kind: Transform::Identity,
                error_feedback: base_ef,
            })
            .topk_axis(&topk_list, base_ef);
    }

    let report = run_sweep(&grid)?;
    for cell in &report.cells {
        let mut line = format!(
            "{:<22} seed={:<6} channel={:<14} acc={:.4} uplink={:.5} Gb",
            cell.label,
            cell.seed,
            cell.channel,
            cell.report.final_accuracy,
            cell.report.uplink_gigabits()
        );
        if rate_axis {
            line.push_str(&format!(
                " rate={:<10} realized={:.3} downlink={:.6} Gb",
                cell.rate,
                cell.report.realized_bpc(),
                cell.report.downlink_bits as f64 / 1e9
            ));
        }
        if alloc_axis {
            line.push_str(&format!(
                " alloc={:<14} gini={:.3} downlink={:.6} Gb",
                cell.alloc,
                cell.report.alloc_gini(),
                cell.report.downlink_bits as f64 / 1e9
            ));
        }
        if transform_axis {
            line.push_str(&format!(
                " transform={:<11} sparsity={:.3}",
                cell.transform,
                cell.report.metrics.final_sparsity()
            ));
        }
        if down_axis {
            line.push_str(&format!(
                " down={:<12} down_bpc={:.3}",
                cell.down,
                cell.report.down_bpc()
            ));
        }
        println!("{line}");
    }
    use rcfed::util::csv::CsvField;
    // schema grows key columns only for the axes actually in play, so
    // plain sweeps keep the pre-engine "scheme,acc,gigabits" bytes
    let mut header: Vec<&str> = vec!["scheme"];
    if replicated {
        header.push("seed");
    }
    if channel_axis {
        header.push("channel");
    }
    if rate_axis {
        header.push("rate_target");
    }
    if alloc_axis {
        header.push("alloc");
    }
    if transform_axis {
        header.push("transform");
    }
    if down_axis {
        header.push("down");
    }
    header.extend_from_slice(&["acc", "gigabits"]);
    if rate_axis {
        header.extend_from_slice(&["realized_bpc", "downlink_gigabits"]);
    }
    if alloc_axis {
        header.push("alloc_gini");
        if !rate_axis {
            header.push("downlink_gigabits");
        }
    }
    if transform_axis {
        header.push("sparsity");
    }
    if down_axis {
        header.push("down_bpc");
        if !rate_axis && !alloc_axis {
            header.push("downlink_gigabits");
        }
    }
    report.write_csv_with(&out, &header, |c| {
        let mut row = vec![CsvField::from(c.label.clone())];
        if replicated {
            row.push(CsvField::from(c.seed));
        }
        if channel_axis {
            row.push(CsvField::from(c.channel.clone()));
        }
        if rate_axis {
            row.push(CsvField::from(c.rate.clone()));
        }
        if alloc_axis {
            row.push(CsvField::from(c.alloc.clone()));
        }
        if transform_axis {
            row.push(CsvField::from(c.transform.clone()));
        }
        if down_axis {
            row.push(CsvField::from(c.down.clone()));
        }
        row.push(CsvField::from(c.report.final_accuracy));
        row.push(CsvField::from(c.report.uplink_gigabits()));
        if rate_axis {
            row.push(CsvField::from(c.report.realized_bpc()));
            row.push(CsvField::from(c.report.downlink_bits as f64 / 1e9));
        }
        if alloc_axis {
            row.push(CsvField::from(c.report.alloc_gini()));
            if !rate_axis {
                row.push(CsvField::from(
                    c.report.downlink_bits as f64 / 1e9,
                ));
            }
        }
        if transform_axis {
            row.push(CsvField::from(c.report.metrics.final_sparsity()));
        }
        if down_axis {
            row.push(CsvField::from(c.report.down_bpc()));
            if !rate_axis && !alloc_axis {
                row.push(CsvField::from(
                    c.report.downlink_bits as f64 / 1e9,
                ));
            }
        }
        row
    })?;
    println!("{}", report.summary());
    if let Some(path) = json_out {
        report.write_json(&path)?;
        println!("wrote {path}");
    }
    println!("wrote {out}");
    Ok(())
}

fn cmd_design(args: &Args) -> Result<()> {
    let (scheme, transform) = parse_scheme(args)?;
    // design is about the quantizer codebook; a sparsifying scheme
    // token would silently design the dense codebook instead, so
    // reject it rather than mislead
    if transform != Transform::Identity {
        return Err(Error::Config(
            "design has no transform stage; pass a plain scheme name \
             (rcfed|lloyd)"
                .into(),
        ));
    }
    let target = args.f64_or("target-rate", f64::NAN)?;
    args.finish()?;
    match scheme {
        CompressionScheme::RcFed { bits, length_model, .. }
            if !target.is_nan() =>
        {
            let (cb, rep, lam) =
                RateConstrainedQuantizer::design_for_target_rate(
                    &StdGaussian, bits, target, length_model)?;
            println!("solved lambda={lam:.5} for target {target} bits");
            print_design(&cb.levels, &cb.bounds, rep.mse,
                         rep.entropy_bits, rep.huffman_rate);
        }
        CompressionScheme::RcFed { .. } | CompressionScheme::Lloyd { .. } => {
            // served from the process-wide design cache
            let (cb, rep) = designed_codebook(scheme)?;
            print_design(&cb.levels, &cb.bounds, rep.mse,
                         rep.entropy_bits, rep.huffman_rate);
        }
        other => {
            return Err(Error::Config(format!(
                "design supports rcfed|lloyd, got {other:?}")))
        }
    }
    Ok(())
}

fn print_design(levels: &[f32], bounds: &[f32], mse: f64, h: f64, r: f64) {
    println!("levels  = {levels:.4?}");
    println!("bounds  = {bounds:.4?}");
    println!("mse     = {mse:.6}");
    println!("H(Q(Z)) = {h:.4} bits/symbol");
    println!("E[huff] = {r:.4} bits/symbol");
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or(
        "artifacts",
        rcfed::runtime::artifacts::default_dir().to_str().unwrap(),
    );
    args.finish()?;
    let man = rcfed::runtime::Manifest::load(&dir)?;
    man.validate()?;
    println!("artifacts: {dir}");
    println!("chunk={} block={} bits={:?}", man.chunk, man.block, man.bits);
    println!("\nmodels:");
    for (name, m) in &man.models {
        println!(
            "  {name:<20} {}  d={:<8} batch={} classes={}",
            m.kind, m.num_params, m.batch, m.num_classes
        );
    }
    println!("\ngraphs:");
    for (name, a) in &man.artifacts {
        println!(
            "  {name:<24} {} inputs, {} outputs  ({})",
            a.inputs.len(), a.outputs.len(), a.file
        );
    }
    Ok(())
}
