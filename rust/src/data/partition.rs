//! Non-IID label partitioning.
//!
//! * [`dirichlet_class_weights`] — the CIFAR-10 protocol of §5: each
//!   client `k` draws a class distribution `q_k ~ Dir(β·1)`; smaller β ⇒
//!   more skew (β=0.5 in the paper).
//! * [`device_class_subsets`] — the FEMNIST-style protocol: each device
//!   holds a small random subset of classes (a "writer" produces only a
//!   few symbols), plus a long-tailed device size distribution.

use crate::util::rng::Rng;

/// Per-client class weight vectors `q_k ~ Dir(β)`.
pub fn dirichlet_class_weights(
    rng: &mut Rng,
    num_clients: usize,
    num_classes: usize,
    beta: f64,
) -> Vec<Vec<f64>> {
    (0..num_clients).map(|_| rng.dirichlet(beta, num_classes)).collect()
}

/// FEMNIST-style: each device gets `min_classes..=max_classes` distinct
/// classes with uniform weights over its subset.
pub fn device_class_subsets(
    rng: &mut Rng,
    num_devices: usize,
    num_classes: usize,
    min_classes: usize,
    max_classes: usize,
) -> Vec<Vec<f64>> {
    assert!(1 <= min_classes && min_classes <= max_classes);
    assert!(max_classes <= num_classes);
    (0..num_devices)
        .map(|_| {
            let k = min_classes + rng.below(max_classes - min_classes + 1);
            let classes = rng.sample_indices(num_classes, k);
            let mut w = vec![0.0; num_classes];
            for &c in &classes {
                w[c] = 1.0 / k as f64;
            }
            w
        })
        .collect()
}

/// Earth-mover-ish skew diagnostic: mean total-variation distance between
/// client label distributions and the global uniform distribution.
/// 0 = IID, →1 = maximally skewed. Used by tests and EXPERIMENTS.md.
pub fn skew_tv(weights: &[Vec<f64>]) -> f64 {
    if weights.is_empty() {
        return 0.0;
    }
    let c = weights[0].len() as f64;
    let mut acc = 0.0;
    for w in weights {
        acc += 0.5 * w.iter().map(|&x| (x - 1.0 / c).abs()).sum::<f64>();
    }
    acc / weights.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirichlet_weights_are_distributions() {
        let mut rng = Rng::new(1);
        let ws = dirichlet_class_weights(&mut rng, 10, 10, 0.5);
        assert_eq!(ws.len(), 10);
        for w in &ws {
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn smaller_beta_is_more_skewed() {
        let mut rng = Rng::new(2);
        let skew_01 = skew_tv(&dirichlet_class_weights(&mut rng, 200, 10, 0.1));
        let skew_05 = skew_tv(&dirichlet_class_weights(&mut rng, 200, 10, 0.5));
        let skew_50 = skew_tv(&dirichlet_class_weights(&mut rng, 200, 10, 50.0));
        assert!(skew_01 > skew_05, "{skew_01} vs {skew_05}");
        assert!(skew_05 > skew_50, "{skew_05} vs {skew_50}");
        assert!(skew_50 < 0.15);
    }

    #[test]
    fn device_subsets_respect_bounds() {
        let mut rng = Rng::new(3);
        let ws = device_class_subsets(&mut rng, 100, 62, 2, 5);
        for w in &ws {
            let nz = w.iter().filter(|&&x| x > 0.0).count();
            assert!((2..=5).contains(&nz), "{nz}");
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
        // devices differ
        assert_ne!(ws[0], ws[1]);
    }

    #[test]
    fn skew_tv_extremes() {
        // IID
        let iid = vec![vec![0.25; 4]; 8];
        assert!(skew_tv(&iid) < 1e-12);
        // one-hot
        let hot = vec![vec![1.0, 0.0, 0.0, 0.0]; 8];
        assert!((skew_tv(&hot) - 0.75).abs() < 1e-12);
    }
}
