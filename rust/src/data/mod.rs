//! Federated data substrate.
//!
//! The paper evaluates on CIFAR-10 (K=10 clients, Dirichlet β=0.5) and
//! FEMNIST (3550 naturally non-IID devices, 500 sampled per round). Both
//! are unavailable in this offline image, so we build the synthetic
//! equivalents described in DESIGN.md §Substitutions: class-conditional
//! Gaussian-mixture tasks with the same federated structure (client
//! counts, Dirichlet label skew, per-device class subsets, sampling,
//! local batching). The compression path — the system under test — sees
//! identical mechanics.

pub mod partition;
pub mod synth;

use crate::util::rng::Rng;
use crate::util::{Error, Result};

/// Which synthetic task to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// CIFAR-10 stand-in: 10 classes, 16×16×3 = 768 features,
    /// K clients via Dirichlet(β) label skew.
    SynthCifar,
    /// FEMNIST stand-in: 62 classes, 28×28×1 = 784 features, many devices
    /// each holding a small subset of classes (writer-style skew).
    SynthFemnist,
    /// 4-class / 32-feature task for fast tests (`mlp_tiny`).
    Tiny,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<DatasetKind> {
        match s {
            "synthcifar" | "cifar" => Ok(DatasetKind::SynthCifar),
            "synthfemnist" | "femnist" => Ok(DatasetKind::SynthFemnist),
            "tiny" => Ok(DatasetKind::Tiny),
            other => Err(Error::Config(format!("unknown dataset {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::SynthCifar => "synthcifar",
            DatasetKind::SynthFemnist => "synthfemnist",
            DatasetKind::Tiny => "tiny",
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::SynthCifar => 10,
            DatasetKind::SynthFemnist => 62,
            DatasetKind::Tiny => 4,
        }
    }

    pub fn feature_shape(&self) -> Vec<usize> {
        match self {
            DatasetKind::SynthCifar => vec![768],
            DatasetKind::SynthFemnist => vec![28, 28, 1],
            DatasetKind::Tiny => vec![32],
        }
    }

    pub fn num_features(&self) -> usize {
        self.feature_shape().iter().product()
    }
}

/// Dataset construction parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetConfig {
    pub kind: DatasetKind,
    /// number of client shards (paper: 10 for CIFAR, 3550 for FEMNIST)
    pub num_clients: usize,
    /// Dirichlet concentration for label skew (None = per-device class
    /// subsets, FEMNIST-style)
    pub dirichlet_beta: Option<f64>,
    pub examples_per_client: usize,
    pub test_examples: usize,
    pub seed: u64,
    /// additive noise std relative to unit class prototypes
    pub noise: f32,
}

impl DatasetConfig {
    /// Paper §5 CIFAR-10 setup (scaled-down shard size; see DESIGN.md).
    pub fn synth_cifar() -> DatasetConfig {
        DatasetConfig {
            kind: DatasetKind::SynthCifar,
            num_clients: 10,
            dirichlet_beta: Some(0.5),
            examples_per_client: 512,
            test_examples: 2048,
            seed: 1234,
            noise: 1.0,
        }
    }

    /// Paper §5 FEMNIST setup (3550 devices is the paper value; benches
    /// scale `num_clients` down, recording the scaling in EXPERIMENTS.md).
    pub fn synth_femnist() -> DatasetConfig {
        DatasetConfig {
            kind: DatasetKind::SynthFemnist,
            num_clients: 3550,
            dirichlet_beta: None,
            examples_per_client: 48,
            test_examples: 2048,
            seed: 1234,
            noise: 1.0,
        }
    }

    pub fn tiny() -> DatasetConfig {
        DatasetConfig {
            kind: DatasetKind::Tiny,
            num_clients: 4,
            dirichlet_beta: Some(0.5),
            examples_per_client: 64,
            test_examples: 256,
            seed: 7,
            noise: 0.8,
        }
    }
}

/// One client's local data (row-major features).
#[derive(Clone, Debug)]
pub struct Shard {
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    pub num_features: usize,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    /// Sample a mini-batch (with replacement — the paper's "randomly
    /// chosen mini-batch") into caller-provided buffers.
    pub fn sample_batch(
        &self,
        rng: &mut Rng,
        batch: usize,
        xs: &mut Vec<f32>,
        ys: &mut Vec<i32>,
    ) {
        xs.clear();
        ys.clear();
        xs.reserve(batch * self.num_features);
        ys.reserve(batch);
        for _ in 0..batch {
            let i = rng.below(self.len());
            let off = i * self.num_features;
            xs.extend_from_slice(&self.xs[off..off + self.num_features]);
            ys.push(self.ys[i]);
        }
    }

    /// Class histogram of this shard.
    pub fn label_counts(&self, num_classes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; num_classes];
        for &y in &self.ys {
            counts[y as usize] += 1;
        }
        counts
    }
}

/// The assembled federated dataset.
#[derive(Clone, Debug)]
pub struct FederatedDataset {
    pub config: DatasetConfig,
    pub shards: Vec<Shard>,
    pub test_xs: Vec<f32>,
    pub test_ys: Vec<i32>,
    pub num_classes: usize,
    pub num_features: usize,
}

impl FederatedDataset {
    /// Build per `config` (fully deterministic in `config.seed`).
    pub fn build(config: &DatasetConfig) -> FederatedDataset {
        synth::build(config)
    }

    pub fn num_clients(&self) -> usize {
        self.shards.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_ys.len()
    }

    /// Iterate the test set in contiguous batches of exactly `batch`
    /// (final ragged remainder is dropped; callers account for it).
    pub fn test_batches(
        &self,
        batch: usize,
    ) -> impl Iterator<Item = (&[f32], &[i32])> {
        let nb = self.test_len() / batch;
        let f = self.num_features;
        (0..nb).map(move |i| {
            (
                &self.test_xs[i * batch * f..(i + 1) * batch * f],
                &self.test_ys[i * batch..(i + 1) * batch],
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_parse() {
        assert_eq!(DatasetKind::parse("cifar").unwrap(), DatasetKind::SynthCifar);
        assert_eq!(
            DatasetKind::parse("synthfemnist").unwrap(),
            DatasetKind::SynthFemnist
        );
        assert!(DatasetKind::parse("mnist").is_err());
    }

    #[test]
    fn shard_batching() {
        let shard = Shard {
            xs: (0..20).map(|i| i as f32).collect(),
            ys: (0..10).collect(),
            num_features: 2,
        };
        let mut rng = Rng::new(1);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        shard.sample_batch(&mut rng, 6, &mut xs, &mut ys);
        assert_eq!(xs.len(), 12);
        assert_eq!(ys.len(), 6);
        // feature rows must align with labels
        for (i, &y) in ys.iter().enumerate() {
            assert_eq!(xs[2 * i], (y * 2) as f32);
        }
    }

    #[test]
    fn test_batches_are_contiguous() {
        let cfg = DatasetConfig::tiny();
        let ds = FederatedDataset::build(&cfg);
        let b = 32;
        let n: usize = ds.test_batches(b).count();
        assert_eq!(n, ds.test_len() / b);
        for (xs, ys) in ds.test_batches(b) {
            assert_eq!(xs.len(), b * ds.num_features);
            assert_eq!(ys.len(), b);
        }
    }
}
