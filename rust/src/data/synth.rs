//! Synthetic task generators (DESIGN.md §Substitutions).
//!
//! Class-conditional Gaussian mixture: class `c` has a fixed prototype
//! `μ_c ~ N(0, s²)^F`; an example of class `c` is `x = μ_c + noise·ε`,
//! `ε ~ N(0,1)^F`. Prototype scale is set so the Bayes classifier is
//! strong but finite-sample learning is non-trivial — the regime in which
//! quantization noise visibly moves test accuracy, which is what Fig. 1
//! measures.

use crate::data::partition::{device_class_subsets, dirichlet_class_weights};
use crate::data::{DatasetConfig, DatasetKind, FederatedDataset, Shard};
use crate::util::rng::Rng;

/// Prototype scale per task (relative to unit noise).
fn prototype_scale(kind: DatasetKind) -> f32 {
    match kind {
        DatasetKind::SynthCifar => 0.22,
        // 62 classes in 784 dims need slightly stronger separation
        DatasetKind::SynthFemnist => 0.30,
        DatasetKind::Tiny => 0.8,
    }
}

/// Class prototypes, deterministic in the dataset seed.
fn prototypes(rng: &mut Rng, classes: usize, feat: usize, scale: f32) -> Vec<Vec<f32>> {
    (0..classes)
        .map(|_| {
            let mut p = vec![0f32; feat];
            rng.fill_normal_f32(&mut p, 0.0, scale);
            p
        })
        .collect()
}

fn gen_examples(
    rng: &mut Rng,
    protos: &[Vec<f32>],
    class_weights: &[f64],
    n: usize,
    noise: f32,
    xs: &mut Vec<f32>,
    ys: &mut Vec<i32>,
) {
    let feat = protos[0].len();
    xs.reserve(n * feat);
    ys.reserve(n);
    for _ in 0..n {
        let c = rng.categorical(class_weights);
        ys.push(c as i32);
        let proto = &protos[c];
        for &p in proto.iter().take(feat) {
            xs.push(p + noise * rng.normal() as f32);
        }
    }
}

/// Build a full federated dataset per `config`.
pub fn build(config: &DatasetConfig) -> FederatedDataset {
    let kind = config.kind;
    let classes = kind.num_classes();
    let feat = kind.num_features();
    let mut rng = Rng::new(config.seed);
    let protos =
        prototypes(&mut rng, classes, feat, prototype_scale(kind));

    // per-client class weights: Dirichlet (CIFAR protocol) or
    // device-subset (FEMNIST protocol)
    let weights = match config.dirichlet_beta {
        Some(beta) => dirichlet_class_weights(
            &mut rng, config.num_clients, classes, beta),
        None => device_class_subsets(
            &mut rng, config.num_clients, classes, 3, 8),
    };

    let mut shards = Vec::with_capacity(config.num_clients);
    for w in &weights {
        let mut srng = rng.fork(shards.len() as u64);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        gen_examples(&mut srng, &protos, w, config.examples_per_client,
                     config.noise, &mut xs, &mut ys);
        shards.push(Shard { xs, ys, num_features: feat });
    }

    // IID balanced test set
    let uniform = vec![1.0 / classes as f64; classes];
    let mut trng = rng.fork(u64::MAX);
    let (mut test_xs, mut test_ys) = (Vec::new(), Vec::new());
    gen_examples(&mut trng, &protos, &uniform, config.test_examples,
                 config.noise, &mut test_xs, &mut test_ys);

    FederatedDataset {
        config: config.clone(),
        shards,
        test_xs,
        test_ys,
        num_classes: classes,
        num_features: feat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::skew_tv;

    #[test]
    fn deterministic_in_seed() {
        let cfg = DatasetConfig::tiny();
        let a = build(&cfg);
        let b = build(&cfg);
        assert_eq!(a.shards[0].xs, b.shards[0].xs);
        assert_eq!(a.test_ys, b.test_ys);
        let mut cfg2 = cfg.clone();
        cfg2.seed += 1;
        let c = build(&cfg2);
        assert_ne!(a.shards[0].xs, c.shards[0].xs);
    }

    #[test]
    fn shapes_and_sizes() {
        let cfg = DatasetConfig::synth_cifar();
        let ds = build(&cfg);
        assert_eq!(ds.num_clients(), 10);
        assert_eq!(ds.num_features, 768);
        assert_eq!(ds.num_classes, 10);
        for s in &ds.shards {
            assert_eq!(s.len(), cfg.examples_per_client);
            assert_eq!(s.xs.len(), s.len() * ds.num_features);
        }
        assert_eq!(ds.test_len(), cfg.test_examples);
    }

    #[test]
    fn labels_in_range_and_nontrivially_distributed() {
        let ds = build(&DatasetConfig::synth_cifar());
        for s in &ds.shards {
            assert!(s.ys.iter().all(|&y| (0..10).contains(&y)));
        }
        // Dirichlet(0.5) shards must be visibly non-IID
        let weights: Vec<Vec<f64>> = ds
            .shards
            .iter()
            .map(|s| {
                let c = s.label_counts(10);
                let n: usize = c.iter().sum();
                c.iter().map(|&x| x as f64 / n as f64).collect()
            })
            .collect();
        assert!(skew_tv(&weights) > 0.2, "skew={}", skew_tv(&weights));
    }

    #[test]
    fn femnist_devices_have_few_classes() {
        let mut cfg = DatasetConfig::synth_femnist();
        cfg.num_clients = 50; // keep the test fast
        let ds = build(&cfg);
        for s in &ds.shards {
            let nz = s
                .label_counts(62)
                .iter()
                .filter(|&&c| c > 0)
                .count();
            assert!(nz <= 8, "device has {nz} classes");
        }
    }

    #[test]
    fn task_is_learnable_by_nearest_prototype() {
        // sanity: the Bayes-ish classifier (nearest class mean estimated
        // from training shards) beats chance comfortably on the test set
        let ds = build(&DatasetConfig::tiny());
        let f = ds.num_features;
        let mut means = vec![vec![0f64; f]; ds.num_classes];
        let mut counts = vec![0usize; ds.num_classes];
        for s in &ds.shards {
            for (i, &y) in s.ys.iter().enumerate() {
                counts[y as usize] += 1;
                for j in 0..f {
                    means[y as usize][j] += s.xs[i * f + j] as f64;
                }
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            if c > 0 {
                m.iter_mut().for_each(|x| *x /= c as f64);
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let x = &ds.test_xs[i * f..(i + 1) * f];
            let pred = (0..ds.num_classes)
                .min_by(|&a, &b| {
                    let da: f64 = x.iter().zip(&means[a])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2)).sum();
                    let db: f64 = x.iter().zip(&means[b])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            correct += (pred as i32 == ds.test_ys[i]) as usize;
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.5, "nearest-prototype acc {acc}");
    }
}
