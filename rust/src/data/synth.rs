//! Synthetic task generators (DESIGN.md §Substitutions).
//!
//! Class-conditional Gaussian mixture: class `c` has a fixed prototype
//! `μ_c ~ N(0, s²)^F`; an example of class `c` is `x = μ_c + noise·ε`,
//! `ε ~ N(0,1)^F`. Prototype scale is set so the Bayes classifier is
//! strong but finite-sample learning is non-trivial — the regime in which
//! quantization noise visibly moves test accuracy, which is what Fig. 1
//! measures.
//!
//! Two construction modes share one deterministic recipe:
//!
//! * **Eager** — [`build`] materializes every shard up front (the
//!   historical path; memory is O(population · examples)).
//! * **Lazy** — [`ShardGen`] captures only the compact per-client recipe
//!   (class weights + a precomputed per-shard seed) and materializes any
//!   shard on demand. `build` itself delegates to `ShardGen`, so the two
//!   modes are byte-identical *by construction*, not by parallel
//!   maintenance.
//!
//! The lazy recipe is O(population) in the number of clients but with a
//! tiny constant (one `u64` seed plus the class-weight vector per client,
//! ~100 bytes) versus the O(examples · features) shard itself (~MBs), so
//! million-client populations fit comfortably while a round only ever
//! materializes its active cohort.

use crate::data::partition::{device_class_subsets, dirichlet_class_weights};
use crate::data::{DatasetConfig, DatasetKind, FederatedDataset, Shard};
use crate::util::rng::Rng;

/// Prototype scale per task (relative to unit noise).
fn prototype_scale(kind: DatasetKind) -> f32 {
    match kind {
        DatasetKind::SynthCifar => 0.22,
        // 62 classes in 784 dims need slightly stronger separation
        DatasetKind::SynthFemnist => 0.30,
        DatasetKind::Tiny => 0.8,
    }
}

/// Class prototypes, deterministic in the dataset seed.
fn prototypes(rng: &mut Rng, classes: usize, feat: usize, scale: f32) -> Vec<Vec<f32>> {
    (0..classes)
        .map(|_| {
            let mut p = vec![0f32; feat];
            rng.fill_normal_f32(&mut p, 0.0, scale);
            p
        })
        .collect()
}

fn gen_examples(
    rng: &mut Rng,
    protos: &[Vec<f32>],
    class_weights: &[f64],
    n: usize,
    noise: f32,
    xs: &mut Vec<f32>,
    ys: &mut Vec<i32>,
) {
    let feat = protos[0].len();
    xs.reserve(n * feat);
    ys.reserve(n);
    for _ in 0..n {
        let c = rng.categorical(class_weights);
        ys.push(c as i32);
        let proto = &protos[c];
        for &p in proto.iter().take(feat) {
            xs.push(p + noise * rng.normal() as f32);
        }
    }
}

/// Per-client class weights, stored densely (Dirichlet skew touches every
/// class) or sparsely (device subsets touch ≤ 8), whichever is smaller.
/// Densification restores the exact `Vec<f64>` the partitioner produced,
/// so `categorical` sees bit-identical weights either way.
#[derive(Clone, Debug)]
enum ClassWeights {
    Dense(Vec<f64>),
    Sparse(Vec<(u32, f64)>),
}

impl ClassWeights {
    fn compact(dense: Vec<f64>, classes: usize) -> ClassWeights {
        let nnz = dense.iter().filter(|&&w| w != 0.0).count();
        // a sparse entry costs 12 B packed (u32 + f64) vs 8 B dense
        if nnz * 3 < classes * 2 {
            ClassWeights::Sparse(
                dense
                    .iter()
                    .enumerate()
                    .filter(|(_, &w)| w != 0.0)
                    .map(|(c, &w)| (c as u32, w))
                    .collect(),
            )
        } else {
            ClassWeights::Dense(dense)
        }
    }

    fn densify_into(&self, classes: usize, out: &mut Vec<f64>) {
        out.clear();
        match self {
            ClassWeights::Dense(w) => out.extend_from_slice(w),
            ClassWeights::Sparse(pairs) => {
                out.resize(classes, 0.0);
                for &(c, w) in pairs {
                    out[c as usize] = w;
                }
            }
        }
    }
}

/// Compact deterministic recipe for a federated dataset: prototypes, each
/// client's class-weight vector, and a precomputed per-shard RNG seed.
///
/// The seed table exists because [`Rng::fork`] *mutates* its parent (one
/// `next_u64` draw per fork): shard `i`'s generator depends on the `i`
/// forks before it, so lazy materialization cannot replay forks on
/// demand. Capturing the parent draw for every shard up front freezes
/// the exact eager sequence into random-access form.
#[derive(Clone, Debug)]
pub struct ShardGen {
    config: DatasetConfig,
    num_classes: usize,
    num_features: usize,
    protos: Vec<Vec<f32>>,
    weights: Vec<ClassWeights>,
    shard_seeds: Vec<u64>,
    test_seed: u64,
}

impl ShardGen {
    /// Capture the generation recipe for `config`. Replays the exact RNG
    /// schedule of the eager builder: prototypes, then partition weights,
    /// then one fork draw per shard, then the test-set fork.
    pub fn new(config: &DatasetConfig) -> ShardGen {
        let kind = config.kind;
        let classes = kind.num_classes();
        let feat = kind.num_features();
        let mut rng = Rng::new(config.seed);
        let protos = prototypes(&mut rng, classes, feat, prototype_scale(kind));

        // per-client class weights: Dirichlet (CIFAR protocol) or
        // device-subset (FEMNIST protocol)
        let dense_weights = match config.dirichlet_beta {
            Some(beta) => dirichlet_class_weights(
                &mut rng, config.num_clients, classes, beta),
            None => device_class_subsets(
                &mut rng, config.num_clients, classes, 3, 8),
        };
        let weights: Vec<ClassWeights> = dense_weights
            .into_iter()
            .map(|w| ClassWeights::compact(w, classes))
            .collect();

        // freeze the fork schedule: seed_i is exactly what
        // `rng.fork(i)` would have produced at this point in the
        // sequence (one parent draw per shard, in shard order)
        let mut shard_seeds = Vec::with_capacity(config.num_clients);
        for i in 0..config.num_clients as u64 {
            let base = rng.next_u64();
            shard_seeds.push(base ^ i.wrapping_mul(0x9E3779B97F4A7C15));
        }
        let test_seed =
            rng.next_u64() ^ u64::MAX.wrapping_mul(0x9E3779B97F4A7C15);

        ShardGen {
            config: config.clone(),
            num_classes: classes,
            num_features: feat,
            protos,
            weights,
            shard_seeds,
            test_seed,
        }
    }

    pub fn num_clients(&self) -> usize {
        self.shard_seeds.len()
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn num_features(&self) -> usize {
        self.num_features
    }

    pub fn config(&self) -> &DatasetConfig {
        &self.config
    }

    /// Materialize client `i`'s shard. Byte-identical to
    /// `build(config).shards[i]` for any order of calls (`&self`: safe to
    /// call concurrently from a worker pool).
    pub fn shard(&self, i: usize) -> Shard {
        assert!(i < self.shard_seeds.len(), "shard {i} out of range");
        let mut srng = Rng::new(self.shard_seeds[i]);
        let mut dense = Vec::with_capacity(self.num_classes);
        self.weights[i].densify_into(self.num_classes, &mut dense);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        gen_examples(
            &mut srng,
            &self.protos,
            &dense,
            self.config.examples_per_client,
            self.config.noise,
            &mut xs,
            &mut ys,
        );
        Shard { xs, ys, num_features: self.num_features }
    }

    /// Materialize the IID balanced test set.
    pub fn test_set(&self) -> (Vec<f32>, Vec<i32>) {
        let uniform = vec![1.0 / self.num_classes as f64; self.num_classes];
        let mut trng = Rng::new(self.test_seed);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        gen_examples(
            &mut trng,
            &self.protos,
            &uniform,
            self.config.test_examples,
            self.config.noise,
            &mut xs,
            &mut ys,
        );
        (xs, ys)
    }

    /// An evaluation-only view: test set materialized, **no shards**.
    /// Used by the streamed round loop, which pulls shards straight from
    /// this generator; `num_clients` lives in `.config`, not in
    /// `shards.len()`.
    pub fn eval_dataset(&self) -> FederatedDataset {
        let (test_xs, test_ys) = self.test_set();
        FederatedDataset {
            config: self.config.clone(),
            shards: Vec::new(),
            test_xs,
            test_ys,
            num_classes: self.num_classes,
            num_features: self.num_features,
        }
    }
}

/// Build a full federated dataset per `config` (eager: every shard
/// materialized, via the same [`ShardGen`] recipe the lazy path uses).
pub fn build(config: &DatasetConfig) -> FederatedDataset {
    let gen = ShardGen::new(config);
    let shards: Vec<Shard> =
        (0..gen.num_clients()).map(|i| gen.shard(i)).collect();
    let (test_xs, test_ys) = gen.test_set();
    FederatedDataset {
        config: config.clone(),
        shards,
        test_xs,
        test_ys,
        num_classes: gen.num_classes(),
        num_features: gen.num_features(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::skew_tv;

    #[test]
    fn deterministic_in_seed() {
        let cfg = DatasetConfig::tiny();
        let a = build(&cfg);
        let b = build(&cfg);
        assert_eq!(a.shards[0].xs, b.shards[0].xs);
        assert_eq!(a.test_ys, b.test_ys);
        let mut cfg2 = cfg.clone();
        cfg2.seed += 1;
        let c = build(&cfg2);
        assert_ne!(a.shards[0].xs, c.shards[0].xs);
    }

    #[test]
    fn lazy_shards_match_eager_build() {
        // Dirichlet (dense weights) and device-subset (sparse weights)
        // recipes must both materialize byte-identically, in any order.
        let mut femnist = DatasetConfig::synth_femnist();
        femnist.num_clients = 12;
        for cfg in [DatasetConfig::tiny(), femnist] {
            let eager = build(&cfg);
            let gen = ShardGen::new(&cfg);
            assert_eq!(gen.num_clients(), cfg.num_clients);
            // out-of-order, repeated materialization
            for &i in &[cfg.num_clients - 1, 0, 1, 0] {
                let s = gen.shard(i);
                assert_eq!(s.xs, eager.shards[i].xs, "shard {i} xs");
                assert_eq!(s.ys, eager.shards[i].ys, "shard {i} ys");
            }
            let (txs, tys) = gen.test_set();
            assert_eq!(txs, eager.test_xs);
            assert_eq!(tys, eager.test_ys);
            let eval = gen.eval_dataset();
            assert!(eval.shards.is_empty());
            assert_eq!(eval.test_xs, eager.test_xs);
            assert_eq!(eval.config, cfg);
        }
    }

    #[test]
    fn shapes_and_sizes() {
        let cfg = DatasetConfig::synth_cifar();
        let ds = build(&cfg);
        assert_eq!(ds.num_clients(), 10);
        assert_eq!(ds.num_features, 768);
        assert_eq!(ds.num_classes, 10);
        for s in &ds.shards {
            assert_eq!(s.len(), cfg.examples_per_client);
            assert_eq!(s.xs.len(), s.len() * ds.num_features);
        }
        assert_eq!(ds.test_len(), cfg.test_examples);
    }

    #[test]
    fn labels_in_range_and_nontrivially_distributed() {
        let ds = build(&DatasetConfig::synth_cifar());
        for s in &ds.shards {
            assert!(s.ys.iter().all(|&y| (0..10).contains(&y)));
        }
        // Dirichlet(0.5) shards must be visibly non-IID
        let weights: Vec<Vec<f64>> = ds
            .shards
            .iter()
            .map(|s| {
                let c = s.label_counts(10);
                let n: usize = c.iter().sum();
                c.iter().map(|&x| x as f64 / n as f64).collect()
            })
            .collect();
        assert!(skew_tv(&weights) > 0.2, "skew={}", skew_tv(&weights));
    }

    #[test]
    fn femnist_devices_have_few_classes() {
        let mut cfg = DatasetConfig::synth_femnist();
        cfg.num_clients = 50; // keep the test fast
        let ds = build(&cfg);
        for s in &ds.shards {
            let nz = s
                .label_counts(62)
                .iter()
                .filter(|&&c| c > 0)
                .count();
            assert!(nz <= 8, "device has {nz} classes");
        }
    }

    #[test]
    fn task_is_learnable_by_nearest_prototype() {
        // sanity: the Bayes-ish classifier (nearest class mean estimated
        // from training shards) beats chance comfortably on the test set
        let ds = build(&DatasetConfig::tiny());
        let f = ds.num_features;
        let mut means = vec![vec![0f64; f]; ds.num_classes];
        let mut counts = vec![0usize; ds.num_classes];
        for s in &ds.shards {
            for (i, &y) in s.ys.iter().enumerate() {
                counts[y as usize] += 1;
                for j in 0..f {
                    means[y as usize][j] += s.xs[i * f + j] as f64;
                }
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            if c > 0 {
                m.iter_mut().for_each(|x| *x /= c as f64);
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let x = &ds.test_xs[i * f..(i + 1) * f];
            let pred = (0..ds.num_classes)
                .min_by(|&a, &b| {
                    let da: f64 = x.iter().zip(&means[a])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2)).sum();
                    let db: f64 = x.iter().zip(&means[b])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            correct += (pred as i32 == ds.test_ys[i]) as usize;
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.5, "nearest-prototype acc {acc}");
    }
}
