//! Deterministic pseudo-random generation.
//!
//! `xoshiro256++` core (Blackman & Vigna) seeded through SplitMix64, plus
//! the distribution samplers the FL simulation needs: uniform, Gaussian
//! (Box–Muller with cached spare), Gamma (Marsaglia–Tsang), Dirichlet,
//! categorical, permutation and reservoir-free subset sampling.
//!
//! Every run of the system is reproducible from a single `u64` seed;
//! clients derive independent streams via [`Rng::fork`].

/// xoshiro256++ PRNG with distribution samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream, e.g. one per client. Deterministic in
    /// `(self state, tag)`; does not advance `self`'s own sequence beyond
    /// one draw.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire rejection, unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form), with spare caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with i.i.d. `N(mu, sigma^2)` f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        for x in out.iter_mut() {
            *x = mu + sigma * self.normal() as f32;
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; `shape > 0`.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u: f64 = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Symmetric Dirichlet(beta) over `k` categories.
    pub fn dirichlet(&mut self, beta: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(beta)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to a random one-hot
            let j = self.below(k);
            g.iter_mut().for_each(|x| *x = 0.0);
            g[j] = 1.0;
            return g;
        }
        g.iter_mut().for_each(|x| *x /= sum);
        g
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &shape in &[0.5, 1.0, 2.5] {
            let n = 30_000;
            let mean: f64 =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.1 * shape.max(1.0), "{shape}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(17);
        for &beta in &[0.1, 0.5, 5.0] {
            let p = r.dirichlet(beta, 10);
            assert_eq!(p.len(), 10);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "{counts:?}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
