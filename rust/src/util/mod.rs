//! Standard-library-only substrates.
//!
//! The build image has no network registry, so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `tokio`, `criterion`) are unavailable. This
//! module provides the replacements the rest of the crate builds on:
//! deterministic PRNGs ([`rng`]), a JSON codec for the artifact manifest
//! and result files ([`json`]), a CLI/config parser ([`cli`]), a leveled
//! logger ([`log`]), CSV emission ([`csv`]) and wallclock timing helpers
//! ([`timer`]).

pub mod cli;
pub mod csv;
pub mod json;
pub mod log;
pub mod rng;
pub mod timer;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("config error: {0}")]
    Config(String),
    #[error("json error: {0}")]
    Json(String),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("coding error: {0}")]
    Coding(String),
    #[error("quantizer error: {0}")]
    Quant(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
