//! Standard-library-only substrates.
//!
//! The build image has no network registry, so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `tokio`, `criterion`, `thiserror`) are
//! unavailable. This module provides the replacements the rest of the
//! crate builds on: deterministic PRNGs ([`rng`]), a JSON codec for the
//! artifact manifest and result files ([`json`]), a CLI/config parser
//! ([`cli`]), a leveled logger ([`log`]), CSV emission ([`csv`]),
//! wallclock timing helpers ([`timer`]), and a hand-rolled crate-wide
//! error type (no `thiserror` derive on this image).

pub mod cli;
pub mod csv;
pub mod json;
pub mod log;
pub mod mem;
pub mod rng;
pub mod timer;

/// Crate-wide error type.
#[derive(Debug)]
pub enum Error {
    Config(String),
    Json(String),
    Artifact(String),
    Coding(String),
    Quant(String),
    Io(std::io::Error),
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Coding(m) => write!(f, "coding error: {m}"),
            Error::Quant(m) => write!(f, "quantizer error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

// From<xla::Error> lives next to the stub in `runtime::xla_stub`, so this
// bottom-layer module stays standard-library-only.

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(Error::Quant("q".into()).to_string(), "quantizer error: q");
        let io: Error =
            std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(io.to_string().contains("io error"));
    }

    #[test]
    fn error_is_std_error() {
        fn takes_err<E: std::error::Error>(_e: &E) {}
        takes_err(&Error::Coding("c".into()));
    }
}
