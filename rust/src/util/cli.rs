//! Tiny CLI argument parser (the image has no `clap`).
//!
//! Grammar: `rcfed <subcommand> [--key value | --key=value | --flag] ...`
//! Typed getters with defaults; unknown-flag detection via [`Args::finish`].

use std::collections::BTreeMap;

use crate::util::{Error, Result};

/// Parsed command line: one optional subcommand + `--key value` pairs.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| {
                    Error::Config(format!("expected --flag, got {tok:?}"))
                })?
                .to_string();
            if let Some((k, v)) = key.split_once('=') {
                out.kv.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
            {
                out.kv.insert(key, it.next().unwrap());
            } else {
                out.flags.push(key);
            }
        }
        Ok(out)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{key} expects integer, got {v:?}"))
            }),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{key} expects integer, got {v:?}"))
            }),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{key} expects number, got {v:?}"))
            }),
        }
    }

    /// Comma-separated list of floats, e.g. `--lambdas 0.02,0.05,0.1`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim().parse().map_err(|_| {
                        Error::Config(format!("bad float {t:?} in --{key}"))
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated list of usizes, e.g. `--bits 3,6`.
    pub fn usize_list_or(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim().parse().map_err(|_| {
                        Error::Config(format!("bad int {t:?} in --{key}"))
                    })
                })
                .collect(),
        }
    }

    /// Error on any provided key that was never queried (typo protection).
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(Error::Config(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse(&["run", "--rounds", "50", "--lambda=0.05", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.usize_or("rounds", 0).unwrap(), 50);
        assert_eq!(a.f64_or("lambda", 0.0).unwrap(), 0.05);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.usize_or("k", 10).unwrap(), 10);
        assert_eq!(a.str_or("scheme", "rcfed"), "rcfed");
    }

    #[test]
    fn lists() {
        let a = parse(&["--bits", "3,6", "--lambdas", "0.02, 0.1"]);
        assert_eq!(a.usize_list_or("bits", &[]).unwrap(), vec![3, 6]);
        assert_eq!(a.f64_list_or("lambdas", &[]).unwrap(), vec![0.02, 0.1]);
    }

    #[test]
    fn unknown_option_detected() {
        let a = parse(&["--typo", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--rounds", "abc"]);
        assert!(a.usize_or("rounds", 1).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "7"]);
        assert!(a.has_flag("a"));
        assert_eq!(a.usize_or("b", 0).unwrap(), 7);
    }
}
