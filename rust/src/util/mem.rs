//! Process memory introspection (no external crates: parses
//! `/proc/self/status` directly).
//!
//! Backs the streamed round loop's flat-RSS evidence: the experiment
//! samples [`current_rss_kb`] at every round boundary and reports the
//! peak, and the CI smoke run asserts a ceiling on it. On platforms
//! without procfs the probe returns 0 and every consumer treats the
//! measurement as absent rather than failing.

/// Current resident-set size in KiB, or 0 when unavailable.
pub fn current_rss_kb() -> u64 {
    read_status_kb("VmRSS:").unwrap_or(0)
}

/// Kernel-tracked peak resident-set size in KiB, or 0 when unavailable.
/// (`VmHWM` is the high-water mark over the whole process lifetime, so
/// it can only grow; the per-round `VmRSS` samples are what show a flat
/// curve.)
pub fn peak_rss_kb() -> u64 {
    read_status_kb("VmHWM:").unwrap_or(0)
}

fn read_status_kb(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            // format: "VmRSS:\t   12345 kB"
            return rest
                .split_whitespace()
                .next()
                .and_then(|v| v.parse().ok());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_probe_is_sane() {
        let rss = current_rss_kb();
        if cfg!(target_os = "linux") {
            // a running test binary occupies at least a megabyte
            assert!(rss > 1024, "rss={rss}");
            assert!(peak_rss_kb() >= rss);
        }
    }

    #[test]
    fn growth_is_observable() {
        if !cfg!(target_os = "linux") {
            return;
        }
        let before = current_rss_kb();
        // touch ~32 MiB so the delta clears page-cache noise
        let v: Vec<u8> = (0..32 * 1024 * 1024).map(|i| i as u8).collect();
        let after = current_rss_kb();
        assert!(
            after > before + 16 * 1024,
            "rss {before} -> {after} after allocating {} bytes",
            v.len()
        );
    }
}
