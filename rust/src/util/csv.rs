//! CSV emission for experiment results (`results/*.csv`).
//!
//! Every bench/experiment writes a header row plus typed records; values
//! are formatted with enough precision to regenerate the paper's plots.

use std::fs::{create_dir_all, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::Result;

/// Streaming CSV writer with a fixed column schema.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncols: usize,
}

impl CsvWriter {
    /// Create (truncating) `path`, writing the header immediately. Parent
    /// directories are created as needed.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                create_dir_all(parent)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, ncols: header.len() })
    }

    /// Write one row; panics in debug builds if the arity mismatches.
    pub fn row(&mut self, fields: &[CsvField]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.ncols, "csv arity mismatch");
        let mut first = true;
        for f in fields {
            if !first {
                write!(self.out, ",")?;
            }
            first = false;
            match f {
                CsvField::Str(s) => write!(self.out, "{s}")?,
                CsvField::Int(i) => write!(self.out, "{i}")?,
                CsvField::Float(x) => write!(self.out, "{x:.6}")?,
                CsvField::Exp(x) => write!(self.out, "{x:e}")?,
            }
        }
        writeln!(self.out)?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// One CSV cell.
pub enum CsvField {
    Str(String),
    Int(i64),
    Float(f64),
    Exp(f64),
}

impl From<&str> for CsvField {
    fn from(s: &str) -> Self {
        CsvField::Str(s.to_string())
    }
}
impl From<String> for CsvField {
    fn from(s: String) -> Self {
        CsvField::Str(s)
    }
}
impl From<usize> for CsvField {
    fn from(x: usize) -> Self {
        CsvField::Int(x as i64)
    }
}
impl From<i64> for CsvField {
    fn from(x: i64) -> Self {
        CsvField::Int(x)
    }
}
impl From<u64> for CsvField {
    fn from(x: u64) -> Self {
        CsvField::Int(x as i64)
    }
}
impl From<f64> for CsvField {
    fn from(x: f64) -> Self {
        CsvField::Float(x)
    }
}

/// Shorthand: `csv_row!(w, "name", 3, 0.5)`.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($v:expr),+ $(,)?) => {
        $w.row(&[$($crate::util::csv::CsvField::from($v)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_csv_test_{}", std::process::id()));
        let path = dir.join("t.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["scheme", "round", "acc"]).unwrap();
            csv_row!(w, "rcfed", 1usize, 0.5f64).unwrap();
            csv_row!(w, "qsgd", 2usize, 0.25f64).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "scheme,round,acc");
        assert!(lines[1].starts_with("rcfed,1,0.5"));
        assert_eq!(lines.len(), 3);
        std::fs::remove_dir_all(dir).ok();
    }
}
