//! Minimal JSON codec (RFC 8259 subset) — parses the artifact manifest
//! written by `python/compile/aot.py` and serializes experiment results.
//!
//! Supports all JSON value kinds, UTF-8 strings with the standard escape
//! set (`\uXXXX` included, surrogate pairs handled), and numbers as `f64`.
//! No serde on this image, so this ~300-line codec is the substrate.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!(
                "trailing bytes at offset {}", p.i)));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the key name on miss.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Json(format!("expected usize, got {x}")));
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- serialization ------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for emitting results.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at offset {}, got {:?}",
                c as char, self.i, self.b[self.i] as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at offset {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at {}, got {:?}",
                        self.i, c as char)))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            out.insert(k, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at {}, got {:?}",
                        self.i, c as char)))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| {
                                Error::Json("bad \\u escape".into())
                            })?);
                        }
                        _ => {
                            return Err(Error::Json(format!(
                                "bad escape \\{}", e as char)))
                        }
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(Error::Json("truncated utf-8".into()));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| Error::Json(e.to_string()))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(Error::Json("truncated \\u escape".into()));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|e| Error::Json(e.to_string()))?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|e| Error::Json(e.to_string()))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| Error::Json(e.to_string()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number {s:?} at {start}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v, Json::Str("a\n\t\"\\ é 😀".into()));
        // non-escaped multibyte passthrough
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v, Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v"},"s":"a\"b","t":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        // a trimmed copy of what aot.py emits
        let src = r#"{"artifacts":{"moments":{"file":"moments.hlo.txt",
          "inputs":[{"dtype":"f32","shape":[65536]}],
          "outputs":[{"dtype":"f32","shape":[8]},{"dtype":"f32","shape":[8]}]}},
          "bits":[2,3,4,6],"block":8192,"chunk":65536,"version":1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("chunk").unwrap().as_usize().unwrap(), 65536);
        assert_eq!(
            v.req("bits").unwrap().usize_array().unwrap(),
            vec![2, 3, 4, 6]
        );
        let art = v.req("artifacts").unwrap().req("moments").unwrap();
        assert_eq!(art.req("file").unwrap().as_str().unwrap(),
                   "moments.hlo.txt");
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("missing").is_err());
        assert!(v.req("a").unwrap().as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }
}
