//! Leveled stderr logger with wallclock-relative timestamps.
//!
//! Level is set once per process (`RCFED_LOG=debug|info|warn|error` or
//! [`set_level`]); macros are cheap no-ops below the threshold.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    let lvl = match std::env::var("RCFED_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
    let _ = START.set(Instant::now());
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! debug {
    ($($a:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($a)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($a:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($a)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($a:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($a)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
