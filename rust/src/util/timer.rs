//! Wallclock timing + lightweight statistics for the in-tree bench harness
//! (no `criterion` on this image).

use std::time::{Duration, Instant};

/// Scoped stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Summary statistics over repeated measurements (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub samples: Vec<f64>,
}

impl BenchStats {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }

    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs; returns per-run
/// wallclock stats. `f` should do a fixed amount of work per call.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    BenchStats { samples }
}

/// Standard one-line bench report: name, median, mean±sd, derived
/// throughput (items/s) if `items_per_iter > 0`.
pub fn report(name: &str, stats: &BenchStats, items_per_iter: f64) {
    let med = stats.median();
    if items_per_iter > 0.0 {
        println!(
            "{name:<44} median {:>10.3} ms   mean {:>10.3} ms ± {:>7.3}   {:>12.2} Mitems/s",
            med * 1e3,
            stats.mean() * 1e3,
            stats.stddev() * 1e3,
            items_per_iter / med / 1e6,
        );
    } else {
        println!(
            "{name:<44} median {:>10.3} ms   mean {:>10.3} ms ± {:>7.3}",
            med * 1e3,
            stats.mean() * 1e3,
            stats.stddev() * 1e3,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let stats = bench(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(stats.samples.len(), 5);
        assert!(stats.min() >= 0.0);
        assert!(stats.mean() >= stats.min());
    }

    #[test]
    fn stats_math() {
        let s = BenchStats { samples: vec![1.0, 2.0, 3.0] };
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.min(), 1.0);
        assert!((s.stddev() - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
