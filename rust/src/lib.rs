//! # RC-FED — Rate-Constrained Quantization for Communication-Efficient FL
//!
//! Production-grade reproduction of *"Rate-Constrained Quantization for
//! Communication-Efficient Federated Learning"* (Mohajer Hamidi & Bereyhi,
//! 2024). The crate is the **Layer-3 rust coordinator** of a three-layer
//! stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the fused
//!   normalize→bucketize→dequantize gradient-compression hot spot.
//! * **L2** — JAX model graphs (`python/compile/model.py`): client
//!   train/eval steps. Both layers are AOT-lowered **once** to HLO text
//!   (`make artifacts`); Python never runs on the request path.
//! * **L3** — this crate: the federated-learning system. Quantizer design
//!   (the paper's contribution, [`quant::rcq`]), entropy coding
//!   ([`coding`]), federated data ([`data`]), the client/server runtime
//!   ([`fl`]), the round scheduler ([`coordinator`]) and the PJRT bridge
//!   ([`runtime`]).
//!
//! ## Quick start
//!
//! ```no_run
//! use rcfed::prelude::*;
//! use rcfed::quant::rcq::LengthModel;
//!
//! let mut cfg = ExperimentConfig::synth_cifar();
//! cfg.scheme = SchemeConfig::RcFed {
//!     bits: 3,
//!     lambda: 0.05,
//!     length_model: LengthModel::Huffman,
//! };
//! cfg.rounds = 20;
//! let report = run_experiment(&cfg).unwrap();
//! println!("acc={:.3} uplink={:.3} Gb", report.final_accuracy,
//!          report.uplink_gigabits());
//! ```
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! harnesses regenerating every figure in the paper (DESIGN.md §Experiment
//! index).

pub mod coding;
pub mod coordinator;
pub mod data;
pub mod fl;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::experiment::{
        run_experiment, ExperimentConfig, ExperimentReport, SchemeConfig,
    };
    pub use crate::coordinator::network::{
        ChannelSpec, ChannelStats, SimulatedNetwork,
    };
    pub use crate::coordinator::sweep::{
        run_design_sweep, run_sweep, DesignGrid, SweepGrid, SweepReport,
    };
    pub use crate::coding::huffman::HuffmanCode;
    pub use crate::data::{DatasetConfig, FederatedDataset};
    pub use crate::fl::compression::{
        designed_codebook, CompressionPipeline, CompressionScheme,
        Compressor, RateTarget,
    };
    pub use crate::quant::{
        codebook::Codebook, lloyd::LloydMax, rcq::RateConstrainedQuantizer,
    };
    pub use crate::stats::gaussian::StdGaussian;
    pub use crate::util::rng::Rng;
}
