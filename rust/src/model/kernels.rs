//! Blocked model-compute kernels for the native MLP backend, each pinned
//! to a scalar `*_reference` twin with the **same accumulation tree**.
//!
//! The fast kernels restructure the loops for cache locality and
//! autovectorization — row blocks of the weight matrix stay resident
//! across the batch, inner loops run over contiguous lanes — without
//! reordering any floating-point addition: per output element the adds
//! happen in exactly the order the reference twin performs them, so fast
//! and reference results are byte-identical (pinned by the differential
//! battery below and consumed by `benches/model_throughput.rs`). That in
//! turn is what keeps the round loop thread-count invariant: every
//! worker computes bit-for-bit the same gradient regardless of which
//! kernel tier runs.
//!
//! No `unsafe`: the speed comes from shapes the compiler can vectorize
//! (contiguous axpy rows, fixed-width partial-sum lanes), not intrinsics.

// The reference twins are *deliberately* index-walked scalar loops — the
// pre-tier shapes the bench compares against — so the iterator rewrites
// clippy suggests would defeat their purpose.
#![allow(clippy::needless_range_loop)]

/// Rows of the weight matrix processed per cache block: a block of
/// `ROW_BLOCK × o` weights (≤ 32 KiB at o = 128) stays L1/L2-resident
/// while the whole batch streams against it.
pub const ROW_BLOCK: usize = 64;

/// Partial-sum lanes in the dot-product reductions ([`backprop_delta`]).
/// Fixed width so the fast and reference twins share one combine tree.
pub const LANES: usize = 8;

/// Dense layer forward: `out[n, :] = b + Σ_i x[n, i] · w[i, :]` for a
/// row-major `w` of shape `[i_dim, o_dim]`.
///
/// Blocked over rows of `w` so each weight block is reused across the
/// whole batch; the inner axpy over `o_dim` is contiguous and
/// vectorizable. Coordinates with `x[n, i] == 0.0` are skipped (ReLU
/// sparsity) — the skip predicate is shared verbatim with the reference
/// twin because adding `0.0 · w` is not a no-op for `-0.0` outputs.
pub fn matvec_bias(
    w: &[f32],
    b: &[f32],
    x: &[f32],
    batch: usize,
    i_dim: usize,
    o_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), i_dim * o_dim);
    debug_assert_eq!(b.len(), o_dim);
    debug_assert_eq!(x.len(), batch * i_dim);
    debug_assert_eq!(out.len(), batch * o_dim);
    for ic in (0..i_dim).step_by(ROW_BLOCK) {
        let ie = (ic + ROW_BLOCK).min(i_dim);
        for n in 0..batch {
            let row = &x[n * i_dim + ic..n * i_dim + ie];
            let o = &mut out[n * o_dim..(n + 1) * o_dim];
            if ic == 0 {
                o.copy_from_slice(b);
            }
            for (ii, &xv) in row.iter().enumerate() {
                if xv == 0.0 {
                    continue; // ReLU sparsity
                }
                let wrow = &w[(ic + ii) * o_dim..(ic + ii + 1) * o_dim];
                for (oj, &wij) in o.iter_mut().zip(wrow) {
                    *oj += xv * wij;
                }
            }
        }
    }
}

/// Scalar twin of [`matvec_bias`]: per-output-element strided dot
/// products (stride-`o_dim` weight access, serial f32 reduction — the
/// cache-hostile, non-vectorizable form). Same adds in the same order
/// per element as the blocked kernel, so results are byte-identical.
pub fn matvec_bias_reference(
    w: &[f32],
    b: &[f32],
    x: &[f32],
    batch: usize,
    i_dim: usize,
    o_dim: usize,
    out: &mut [f32],
) {
    for n in 0..batch {
        let row = &x[n * i_dim..(n + 1) * i_dim];
        for j in 0..o_dim {
            let mut acc = b[j];
            for (ii, &xv) in row.iter().enumerate() {
                if xv == 0.0 {
                    continue; // ReLU sparsity
                }
                acc += xv * w[ii * o_dim + j];
            }
            out[n * o_dim + j] = acc;
        }
    }
}

/// Weight-gradient rank-1 accumulation:
/// `gw[i, :] += Σ_n x[n, i] · delta[n, :]`.
///
/// Same row-blocking as the forward: a block of `gw` rows stays resident
/// while the batch streams through, and per `(i, j)` the batch terms add
/// in ascending `n` — identical tree to the reference twin.
pub fn grad_weights(
    x: &[f32],
    delta: &[f32],
    batch: usize,
    i_dim: usize,
    o_dim: usize,
    gw: &mut [f32],
) {
    debug_assert_eq!(x.len(), batch * i_dim);
    debug_assert_eq!(delta.len(), batch * o_dim);
    debug_assert_eq!(gw.len(), i_dim * o_dim);
    for ic in (0..i_dim).step_by(ROW_BLOCK) {
        let ie = (ic + ROW_BLOCK).min(i_dim);
        for n in 0..batch {
            let row = &x[n * i_dim + ic..n * i_dim + ie];
            let drow = &delta[n * o_dim..(n + 1) * o_dim];
            for (ii, &xv) in row.iter().enumerate() {
                if xv == 0.0 {
                    continue; // ReLU sparsity
                }
                let grow = &mut gw[(ic + ii) * o_dim..(ic + ii + 1) * o_dim];
                for (g, &d) in grow.iter_mut().zip(drow) {
                    *g += xv * d;
                }
            }
        }
    }
}

/// Scalar twin of [`grad_weights`]: per-element strided batch reduction
/// (stride-`i_dim` activations, stride-`o_dim` deltas). Byte-identical.
pub fn grad_weights_reference(
    x: &[f32],
    delta: &[f32],
    batch: usize,
    i_dim: usize,
    o_dim: usize,
    gw: &mut [f32],
) {
    for ii in 0..i_dim {
        for j in 0..o_dim {
            let mut acc = gw[ii * o_dim + j];
            for n in 0..batch {
                let xv = x[n * i_dim + ii];
                if xv == 0.0 {
                    continue; // ReLU sparsity
                }
                acc += xv * delta[n * o_dim + j];
            }
            gw[ii * o_dim + j] = acc;
        }
    }
}

/// Bias-gradient accumulation: `gb[:] += Σ_n delta[n, :]`, batch terms
/// in ascending `n` per output (contiguous vectorizable inner loop).
pub fn grad_bias(delta: &[f32], batch: usize, o_dim: usize, gb: &mut [f32]) {
    debug_assert_eq!(delta.len(), batch * o_dim);
    debug_assert_eq!(gb.len(), o_dim);
    for n in 0..batch {
        let drow = &delta[n * o_dim..(n + 1) * o_dim];
        for (g, &d) in gb.iter_mut().zip(drow) {
            *g += d;
        }
    }
}

/// Scalar twin of [`grad_bias`]: per-output strided batch reduction.
pub fn grad_bias_reference(
    delta: &[f32],
    batch: usize,
    o_dim: usize,
    gb: &mut [f32],
) {
    for (j, g) in gb.iter_mut().enumerate() {
        let mut acc = *g;
        for n in 0..batch {
            acc += delta[n * o_dim + j];
        }
        *g = acc;
    }
}

/// Backpropagated delta through a dense layer with a ReLU mask:
/// `nd[n, i] = Σ_j delta[n, j] · w[i, j]` where `h[n, i] > 0`, else
/// `0.0` (written explicitly — the buffer is reused, not fresh-zeroed).
///
/// The reduction over `j` runs as [`LANES`] independent partial sums
/// combined in fixed lane order — the one tree both twins share. The
/// fast kernel walks the lanes as contiguous chunks (vectorizable); the
/// reference twin walks each lane as a strided scalar pass.
pub fn backprop_delta(
    w: &[f32],
    delta: &[f32],
    h: &[f32],
    batch: usize,
    i_dim: usize,
    o_dim: usize,
    nd: &mut [f32],
) {
    debug_assert_eq!(w.len(), i_dim * o_dim);
    debug_assert_eq!(delta.len(), batch * o_dim);
    debug_assert_eq!(h.len(), batch * i_dim);
    debug_assert_eq!(nd.len(), batch * i_dim);
    for n in 0..batch {
        let drow = &delta[n * o_dim..(n + 1) * o_dim];
        let hrow = &h[n * i_dim..(n + 1) * i_dim];
        let ndrow = &mut nd[n * i_dim..(n + 1) * i_dim];
        for ii in 0..i_dim {
            if hrow[ii] <= 0.0 {
                ndrow[ii] = 0.0; // ReLU gradient mask
                continue;
            }
            let wrow = &w[ii * o_dim..(ii + 1) * o_dim];
            let mut lanes = [0f32; LANES];
            let mut dc = drow.chunks_exact(LANES);
            let mut wc = wrow.chunks_exact(LANES);
            for (dv, wv) in (&mut dc).zip(&mut wc) {
                for l in 0..LANES {
                    lanes[l] += dv[l] * wv[l];
                }
            }
            for (l, (&dv, &wv)) in
                dc.remainder().iter().zip(wc.remainder()).enumerate()
            {
                lanes[l] += dv * wv;
            }
            let mut acc = 0f32;
            for &lane in &lanes {
                acc += lane;
            }
            ndrow[ii] = acc;
        }
    }
}

/// Scalar twin of [`backprop_delta`]: each of the [`LANES`] partial sums
/// is a serial strided pass over `j ≡ l (mod LANES)` — the same terms in
/// the same per-lane order and the same fixed combine, so byte-identical
/// to the chunked kernel.
pub fn backprop_delta_reference(
    w: &[f32],
    delta: &[f32],
    h: &[f32],
    batch: usize,
    i_dim: usize,
    o_dim: usize,
    nd: &mut [f32],
) {
    for n in 0..batch {
        let drow = &delta[n * o_dim..(n + 1) * o_dim];
        let hrow = &h[n * i_dim..(n + 1) * i_dim];
        let ndrow = &mut nd[n * i_dim..(n + 1) * i_dim];
        for ii in 0..i_dim {
            if hrow[ii] <= 0.0 {
                ndrow[ii] = 0.0; // ReLU gradient mask
                continue;
            }
            let mut lanes = [0f32; LANES];
            for (l, lane) in lanes.iter_mut().enumerate() {
                let mut j = l;
                while j < o_dim {
                    *lane += drow[j] * w[ii * o_dim + j];
                    j += LANES;
                }
            }
            let mut acc = 0f32;
            for &lane in &lanes {
                acc += lane;
            }
            ndrow[ii] = acc;
        }
    }
}

/// Fused SGD step `p[i] -= lr · g[i]` over one contiguous span — the ONE
/// traversal behind the client's local step, the server's aggregate step
/// and `ParamSet::sgd_step`'s per-tensor walk (which previously indexed
/// the flat gradient element by element).
pub fn sgd_step(params: &mut [f32], grad: &[f32], lr: f32) {
    debug_assert_eq!(params.len(), grad.len());
    for (p, &g) in params.iter_mut().zip(grad) {
        *p -= lr * g;
    }
}

/// Scalar twin of [`sgd_step`] (indexed element walk). The op per
/// element is identical, so the pair is byte-identical by construction;
/// it exists to complete the differential battery and give the bench a
/// baseline row.
pub fn sgd_step_reference(params: &mut [f32], grad: &[f32], lr: f32) {
    for i in 0..params.len() {
        params[i] -= lr * grad[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize, zeros: bool) -> Vec<f32> {
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        if zeros {
            // inject exact zeros so the ReLU-sparsity skip is exercised
            for x in v.iter_mut().step_by(3) {
                *x = 0.0;
            }
        }
        v
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Ragged shapes around the block/lane widths: non-multiples of
    /// ROW_BLOCK and LANES, degenerate 1s, and a shape larger than one
    /// row block.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 7, 5),
        (16, 32, 4),
        (5, 63, 65),
        (33, 130, 62),
        (2, 100, 9),
    ];

    #[test]
    fn matvec_bias_matches_reference_bitwise() {
        let mut rng = Rng::new(11);
        for &(batch, i, o) in SHAPES {
            let w = fill(&mut rng, i * o, false);
            let b = fill(&mut rng, o, false);
            let x = fill(&mut rng, batch * i, true);
            let mut fast = vec![0f32; batch * o];
            let mut refr = vec![1f32; batch * o]; // dirty: must be overwritten
            matvec_bias(&w, &b, &x, batch, i, o, &mut fast);
            matvec_bias_reference(&w, &b, &x, batch, i, o, &mut refr);
            assert_eq!(bits(&fast), bits(&refr), "shape {batch}x{i}x{o}");
        }
    }

    #[test]
    fn grad_weights_matches_reference_bitwise() {
        let mut rng = Rng::new(12);
        for &(batch, i, o) in SHAPES {
            let x = fill(&mut rng, batch * i, true);
            let d = fill(&mut rng, batch * o, false);
            // non-zero starting accumulator: the kernels accumulate
            let g0 = fill(&mut rng, i * o, false);
            let mut fast = g0.clone();
            let mut refr = g0.clone();
            grad_weights(&x, &d, batch, i, o, &mut fast);
            grad_weights_reference(&x, &d, batch, i, o, &mut refr);
            assert_eq!(bits(&fast), bits(&refr), "shape {batch}x{i}x{o}");
        }
    }

    #[test]
    fn grad_bias_matches_reference_bitwise() {
        let mut rng = Rng::new(13);
        for &(batch, _, o) in SHAPES {
            let d = fill(&mut rng, batch * o, false);
            let g0 = fill(&mut rng, o, false);
            let mut fast = g0.clone();
            let mut refr = g0.clone();
            grad_bias(&d, batch, o, &mut fast);
            grad_bias_reference(&d, batch, o, &mut refr);
            assert_eq!(bits(&fast), bits(&refr), "batch {batch} o {o}");
        }
    }

    #[test]
    fn backprop_delta_matches_reference_bitwise() {
        let mut rng = Rng::new(14);
        for &(batch, i, o) in SHAPES {
            let w = fill(&mut rng, i * o, false);
            let d = fill(&mut rng, batch * o, false);
            // mix of positive / zero / negative activations so both the
            // mask write and the lane reduction run
            let h = fill(&mut rng, batch * i, true);
            let mut fast = vec![7f32; batch * i]; // dirty: mask must zero it
            let mut refr = vec![-7f32; batch * i];
            backprop_delta(&w, &d, &h, batch, i, o, &mut fast);
            backprop_delta_reference(&w, &d, &h, batch, i, o, &mut refr);
            assert_eq!(bits(&fast), bits(&refr), "shape {batch}x{i}x{o}");
        }
    }

    #[test]
    fn sgd_step_matches_reference_bitwise() {
        let mut rng = Rng::new(15);
        for n in [0usize, 1, 7, 64, 1000] {
            let g = fill(&mut rng, n, false);
            let p0 = fill(&mut rng, n, false);
            let mut fast = p0.clone();
            let mut refr = p0;
            sgd_step(&mut fast, &g, 0.05);
            sgd_step_reference(&mut refr, &g, 0.05);
            assert_eq!(bits(&fast), bits(&refr), "n {n}");
        }
    }

    #[test]
    fn non_finite_inputs_propagate_identically() {
        // NaN / ±∞ in weights, activations and deltas must flow through
        // both twins identically (bit-compare, NaN included): the skip
        // predicates are on exact zero, never on finiteness
        let mut rng = Rng::new(16);
        let (batch, i, o) = (4usize, 19usize, 11usize);
        let mut w = fill(&mut rng, i * o, false);
        let mut x = fill(&mut rng, batch * i, true);
        let mut d = fill(&mut rng, batch * o, false);
        w[5] = f32::NAN;
        w[i * o - 1] = f32::INFINITY;
        x[3] = f32::NEG_INFINITY;
        d[1] = f32::NAN;
        let b = fill(&mut rng, o, false);

        let mut fast = vec![0f32; batch * o];
        let mut refr = vec![0f32; batch * o];
        matvec_bias(&w, &b, &x, batch, i, o, &mut fast);
        matvec_bias_reference(&w, &b, &x, batch, i, o, &mut refr);
        assert_eq!(bits(&fast), bits(&refr));

        let mut gf = vec![0f32; i * o];
        let mut gr = vec![0f32; i * o];
        grad_weights(&x, &d, batch, i, o, &mut gf);
        grad_weights_reference(&x, &d, batch, i, o, &mut gr);
        assert_eq!(bits(&gf), bits(&gr));

        let h = fill(&mut rng, batch * i, true);
        let mut nf = vec![0f32; batch * i];
        let mut nr = vec![0f32; batch * i];
        backprop_delta(&w, &d, &h, batch, i, o, &mut nf);
        backprop_delta_reference(&w, &d, &h, batch, i, o, &mut nr);
        assert_eq!(bits(&nf), bits(&nr));
    }

    #[test]
    fn zero_batch_touches_nothing() {
        // batch = 0 is rejected upstream (NativeMlp::check_batch); the
        // kernels themselves must simply leave the outputs alone
        let mut gw = vec![3f32; 6];
        grad_weights(&[], &[], 0, 2, 3, &mut gw);
        grad_weights_reference(&[], &[], 0, 2, 3, &mut gw);
        assert_eq!(gw, vec![3f32; 6]);
        let mut gb = vec![2f32; 3];
        grad_bias(&[], 0, 3, &mut gb);
        assert_eq!(gb, vec![2f32; 3]);
    }
}
