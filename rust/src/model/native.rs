//! Pure-rust MLP backend.
//!
//! Mirrors `python/compile/model.py::_mlp_logits` exactly: parameters in
//! `(w0, b0, w1, b1, …)` order, weights `[in, out]` row-major, ReLU
//! between layers, mean softmax cross-entropy. Used for the wide Fig. 1
//! sweeps (hundreds of rounds × many configs) where PJRT round-trips per
//! client step would dominate; numerics are cross-validated against the
//! AOT JAX graph in `rust/tests/pjrt_roundtrip.rs`.
//!
//! The compute itself runs on the blocked kernels in
//! [`crate::model::kernels`] with a caller-owned [`ModelScratch`]
//! workspace, so a warm `grad_with`/`eval_with` call allocates nothing.
//! [`NativeMlp::grad_reference`] re-runs the identical pipeline on the
//! scalar `*_reference` twins — byte-identical output (pinned below),
//! and the baseline the `model_throughput` bench measures against.

use crate::model::{kernels, Backend, ModelScratch};
use crate::util::rng::Rng;
use crate::util::{Error, Result};

/// MLP architecture + scratch-space layout.
#[derive(Clone, Debug)]
pub struct NativeMlp {
    /// layer widths: `[in, h1, …, classes]`
    pub dims: Vec<usize>,
    batch: usize,
    /// per-layer `(w_l, b_l)` offsets into the flat parameter vector,
    /// cached at construction (previously rebuilt on every call)
    offs: Vec<(usize, usize)>,
    /// total parameter count, cached at construction
    d: usize,
}

impl NativeMlp {
    pub fn new(dims: Vec<usize>, batch: usize) -> NativeMlp {
        assert!(dims.len() >= 2, "need at least input and output dims");
        let layers = dims.len() - 1;
        let mut offs = Vec::with_capacity(layers);
        let mut off = 0;
        for l in 0..layers {
            let (i, o) = (dims[l], dims[l + 1]);
            offs.push((off, off + i * o));
            off += i * o + o;
        }
        NativeMlp { dims, batch, offs, d: off }
    }

    /// The `mlp_synthcifar` architecture from the manifest.
    pub fn synth_cifar() -> NativeMlp {
        NativeMlp::new(vec![768, 256, 128, 10], 64)
    }

    /// MLP stand-in for the FEMNIST CNN on flattened features (native
    /// fast path; the CNN itself runs via the PJRT backend).
    pub fn synth_femnist() -> NativeMlp {
        NativeMlp::new(vec![784, 128, 62], 32)
    }

    pub fn tiny() -> NativeMlp {
        NativeMlp::new(vec![32, 32, 4], 16)
    }

    fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// (offset of w_l, offset of b_l) within the flat parameter vector.
    fn layer_offsets(&self) -> &[(usize, usize)] {
        &self.offs
    }

    /// Forward pass into the workspace: `scratch.acts[l]` holds the
    /// post-activation output of layer `l` (`acts[nl-1]` = logits). The
    /// input batch is read in place — never copied into the workspace.
    fn forward_into(
        &self,
        params: &[f32],
        xs: &[f32],
        batch: usize,
        scratch: &mut ModelScratch,
        reference: bool,
    ) {
        let nl = self.num_layers();
        while scratch.acts.len() < nl {
            scratch.acts.push(Vec::new());
        }
        for l in 0..nl {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let (wo, bo) = self.offs[l];
            let w = &params[wo..wo + i * o];
            let b = &params[bo..bo + o];
            let (prev, rest) = scratch.acts.split_at_mut(l);
            let h_in: &[f32] = if l == 0 { xs } else { &prev[l - 1] };
            let h = &mut rest[0];
            h.resize(batch * o, 0.0);
            if reference {
                kernels::matvec_bias_reference(w, b, h_in, batch, i, o, h);
            } else {
                kernels::matvec_bias(w, b, h_in, batch, i, o, h);
            }
            if l < nl - 1 {
                for x in h.iter_mut() {
                    if *x < 0.0 {
                        *x = 0.0;
                    }
                }
            }
        }
    }

    fn check_batch(&self, xs: &[f32], ys: &[i32]) -> Result<usize> {
        let f = self.dims[0];
        if xs.len() % f != 0 || xs.len() / f != ys.len() {
            return Err(Error::Config(format!(
                "batch shape mismatch: {} features, {} labels",
                xs.len(), ys.len())));
        }
        if ys.is_empty() {
            return Err(Error::Config("empty batch".into()));
        }
        Ok(ys.len())
    }

    /// Softmax + mean cross-entropy on the logits; writes `dL/dlogits`
    /// into `delta` (fully overwritten) and returns the mean loss.
    fn softmax_ce_delta(
        logits: &[f32],
        ys: &[i32],
        batch: usize,
        classes: usize,
        delta: &mut [f32],
    ) -> f32 {
        let mut loss = 0f64;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut zsum = 0f64;
            for &v in row {
                zsum += ((v - m) as f64).exp();
            }
            let logz = zsum.ln() as f32 + m;
            let y = ys[n] as usize;
            loss += (logz - row[y]) as f64;
            let drow = &mut delta[n * classes..(n + 1) * classes];
            for (c, dv) in drow.iter_mut().enumerate() {
                let p = ((row[c] - logz) as f64).exp() as f32;
                *dv = (p - (c == y) as usize as f32) / batch as f32;
            }
        }
        (loss / batch as f64) as f32
    }

    /// One shared gradient pipeline behind [`Backend::grad_with`] (fast
    /// kernels) and [`Self::grad_reference`] (scalar twins).
    fn grad_impl(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
        scratch: &mut ModelScratch,
        reference: bool,
    ) -> Result<f32> {
        let batch = self.check_batch(xs, ys)?;
        if grad_out.len() != self.d {
            return Err(Error::Config("grad_out length mismatch".into()));
        }
        self.forward_into(params, xs, batch, scratch, reference);
        let nl = self.num_layers();
        let classes = self.dims[nl];

        scratch.delta_a.resize(batch * classes, 0.0);
        let loss = NativeMlp::softmax_ce_delta(
            &scratch.acts[nl - 1], ys, batch, classes, &mut scratch.delta_a);

        grad_out.fill(0.0);
        for l in (0..nl).rev() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let (wo, bo) = self.offs[l];
            let h_in: &[f32] = if l == 0 {
                xs
            } else {
                &scratch.acts[l - 1]
            };
            let (gw, rest) = grad_out[wo..bo + o].split_at_mut(i * o);
            let gb = rest;
            if reference {
                kernels::grad_weights_reference(
                    h_in, &scratch.delta_a, batch, i, o, gw);
                kernels::grad_bias_reference(
                    &scratch.delta_a, batch, o, gb);
            } else {
                kernels::grad_weights(h_in, &scratch.delta_a, batch, i, o, gw);
                kernels::grad_bias(&scratch.delta_a, batch, o, gb);
            }
            if l > 0 {
                let w = &params[wo..wo + i * o];
                scratch.delta_b.resize(batch * i, 0.0);
                if reference {
                    kernels::backprop_delta_reference(
                        w, &scratch.delta_a, h_in, batch, i, o,
                        &mut scratch.delta_b);
                } else {
                    kernels::backprop_delta(
                        w, &scratch.delta_a, h_in, batch, i, o,
                        &mut scratch.delta_b);
                }
                std::mem::swap(&mut scratch.delta_a, &mut scratch.delta_b);
            }
        }
        Ok(loss)
    }

    /// Reference-twin gradient: the identical pipeline routed through
    /// the scalar `*_reference` kernels. Byte-identical to
    /// [`Backend::grad`] (pinned in the tests below); exists as the
    /// differential oracle and the `model_throughput` bench baseline.
    pub fn grad_reference(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
        scratch: &mut ModelScratch,
    ) -> Result<f32> {
        self.grad_impl(params, xs, ys, grad_out, scratch, true)
    }
}

impl Backend for NativeMlp {
    fn num_params(&self) -> usize {
        self.d
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        // He init on weights, zero biases — mirrors ParamSet::he_init and
        // model.py::init_params in structure.
        let mut rng = Rng::new(seed);
        let mut out = vec![0f32; self.d];
        for l in 0..self.num_layers() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let (wo, _) = self.layer_offsets()[l];
            let scale = (2.0 / i as f64).sqrt() as f32;
            rng.fill_normal_f32(&mut out[wo..wo + i * o], 0.0, scale);
        }
        out
    }

    fn grad(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32> {
        let mut scratch = ModelScratch::new();
        self.grad_with(params, xs, ys, grad_out, &mut scratch)
    }

    fn grad_with(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
        scratch: &mut ModelScratch,
    ) -> Result<f32> {
        self.grad_impl(params, xs, ys, grad_out, scratch, false)
    }

    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<usize> {
        let mut scratch = ModelScratch::new();
        self.eval_with(params, xs, ys, &mut scratch)
    }

    fn eval_with(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        scratch: &mut ModelScratch,
    ) -> Result<usize> {
        let batch = self.check_batch(xs, ys)?;
        self.forward_into(params, xs, batch, scratch, false);
        let classes = self.dims[self.num_layers()];
        let logits = &scratch.acts[self.num_layers() - 1];
        let mut correct = 0;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += (pred as i32 == ys[n]) as usize;
        }
        Ok(correct)
    }

    fn supports_parallel(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("native_mlp{:?}", self.dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(model: &NativeMlp, seed: u64, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let mut xs = vec![0f32; n * model.dims[0]];
        rng.fill_normal_f32(&mut xs, 0.0, 1.0);
        let classes = *model.dims.last().unwrap();
        let ys: Vec<i32> = (0..n).map(|_| rng.below(classes) as i32).collect();
        (xs, ys)
    }

    #[test]
    fn param_count_matches_manifest_formula() {
        let m = NativeMlp::synth_cifar();
        assert_eq!(
            m.num_params(),
            768 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10
        );
    }

    fn check_finite_differences(m: &NativeMlp, n: usize, seed: u64) {
        let params = m.init_params(seed);
        let (xs, ys) = batch(m, seed + 1, n);
        let mut g = vec![0f32; m.num_params()];
        let loss0 = m.grad(&params, &xs, &ys, &mut g).unwrap();
        assert!(loss0.is_finite());
        let mut rng = Rng::new(seed + 2);
        let eps = 1e-3f32;
        for _ in 0..12 {
            let i = rng.below(m.num_params());
            let mut pp = params.clone();
            pp[i] += eps;
            let mut tmp = vec![0f32; m.num_params()];
            let lp = m.grad(&pp, &xs, &ys, &mut tmp).unwrap();
            pp[i] -= 2.0 * eps;
            let lm = m.grad(&pp, &xs, &ys, &mut tmp).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 5e-2 * g[i].abs().max(0.1),
                "{} param {i}: fd={fd} ad={}", m.name(), g[i]
            );
        }
    }

    #[test]
    fn grad_matches_finite_differences() {
        check_finite_differences(&NativeMlp::tiny(), 8, 3);
    }

    #[test]
    fn grad_matches_finite_differences_odd_batches() {
        // batch sizes that are not multiples of the kernel block/lane
        // widths (and not the preset batch) exercise the ragged tails
        check_finite_differences(&NativeMlp::tiny(), 13, 17);
        check_finite_differences(&NativeMlp::tiny(), 1, 23);
    }

    #[test]
    fn grad_matches_finite_differences_femnist() {
        check_finite_differences(&NativeMlp::synth_femnist(), 5, 31);
    }

    #[test]
    fn grad_matches_finite_differences_cifar() {
        check_finite_differences(&NativeMlp::synth_cifar(), 9, 37);
    }

    #[test]
    fn fast_grad_is_bitwise_identical_to_reference_twin() {
        // the acceptance contract of the kernel tier: blocked kernels and
        // scalar reference twins share one accumulation tree, so the full
        // gradient (and the loss) agree to the bit at every preset shape
        // and at ragged batch sizes
        for (m, n) in [
            (NativeMlp::tiny(), 16usize),
            (NativeMlp::tiny(), 13),
            (NativeMlp::synth_femnist(), 7),
            (NativeMlp::synth_cifar(), 5),
        ] {
            let params = m.init_params(41);
            let (xs, ys) = batch(&m, 42, n);
            let mut scratch = ModelScratch::new();
            let mut fast = vec![0f32; m.num_params()];
            let lf = m
                .grad_with(&params, &xs, &ys, &mut fast, &mut scratch)
                .unwrap();
            let mut refr = vec![0f32; m.num_params()];
            let lr = m
                .grad_reference(&params, &xs, &ys, &mut refr, &mut scratch)
                .unwrap();
            assert_eq!(lf.to_bits(), lr.to_bits(), "{} loss", m.name());
            let fb: Vec<u32> = fast.iter().map(|x| x.to_bits()).collect();
            let rb: Vec<u32> = refr.iter().map(|x| x.to_bits()).collect();
            assert_eq!(fb, rb, "{} batch {n}", m.name());
        }
    }

    #[test]
    fn scratch_reuse_is_bitwise_identical_to_fresh() {
        // a dirty workspace (different model, different batch size) must
        // not change a single bit of the next call's results
        let m = NativeMlp::synth_femnist();
        let params = m.init_params(51);
        let (xs, ys) = batch(&m, 52, 9);
        let mut fresh = vec![0f32; m.num_params()];
        let l0 = m.grad(&params, &xs, &ys, &mut fresh).unwrap();
        let mut scratch = ModelScratch::new();
        // dirty the scratch: bigger batch on this model + another model
        let (xs2, ys2) = batch(&m, 53, 32);
        m.grad_with(&params, &xs2, &ys2, &mut fresh.clone(), &mut scratch)
            .unwrap();
        let other = NativeMlp::synth_cifar();
        let op = other.init_params(54);
        let (xs3, ys3) = batch(&other, 55, 4);
        let mut og = vec![0f32; other.num_params()];
        other.grad_with(&op, &xs3, &ys3, &mut og, &mut scratch).unwrap();
        // now the original call through the dirty scratch
        let mut warm = vec![0f32; m.num_params()];
        let l1 = m.grad_with(&params, &xs, &ys, &mut warm, &mut scratch)
            .unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits());
        assert_eq!(
            fresh.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            warm.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // and eval through the same dirty scratch matches fresh eval
        assert_eq!(
            m.eval(&params, &xs, &ys).unwrap(),
            m.eval_with(&params, &xs, &ys, &mut scratch).unwrap()
        );
    }

    #[test]
    fn sgd_reduces_loss() {
        let m = NativeMlp::tiny();
        let mut params = m.init_params(6);
        let (xs, ys) = batch(&m, 7, 16);
        let mut g = vec![0f32; m.num_params()];
        let first = m.grad(&params, &xs, &ys, &mut g).unwrap();
        let mut last = first;
        for _ in 0..50 {
            last = m.grad(&params, &xs, &ys, &mut g).unwrap();
            for (p, &gv) in params.iter_mut().zip(&g) {
                *p -= 0.1 * gv;
            }
        }
        assert!(last < 0.5 * first, "loss {first} -> {last}");
    }

    #[test]
    fn eval_counts_correct() {
        let m = NativeMlp::tiny();
        let params = m.init_params(8);
        let (xs, ys) = batch(&m, 9, 32);
        let c = m.eval(&params, &xs, &ys).unwrap();
        assert!(c <= 32);
        // after overfitting a small batch, accuracy should be high
        let mut params = params;
        let mut g = vec![0f32; m.num_params()];
        for _ in 0..200 {
            m.grad(&params, &xs, &ys, &mut g).unwrap();
            for (p, &gv) in params.iter_mut().zip(&g) {
                *p -= 0.2 * gv;
            }
        }
        let c = m.eval(&params, &xs, &ys).unwrap();
        assert!(c > 28, "only {c}/32 after overfitting");
    }

    #[test]
    fn shape_mismatch_errors() {
        let m = NativeMlp::tiny();
        let params = m.init_params(0);
        let mut g = vec![0f32; m.num_params()];
        assert!(m.grad(&params, &[0.0; 31], &[0], &mut g).is_err());
        assert!(m.grad(&params, &[0.0; 32], &[0], &mut [0.0; 3]).is_err());
    }

    #[test]
    fn zero_batch_rejected() {
        // an empty batch would make the mean loss 0/0; reject it before
        // the kernels run (for grad AND eval)
        let m = NativeMlp::tiny();
        let params = m.init_params(0);
        let mut g = vec![0f32; m.num_params()];
        assert!(m.grad(&params, &[], &[], &mut g).is_err());
        assert!(m.eval(&params, &[], &[]).is_err());
    }

    #[test]
    fn deterministic_init() {
        let m = NativeMlp::tiny();
        assert_eq!(m.init_params(1), m.init_params(1));
        assert_ne!(m.init_params(1), m.init_params(2));
    }
}
