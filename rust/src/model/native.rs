//! Pure-rust MLP backend.
//!
//! Mirrors `python/compile/model.py::_mlp_logits` exactly: parameters in
//! `(w0, b0, w1, b1, …)` order, weights `[in, out]` row-major, ReLU
//! between layers, mean softmax cross-entropy. Used for the wide Fig. 1
//! sweeps (hundreds of rounds × many configs) where PJRT round-trips per
//! client step would dominate; numerics are cross-validated against the
//! AOT JAX graph in `rust/tests/pjrt_roundtrip.rs`.

use crate::model::Backend;
use crate::util::rng::Rng;
use crate::util::{Error, Result};

/// MLP architecture + scratch-space layout.
#[derive(Clone, Debug)]
pub struct NativeMlp {
    /// layer widths: `[in, h1, …, classes]`
    pub dims: Vec<usize>,
    batch: usize,
}

impl NativeMlp {
    pub fn new(dims: Vec<usize>, batch: usize) -> NativeMlp {
        assert!(dims.len() >= 2, "need at least input and output dims");
        NativeMlp { dims, batch }
    }

    /// The `mlp_synthcifar` architecture from the manifest.
    pub fn synth_cifar() -> NativeMlp {
        NativeMlp::new(vec![768, 256, 128, 10], 64)
    }

    /// MLP stand-in for the FEMNIST CNN on flattened features (native
    /// fast path; the CNN itself runs via the PJRT backend).
    pub fn synth_femnist() -> NativeMlp {
        NativeMlp::new(vec![784, 128, 62], 32)
    }

    pub fn tiny() -> NativeMlp {
        NativeMlp::new(vec![32, 32, 4], 16)
    }

    fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// (offset of w_l, offset of b_l) within the flat parameter vector.
    fn layer_offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.num_layers());
        let mut off = 0;
        for l in 0..self.num_layers() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            out.push((off, off + i * o));
            off += i * o + o;
        }
        out
    }

    /// Forward pass; returns per-layer activations (h0 = input batch).
    fn forward(&self, params: &[f32], xs: &[f32], batch: usize) -> Vec<Vec<f32>> {
        let offs = self.layer_offsets();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.num_layers() + 1);
        acts.push(xs.to_vec());
        for l in 0..self.num_layers() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let (wo, bo) = offs[l];
            let w = &params[wo..wo + i * o];
            let b = &params[bo..bo + o];
            let h_in = &acts[l];
            let mut h = vec![0f32; batch * o];
            // out[n, :] = Σ_i x[n, i] * w[i, :]  (axpy over rows: the inner
            // loop is a contiguous fused-multiply-add, auto-vectorizable)
            for n in 0..batch {
                let row = &h_in[n * i..(n + 1) * i];
                let out = &mut h[n * o..(n + 1) * o];
                out.copy_from_slice(b);
                for (ii, &x) in row.iter().enumerate() {
                    if x == 0.0 {
                        continue; // ReLU sparsity
                    }
                    let wrow = &w[ii * o..(ii + 1) * o];
                    for (oj, &wij) in out.iter_mut().zip(wrow) {
                        *oj += x * wij;
                    }
                }
            }
            if l < self.num_layers() - 1 {
                for x in h.iter_mut() {
                    if *x < 0.0 {
                        *x = 0.0;
                    }
                }
            }
            acts.push(h);
        }
        acts
    }

    fn check_batch(&self, xs: &[f32], ys: &[i32]) -> Result<usize> {
        let f = self.dims[0];
        if xs.len() % f != 0 || xs.len() / f != ys.len() {
            return Err(Error::Config(format!(
                "batch shape mismatch: {} features, {} labels",
                xs.len(), ys.len())));
        }
        Ok(ys.len())
    }
}

impl Backend for NativeMlp {
    fn num_params(&self) -> usize {
        (0..self.num_layers())
            .map(|l| self.dims[l] * self.dims[l + 1] + self.dims[l + 1])
            .sum()
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        // He init on weights, zero biases — mirrors ParamSet::he_init and
        // model.py::init_params in structure.
        let mut rng = Rng::new(seed);
        let mut out = vec![0f32; self.num_params()];
        let offs = self.layer_offsets();
        for l in 0..self.num_layers() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let (wo, _) = offs[l];
            let scale = (2.0 / i as f64).sqrt() as f32;
            rng.fill_normal_f32(&mut out[wo..wo + i * o], 0.0, scale);
        }
        out
    }

    fn grad(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32> {
        let batch = self.check_batch(xs, ys)?;
        if grad_out.len() != self.num_params() {
            return Err(Error::Config("grad_out length mismatch".into()));
        }
        let offs = self.layer_offsets();
        let acts = self.forward(params, xs, batch);
        let nl = self.num_layers();
        let classes = self.dims[nl];

        // softmax + CE on the last activation
        let logits = &acts[nl];
        let mut delta = vec![0f32; batch * classes]; // dL/dlogits
        let mut loss = 0f64;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut zsum = 0f64;
            for &v in row {
                zsum += ((v - m) as f64).exp();
            }
            let logz = zsum.ln() as f32 + m;
            let y = ys[n] as usize;
            loss += (logz - row[y]) as f64;
            let drow = &mut delta[n * classes..(n + 1) * classes];
            for (c, dv) in drow.iter_mut().enumerate() {
                let p = ((row[c] - logz) as f64).exp() as f32;
                *dv = (p - (c == y) as usize as f32) / batch as f32;
            }
        }
        let loss = (loss / batch as f64) as f32;

        grad_out.fill(0.0);
        // backprop
        let mut cur_delta = delta;
        for l in (0..nl).rev() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let (wo, bo) = offs[l];
            let h_in = &acts[l];
            // dW[i, :] += h_in[n, i] * delta[n, :]; db += delta[n, :]
            {
                let gw = &mut grad_out[wo..wo + i * o];
                for n in 0..batch {
                    let row = &h_in[n * i..(n + 1) * i];
                    let drow = &cur_delta[n * o..(n + 1) * o];
                    for (ii, &x) in row.iter().enumerate() {
                        if x == 0.0 {
                            continue;
                        }
                        let grow = &mut gw[ii * o..(ii + 1) * o];
                        for (g, &d) in grow.iter_mut().zip(drow) {
                            *g += x * d;
                        }
                    }
                }
            }
            {
                let gb = &mut grad_out[bo..bo + o];
                for n in 0..batch {
                    let drow = &cur_delta[n * o..(n + 1) * o];
                    for (g, &d) in gb.iter_mut().zip(drow) {
                        *g += d;
                    }
                }
            }
            if l > 0 {
                // dh_in[n, i] = Σ_j delta[n, j] w[i, j], masked by ReLU
                let w = &params[wo..wo + i * o];
                let mut next_delta = vec![0f32; batch * i];
                for n in 0..batch {
                    let drow = &cur_delta[n * o..(n + 1) * o];
                    let hrow = &acts[l][n * i..(n + 1) * i];
                    let ndrow = &mut next_delta[n * i..(n + 1) * i];
                    for ii in 0..i {
                        if hrow[ii] <= 0.0 {
                            continue; // ReLU gradient mask
                        }
                        let wrow = &w[ii * o..(ii + 1) * o];
                        let mut acc = 0f32;
                        for (d, wv) in drow.iter().zip(wrow) {
                            acc += d * wv;
                        }
                        ndrow[ii] = acc;
                    }
                }
                cur_delta = next_delta;
            }
        }
        Ok(loss)
    }

    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<usize> {
        let batch = self.check_batch(xs, ys)?;
        let acts = self.forward(params, xs, batch);
        let classes = self.dims[self.num_layers()];
        let logits = &acts[self.num_layers()];
        let mut correct = 0;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += (pred as i32 == ys[n]) as usize;
        }
        Ok(correct)
    }

    fn supports_parallel(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("native_mlp{:?}", self.dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(model: &NativeMlp, seed: u64, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let mut xs = vec![0f32; n * model.dims[0]];
        rng.fill_normal_f32(&mut xs, 0.0, 1.0);
        let classes = *model.dims.last().unwrap();
        let ys: Vec<i32> = (0..n).map(|_| rng.below(classes) as i32).collect();
        (xs, ys)
    }

    #[test]
    fn param_count_matches_manifest_formula() {
        let m = NativeMlp::synth_cifar();
        assert_eq!(
            m.num_params(),
            768 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10
        );
    }

    #[test]
    fn grad_matches_finite_differences() {
        let m = NativeMlp::tiny();
        let params = m.init_params(3);
        let (xs, ys) = batch(&m, 4, 8);
        let mut g = vec![0f32; m.num_params()];
        let loss0 = m.grad(&params, &xs, &ys, &mut g).unwrap();
        assert!(loss0.is_finite());
        let mut rng = Rng::new(5);
        let eps = 1e-3f32;
        for _ in 0..12 {
            let i = rng.below(m.num_params());
            let mut pp = params.clone();
            pp[i] += eps;
            let mut tmp = vec![0f32; m.num_params()];
            let lp = m.grad(&pp, &xs, &ys, &mut tmp).unwrap();
            pp[i] -= 2.0 * eps;
            let lm = m.grad(&pp, &xs, &ys, &mut tmp).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 5e-2 * g[i].abs().max(0.1),
                "param {i}: fd={fd} ad={}", g[i]
            );
        }
    }

    #[test]
    fn sgd_reduces_loss() {
        let m = NativeMlp::tiny();
        let mut params = m.init_params(6);
        let (xs, ys) = batch(&m, 7, 16);
        let mut g = vec![0f32; m.num_params()];
        let first = m.grad(&params, &xs, &ys, &mut g).unwrap();
        let mut last = first;
        for _ in 0..50 {
            last = m.grad(&params, &xs, &ys, &mut g).unwrap();
            for (p, &gv) in params.iter_mut().zip(&g) {
                *p -= 0.1 * gv;
            }
        }
        assert!(last < 0.5 * first, "loss {first} -> {last}");
    }

    #[test]
    fn eval_counts_correct() {
        let m = NativeMlp::tiny();
        let params = m.init_params(8);
        let (xs, ys) = batch(&m, 9, 32);
        let c = m.eval(&params, &xs, &ys).unwrap();
        assert!(c <= 32);
        // after overfitting a small batch, accuracy should be high
        let mut params = params;
        let mut g = vec![0f32; m.num_params()];
        for _ in 0..200 {
            m.grad(&params, &xs, &ys, &mut g).unwrap();
            for (p, &gv) in params.iter_mut().zip(&g) {
                *p -= 0.2 * gv;
            }
        }
        let c = m.eval(&params, &xs, &ys).unwrap();
        assert!(c > 28, "only {c}/32 after overfitting");
    }

    #[test]
    fn shape_mismatch_errors() {
        let m = NativeMlp::tiny();
        let params = m.init_params(0);
        let mut g = vec![0f32; m.num_params()];
        assert!(m.grad(&params, &[0.0; 31], &[0], &mut g).is_err());
        assert!(m.grad(&params, &[0.0; 32], &[0], &mut [0.0; 3]).is_err());
    }

    #[test]
    fn deterministic_init() {
        let m = NativeMlp::tiny();
        assert_eq!(m.init_params(1), m.init_params(1));
        assert_ne!(m.init_params(1), m.init_params(2));
    }
}
