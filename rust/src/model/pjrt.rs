//! PJRT model backend — the paper-faithful three-layer path.
//!
//! Executes the AOT JAX train/eval graphs (which embed the Pallas L1
//! kernels) through the [`crate::runtime::Engine`]. Parameters cross the
//! boundary as per-tensor literals in manifest order and are flattened
//! back into the single wire vector the compression pipeline quantizes.

use std::rc::Rc;

use crate::model::Backend;
use crate::runtime::artifacts::ModelManifest;
use crate::runtime::host::{HostTensor, ParamSet};
use crate::runtime::Engine;
use crate::util::{Error, Result};

/// A model served by the PJRT engine.
pub struct PjrtModel {
    engine: Rc<Engine>,
    model: ModelManifest,
}

impl PjrtModel {
    pub fn new(engine: Rc<Engine>, model_name: &str) -> Result<PjrtModel> {
        let model = engine.manifest().model(model_name)?.clone();
        Ok(PjrtModel { engine, model })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.model
    }

    fn batch_tensors(&self, xs: &[f32], ys: &[i32]) -> Result<(HostTensor, HostTensor)> {
        let feat: usize = self.model.input_shape.iter().product();
        let b = self.model.batch;
        if xs.len() != b * feat || ys.len() != b {
            return Err(Error::Config(format!(
                "pjrt batch shape: got {} feats / {} labels, want {}x{feat}",
                xs.len(), ys.len(), b)));
        }
        let mut xshape = vec![b];
        xshape.extend_from_slice(&self.model.input_shape);
        Ok((
            HostTensor::F32(xs.to_vec(), xshape),
            HostTensor::I32(ys.to_vec(), vec![b]),
        ))
    }

    fn param_tensors(&self, params: &[f32]) -> Result<Vec<HostTensor>> {
        let mut set = ParamSet::zeros(&self.model);
        set.unflatten_from(params)?;
        Ok(set
            .tensors
            .into_iter()
            .zip(&self.model.params)
            .map(|(t, p)| HostTensor::F32(t, p.shape.clone()))
            .collect())
    }
}

impl Backend for PjrtModel {
    fn num_params(&self) -> usize {
        self.model.num_params
    }

    fn batch_size(&self) -> usize {
        self.model.batch
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        ParamSet::he_init(&self.model, seed).flatten()
    }

    fn grad(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32> {
        if grad_out.len() != self.num_params() {
            return Err(Error::Config("grad_out length mismatch".into()));
        }
        let mut inputs = self.param_tensors(params)?;
        let (xt, yt) = self.batch_tensors(xs, ys)?;
        inputs.push(xt);
        inputs.push(yt);
        let outputs = self.engine.run(&self.model.train, &inputs)?;
        // outputs = grads (per tensor, manifest order) + scalar loss
        let mut off = 0;
        for g in &outputs[..outputs.len() - 1] {
            let v = g.as_f32()?;
            grad_out[off..off + v.len()].copy_from_slice(v);
            off += v.len();
        }
        debug_assert_eq!(off, self.num_params());
        let loss = outputs.last().unwrap().as_f32()?[0];
        Ok(loss)
    }

    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<usize> {
        let mut inputs = self.param_tensors(params)?;
        let (xt, yt) = self.batch_tensors(xs, ys)?;
        inputs.push(xt);
        inputs.push(yt);
        let outputs = self.engine.run(&self.model.eval, &inputs)?;
        Ok(outputs[0].as_i32()?[0] as usize)
    }

    fn name(&self) -> String {
        format!("pjrt_{}", self.model.name)
    }
}

// Tests for this backend require compiled artifacts and live in
// `rust/tests/pjrt_roundtrip.rs`.
