//! Model backends.
//!
//! The FL loop is generic over a [`Backend`]: something that can produce
//! a stochastic gradient and evaluate accuracy at given parameters.
//!
//! * [`native`] — pure-rust MLP (fast path for the large Fig. 1 sweeps;
//!   layout-compatible with the JAX `mlp_*` models, cross-validated in
//!   `rust/tests/pjrt_roundtrip.rs`);
//! * [`pjrt`] — the AOT JAX/Pallas graphs executed via the PJRT engine
//!   (the paper-faithful three-layer path);
//! * [`convex`] — L-smooth ρ-strongly-convex quadratics with exact optima
//!   for the Theorem-1 convergence harness (E4).

pub mod convex;
pub mod native;
pub mod pjrt;

use crate::util::Result;

/// A model the FL system can train.
///
/// Parameters travel as one flat `f32` vector (manifest order for PJRT
/// models); the compression pipeline quantizes exactly this vector.
pub trait Backend {
    /// Total parameter count `d`.
    fn num_params(&self) -> usize;

    /// Mini-batch size this backend expects.
    fn batch_size(&self) -> usize;

    /// Deterministic parameter initialization.
    fn init_params(&self, seed: u64) -> Vec<f32>;

    /// Compute `(∇f(θ; batch), loss)`; writes the gradient into
    /// `grad_out` (len = `num_params`) and returns the loss.
    fn grad(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32>;

    /// Correct predictions on a batch.
    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<usize>;

    /// Whether `grad`/`eval` may be called concurrently from threads.
    fn supports_parallel(&self) -> bool {
        false
    }

    fn name(&self) -> String;
}
