//! Model backends.
//!
//! The FL loop is generic over a [`Backend`]: something that can produce
//! a stochastic gradient and evaluate accuracy at given parameters.
//!
//! * [`native`] — pure-rust MLP (fast path for the large Fig. 1 sweeps;
//!   layout-compatible with the JAX `mlp_*` models, cross-validated in
//!   `rust/tests/pjrt_roundtrip.rs`);
//! * [`pjrt`] — the AOT JAX/Pallas graphs executed via the PJRT engine
//!   (the paper-faithful three-layer path);
//! * [`convex`] — L-smooth ρ-strongly-convex quadratics with exact optima
//!   for the Theorem-1 convergence harness (E4).

pub mod convex;
pub mod kernels;
pub mod native;
pub mod pjrt;

use crate::util::Result;

/// Reusable per-worker model workspace: the activation and delta buffers
/// a backend's forward/backward pass needs, pre-sized after the first
/// call so the warm training path allocates nothing.
///
/// Safe to share across clients: every buffer is fully overwritten
/// before it is read (the native backprop writes the ReLU mask's zeros
/// explicitly instead of relying on fresh-zeroed memory), so no state
/// leaks between the clients a worker drives. Rides in the round loop's
/// `RoundScratch` next to the codec scratch; backends that manage their
/// own device memory ([`pjrt`]) simply ignore it.
#[derive(Debug, Default)]
pub struct ModelScratch {
    /// per-layer post-activation buffers `h_1 … h_L` (the input batch is
    /// read in place, never copied)
    pub(crate) acts: Vec<Vec<f32>>,
    /// ping-pong backprop delta buffers
    pub(crate) delta_a: Vec<f32>,
    pub(crate) delta_b: Vec<f32>,
}

impl ModelScratch {
    pub fn new() -> ModelScratch {
        ModelScratch::default()
    }
}

/// A model the FL system can train.
///
/// Parameters travel as one flat `f32` vector (manifest order for PJRT
/// models); the compression pipeline quantizes exactly this vector.
pub trait Backend {
    /// Total parameter count `d`.
    fn num_params(&self) -> usize;

    /// Mini-batch size this backend expects.
    fn batch_size(&self) -> usize;

    /// Deterministic parameter initialization.
    fn init_params(&self, seed: u64) -> Vec<f32>;

    /// Compute `(∇f(θ; batch), loss)`; writes the gradient into
    /// `grad_out` (len = `num_params`) and returns the loss.
    fn grad(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32>;

    /// Correct predictions on a batch.
    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<usize>;

    /// [`Self::grad`] with a caller-owned [`ModelScratch`]: the round
    /// loop's zero-alloc entry point. Results are byte-identical to
    /// [`Self::grad`] — scratch is a buffer-reuse knob, never a results
    /// knob. Backends without reusable host buffers ignore the scratch
    /// (the default forwards to [`Self::grad`]).
    fn grad_with(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        grad_out: &mut [f32],
        _scratch: &mut ModelScratch,
    ) -> Result<f32> {
        self.grad(params, xs, ys, grad_out)
    }

    /// [`Self::eval`] with a caller-owned [`ModelScratch`] (same
    /// contract as [`Self::grad_with`]).
    fn eval_with(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        _scratch: &mut ModelScratch,
    ) -> Result<usize> {
        self.eval(params, xs, ys)
    }

    /// Whether `grad`/`eval` may be called concurrently from threads.
    fn supports_parallel(&self) -> bool {
        false
    }

    fn name(&self) -> String;
}
