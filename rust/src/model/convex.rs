//! Strongly-convex synthetic objective for the Theorem-1 harness (E4).
//!
//! Each client `k` holds `f_k(θ) = ½ (θ − c_k)ᵀ A_k (θ − c_k)` with
//! diagonal `A_k`, eigenvalues in `[ρ, L]`. Then:
//!
//! * every `f_k` is `ρ`-strongly convex and `L`-smooth (A-III, A-IV);
//! * `min_θ f_k = 0` at `θ = c_k`, so the heterogeneity gap is
//!   `Γ = f(θ*) − 0 = f(θ*)`;
//! * the global optimum is closed-form: `θ* = (Σ A_k)⁻¹ Σ A_k c_k`
//!   (diagonal ⇒ coordinate-wise).
//!
//! Stochasticity: `grad` adds bounded Gaussian mini-batch noise so (A-I)
//! and (A-II) are exercised. Exact `f(θ) − f(θ*)` is available, which is
//! what Theorem 1 bounds.

use crate::model::Backend;
use crate::util::rng::Rng;
use crate::util::{Error, Result};

/// The federation of quadratic clients.
#[derive(Clone, Debug)]
pub struct QuadraticFederation {
    pub dim: usize,
    /// per-client diagonal Hessians (values in [rho, l_smooth])
    pub a: Vec<Vec<f32>>,
    /// per-client optima c_k
    pub c: Vec<Vec<f32>>,
    pub rho: f64,
    pub l_smooth: f64,
    /// std of additive gradient noise (per coordinate)
    pub grad_noise: f32,
}

impl QuadraticFederation {
    /// Random federation; client optima are spread with `spread` so the
    /// heterogeneity gap Γ is non-trivial.
    pub fn new(
        dim: usize,
        num_clients: usize,
        rho: f64,
        l_smooth: f64,
        spread: f32,
        grad_noise: f32,
        seed: u64,
    ) -> QuadraticFederation {
        assert!(rho > 0.0 && l_smooth >= rho);
        let mut rng = Rng::new(seed);
        let a = (0..num_clients)
            .map(|_| {
                (0..dim)
                    .map(|_| rng.uniform_in(rho, l_smooth) as f32)
                    .collect()
            })
            .collect();
        let c = (0..num_clients)
            .map(|_| {
                let mut v = vec![0f32; dim];
                rng.fill_normal_f32(&mut v, 0.0, spread);
                v
            })
            .collect();
        QuadraticFederation { dim, a, c, rho, l_smooth, grad_noise }
    }

    pub fn num_clients(&self) -> usize {
        self.a.len()
    }

    /// Local loss `f_k(θ)`.
    pub fn local_loss(&self, k: usize, theta: &[f32]) -> f64 {
        self.a[k]
            .iter()
            .zip(&self.c[k])
            .zip(theta)
            .map(|((&a, &c), &t)| 0.5 * a as f64 * ((t - c) as f64).powi(2))
            .sum()
    }

    /// Global loss `f(θ) = (1/K) Σ f_k(θ)`.
    pub fn global_loss(&self, theta: &[f32]) -> f64 {
        (0..self.num_clients())
            .map(|k| self.local_loss(k, theta))
            .sum::<f64>()
            / self.num_clients() as f64
    }

    /// Exact minimizer `θ*` (coordinate-wise weighted mean).
    pub fn optimum(&self) -> Vec<f32> {
        let mut num = vec![0f64; self.dim];
        let mut den = vec![0f64; self.dim];
        for (ak, ck) in self.a.iter().zip(&self.c) {
            for j in 0..self.dim {
                num[j] += ak[j] as f64 * ck[j] as f64;
                den[j] += ak[j] as f64;
            }
        }
        num.iter().zip(&den).map(|(&n, &d)| (n / d) as f32).collect()
    }

    /// Heterogeneity gap `Γ = f(θ*) − (1/K) Σ min f_k = f(θ*)`.
    pub fn heterogeneity_gap(&self) -> f64 {
        self.global_loss(&self.optimum())
    }

    /// Exact local gradient `∇f_k(θ) = A_k (θ − c_k)`, plus optional
    /// noise (drawn from `rng`) to model mini-batch stochasticity.
    pub fn local_grad(
        &self,
        k: usize,
        theta: &[f32],
        rng: Option<&mut Rng>,
        out: &mut [f32],
    ) {
        for j in 0..self.dim {
            out[j] = self.a[k][j] * (theta[j] - self.c[k][j]);
        }
        if let Some(rng) = rng {
            if self.grad_noise > 0.0 {
                for o in out.iter_mut() {
                    *o += self.grad_noise * rng.normal() as f32;
                }
            }
        }
    }

    /// The constant C of Theorem 1 for a given per-symbol rate
    /// `R_Q*(Z)` (bits), local-iteration count `e`, and per-client
    /// gradient-norm bounds ζ_k² (we use the exact grad-noise variance
    /// plus the deterministic norm bound at θ₀ as a proxy).
    pub fn theorem_c(
        &self,
        rate_bits: f64,
        e: usize,
        sigma_sq: f64,
        zeta_sq: f64,
    ) -> f64 {
        let k = self.num_clients() as f64;
        let pi_e = std::f64::consts::PI * std::f64::consts::E;
        (pi_e / (6.0 * k))
            * (k * sigma_sq)
            * 2f64.powf(-2.0 * rate_bits)
            + 6.0 * self.l_smooth * self.heterogeneity_gap()
            + 8.0 * (e as f64 - 1.0) * zeta_sq
    }
}

/// Backend view of one federation client (for reusing the FL pipeline).
pub struct QuadraticClientBackend {
    pub fed: std::sync::Arc<QuadraticFederation>,
    pub client: usize,
    /// deterministic per-call noise stream (interior mutability so the
    /// Backend signature stays &self)
    rng: std::sync::Mutex<Rng>,
}

impl QuadraticClientBackend {
    pub fn new(
        fed: std::sync::Arc<QuadraticFederation>,
        client: usize,
        seed: u64,
    ) -> Self {
        QuadraticClientBackend {
            fed,
            client,
            rng: std::sync::Mutex::new(Rng::new(seed)),
        }
    }
}

impl Backend for QuadraticClientBackend {
    fn num_params(&self) -> usize {
        self.fed.dim
    }

    fn batch_size(&self) -> usize {
        1
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0f32; self.fed.dim];
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }

    fn grad(
        &self,
        params: &[f32],
        _xs: &[f32],
        _ys: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32> {
        if grad_out.len() != self.fed.dim {
            return Err(Error::Config("grad length".into()));
        }
        let mut rng = self.rng.lock().unwrap();
        self.fed
            .local_grad(self.client, params, Some(&mut rng), grad_out);
        Ok(self.fed.local_loss(self.client, params) as f32)
    }

    fn eval(&self, _p: &[f32], _xs: &[f32], _ys: &[i32]) -> Result<usize> {
        Ok(0) // accuracy is meaningless for the quadratic harness
    }

    fn name(&self) -> String {
        format!("quadratic_client{}", self.client)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed() -> QuadraticFederation {
        QuadraticFederation::new(16, 5, 0.5, 4.0, 1.0, 0.0, 42)
    }

    #[test]
    fn optimum_has_zero_gradient() {
        let f = fed();
        let opt = f.optimum();
        // global gradient = mean of local gradients must vanish at θ*
        let mut g = vec![0f32; f.dim];
        let mut total = vec![0f64; f.dim];
        for k in 0..f.num_clients() {
            f.local_grad(k, &opt, None, &mut g);
            for (t, &gv) in total.iter_mut().zip(&g) {
                *t += gv as f64;
            }
        }
        for t in total {
            assert!(t.abs() < 1e-4, "grad {t}");
        }
    }

    #[test]
    fn optimum_is_a_minimum() {
        let f = fed();
        let opt = f.optimum();
        let f_opt = f.global_loss(&opt);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let mut p = opt.clone();
            for x in p.iter_mut() {
                *x += 0.1 * rng.normal() as f32;
            }
            assert!(f.global_loss(&p) >= f_opt);
        }
    }

    #[test]
    fn strong_convexity_and_smoothness() {
        // ρ/2 ||d||² <= f(θ*+d) - f(θ*) <= L/2 ||d||²
        let f = fed();
        let opt = f.optimum();
        let f_opt = f.global_loss(&opt);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let mut d = vec![0f32; f.dim];
            rng.fill_normal_f32(&mut d, 0.0, 0.5);
            let dn: f64 = d.iter().map(|&x| (x as f64).powi(2)).sum();
            let p: Vec<f32> =
                opt.iter().zip(&d).map(|(&o, &dv)| o + dv).collect();
            let gap = f.global_loss(&p) - f_opt;
            assert!(gap >= 0.5 * f.rho * dn - 1e-6, "{gap} vs {dn}");
            assert!(gap <= 0.5 * f.l_smooth * dn + 1e-6, "{gap} vs {dn}");
        }
    }

    #[test]
    fn heterogeneity_gap_positive_for_spread_clients() {
        assert!(fed().heterogeneity_gap() > 0.01);
        // zero spread ⇒ all optima coincide ⇒ Γ ≈ 0
        let f0 = QuadraticFederation::new(8, 4, 0.5, 2.0, 0.0, 0.0, 3);
        assert!(f0.heterogeneity_gap() < 1e-9);
    }

    #[test]
    fn gd_converges_to_optimum() {
        let f = fed();
        let mut theta = vec![1.0f32; f.dim];
        let mut g = vec![0f32; f.dim];
        for _ in 0..400 {
            let mut total = vec![0f32; f.dim];
            for k in 0..f.num_clients() {
                f.local_grad(k, &theta, None, &mut g);
                for (t, &gv) in total.iter_mut().zip(&g) {
                    *t += gv / f.num_clients() as f32;
                }
            }
            for (t, &gv) in theta.iter_mut().zip(&total) {
                *t -= 0.2 * gv;
            }
        }
        let gap = f.global_loss(&theta) - f.global_loss(&f.optimum());
        assert!(gap < 1e-6, "gap={gap}");
    }
}
