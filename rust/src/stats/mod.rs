//! Statistical substrates: Gaussian special functions and partial moments
//! (closed-form Lloyd/RC-quantizer design, [`gaussian`]), empirical source
//! PDFs over gradient samples ([`empirical`]), running moments
//! ([`moments`]) and entropy helpers ([`entropy`]).

pub mod empirical;
pub mod entropy;
pub mod gaussian;
pub mod moments;

/// A scalar source distribution exposing the partial moments the
/// quantizer-design math needs (paper eqs. (3), (4), (8)).
///
/// All integrals are over the half-open cell `(a, b]`; `a = -inf` /
/// `b = +inf` are allowed.
pub trait SourcePdf {
    /// `P(a < Z <= b)`.
    fn prob(&self, a: f64, b: f64) -> f64;
    /// `E[Z; a < Z <= b]` (unnormalized partial mean).
    fn partial_mean(&self, a: f64, b: f64) -> f64;
    /// `E[Z^2; a < Z <= b]` (unnormalized partial second moment).
    fn partial_second(&self, a: f64, b: f64) -> f64;
    /// A finite interval containing (effectively) all probability mass,
    /// used to initialize and clamp codebook boundaries.
    fn support(&self) -> (f64, f64);

    /// Conditional mean of a cell — the Lloyd centroid, eq. (8). Falls back
    /// to the midpoint for (numerically) empty cells.
    fn centroid(&self, a: f64, b: f64) -> f64 {
        let p = self.prob(a, b);
        if p <= 1e-300 {
            let (lo, hi) = self.support();
            return 0.5 * (a.max(lo) + b.min(hi));
        }
        self.partial_mean(a, b) / p
    }

    /// `E[(Z - s)^2; a < Z <= b]` — one cell's MSE contribution, eq. (3).
    fn cell_mse(&self, a: f64, b: f64, s: f64) -> f64 {
        self.partial_second(a, b) - 2.0 * s * self.partial_mean(a, b)
            + s * s * self.prob(a, b)
    }
}
