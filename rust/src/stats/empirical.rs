//! Empirical source PDF over observed gradient samples.
//!
//! The paper designs the universal quantizer against the Gaussian limit of
//! normalized gradients; this module provides the *empirical* alternative
//! (sorted samples + prefix sums, exact partial moments in O(log n)) used
//! by the `--pdf empirical` ablation and by tests that validate the
//! Gaussian approximation against real gradients.

use crate::stats::SourcePdf;

/// Exact empirical distribution of a sample set.
#[derive(Clone, Debug)]
pub struct EmpiricalPdf {
    sorted: Vec<f64>,
    /// prefix[i] = sum of sorted[0..i]
    prefix_z: Vec<f64>,
    /// prefix of squares
    prefix_z2: Vec<f64>,
}

impl EmpiricalPdf {
    pub fn from_samples(samples: &[f32]) -> Self {
        assert!(!samples.is_empty(), "empirical pdf needs samples");
        let mut sorted: Vec<f64> =
            samples.iter().map(|&x| x as f64).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prefix_z = Vec::with_capacity(sorted.len() + 1);
        let mut prefix_z2 = Vec::with_capacity(sorted.len() + 1);
        let (mut s, mut s2) = (0.0, 0.0);
        prefix_z.push(0.0);
        prefix_z2.push(0.0);
        for &z in &sorted {
            s += z;
            s2 += z * z;
            prefix_z.push(s);
            prefix_z2.push(s2);
        }
        EmpiricalPdf { sorted, prefix_z, prefix_z2 }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Number of samples `<= x` (upper bound index).
    fn rank(&self, x: f64) -> usize {
        if x == f64::INFINITY {
            return self.sorted.len();
        }
        // partition_point = first index with sorted[i] > x
        self.sorted.partition_point(|&z| z <= x)
    }

    /// Ranks of the half-open cell `(a, b]`, with `ra <= rb` guaranteed.
    /// A degenerate or inverted interval (`a >= b`, including NaN bounds,
    /// as fed by transient design iterates whose boundaries fold over)
    /// carries zero mass: both ranks collapse so every partial moment is
    /// exactly 0 instead of a `usize` wrap (garbage in release, panic in
    /// debug).
    fn interval_ranks(&self, a: f64, b: f64) -> (usize, usize) {
        if a.is_nan() || b.is_nan() || a >= b {
            let r = self.rank(a.min(b));
            return (r, r);
        }
        let (ra, rb) = (self.rank(a), self.rank(b));
        (ra, rb.max(ra))
    }

    pub fn quantile(&self, q: f64) -> f64 {
        // guard against NaN / out-of-range q: NaN and q < 0 clamp to the
        // minimum sample, q > 1 to the maximum
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let n = self.sorted.len();
        let i = ((q * n as f64) as usize).min(n - 1);
        self.sorted[i]
    }
}

impl SourcePdf for EmpiricalPdf {
    fn prob(&self, a: f64, b: f64) -> f64 {
        let (ra, rb) = self.interval_ranks(a, b);
        rb.saturating_sub(ra) as f64 / self.sorted.len() as f64
    }

    fn partial_mean(&self, a: f64, b: f64) -> f64 {
        let (ra, rb) = self.interval_ranks(a, b);
        (self.prefix_z[rb] - self.prefix_z[ra]) / self.sorted.len() as f64
    }

    fn partial_second(&self, a: f64, b: f64) -> f64 {
        let (ra, rb) = self.interval_ranks(a, b);
        (self.prefix_z2[rb] - self.prefix_z2[ra]) / self.sorted.len() as f64
    }

    fn support(&self) -> (f64, f64) {
        (
            self.sorted[0] - 1e-9,
            self.sorted[self.sorted.len() - 1] + 1e-9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::gaussian::StdGaussian;
    use crate::util::rng::Rng;

    #[test]
    fn total_moments() {
        let samples = [1.0f32, 2.0, 3.0, 4.0];
        let p = EmpiricalPdf::from_samples(&samples);
        let inf = f64::INFINITY;
        assert_eq!(p.prob(-inf, inf), 1.0);
        assert!((p.partial_mean(-inf, inf) - 2.5).abs() < 1e-12);
        assert!((p.partial_second(-inf, inf) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn half_open_cells() {
        let samples = [1.0f32, 2.0, 3.0];
        let p = EmpiricalPdf::from_samples(&samples);
        // (1, 2] contains exactly {2}
        assert!((p.prob(1.0, 2.0) - 1.0 / 3.0).abs() < 1e-12);
        // (0, 1] contains {1}
        assert!((p.prob(0.0, 1.0) - 1.0 / 3.0).abs() < 1e-12);
        // boundary exactly on sample: (1,3] has {2,3}
        assert!((p.prob(1.0, 3.0) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn centroid_is_cell_mean() {
        let samples = [0.0f32, 1.0, 10.0];
        let p = EmpiricalPdf::from_samples(&samples);
        assert!((p.centroid(-0.5, 1.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn converges_to_gaussian() {
        // with many N(0,1) samples the empirical moments approach the
        // closed-form Gaussian ones — the premise of the universal design
        let mut rng = Rng::new(5);
        let mut samples = vec![0f32; 200_000];
        rng.fill_normal_f32(&mut samples, 0.0, 1.0);
        let emp = EmpiricalPdf::from_samples(&samples);
        let g = StdGaussian;
        for (a, b) in [(-1.0, 1.0), (0.5, 2.0), (-3.0, -0.5)] {
            assert!((emp.prob(a, b) - g.prob(a, b)).abs() < 0.01);
            assert!(
                (emp.partial_mean(a, b) - g.partial_mean(a, b)).abs() < 0.01
            );
        }
    }

    #[test]
    fn quantiles() {
        let samples: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let p = EmpiricalPdf::from_samples(&samples);
        assert_eq!(p.quantile(0.0), 0.0);
        assert_eq!(p.quantile(0.5), 50.0);
        assert_eq!(p.quantile(1.0), 99.0);
    }

    #[test]
    fn inverted_and_degenerate_intervals_carry_zero_mass() {
        // regression: (rb - ra) was computed on usize, so an inverted
        // interval wrapped in release builds and panicked in debug
        let samples = [1.0f32, 2.0, 3.0, 4.0];
        let p = EmpiricalPdf::from_samples(&samples);
        for (a, b) in [(3.0, 1.0), (2.0, 2.0), (4.0, -1.0), (10.0, 5.0)] {
            assert_eq!(p.prob(a, b), 0.0, "prob({a}, {b})");
            assert_eq!(p.partial_mean(a, b), 0.0, "mean({a}, {b})");
            assert_eq!(p.partial_second(a, b), 0.0, "second({a}, {b})");
        }
        // NaN bounds are degenerate, not a panic
        assert_eq!(p.prob(f64::NAN, 2.0), 0.0);
        assert_eq!(p.prob(1.0, f64::NAN), 0.0);
        assert_eq!(p.partial_mean(f64::NAN, f64::NAN), 0.0);
        // the fix must not change well-formed intervals
        assert!((p.prob(1.0, 3.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_guards_bad_input() {
        let samples = [1.0f32, 2.0, 3.0, 4.0];
        let p = EmpiricalPdf::from_samples(&samples);
        assert_eq!(p.quantile(-0.5), 1.0);
        assert_eq!(p.quantile(f64::NAN), 1.0);
        assert_eq!(p.quantile(2.0), 4.0);
        assert_eq!(p.quantile(f64::INFINITY), 4.0);
        assert_eq!(p.quantile(f64::NEG_INFINITY), 1.0);
    }
}
