//! Gaussian special functions and the standard-normal source PDF.
//!
//! RC-FED normalizes client gradients to ~N(0,1) (paper §3.1), so the
//! universal quantizer is designed against the standard Gaussian. The
//! closed-form partial moments here feed the Lloyd/RC alternating updates:
//!
//! * `P(a < Z <= b)        = Φ(b) − Φ(a)`
//! * `∫_a^b z φ(z) dz      = φ(a) − φ(b)`
//! * `∫_a^b z² φ(z) dz     = P(a,b) + a·φ(a) − b·φ(b)`

use crate::stats::SourcePdf;

pub const SQRT_2: f64 = std::f64::consts::SQRT_2;
pub const INV_SQRT_2PI: f64 = 0.398_942_280_401_432_7;

/// ln Γ(1/2) = ln √π.
const LN_GAMMA_HALF: f64 = 0.5723649429247001;

/// Regularized lower incomplete gamma `P(1/2, x)` by series expansion
/// (for `x < 1.5`) — double-precision accurate.
fn gamma_p_half_series(x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    let a = 0.5;
    let mut ap = a;
    let mut del = 1.0 / a;
    let mut sum = del;
    for _ in 0..200 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-17 {
            break;
        }
    }
    sum * (-x + a * x.ln() - LN_GAMMA_HALF).exp()
}

/// Regularized upper incomplete gamma `Q(1/2, x)` by modified-Lentz
/// continued fraction (for `x >= 1.5`).
fn gamma_q_half_cf(x: f64) -> f64 {
    const TINY: f64 = 1e-300;
    let a = 0.5;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / TINY;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..200 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < TINY {
            d = TINY;
        }
        c = b + an / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x + a * x.ln() - LN_GAMMA_HALF).exp() * h
}

/// Error function, double-precision accurate via the regularized
/// incomplete gamma: `erf(x) = sign(x) · P(1/2, x²)`.
pub fn erf(x: f64) -> f64 {
    if x.is_infinite() {
        return x.signum();
    }
    let x2 = x * x;
    let p = if x2 < 1.5 {
        gamma_p_half_series(x2)
    } else {
        1.0 - gamma_q_half_cf(x2)
    };
    if x >= 0.0 {
        p
    } else {
        -p
    }
}

/// Complementary error function (accurate in both tails).
pub fn erfc(x: f64) -> f64 {
    if x.is_infinite() {
        return if x > 0.0 { 0.0 } else { 2.0 };
    }
    let x2 = x * x;
    if x >= 0.0 {
        if x2 < 1.5 {
            1.0 - gamma_p_half_series(x2)
        } else {
            gamma_q_half_cf(x2)
        }
    } else if x2 < 1.5 {
        1.0 + gamma_p_half_series(x2)
    } else {
        2.0 - gamma_q_half_cf(x2)
    }
}

/// Standard normal density φ(z).
#[inline]
pub fn phi(z: f64) -> f64 {
    INV_SQRT_2PI * (-0.5 * z * z).exp()
}

/// Standard normal CDF Φ(z).
#[inline]
pub fn cdf(z: f64) -> f64 {
    0.5 * erfc(-z / SQRT_2)
}

/// Inverse standard normal CDF (Acklam's rational approximation,
/// |rel err| < 1.15e-9), refined by one Halley step.
pub fn inv_cdf(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "inv_cdf domain: {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02,
        -2.759285104469687e+02, 1.383577518672690e+02,
        -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02,
        -1.556989798598866e+02, 6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01,
        -2.400758277161838e+00, -2.549732539343734e+00,
        4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01,
        2.445134137142996e+00, 3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Halley refinement
    let e = cdf(x) - p;
    let u = e / phi(x);
    x - u / (1.0 + 0.5 * x * u)
}

/// Differential entropy of N(0, σ²) in **bits**: ½ log₂(2πe σ²).
pub fn differential_entropy_bits(sigma: f64) -> f64 {
    0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E * sigma * sigma)
        .log2()
}

/// The standard normal as a [`SourcePdf`] (the universal design target).
#[derive(Clone, Copy, Debug, Default)]
pub struct StdGaussian;

impl SourcePdf for StdGaussian {
    fn prob(&self, a: f64, b: f64) -> f64 {
        (cdf(b) - cdf(a)).max(0.0)
    }

    fn partial_mean(&self, a: f64, b: f64) -> f64 {
        let pa = if a.is_finite() { phi(a) } else { 0.0 };
        let pb = if b.is_finite() { phi(b) } else { 0.0 };
        pa - pb
    }

    fn partial_second(&self, a: f64, b: f64) -> f64 {
        let ta = if a.is_finite() { a * phi(a) } else { 0.0 };
        let tb = if b.is_finite() { b * phi(b) } else { 0.0 };
        self.prob(a, b) + ta - tb
    }

    fn support(&self) -> (f64, f64) {
        // ±8σ carries 1 - 1.2e-15 of the mass — beyond f32 resolution.
        (-8.0, 8.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // reference values from standard tables
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn cdf_symmetry_and_values() {
        assert!((cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((cdf(1.96) - 0.9750021).abs() < 1e-6);
        for z in [-3.0, -1.0, 0.3, 2.5] {
            assert!((cdf(z) + cdf(-z) - 1.0).abs() < 1e-10, "z={z}");
        }
    }

    #[test]
    fn inv_cdf_roundtrip() {
        for p in [0.001, 0.01, 0.25, 0.5, 0.77, 0.99, 0.9999] {
            let z = inv_cdf(p);
            assert!((cdf(z) - p).abs() < 1e-9, "p={p} z={z}");
        }
        assert_eq!(inv_cdf(0.0), f64::NEG_INFINITY);
        assert_eq!(inv_cdf(1.0), f64::INFINITY);
    }

    #[test]
    fn partial_moments_total() {
        let g = StdGaussian;
        let inf = f64::INFINITY;
        assert!((g.prob(-inf, inf) - 1.0).abs() < 1e-9);
        assert!(g.partial_mean(-inf, inf).abs() < 1e-12);
        assert!((g.partial_second(-inf, inf) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn partial_moments_halves() {
        let g = StdGaussian;
        let inf = f64::INFINITY;
        // E[Z; Z>0] = φ(0) = 1/sqrt(2π)
        assert!((g.partial_mean(0.0, inf) - INV_SQRT_2PI).abs() < 1e-10);
        assert!((g.prob(0.0, inf) - 0.5).abs() < 1e-9);
        // E[Z | Z>0] = sqrt(2/π)
        let want = (2.0 / std::f64::consts::PI).sqrt();
        assert!((g.centroid(0.0, inf) - want).abs() < 1e-7);
    }

    #[test]
    fn partial_moments_match_numeric_integration() {
        let g = StdGaussian;
        let (a, b) = (-0.7, 1.3);
        let n = 200_000;
        let h = (b - a) / n as f64;
        let (mut p, mut m1, mut m2) = (0.0, 0.0, 0.0);
        for i in 0..n {
            let z = a + (i as f64 + 0.5) * h;
            let w = phi(z) * h;
            p += w;
            m1 += z * w;
            m2 += z * z * w;
        }
        assert!((g.prob(a, b) - p).abs() < 1e-6);
        assert!((g.partial_mean(a, b) - m1).abs() < 1e-6);
        assert!((g.partial_second(a, b) - m2).abs() < 1e-6);
    }

    #[test]
    fn cell_mse_is_minimized_at_centroid() {
        let g = StdGaussian;
        let (a, b) = (0.2, 1.5);
        let c = g.centroid(a, b);
        let at_c = g.cell_mse(a, b, c);
        for ds in [-0.1, -0.01, 0.01, 0.1] {
            assert!(g.cell_mse(a, b, c + ds) > at_c);
        }
    }

    #[test]
    fn entropy_of_std_normal() {
        // h(N(0,1)) = 0.5 log2(2πe) ≈ 2.0471 bits
        assert!((differential_entropy_bits(1.0) - 2.047095585).abs() < 1e-6);
    }
}
