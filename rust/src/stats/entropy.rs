//! Discrete entropy / information helpers (bits).
//!
//! The RC design couples the quantizer to the *post-entropy-coding* rate:
//! with an entropy coder the per-symbol cost is `H(Q(Z))` (paper §2,
//! "Source-encoded Transmission"), and codeword lengths enter the
//! alternating update (10) either as true Huffman lengths or as the
//! idealized `ℓ_l = −log₂ p_l`.

/// Shannon entropy of a probability vector, in bits. Zero entries are
/// skipped (0·log 0 = 0). Input need not be normalized.
pub fn entropy_bits(p: &[f64]) -> f64 {
    let total: f64 = p.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &x in p {
        if x > 0.0 {
            let q = x / total;
            h -= q * q.log2();
        }
    }
    h
}

/// Average codeword length `Σ p_l ℓ_l` in bits (paper eq. (4)).
pub fn expected_length_bits(p: &[f64], lens: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), lens.len());
    let total: f64 = p.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    p.iter().zip(lens).map(|(&x, &l)| x * l).sum::<f64>() / total
}

/// Idealized codeword lengths `ℓ_l = −log₂ p_l` (achievable by arithmetic
/// coding; lower-bounds Huffman). Probabilities are floored to keep dead
/// cells finite.
pub fn ideal_lengths(p: &[f64], floor: f64) -> Vec<f64> {
    let total: f64 = p.iter().sum::<f64>().max(f64::MIN_POSITIVE);
    p.iter()
        .map(|&x| -((x / total).max(floor)).log2())
        .collect()
}

/// Empirical symbol distribution of a quantized message.
pub fn symbol_histogram(symbols: &[u8], num_symbols: usize) -> Vec<f64> {
    let mut counts = vec![0u64; num_symbols];
    for &s in symbols {
        counts[s as usize] += 1;
    }
    counts.iter().map(|&c| c as f64).collect()
}

/// KL divergence D(p || q) in bits; q entries are floored.
pub fn kl_bits(p: &[f64], q: &[f64]) -> f64 {
    let pt: f64 = p.iter().sum();
    let qt: f64 = q.iter().sum();
    let mut d = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            let pn = pi / pt;
            let qn = (qi / qt).max(1e-300);
            d += pn * (pn / qn).log2();
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_entropy() {
        assert!((entropy_bits(&[0.25; 4]) - 2.0).abs() < 1e-12);
        assert!((entropy_bits(&[1.0 / 8.0; 8]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_has_zero_entropy() {
        assert_eq!(entropy_bits(&[1.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn unnormalized_ok() {
        assert!((entropy_bits(&[2.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ideal_lengths_achieve_entropy() {
        let p = [0.5, 0.25, 0.125, 0.125];
        let l = ideal_lengths(&p, 1e-12);
        assert!((expected_length_bits(&p, &l) - entropy_bits(&p)).abs() < 1e-9);
        assert!((l[0] - 1.0).abs() < 1e-9);
        assert!((l[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn expected_length_bounded_below_by_entropy() {
        // any length assignment satisfying Kraft has E[ℓ] >= H
        let p = [0.7, 0.15, 0.1, 0.05];
        let huff_like = [1.0, 2.0, 3.0, 3.0];
        assert!(expected_length_bits(&p, &huff_like) >= entropy_bits(&p) - 1e-9);
    }

    #[test]
    fn histogram_counts() {
        let h = symbol_histogram(&[0, 0, 1, 3, 3, 3], 4);
        assert_eq!(h, vec![2.0, 1.0, 0.0, 3.0]);
    }

    #[test]
    fn kl_properties() {
        let p = [0.5, 0.5];
        assert!(kl_bits(&p, &p).abs() < 1e-12);
        assert!(kl_bits(&p, &[0.9, 0.1]) > 0.0);
    }
}
