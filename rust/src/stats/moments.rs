//! Running/streaming moment computation.
//!
//! Clients need `(μ_{k,t}, σ_{k,t})` of each local gradient (paper §3.1).
//! [`Welford`] is the numerically-stable streaming version; [`mean_std`]
//! is the vectorizable single-pass lane version used on the hot path
//! (with [`mean_std_reference`], the old two-pass form, as its oracle);
//! all must agree (tested below). `combine` merges per-block partials
//! produced by the L1 `moments` kernel.

/// Numerically stable streaming mean/variance (Welford / Chan).
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Parallel combine (Chan et al.).
    pub fn merge(&self, other: &Welford) -> Welford {
        if self.n == 0 {
            return *other;
        }
        if other.n == 0 {
            return *self;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        Welford { n, mean, m2 }
    }
}

/// Lane width of the fused moments pass (matches the model kernels).
const LANES: usize = 8;

/// Single-pass `(Σx, Σx²)` in f64 over [`LANES`] independent partial
/// sums, combined in fixed lane order — the accumulation tree is a
/// function of the data only, never of chunking or thread count.
fn lane_moments(xs: &[f32]) -> (f64, f64) {
    let mut s = [0f64; LANES];
    let mut s2 = [0f64; LANES];
    let mut it = xs.chunks_exact(LANES);
    for c in &mut it {
        for l in 0..LANES {
            let x = c[l] as f64;
            s[l] += x;
            s2[l] += x * x;
        }
    }
    for (l, &x) in it.remainder().iter().enumerate() {
        let x = x as f64;
        s[l] += x;
        s2[l] += x * x;
    }
    (s.iter().sum::<f64>(), s2.iter().sum::<f64>())
}

/// Population mean/std of an f32 slice: one fused pass accumulating
/// `(Σx, Σx²)` in f64 lanes, `σ² = (Σx²/n − μ²)₊` — the same moment
/// identity [`combine_partials`] uses. The f64 lane accumulators keep
/// the cancellation benign at gradient scale (see
/// `single_pass_close_to_two_pass_reference` below); exact for constant
/// inputs.
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let (s, s2) = lane_moments(xs);
    let mean = s / n;
    let var = (s2 / n - mean * mean).max(0.0);
    (mean as f32, var.sqrt() as f32)
}

/// The previous two-pass formulation (serial f64 sums, centered second
/// pass) — the differential oracle for [`mean_std`] and the
/// `model_throughput` baseline.
pub fn mean_std_reference(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs
        .iter()
        .map(|&x| {
            let d = x as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n;
    (mean as f32, var.sqrt() as f32)
}

/// [`mean_std`] fused with the adaptive controller's strided raw-value
/// capture: appends every `stride`-th element of `xs` to `sample`
/// (un-normalized — the caller normalizes once (μ, σ) are known). One
/// entry point for the quantizer's moments + stats-sample pass, so the
/// sampled positions cannot drift from the dedicated sampler's.
pub fn mean_std_with_stride_sample(
    xs: &[f32],
    stride: usize,
    sample: &mut Vec<f32>,
) -> (f32, f32) {
    let (mean, std) = mean_std(xs);
    sample.extend(xs.iter().step_by(stride.max(1)));
    (mean, std)
}

/// Combine per-block `(sum, sumsq)` partials (from the L1 `moments`
/// kernel) into `(mean, std)` over `n` total elements.
pub fn combine_partials(sums: &[f32], sumsqs: &[f32], n: usize) -> (f32, f32) {
    let s: f64 = sums.iter().map(|&x| x as f64).sum();
    let s2: f64 = sumsqs.iter().map(|&x| x as f64).sum();
    let mean = s / n as f64;
    let var = (s2 / n as f64 - mean * mean).max(0.0);
    (mean as f32, var.sqrt() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_two_pass() {
        let mut rng = Rng::new(1);
        let mut xs = vec![0f32; 10_000];
        rng.fill_normal_f32(&mut xs, 3.0, 0.7);
        let (m, s) = mean_std(&xs);
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x as f64);
        }
        assert!((w.mean() as f32 - m).abs() < 1e-4);
        assert!((w.stddev() as f32 - s).abs() < 1e-4);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal_with(-1.0, 2.0)).collect();
        let mut all = Welford::default();
        xs.iter().for_each(|&x| all.push(x));
        let (mut a, mut b) = (Welford::default(), Welford::default());
        xs[..1234].iter().for_each(|&x| a.push(x));
        xs[1234..].iter().for_each(|&x| b.push(x));
        let merged = a.merge(&b);
        assert_eq!(merged.count(), all.count());
        assert!((merged.mean() - all.mean()).abs() < 1e-10);
        assert!((merged.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn combine_partials_matches_direct() {
        let mut rng = Rng::new(3);
        let mut xs = vec![0f32; 4096];
        rng.fill_normal_f32(&mut xs, 0.5, 1.5);
        let block = 512;
        let sums: Vec<f32> = xs
            .chunks(block)
            .map(|c| c.iter().sum::<f32>())
            .collect();
        let sumsqs: Vec<f32> = xs
            .chunks(block)
            .map(|c| c.iter().map(|x| x * x).sum::<f32>())
            .collect();
        let (m1, s1) = combine_partials(&sums, &sumsqs, xs.len());
        let (m2, s2) = mean_std(&xs);
        assert!((m1 - m2).abs() < 1e-3);
        assert!((s1 - s2).abs() < 1e-3);
    }

    #[test]
    fn empty_and_constant() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        let (m, s) = mean_std(&[2.5; 100]);
        assert_eq!(m, 2.5);
        assert!(s.abs() < 1e-6);
        // the (Σx²/n − μ²) identity must clamp, not sqrt a tiny
        // negative residue, on constant inputs
        assert_eq!(s, 0.0);
    }

    #[test]
    fn single_pass_close_to_two_pass_reference() {
        // ragged lengths around the lane width, offset means, and a
        // near-constant vector (the cancellation-hostile case)
        let mut rng = Rng::new(7);
        for n in [1usize, 7, 8, 9, 1023, 4096] {
            let mut xs = vec![0f32; n];
            rng.fill_normal_f32(&mut xs, -2.0, 0.3);
            let (m, s) = mean_std(&xs);
            let (mr, sr) = mean_std_reference(&xs);
            assert!((m - mr).abs() < 1e-5, "n={n}: {m} vs {mr}");
            assert!((s - sr).abs() < 1e-5, "n={n}: {s} vs {sr}");
        }
        let mut tight = vec![0f32; 2048];
        rng.fill_normal_f32(&mut tight, 1000.0, 1e-3);
        let (s, sr) = (mean_std(&tight).1, mean_std_reference(&tight).1);
        assert!((s - sr).abs() < 1e-4, "{s} vs {sr}");
    }

    #[test]
    fn stride_sample_collects_raw_values() {
        let mut rng = Rng::new(8);
        let mut xs = vec![0f32; 100];
        rng.fill_normal_f32(&mut xs, 0.0, 1.0);
        let mut sample = Vec::new();
        let (m, s) = mean_std_with_stride_sample(&xs, 7, &mut sample);
        assert_eq!((m, s), mean_std(&xs));
        let expect: Vec<f32> = xs.iter().step_by(7).copied().collect();
        assert_eq!(sample, expect);
        // stride 0 is treated as 1, not a panic
        let mut all = Vec::new();
        mean_std_with_stride_sample(&xs, 0, &mut all);
        assert_eq!(all, xs);
    }
}
