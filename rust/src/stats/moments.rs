//! Running/streaming moment computation.
//!
//! Clients need `(μ_{k,t}, σ_{k,t})` of each local gradient (paper §3.1).
//! [`Welford`] is the numerically-stable streaming version; [`mean_std`]
//! is the vectorizable two-pass version used on the hot path; both must
//! agree (tested below). `combine` merges per-block partials produced by
//! the L1 `moments` kernel.

/// Numerically stable streaming mean/variance (Welford / Chan).
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Parallel combine (Chan et al.).
    pub fn merge(&self, other: &Welford) -> Welford {
        if self.n == 0 {
            return *other;
        }
        if other.n == 0 {
            return *self;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        Welford { n, mean, m2 }
    }
}

/// Two-pass population mean/std of an f32 slice (f64 accumulation).
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs
        .iter()
        .map(|&x| {
            let d = x as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n;
    (mean as f32, var.sqrt() as f32)
}

/// Combine per-block `(sum, sumsq)` partials (from the L1 `moments`
/// kernel) into `(mean, std)` over `n` total elements.
pub fn combine_partials(sums: &[f32], sumsqs: &[f32], n: usize) -> (f32, f32) {
    let s: f64 = sums.iter().map(|&x| x as f64).sum();
    let s2: f64 = sumsqs.iter().map(|&x| x as f64).sum();
    let mean = s / n as f64;
    let var = (s2 / n as f64 - mean * mean).max(0.0);
    (mean as f32, var.sqrt() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_two_pass() {
        let mut rng = Rng::new(1);
        let mut xs = vec![0f32; 10_000];
        rng.fill_normal_f32(&mut xs, 3.0, 0.7);
        let (m, s) = mean_std(&xs);
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x as f64);
        }
        assert!((w.mean() as f32 - m).abs() < 1e-4);
        assert!((w.stddev() as f32 - s).abs() < 1e-4);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal_with(-1.0, 2.0)).collect();
        let mut all = Welford::default();
        xs.iter().for_each(|&x| all.push(x));
        let (mut a, mut b) = (Welford::default(), Welford::default());
        xs[..1234].iter().for_each(|&x| a.push(x));
        xs[1234..].iter().for_each(|&x| b.push(x));
        let merged = a.merge(&b);
        assert_eq!(merged.count(), all.count());
        assert!((merged.mean() - all.mean()).abs() < 1e-10);
        assert!((merged.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn combine_partials_matches_direct() {
        let mut rng = Rng::new(3);
        let mut xs = vec![0f32; 4096];
        rng.fill_normal_f32(&mut xs, 0.5, 1.5);
        let block = 512;
        let sums: Vec<f32> = xs
            .chunks(block)
            .map(|c| c.iter().sum::<f32>())
            .collect();
        let sumsqs: Vec<f32> = xs
            .chunks(block)
            .map(|c| c.iter().map(|x| x * x).sum::<f32>())
            .collect();
        let (m1, s1) = combine_partials(&sums, &sumsqs, xs.len());
        let (m2, s2) = mean_std(&xs);
        assert!((m1 - m2).abs() < 1e-3);
        assert!((s1 - s2).abs() < 1e-3);
    }

    #[test]
    fn empty_and_constant() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        let (m, s) = mean_std(&[2.5; 100]);
        assert_eq!(m, 2.5);
        assert!(s.abs() < 1e-6);
    }
}
