//! Classical Lloyd-Max scalar quantizer design [19].
//!
//! The distortion-only baseline the paper compares against ([16]) and the
//! λ → 0 limit of the rate-constrained design. Alternates
//!
//! * levels:     `s_l = E[Z | u_l < Z ≤ u_{l+1}]`            (eq. (8))
//! * boundaries: `u_l = (s_l + s_{l-1}) / 2`                 (nearest rule)
//!
//! until the MSE stops improving.

use crate::quant::codebook::Codebook;
use crate::quant::{evaluate, DesignReport};
use crate::stats::entropy::entropy_bits;
use crate::stats::gaussian::inv_cdf;
use crate::stats::SourcePdf;
use crate::util::Result;

/// Lloyd-Max designer.
#[derive(Clone, Copy, Debug)]
pub struct LloydMax {
    pub max_iters: usize,
    /// relative MSE-improvement convergence threshold
    pub tol: f64,
}

impl Default for LloydMax {
    fn default() -> Self {
        LloydMax { max_iters: 500, tol: 1e-10 }
    }
}

/// Quantile-spaced initial levels: `s_l = F^{-1}((l + ½)/N)` under a
/// Gaussian-shaped guess over the pdf's support. Robust for both the
/// standard Gaussian and empirical pdfs.
pub fn init_levels(pdf: &dyn SourcePdf, n: usize) -> Vec<f64> {
    let (lo, hi) = pdf.support();
    let mut levels: Vec<f64> = (0..n)
        .map(|l| {
            let q = (l as f64 + 0.5) / n as f64;
            let z = inv_cdf(q);
            // map the Gaussian quantile into the support window
            z.clamp(lo, hi)
        })
        .collect();
    // ensure strict monotonicity even under clamping
    for i in 1..n {
        if levels[i] <= levels[i - 1] {
            levels[i] = levels[i - 1] + 1e-6;
        }
    }
    levels
}

/// Midpoint boundaries of a level vector.
pub fn midpoints(levels: &[f64]) -> Vec<f64> {
    levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect()
}

impl LloydMax {
    /// Design a `2^bits`-level quantizer for `pdf`.
    pub fn design(
        &self,
        pdf: &dyn SourcePdf,
        bits: u32,
    ) -> Result<(Codebook, DesignReport)> {
        let n = 1usize << bits;
        let mut levels = init_levels(pdf, n);
        let mut bounds = midpoints(&levels);
        let mut prev_mse = f64::INFINITY;
        let mut iters = 0;
        for it in 0..self.max_iters {
            iters = it + 1;
            // centroid step (8)
            for l in 0..n {
                let a = if l == 0 { f64::NEG_INFINITY } else { bounds[l - 1] };
                let b = if l == n - 1 { f64::INFINITY } else { bounds[l] };
                levels[l] = pdf.centroid(a, b);
            }
            enforce_monotone(&mut levels);
            // nearest-boundary step
            bounds = midpoints(&levels);
            // convergence on MSE
            let cb = Codebook::from_f64_sanitized(&levels, &bounds)?;
            let (mse, _) = evaluate(pdf, &cb);
            if (prev_mse - mse).abs() <= self.tol * mse.max(1e-300) {
                break;
            }
            prev_mse = mse;
        }
        let cb = Codebook::from_f64_sanitized(&levels, &bounds)?;
        let (mse, probs) = evaluate(pdf, &cb);
        let huff =
            crate::coding::huffman::HuffmanCode::from_probs(&probs)?;
        Ok((
            cb,
            DesignReport {
                mse,
                entropy_bits: entropy_bits(&probs),
                huffman_rate: huff.expected_length(&probs),
                probs,
                iterations: iters,
            },
        ))
    }
}

/// Repair strictly-increasing structure after a centroid step (empty or
/// near-empty cells can collapse neighbours onto the same point).
pub fn enforce_monotone(levels: &mut [f64]) {
    for i in 1..levels.len() {
        if levels[i] <= levels[i - 1] {
            levels[i] = levels[i - 1] + 1e-9;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::empirical::EmpiricalPdf;
    use crate::stats::gaussian::StdGaussian;
    use crate::util::rng::Rng;

    #[test]
    fn one_bit_gaussian_is_sign_quantizer() {
        // optimal 1-bit quantizer for N(0,1): levels ±sqrt(2/π), bound 0
        let (cb, rep) = LloydMax::default().design(&StdGaussian, 1).unwrap();
        let want = (2.0 / std::f64::consts::PI).sqrt() as f32;
        assert!((cb.levels[0] + want).abs() < 1e-4, "{:?}", cb.levels);
        assert!((cb.levels[1] - want).abs() < 1e-4);
        assert!(cb.bounds[0].abs() < 1e-4);
        // MSE = 1 - 2/π ≈ 0.3634
        assert!((rep.mse - (1.0 - 2.0 / std::f64::consts::PI)).abs() < 1e-4);
    }

    #[test]
    fn two_bit_gaussian_matches_max_1960() {
        // Max (1960) table: N=4 levels ±0.4528, ±1.510; MSE ≈ 0.1175
        let (cb, rep) = LloydMax::default().design(&StdGaussian, 2).unwrap();
        assert!((cb.levels[2] - 0.4528).abs() < 2e-3, "{:?}", cb.levels);
        assert!((cb.levels[3] - 1.510).abs() < 5e-3);
        assert!((rep.mse - 0.1175).abs() < 1e-3, "mse={}", rep.mse);
    }

    #[test]
    fn three_bit_gaussian_mse() {
        // Max (1960): N=8 → MSE ≈ 0.03454
        let (_, rep) = LloydMax::default().design(&StdGaussian, 3).unwrap();
        assert!((rep.mse - 0.03454).abs() < 5e-4, "mse={}", rep.mse);
    }

    #[test]
    fn mse_decreases_with_bits() {
        let mut last = f64::INFINITY;
        for b in 1..=6 {
            let (_, rep) = LloydMax::default().design(&StdGaussian, b).unwrap();
            assert!(rep.mse < last, "b={b}");
            last = rep.mse;
        }
    }

    #[test]
    fn symmetric_for_symmetric_pdf() {
        let (cb, _) = LloydMax::default().design(&StdGaussian, 3).unwrap();
        let n = cb.levels.len();
        for i in 0..n / 2 {
            assert!(
                (cb.levels[i] + cb.levels[n - 1 - i]).abs() < 1e-3,
                "levels not symmetric: {:?}", cb.levels
            );
        }
    }

    #[test]
    fn empirical_pdf_design_close_to_gaussian_design(){
        let mut rng = Rng::new(21);
        let mut z = vec![0f32; 100_000];
        rng.fill_normal_f32(&mut z, 0.0, 1.0);
        let emp = EmpiricalPdf::from_samples(&z);
        let (cb_e, _) = LloydMax::default().design(&emp, 2).unwrap();
        let (cb_g, _) = LloydMax::default().design(&StdGaussian, 2).unwrap();
        for (a, b) in cb_e.levels.iter().zip(&cb_g.levels) {
            assert!((a - b).abs() < 0.05, "{cb_e:?} vs {cb_g:?}");
        }
    }

    #[test]
    fn design_probabilities_sum_to_one() {
        let (_, rep) = LloydMax::default().design(&StdGaussian, 4).unwrap();
        let total: f64 = rep.probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!(rep.huffman_rate >= rep.entropy_bits - 1e-9);
    }
}
