//! NQFL baseline [14] (Chen et al., IEEE Comm. Letters 2023):
//! nonuniform quantization for communication-efficient FL.
//!
//! NQFL quantizes normalized gradients with levels matched to the
//! (approximately Gaussian) gradient density rather than uniformly. We
//! realize it as a Gaussian-CDF compander: the normalized coordinate is
//! mapped through `Φ(·)` (making it ~uniform on [0,1]), uniformly
//! quantized with `2^b` cells, and expanded back through `Φ^{-1}` at cell
//! centers. This is the standard companding construction for
//! density-matched nonuniform quantization and reproduces NQFL's headline
//! behaviour: denser levels near zero where gradient mass concentrates.
//! (The original letter is not open-source; DESIGN.md records this
//! substitution.)

use crate::quant::codebook::Codebook;
use crate::stats::gaussian::{cdf, inv_cdf};
use crate::util::Result;

/// Build the NQFL-style companded codebook for normalized (~N(0,1))
/// gradients at bit-width `bits`.
pub fn nqfl_codebook(bits: u32) -> Result<Codebook> {
    let n = 1usize << bits;
    // cell edges uniform in probability space: q_l = l/N
    // levels at probability cell centers: Φ^{-1}((l+½)/N)
    let levels: Vec<f64> = (0..n)
        .map(|l| inv_cdf((l as f64 + 0.5) / n as f64))
        .collect();
    let bounds: Vec<f64> =
        (1..n).map(|l| inv_cdf(l as f64 / n as f64)).collect();
    Codebook::from_f64(&levels, &bounds)
}

/// The compander map (exposed for tests/benches).
pub fn compress(z: f64) -> f64 {
    cdf(z)
}

/// Inverse compander.
pub fn expand(u: f64) -> f64 {
    inv_cdf(u.clamp(1e-12, 1.0 - 1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{evaluate, lloyd::LloydMax, uniform::uniform_codebook};
    use crate::stats::gaussian::StdGaussian;

    #[test]
    fn codebook_is_valid_and_symmetric() {
        for bits in [1u32, 2, 3, 6] {
            let cb = nqfl_codebook(bits).unwrap();
            cb.validate().unwrap();
            assert_eq!(cb.num_levels(), 1 << bits);
            let n = cb.levels.len();
            for i in 0..n / 2 {
                assert!(
                    (cb.levels[i] + cb.levels[n - 1 - i]).abs() < 1e-5,
                    "b={bits} {:?}", cb.levels
                );
            }
        }
    }

    #[test]
    fn cells_are_equiprobable() {
        // defining property of the CDF compander
        let cb = nqfl_codebook(3).unwrap();
        let (_, probs) = evaluate(&StdGaussian, &cb);
        for &p in &probs {
            assert!((p - 1.0 / 8.0).abs() < 1e-4, "{probs:?}");
        }
    }

    #[test]
    fn denser_near_zero() {
        let cb = nqfl_codebook(4).unwrap();
        let gaps: Vec<f32> =
            cb.levels.windows(2).map(|w| w[1] - w[0]).collect();
        let mid = gaps[gaps.len() / 2];
        let edge = gaps[0];
        assert!(mid < edge, "inner gap {mid} should be < outer gap {edge}");
    }

    #[test]
    fn better_than_uniform_worse_than_lloyd() {
        // nonuniform companding beats a clipped uniform grid on Gaussian
        // data but cannot beat the MSE-optimal Lloyd design
        let (mse_nqfl, _) =
            evaluate(&StdGaussian, &nqfl_codebook(3).unwrap());
        let (mse_unif, _) =
            evaluate(&StdGaussian, &uniform_codebook(3, 4.0).unwrap());
        let (_, rep_lloyd) = LloydMax::default().design(&StdGaussian, 3).unwrap();
        assert!(mse_nqfl < mse_unif, "{mse_nqfl} vs uniform {mse_unif}");
        assert!(mse_nqfl > rep_lloyd.mse, "{mse_nqfl} vs lloyd {}", rep_lloyd.mse);
    }

    #[test]
    fn compander_roundtrip() {
        for z in [-3.0, -0.5, 0.0, 1.7] {
            assert!((expand(compress(z)) - z).abs() < 1e-7, "z={z}");
        }
    }
}
