//! Rate-constrained quantizer design — the paper's core contribution
//! (§3.2, eqs. (5)–(10)).
//!
//! Minimizes `MSE_Q(Z) + λ·R_Q(Z)` by alternating the two marginal
//! updates:
//!
//! * **levels** (eq. (8)) — the rate term does not depend on `s_l`, so the
//!   marginal problem is the classic Lloyd centroid;
//! * **boundaries** (eq. (10)) — continuity of the piecewise integrand at
//!   `u_l` gives the midpoint *shifted toward the level with the longer
//!   codeword*:
//!   `u_l = (s_l + s_{l-1})/2 + (λ/2)·(ℓ_l − ℓ_{l-1})/(s_l − s_{l-1})`,
//!
//! with the codeword lengths `ℓ_l` recomputed each sweep from the current
//! cell probabilities — either true integer Huffman lengths (what the wire
//! coder will realize) or the idealized `−log₂ p_l` (what an arithmetic
//! coder approaches). The constrained form (5) (`R_Q ≤ R^trg`) is solved
//! by bisecting λ.

use crate::coding::huffman::HuffmanCode;
use crate::quant::codebook::Codebook;
use crate::quant::lloyd::{enforce_monotone, init_levels, midpoints};
use crate::quant::{evaluate, DesignReport};
use crate::stats::entropy::{entropy_bits, ideal_lengths};
use crate::stats::SourcePdf;
use crate::util::Result;

/// How codeword lengths `ℓ_l` are modeled inside the design loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LengthModel {
    /// true integer Huffman lengths (matches the wire coder)
    Huffman,
    /// idealized `ℓ_l = −log₂ p_l` (Shannon/arithmetic-coding lengths)
    Ideal,
}

/// Rate-constrained Lloyd-Max designer.
#[derive(Clone, Copy, Debug)]
pub struct RateConstrainedQuantizer {
    /// distortion–rate trade-off multiplier λ ≥ 0 of eq. (6)
    pub lambda: f64,
    pub length_model: LengthModel,
    pub max_iters: usize,
    /// relative improvement threshold on the Lagrangian `MSE + λR`
    pub tol: f64,
}

impl Default for RateConstrainedQuantizer {
    fn default() -> Self {
        RateConstrainedQuantizer {
            lambda: 0.05,
            length_model: LengthModel::Huffman,
            max_iters: 300,
            tol: 1e-10,
        }
    }
}

impl RateConstrainedQuantizer {
    pub fn new(lambda: f64) -> Self {
        RateConstrainedQuantizer { lambda, ..Default::default() }
    }

    /// Codeword lengths for the current cell probabilities.
    fn lengths(&self, probs: &[f64]) -> Result<Vec<f64>> {
        match self.length_model {
            LengthModel::Huffman => {
                let code = HuffmanCode::from_probs(probs)?;
                Ok(code.lengths().iter().map(|&l| l as f64).collect())
            }
            LengthModel::Ideal => Ok(ideal_lengths(probs, 1e-12)),
        }
    }

    /// Design a `2^bits`-level rate-constrained quantizer for `pdf`.
    ///
    /// Tracks the best Lagrangian seen: with integer Huffman lengths the
    /// alternating updates can cycle, so the returned codebook is the
    /// best iterate, not the last.
    pub fn design(
        &self,
        pdf: &dyn SourcePdf,
        bits: u32,
    ) -> Result<(Codebook, DesignReport)> {
        self.design_warm(pdf, bits, None)
    }

    /// Like [`design`](Self::design), but optionally warm-started from a
    /// previously designed codebook. The per-round adaptive pipeline
    /// re-designs against a drifting empirical pdf every window; seeding
    /// the alternation with the previous window's levels typically
    /// converges in a handful of sweeps instead of a cold start's
    /// hundreds. A warm codebook with the wrong arity (different `bits`)
    /// is ignored.
    pub fn design_warm(
        &self,
        pdf: &dyn SourcePdf,
        bits: u32,
        warm: Option<&Codebook>,
    ) -> Result<(Codebook, DesignReport)> {
        let n = 1usize << bits;
        let (lo, hi) = pdf.support();
        let mut levels = match warm {
            Some(cb) if cb.levels.len() == n => {
                let mut ls: Vec<f64> =
                    cb.levels.iter().map(|&x| (x as f64).clamp(lo, hi)).collect();
                enforce_monotone(&mut ls);
                ls
            }
            _ => init_levels(pdf, n),
        };
        let mut bounds = midpoints(&levels);
        let mut best: Option<(f64, Codebook)> = None;
        let mut prev_obj = f64::INFINITY;
        let mut iters = 0;
        for it in 0..self.max_iters {
            iters = it + 1;
            // cell probabilities under current boundaries
            let probs = cell_probs(pdf, &bounds);
            // codeword lengths ℓ_l from the entropy coder model
            let lens = self.lengths(&probs)?;
            // (8): centroid step (rate term independent of levels)
            for l in 0..n {
                let a = if l == 0 { f64::NEG_INFINITY } else { bounds[l - 1] };
                let b = if l == n - 1 { f64::INFINITY } else { bounds[l] };
                levels[l] = pdf.centroid(a, b);
            }
            enforce_monotone(&mut levels);
            // (10): shifted-midpoint boundary step
            for l in 1..n {
                let mid = 0.5 * (levels[l] + levels[l - 1]);
                let gap = levels[l] - levels[l - 1];
                let shift = if gap.abs() > 1e-12 {
                    0.5 * self.lambda * (lens[l] - lens[l - 1]) / gap
                } else {
                    0.0
                };
                bounds[l - 1] = (mid + shift).clamp(lo, hi);
            }
            repair_bounds(&mut bounds, lo, hi);
            // Lagrangian objective on this iterate
            let cb = Codebook::from_f64_sanitized(&levels, &bounds)?;
            let (mse, probs) = evaluate(pdf, &cb);
            let lens = self.lengths(&probs)?;
            let rate: f64 = probs
                .iter()
                .zip(&lens)
                .map(|(&p, &l)| p * l)
                .sum();
            let obj = mse + self.lambda * rate;
            if best.as_ref().map(|(b, _)| obj < *b).unwrap_or(true) {
                best = Some((obj, cb));
            }
            if (prev_obj - obj).abs() <= self.tol * obj.abs().max(1e-300) {
                break;
            }
            prev_obj = obj;
        }
        let (_, cb) = best.expect("at least one iterate");
        let (mse, probs) = evaluate(pdf, &cb);
        let huff = HuffmanCode::from_probs(&probs)?;
        Ok((
            cb,
            DesignReport {
                mse,
                entropy_bits: entropy_bits(&probs),
                huffman_rate: huff.expected_length(&probs),
                probs,
                iterations: iters,
            },
        ))
    }

    /// Solve the constrained form (5): smallest distortion with
    /// `R_Q(Z) ≤ r_target` (bits/symbol), by bisection on λ.
    ///
    /// Returns the designed codebook, its report, and the λ found.
    pub fn design_for_target_rate(
        pdf: &dyn SourcePdf,
        bits: u32,
        r_target: f64,
        length_model: LengthModel,
    ) -> Result<(Codebook, DesignReport, f64)> {
        let rate_of = |rep: &DesignReport| match length_model {
            LengthModel::Huffman => rep.huffman_rate,
            LengthModel::Ideal => rep.entropy_bits,
        };
        // λ = 0: unconstrained (max rate). If already under target, done.
        let mut rc = RateConstrainedQuantizer {
            lambda: 0.0,
            length_model,
            ..Default::default()
        };
        let (cb0, rep0) = rc.design(pdf, bits)?;
        if rate_of(&rep0) <= r_target {
            return Ok((cb0, rep0, 0.0));
        }
        // grow an upper bracket
        let mut lam_hi = 0.05;
        let mut hi_result = None;
        for _ in 0..20 {
            rc.lambda = lam_hi;
            let (cb, rep) = rc.design(pdf, bits)?;
            if rate_of(&rep) <= r_target {
                hi_result = Some((cb, rep));
                break;
            }
            lam_hi *= 2.0;
        }
        let mut hi_result = hi_result.ok_or_else(|| {
            crate::util::Error::Quant(format!(
                "target rate {r_target} unreachable at b={bits}"))
        })?;
        let mut lam_lo = 0.0;
        let mut lam = lam_hi;
        // bisection: smallest λ meeting the constraint (min distortion)
        for _ in 0..24 {
            let mid = 0.5 * (lam_lo + lam_hi);
            rc.lambda = mid;
            let (cb, rep) = rc.design(pdf, bits)?;
            if rate_of(&rep) <= r_target {
                lam_hi = mid;
                lam = mid;
                hi_result = (cb, rep);
            } else {
                lam_lo = mid;
            }
            if lam_hi - lam_lo < 1e-5 {
                break;
            }
        }
        let (cb, rep) = hi_result;
        Ok((cb, rep, lam))
    }
}

/// Probability of each cell induced by `bounds` (with ±∞ outer edges).
pub fn cell_probs(pdf: &dyn SourcePdf, bounds: &[f64]) -> Vec<f64> {
    let n = bounds.len() + 1;
    (0..n)
        .map(|l| {
            let a = if l == 0 { f64::NEG_INFINITY } else { bounds[l - 1] };
            let b = if l == n - 1 { f64::INFINITY } else { bounds[l] };
            pdf.prob(a, b)
        })
        .collect()
}

/// Repair monotonicity after the shifted-midpoint step; λ large enough
/// can fold neighbouring boundaries over each other.
///
/// Postconditions: non-decreasing order and every boundary inside
/// `[lo, hi]`. Strictness is restored downstream by
/// [`Codebook::from_f64_sanitized`]; what must never survive is an
/// out-of-support boundary, which would put probability mass in cells
/// the design integrals can't see.
pub(crate) fn repair_bounds(bounds: &mut [f64], lo: f64, hi: f64) {
    let n = bounds.len();
    if n == 0 {
        return;
    }
    let eps = (hi - lo).max(1e-6) * 1e-9;
    bounds[0] = bounds[0].clamp(lo, hi);
    for i in 1..n {
        if bounds[i] <= bounds[i - 1] {
            bounds[i] = bounds[i - 1] + eps;
        }
        bounds[i] = bounds[i].clamp(lo, hi);
    }
    // a backward pass in case clamping at hi collapsed the tail
    for i in (0..n - 1).rev() {
        if bounds[i] >= bounds[i + 1] {
            bounds[i] = bounds[i + 1] - eps;
        }
    }
    // the backward pass subtracts below already-clamped values, so it can
    // step past `lo` when a run of boundaries collapses near the support
    // edge at large λ; clamp once more (clamping a sorted sequence keeps
    // it sorted, so both postconditions hold).
    for b in bounds.iter_mut() {
        *b = b.clamp(lo, hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::lloyd::LloydMax;
    use crate::stats::gaussian::{differential_entropy_bits, StdGaussian};

    #[test]
    fn lambda_zero_reduces_to_lloyd() {
        let rc = RateConstrainedQuantizer {
            lambda: 0.0,
            ..Default::default()
        };
        let (cb_rc, rep_rc) = rc.design(&StdGaussian, 3).unwrap();
        let (cb_ll, rep_ll) = LloydMax::default().design(&StdGaussian, 3).unwrap();
        assert!((rep_rc.mse - rep_ll.mse).abs() < 1e-6);
        for (a, b) in cb_rc.levels.iter().zip(&cb_ll.levels) {
            assert!((a - b).abs() < 1e-3, "{cb_rc:?} vs {cb_ll:?}");
        }
    }

    #[test]
    fn rate_decreases_and_mse_increases_with_lambda() {
        let mut last_rate = f64::INFINITY;
        let mut last_mse = 0.0;
        for &lam in &[0.0, 0.02, 0.05, 0.1, 0.3] {
            let rc = RateConstrainedQuantizer {
                lambda: lam,
                length_model: LengthModel::Ideal,
                ..Default::default()
            };
            let (_, rep) = rc.design(&StdGaussian, 3).unwrap();
            assert!(
                rep.entropy_bits <= last_rate + 1e-6,
                "rate not decreasing at λ={lam}: {} > {last_rate}",
                rep.entropy_bits
            );
            assert!(
                rep.mse >= last_mse - 1e-9,
                "mse not increasing at λ={lam}"
            );
            last_rate = rep.entropy_bits;
            last_mse = rep.mse;
        }
        // a strict gap end-to-end
        let rc0 = RateConstrainedQuantizer {
            lambda: 0.0,
            length_model: LengthModel::Ideal,
            ..Default::default()
        };
        let rc3 = RateConstrainedQuantizer {
            lambda: 0.3,
            length_model: LengthModel::Ideal,
            ..Default::default()
        };
        let (_, r0) = rc0.design(&StdGaussian, 3).unwrap();
        let (_, r3) = rc3.design(&StdGaussian, 3).unwrap();
        assert!(r3.entropy_bits < r0.entropy_bits - 0.05);
    }

    #[test]
    fn boundaries_shift_toward_longer_codeword() {
        // paper §3.2: "u_l is shifted towards the reconstruction level
        // associated with the longer codeword", shrinking rare cells.
        let rc = RateConstrainedQuantizer {
            lambda: 0.05,
            length_model: LengthModel::Huffman,
            ..Default::default()
        };
        let (cb, rep) = rc.design(&StdGaussian, 3).unwrap();
        let code = HuffmanCode::from_probs(&rep.probs).unwrap();
        let lens = code.lengths();
        // recompute what the unshifted midpoints would be
        let levels: Vec<f64> = cb.levels.iter().map(|&x| x as f64).collect();
        let mids = midpoints(&levels);
        let mut checked = 0;
        for l in 1..cb.levels.len() {
            let shift = cb.bounds[l - 1] as f64 - mids[l - 1];
            let dlen = lens[l] as i64 - lens[l - 1] as i64;
            if dlen != 0 && shift.abs() > 1e-9 {
                assert_eq!(
                    shift.signum() as i64,
                    dlen.signum(),
                    "boundary {l}: shift {shift} vs Δℓ {dlen}"
                );
                checked += 1;
            }
        }
        assert!(checked > 0, "no informative boundaries");
    }

    #[test]
    fn target_rate_constraint_is_met() {
        for &target in &[2.5, 2.0, 1.5] {
            let (_, rep, lam) =
                RateConstrainedQuantizer::design_for_target_rate(
                    &StdGaussian, 3, target, LengthModel::Ideal)
                .unwrap();
            assert!(
                rep.entropy_bits <= target + 1e-3,
                "target={target} got {}", rep.entropy_bits
            );
            // shouldn't be wildly over-constrained either
            assert!(
                rep.entropy_bits > target - 0.5,
                "target={target} got {} (λ={lam})", rep.entropy_bits
            );
        }
    }

    #[test]
    fn target_rate_zero_is_unreachable() {
        assert!(RateConstrainedQuantizer::design_for_target_rate(
            &StdGaussian, 3, 0.0, LengthModel::Ideal)
        .is_err());
    }

    #[test]
    fn high_rate_distortion_matches_eq20() {
        // paper eq. (20): MSE ≈ (1/12) 2^{2h(Z)} 2^{-2R} in the high-rate
        // regime. At b=6 with mild λ the ratio should be near 1.
        let rc = RateConstrainedQuantizer {
            lambda: 0.002,
            length_model: LengthModel::Ideal,
            ..Default::default()
        };
        let (_, rep) = rc.design(&StdGaussian, 6).unwrap();
        let h = differential_entropy_bits(1.0);
        let predicted =
            (1.0 / 12.0) * 2f64.powf(2.0 * h) * 2f64.powf(-2.0 * rep.entropy_bits);
        let ratio = rep.mse / predicted;
        assert!(
            (0.5..2.0).contains(&ratio),
            "mse={} predicted={predicted} ratio={ratio}",
            rep.mse
        );
    }

    #[test]
    fn huffman_model_rate_is_realizable() {
        // designed huffman_rate equals what the actual wire code achieves
        let rc = RateConstrainedQuantizer::new(0.05);
        let (_, rep) = rc.design(&StdGaussian, 3).unwrap();
        let code = HuffmanCode::from_probs(&rep.probs).unwrap();
        let realized = code.expected_length(&rep.probs);
        assert!((realized - rep.huffman_rate).abs() < 1e-9);
        assert!(rep.huffman_rate >= rep.entropy_bits - 1e-9);
        assert!(rep.huffman_rate <= rep.entropy_bits + 1.0);
    }

    #[test]
    fn stable_under_large_lambda() {
        // large λ collapses to (nearly) one live cell; must not panic or
        // produce invalid codebooks
        let rc = RateConstrainedQuantizer {
            lambda: 5.0,
            length_model: LengthModel::Ideal,
            ..Default::default()
        };
        let (cb, rep) = rc.design(&StdGaussian, 3).unwrap();
        cb.validate().unwrap();
        assert!(rep.entropy_bits < 1.5);
    }

    #[test]
    fn large_lambda_bounds_stay_in_support() {
        // regression: the old repair_bounds ran its backward
        // tie-breaking pass after clamping, so a collapsed run of
        // boundaries could be stepped past the lower support edge at
        // large λ. All boundaries must lie inside pdf.support().
        let (lo, hi) = StdGaussian.support();
        for &length_model in &[LengthModel::Huffman, LengthModel::Ideal] {
            let rc = RateConstrainedQuantizer {
                lambda: 5.0,
                length_model,
                ..Default::default()
            };
            let (cb, _) = rc.design(&StdGaussian, 3).unwrap();
            cb.validate().unwrap();
            // tolerance: one f32 rounding + sanitizer ULP step
            let tol = 1e-3;
            for (i, &b) in cb.bounds.iter().enumerate() {
                let b = b as f64;
                assert!(
                    b >= lo - tol && b <= hi + tol,
                    "{length_model:?}: bound {i} = {b} outside \
                     support [{lo}, {hi}]"
                );
            }
        }
    }

    #[test]
    fn repair_bounds_postconditions() {
        let (lo, hi) = (-8.0, 8.0);
        let cases: Vec<Vec<f64>> = vec![
            vec![9.0, -9.0, 9.0, 9.0, -9.0],          // wild fold-over
            vec![8.0; 7],                              // collapse at hi
            vec![-8.0; 7],                             // collapse at lo
            vec![-20.0, -19.0, 0.0, 19.0, 20.0],       // clamped tails
            vec![0.5, 0.5, 0.5],                       // interior ties
        ];
        for mut bounds in cases {
            let orig = bounds.clone();
            repair_bounds(&mut bounds, lo, hi);
            for w in bounds.windows(2) {
                assert!(w[0] <= w[1], "{orig:?} -> {bounds:?} not sorted");
            }
            for &b in &bounds {
                assert!(
                    (lo..=hi).contains(&b),
                    "{orig:?} -> {bounds:?} leaves the support"
                );
            }
        }
    }

    #[test]
    fn warm_start_matches_cold_design_and_converges_faster() {
        use crate::stats::empirical::EmpiricalPdf;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let mut z = vec![0f32; 40_000];
        rng.fill_normal_f32(&mut z, 0.0, 1.0);
        let emp = EmpiricalPdf::from_samples(&z);
        let rc = RateConstrainedQuantizer::new(0.05);
        let (cold_cb, cold_rep) = rc.design(&emp, 3).unwrap();
        // warm-start from the closely-related Gaussian design
        let (gauss_cb, _) = rc.design(&StdGaussian, 3).unwrap();
        let (warm_cb, warm_rep) =
            rc.design_warm(&emp, 3, Some(&gauss_cb)).unwrap();
        warm_cb.validate().unwrap();
        // same operating point (the Lagrangian landscape has one basin
        // here), reached in no more iterations than the cold start
        assert!(
            (warm_rep.mse - cold_rep.mse).abs() < 5e-3,
            "warm {} vs cold {}", warm_rep.mse, cold_rep.mse
        );
        assert!(
            (warm_rep.huffman_rate - cold_rep.huffman_rate).abs() < 0.1,
            "warm {} vs cold {}", warm_rep.huffman_rate, cold_rep.huffman_rate
        );
        // both must converge within the iteration budget (the speedup
        // itself is profiled in benches, not asserted — integer Huffman
        // lengths can limit-cycle either run to the cap)
        assert!(warm_rep.iterations >= 1);
        assert!(warm_rep.iterations <= rc.max_iters);
        // wrong-arity warm codebooks are ignored, not an error
        let (cb2, _) = rc.design_warm(&emp, 2, Some(&gauss_cb)).unwrap();
        assert_eq!(cb2.levels.len(), 4);
    }

    #[test]
    fn works_on_empirical_pdf() {
        use crate::stats::empirical::EmpiricalPdf;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let mut z = vec![0f32; 50_000];
        rng.fill_normal_f32(&mut z, 0.0, 1.0);
        let emp = EmpiricalPdf::from_samples(&z);
        let rc = RateConstrainedQuantizer::new(0.05);
        let (cb, rep) = rc.design(&emp, 3).unwrap();
        cb.validate().unwrap();
        let (_, rep_g) = rc.design(&StdGaussian, 3).unwrap();
        assert!((rep.entropy_bits - rep_g.entropy_bits).abs() < 0.15);
    }
}
