//! Scalar quantization — the paper's core contribution lives here.
//!
//! * [`codebook`] — levels/boundaries container + the branch-free apply
//!   path (bucketize / dequantize) used on the hot path;
//! * [`lloyd`] — classical Lloyd-Max (baseline [16], and the λ→0 limit);
//! * [`rcq`] — **rate-constrained quantizer design** (paper §3.2,
//!   eqs. (5)–(10)): alternating level/boundary optimization with
//!   entropy-coding-aware codeword lengths;
//! * [`qsgd`] — QSGD baseline [8];
//! * [`nqfl`] — NQFL nonuniform-companding baseline [14];
//! * [`uniform`] — plain uniform mid-rise quantizer (reference).

pub mod codebook;
pub mod dither;
pub mod lloyd;
pub mod nqfl;
pub mod qsgd;
pub mod rcq;
pub mod uniform;

use crate::stats::SourcePdf;

/// Diagnostics of a designed quantizer against its design PDF.
#[derive(Clone, Debug)]
pub struct DesignReport {
    /// mean squared error, eq. (3)
    pub mse: f64,
    /// entropy of the cell distribution H(Q(Z)), bits/symbol
    pub entropy_bits: f64,
    /// expected Huffman length Σ p_l ℓ_l, bits/symbol, eq. (4)
    pub huffman_rate: f64,
    /// cell probabilities
    pub probs: Vec<f64>,
    /// iterations until convergence
    pub iterations: usize,
}

/// Evaluate `(MSE, probs)` of a codebook under `pdf`.
pub fn evaluate(
    pdf: &dyn SourcePdf,
    codebook: &codebook::Codebook,
) -> (f64, Vec<f64>) {
    let n = codebook.levels.len();
    let mut mse = 0.0;
    let mut probs = Vec::with_capacity(n);
    for l in 0..n {
        let (a, b) = codebook.cell(l);
        mse += pdf.cell_mse(a, b, codebook.levels[l] as f64);
        probs.push(pdf.prob(a, b));
    }
    (mse, probs)
}
