//! Plain uniform (mid-rise) scalar quantizer over a clipped range.
//! Reference point for the rate–distortion benches and the simplest
//! possible baseline.

use crate::quant::codebook::Codebook;
use crate::util::Result;

/// `2^bits` levels uniformly spaced over `[−clip, clip]` (mid-rise:
/// levels at cell centers).
pub fn uniform_codebook(bits: u32, clip: f64) -> Result<Codebook> {
    assert!(clip > 0.0);
    let n = 1usize << bits;
    let step = 2.0 * clip / n as f64;
    let levels: Vec<f64> =
        (0..n).map(|l| -clip + (l as f64 + 0.5) * step).collect();
    let bounds: Vec<f64> =
        (1..n).map(|l| -clip + l as f64 * step).collect();
    Codebook::from_f64(&levels, &bounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::evaluate;
    use crate::stats::gaussian::StdGaussian;

    #[test]
    fn structure() {
        let cb = uniform_codebook(2, 2.0).unwrap();
        assert_eq!(cb.levels, vec![-1.5, -0.5, 0.5, 1.5]);
        assert_eq!(cb.bounds, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn step_shrinks_with_bits() {
        let c3 = uniform_codebook(3, 4.0).unwrap();
        let c6 = uniform_codebook(6, 4.0).unwrap();
        let gap3 = c3.levels[1] - c3.levels[0];
        let gap6 = c6.levels[1] - c6.levels[0];
        assert!((gap3 / gap6 - 8.0).abs() < 1e-5);
    }

    #[test]
    fn high_rate_mse_matches_step_squared_over_12() {
        // in-range distortion ≈ Δ²/12 for fine uniform quantization
        let clip = 6.0;
        let cb = uniform_codebook(8, clip).unwrap();
        let (mse, _) = evaluate(&StdGaussian, &cb);
        let step = 2.0 * clip / 256.0;
        let want = step * step / 12.0;
        assert!((mse / want - 1.0).abs() < 0.05, "mse={mse} want={want}");
    }
}
