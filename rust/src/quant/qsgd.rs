//! QSGD baseline [8] (Alistarh et al., NeurIPS 2017).
//!
//! Bucketed variant, as deployed in the reference implementation: the
//! gradient is split into buckets of `bucket` coordinates; per bucket,
//! transmit `‖v‖₂` (32-bit float) and per-coordinate signed,
//! stochastically-rounded magnitude levels `ξ_i ∈ {0, 1/s, …, 1}` with
//! `s = 2^b − 1`, such that `E[Q(v_i)] = v_i` (unbiased). Bucketing is
//! essential at FL scale: with a whole-vector norm and d ~ 10⁵–10⁷,
//! `|v_i|/‖v‖·s ≈ 0` and the quantizer degenerates to zero. Symbols are
//! the signed levels remapped to `[0, 2s]`, entropy-coded by the same
//! Huffman wire coder as RC-FED ("for a fair comparison", paper §5).

use crate::util::rng::Rng;

/// Default bucket size (the QSGD paper's deployment value).
pub const DEFAULT_BUCKET: usize = 512;

/// QSGD encoder/decoder state.
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    /// quantization bit-width b; s = 2^b − 1 magnitude levels
    pub bits: u32,
    /// coordinates per norm bucket
    pub bucket: usize,
}

/// Encoded QSGD message: per-bucket norms + symbol per coordinate.
#[derive(Clone, Debug)]
pub struct QsgdMessage {
    /// ‖v‖₂ of each bucket (ceil(d / bucket) entries)
    pub norms: Vec<f32>,
    /// symbol per coordinate in `[0, 2s]`: `s + signed_level`
    pub symbols: Vec<u8>,
}

impl Qsgd {
    pub fn new(bits: u32) -> Self {
        Self::with_bucket(bits, DEFAULT_BUCKET)
    }

    pub fn with_bucket(bits: u32, bucket: usize) -> Self {
        assert!(bits >= 1 && bits <= 7, "qsgd bits in [1,7] (u8 symbols)");
        assert!(bucket > 0);
        Qsgd { bits, bucket }
    }

    /// Number of magnitude levels `s`.
    pub fn s(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Alphabet size of the emitted symbols (`2s + 1`).
    pub fn num_symbols(&self) -> usize {
        2 * self.s() as usize + 1
    }

    pub fn num_buckets(&self, d: usize) -> usize {
        d.div_ceil(self.bucket)
    }

    /// Stochastically quantize `v`; unbiased: `E[decode(encode(v))] = v`.
    pub fn encode(&self, v: &[f32], rng: &mut Rng) -> QsgdMessage {
        let s = self.s() as f32;
        let mut norms = Vec::with_capacity(self.num_buckets(v.len()));
        let mut symbols = Vec::with_capacity(v.len());
        for chunk in v.chunks(self.bucket) {
            let norm = chunk
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt() as f32;
            norms.push(norm);
            if norm <= 0.0 {
                symbols.extend(
                    std::iter::repeat(self.s() as u8).take(chunk.len()));
                continue;
            }
            for &x in chunk {
                let a = x.abs() / norm * s; // in [0, s]
                let lo = a.floor();
                let p = a - lo; // round up with prob p
                let level = lo as u32 + (rng.uniform() < p as f64) as u32;
                let signed = if x < 0.0 {
                    self.s() as i32 - level as i32
                } else {
                    self.s() as i32 + level as i32
                };
                symbols.push(signed as u8);
            }
        }
        QsgdMessage { norms, symbols }
    }

    /// Reconstruct coordinates from a message.
    pub fn decode_into(&self, msg: &QsgdMessage, out: &mut [f32]) {
        let s = self.s() as f32;
        for (b, chunk) in out.chunks_mut(self.bucket).enumerate() {
            let norm = msg.norms[b];
            for (i, o) in chunk.iter_mut().enumerate() {
                let sym = msg.symbols[b * self.bucket + i];
                let signed = sym as i32 - self.s() as i32;
                *o = norm * signed as f32 / s;
            }
        }
    }

    /// Accumulate reconstruction: `acc[i] += decode(msg)[i]`.
    pub fn decode_accumulate(&self, msg: &QsgdMessage, acc: &mut [f32]) {
        let s = self.s() as f32;
        for (b, chunk) in acc.chunks_mut(self.bucket).enumerate() {
            let norm = msg.norms[b];
            for (i, o) in chunk.iter_mut().enumerate() {
                let sym = msg.symbols[b * self.bucket + i];
                let signed = sym as i32 - self.s() as i32;
                *o += norm * signed as f32 / s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_vector() {
        let q = Qsgd::new(3);
        let mut rng = Rng::new(1);
        let msg = q.encode(&[0.0; 16], &mut rng);
        let mut out = [1.0f32; 16];
        q.decode_into(&msg, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn symbols_in_range_and_bucket_count() {
        let q = Qsgd::with_bucket(3, 100);
        let mut rng = Rng::new(2);
        let mut v = vec![0f32; 1000];
        rng.fill_normal_f32(&mut v, 0.0, 2.0);
        let msg = q.encode(&v, &mut rng);
        assert_eq!(msg.norms.len(), 10);
        assert_eq!(msg.symbols.len(), 1000);
        assert!(msg
            .symbols
            .iter()
            .all(|&s| (s as usize) < q.num_symbols()));
        // ragged tail
        let q = Qsgd::with_bucket(3, 300);
        let msg = q.encode(&v, &mut rng);
        assert_eq!(msg.norms.len(), 4);
    }

    #[test]
    fn unbiasedness() {
        // E[Q(v)] = v: average many stochastic encodings (two buckets)
        let q = Qsgd::with_bucket(2, 3);
        let mut rng = Rng::new(3);
        let v = [0.3f32, -0.7, 0.05, 0.9, -0.2];
        let mut acc = vec![0f64; v.len()];
        let trials = 20_000;
        let mut out = vec![0f32; v.len()];
        for _ in 0..trials {
            let msg = q.encode(&v, &mut rng);
            q.decode_into(&msg, &mut out);
            for (a, &o) in acc.iter_mut().zip(&out) {
                *a += o as f64;
            }
        }
        for (i, (&want, &got)) in v.iter().zip(&acc).enumerate() {
            let mean = got / trials as f64;
            assert!(
                (mean - want as f64).abs() < 0.01,
                "coord {i}: {mean} vs {want}"
            );
        }
    }

    #[test]
    fn reconstruction_error_decreases_with_bits() {
        let mut rng = Rng::new(4);
        let mut v = vec![0f32; 4096];
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        let mut last = f64::INFINITY;
        for bits in [1u32, 3, 5, 7] {
            let q = Qsgd::new(bits);
            let msg = q.encode(&v, &mut rng);
            let mut out = vec![0f32; v.len()];
            q.decode_into(&msg, &mut out);
            let mse: f64 = v
                .iter()
                .zip(&out)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / v.len() as f64;
            assert!(mse < last, "bits={bits} mse={mse} last={last}");
            last = mse;
        }
    }

    #[test]
    fn bucketing_reduces_variance() {
        // smaller buckets ⇒ better-conditioned levels ⇒ lower MSE
        let mut rng = Rng::new(5);
        let mut v = vec![0f32; 8192];
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        let mse_of = |bucket: usize, rng: &mut Rng| {
            let q = Qsgd::with_bucket(3, bucket);
            let msg = q.encode(&v, rng);
            let mut out = vec![0f32; v.len()];
            q.decode_into(&msg, &mut out);
            v.iter()
                .zip(&out)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / v.len() as f64
        };
        let small = mse_of(128, &mut rng);
        let huge = mse_of(8192, &mut rng);
        assert!(small < huge / 4.0, "bucket128 {small} vs whole {huge}");
    }

    #[test]
    fn sign_preserved_for_large_coords() {
        let q = Qsgd::new(4);
        let mut rng = Rng::new(5);
        let v = [10.0f32, -10.0, 0.0, 5.0];
        let msg = q.encode(&v, &mut rng);
        let mut out = [0f32; 4];
        q.decode_into(&msg, &mut out);
        assert!(out[0] > 0.0 && out[1] < 0.0 && out[3] > 0.0);
    }

    #[test]
    fn accumulate_matches_decode() {
        let q = Qsgd::with_bucket(3, 50);
        let mut rng = Rng::new(6);
        let mut v = vec![0f32; 128];
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        let msg = q.encode(&v, &mut rng);
        let mut a = vec![0.5f32; v.len()];
        let mut b = vec![0f32; v.len()];
        q.decode_accumulate(&msg, &mut a);
        q.decode_into(&msg, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y - 0.5).abs() < 1e-6);
        }
    }
}
