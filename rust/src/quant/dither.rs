//! Subtractive dithered quantization (extension).
//!
//! The paper's convergence analysis (Lemma 2) models quantization error
//! as zero-mean noise, but a deterministic scalar quantizer leaves a
//! data-correlated *bias* — visible as the small optimality-gap floor in
//! bench E4. Subtractive dithering (the mechanism underlying UVeQFed
//! [11], and the natural "beyond deterministic scalar quantization"
//! extension the paper's conclusion points to) removes it exactly:
//!
//! * client adds `u_i ~ Uniform(−Δ/2, Δ/2)` (pseudo-random from a seed
//!   shared with the PS — zero extra communication) before a uniform
//!   quantizer with step Δ;
//! * PS reconstructs `Q(z + u) − u`.
//!
//! The reconstruction error is then uniform, independent of the data,
//! and exactly zero-mean (Schuchman's condition), matching the
//! assumptions of the paper's Lemma 2.

use crate::quant::codebook::Codebook;
use crate::quant::uniform::uniform_codebook;
use crate::util::rng::Rng;
use crate::util::Result;

/// Shared-seed subtractive dither around a uniform codebook.
#[derive(Clone, Debug)]
pub struct DitheredUniform {
    pub codebook: Codebook,
    /// quantizer step Δ
    pub step: f32,
}

impl DitheredUniform {
    /// `2^bits` levels over ±clip (normalized domain).
    pub fn new(bits: u32, clip: f64) -> Result<DitheredUniform> {
        let codebook = uniform_codebook(bits, clip)?;
        let step = codebook.levels[1] - codebook.levels[0];
        Ok(DitheredUniform { codebook, step })
    }

    /// Dither stream for a message: deterministic in `(seed, round,
    /// client)` so the PS regenerates it without any transmission.
    pub fn dither_rng(seed: u64, client: u32, round: u32) -> Rng {
        Rng::new(
            seed ^ (client as u64) << 32
                ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15),
        )
    }

    /// Client side: quantize `z + u` to symbols.
    pub fn quantize(&self, z: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(z.len());
        let half = 0.5 * self.step;
        for &x in z {
            let u = rng.uniform_in(-half as f64, half as f64) as f32;
            out.push(self.codebook.index_of(x + u));
        }
    }

    /// PS side: reconstruct `level[s] − u` with the regenerated dither.
    pub fn dequantize_into(
        &self,
        symbols: &[u8],
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        let half = 0.5 * self.step;
        for (o, &s) in out.iter_mut().zip(symbols) {
            let u = rng.uniform_in(-half as f64, half as f64) as f32;
            *o = self.codebook.level(s) - u;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_within_half_step() {
        let dq = DitheredUniform::new(4, 4.0).unwrap();
        let mut enc = DitheredUniform::dither_rng(1, 2, 3);
        let mut dec = DitheredUniform::dither_rng(1, 2, 3);
        let mut rng = Rng::new(9);
        let z: Vec<f32> = (0..4096)
            .map(|_| rng.normal_with(0.0, 1.0) as f32)
            .collect();
        let mut sym = Vec::new();
        dq.quantize(&z, &mut enc, &mut sym);
        let mut out = vec![0f32; z.len()];
        dq.dequantize_into(&sym, &mut dec, &mut out);
        for (i, (&a, &b)) in z.iter().zip(&out).enumerate() {
            if a.abs() < 3.5 {
                assert!(
                    (a - b).abs() <= dq.step * 0.5 + 1e-6,
                    "i={i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn error_is_unbiased_and_data_independent() {
        // Schuchman: with subtractive dither, E[err | z] = 0 for any z in
        // range — the property the deterministic quantizer lacks.
        let dq = DitheredUniform::new(3, 4.0).unwrap();
        for &z0 in &[0.0f32, 0.13, 0.37, -1.234] {
            let mut err_sum = 0f64;
            let trials = 40_000;
            for t in 0..trials {
                let mut enc = DitheredUniform::dither_rng(7, 0, t);
                let mut dec = DitheredUniform::dither_rng(7, 0, t);
                let mut sym = Vec::new();
                dq.quantize(&[z0], &mut enc, &mut sym);
                let mut out = [0f32];
                dq.dequantize_into(&sym, &mut dec, &mut out);
                err_sum += (out[0] - z0) as f64;
            }
            let bias = err_sum / trials as f64;
            assert!(bias.abs() < 0.005, "z={z0}: bias {bias}");
        }
    }

    #[test]
    fn deterministic_quantizer_is_biased_where_dither_is_not() {
        // the contrast that explains the E4 floor: plain uniform
        // quantization of a fixed z has deterministic error; dithered
        // has ~0 — measured at a worst-case point (z halfway into a cell)
        let plain = uniform_codebook(3, 4.0).unwrap();
        let z0 = plain.levels[4] + 0.2; // off-center within a cell
        let det_err = plain.level(plain.index_of(z0)) - z0;
        assert!(det_err.abs() > 0.15, "test point not off-center");
        // dithered bias at the same point ≈ 0 (previous test asserts it)
        let dq = DitheredUniform::new(3, 4.0).unwrap();
        let mut err_sum = 0f64;
        for t in 0..20_000 {
            let mut enc = DitheredUniform::dither_rng(11, 0, t);
            let mut dec = DitheredUniform::dither_rng(11, 0, t);
            let mut sym = Vec::new();
            dq.quantize(&[z0], &mut enc, &mut sym);
            let mut out = [0f32];
            dq.dequantize_into(&sym, &mut dec, &mut out);
            err_sum += (out[0] - z0) as f64;
        }
        let dith_bias = (err_sum / 20_000.0).abs();
        assert!(
            dith_bias < det_err.abs() as f64 / 10.0,
            "dither bias {dith_bias} vs deterministic {det_err}"
        );
    }

    #[test]
    fn shared_seed_regenerates_identical_dither() {
        let mut a = DitheredUniform::dither_rng(42, 7, 9);
        let mut b = DitheredUniform::dither_rng(42, 7, 9);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // different rounds decorrelate
        let mut c = DitheredUniform::dither_rng(42, 7, 10);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn removes_convergence_floor_on_quadratic_federation() {
        // E4 tie-in: with dithering, quantized DSGD converges past the
        // deterministic quantizer's bias floor.
        use crate::model::convex::QuadraticFederation;
        use crate::stats::moments::mean_std;
        let fed = QuadraticFederation::new(32, 8, 1.0, 4.0, 0.8, 0.0, 7);
        let f_star = fed.global_loss(&fed.optimum());
        let gamma = 8.0 * fed.l_smooth / fed.rho;
        let run = |dithered: bool| -> f64 {
            let plain = uniform_codebook(3, 4.0).unwrap();
            let dq = DitheredUniform::new(3, 4.0).unwrap();
            let mut theta = vec![2.0f32; fed.dim];
            let mut g = vec![0f32; fed.dim];
            for t in 0..800u32 {
                let eta =
                    (2.0 / (fed.rho * (t as f64 + gamma))) as f32;
                let mut agg = vec![0f32; fed.dim];
                for k in 0..fed.num_clients() {
                    fed.local_grad(k, &theta, None, &mut g);
                    let (mu, sigma) = mean_std(&g);
                    let s = sigma.max(1e-8);
                    let z: Vec<f32> =
                        g.iter().map(|&x| (x - mu) / s).collect();
                    let mut sym = Vec::new();
                    let mut rec = vec![0f32; fed.dim];
                    if dithered {
                        let mut enc = DitheredUniform::dither_rng(
                            1, k as u32, t);
                        let mut dec = DitheredUniform::dither_rng(
                            1, k as u32, t);
                        dq.quantize(&z, &mut enc, &mut sym);
                        dq.dequantize_into(&sym, &mut dec, &mut rec);
                    } else {
                        plain.quantize_slice(&z, &mut sym);
                        for (r, &sm) in rec.iter_mut().zip(&sym) {
                            *r = plain.level(sm);
                        }
                    }
                    for (a, &r) in agg.iter_mut().zip(&rec) {
                        *a += s * r + mu;
                    }
                }
                for (th, &gv) in theta.iter_mut().zip(&agg) {
                    *th -= eta * gv / fed.num_clients() as f32;
                }
            }
            fed.global_loss(&theta) - f_star
        };
        let floor_det = run(false);
        let floor_dith = run(true);
        assert!(
            floor_dith < floor_det * 0.5,
            "dither {floor_dith} vs deterministic {floor_det}"
        );
    }
}
