//! Codebook container and the hot-path apply routines.
//!
//! A codebook is the paper's `{s_l, u_l : l ∈ [2^b]}`: `2^b` reconstruction
//! levels plus `2^b − 1` interior decision boundaries (the outer cells
//! extend to ±∞). `Q(z) = s_l` iff `u_l < z ≤ u_{l+1}` (§3.2).
//!
//! `quantize_slice` is the rust-native mirror of the L1 Pallas kernel
//! (`python/compile/kernels/quantize.py`); the two are cross-checked in
//! `rust/tests/pjrt_roundtrip.rs`. For the small alphabets RC-FED uses
//! (≤ 64 levels) a branch-free linear compare-sum beats binary search on
//! modern cores for b ≤ 4 and stays competitive at b = 6; we pick the
//! strategy per width (cutoff: [`SMALL_MAX_BOUNDS`]).
//!
//! Perf architecture: the wide-alphabet (b ≥ 5) bin table is built at
//! *design time* — the bin structure lives in the normalized domain and
//! is invariant under the per-packet affine `(μ, σ)` map — so an apply
//! touches no per-call table build. The dequantize side premultiplies
//! `σ·s_l + μ` into a ≤ 256-entry table per packet, reducing the per
//! coordinate work to a single gather (+ add). Every fast kernel has a
//! `*_reference` scalar twin pinned byte-identical by
//! `tests/quantizer_kernels.rs`.

use crate::util::{Error, Result};

/// Sigma floor shared with the Pallas kernel (see kernels/quantize.py).
pub const SIGMA_FLOOR: f32 = 1e-8;

/// Small-alphabet cutoff shared by every apply kernel: alphabets with at
/// most this many interior boundaries (b ≤ 4, i.e. ≤ 16 levels) take the
/// branch-free compare-sum; wider ones take the binned lookup (block
/// kernel) or binary search (`index_of`). One constant so the scalar and
/// block paths can never disagree about which strategy a width gets.
pub const SMALL_MAX_BOUNDS: usize = 15;

/// Uniform lookup bins in the design-time wide-alphabet table.
const BINS: usize = 2048;

/// A scalar quantizer: sorted reconstruction levels + interior boundaries.
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    /// reconstruction levels `s_0 < s_1 < … < s_{N-1}`
    pub levels: Vec<f32>,
    /// interior boundaries `u_1 < … < u_{N-1}` (len = N − 1)
    pub bounds: Vec<f32>,
    /// Design-time bin table for the wide-alphabet quantize path (empty
    /// for small alphabets). Bin `k` of the uniform grid over
    /// `[bounds[0], bounds[n-1]]` stores the `(min_c, max_c)` bracket of
    /// boundary indices any normalized value mapped to that bin can
    /// straddle. The bin structure lives in the *normalized* domain, so
    /// it is invariant under the per-packet affine `(μ, σ)` map and is
    /// built exactly once per codebook instead of per quantize call.
    /// Brackets are widened by one grid cell on each side so the
    /// f32-rounded apply-time bin index (which can land one cell off the
    /// exact edge comparison) always yields a valid bracket.
    bins: Vec<(u8, u8)>,
    /// Grid origin / scale captured at design time; the apply path must
    /// use these exact f32 values for the bracket guarantee to hold.
    bin_lo: f32,
    bin_scale: f32,
}

/// Build the design-time bin table over normalized boundaries.
fn build_bins(bounds: &[f32]) -> (Vec<(u8, u8)>, f32, f32) {
    let n = bounds.len();
    let lo = bounds[0];
    let span = (bounds[n - 1] - lo).max(f32::MIN_POSITIVE);
    let scale = BINS as f32 / span;
    let mut bins = Vec::with_capacity(BINS);
    for k in 0..BINS {
        let min_c = if k == 0 {
            0
        } else {
            let start = lo + (k - 1) as f32 / scale;
            bounds.partition_point(|&u| u < start) as u8
        };
        let max_c = if k + 2 >= BINS {
            n as u8
        } else {
            let end = lo + (k + 2) as f32 / scale;
            bounds.partition_point(|&u| u < end) as u8
        };
        bins.push((min_c, max_c));
    }
    (bins, lo, scale)
}

impl Codebook {
    pub fn new(levels: Vec<f32>, bounds: Vec<f32>) -> Result<Codebook> {
        if levels.is_empty() || bounds.len() + 1 != levels.len() {
            return Err(Error::Quant(format!(
                "codebook arity: {} levels, {} bounds",
                levels.len(),
                bounds.len()
            )));
        }
        let mut cb = Codebook {
            levels,
            bounds,
            bins: Vec::new(),
            bin_lo: 0.0,
            bin_scale: 0.0,
        };
        cb.validate()?;
        // u8 brackets cap the table at 255 boundaries (b ≤ 8 — every
        // alphabet the codec can express); wider books fall back to
        // per-coordinate binary search.
        if cb.bounds.len() > SMALL_MAX_BOUNDS && cb.bounds.len() <= u8::MAX as usize {
            let (bins, lo, scale) = build_bins(&cb.bounds);
            cb.bins = bins;
            cb.bin_lo = lo;
            cb.bin_scale = scale;
        }
        Ok(cb)
    }

    /// Levels from f64 design output.
    pub fn from_f64(levels: &[f64], bounds: &[f64]) -> Result<Codebook> {
        Codebook::new(
            levels.iter().map(|&x| x as f32).collect(),
            bounds.iter().map(|&x| x as f32).collect(),
        )
    }

    /// Like [`from_f64`], but repairs f32-rounding ties: design iterates
    /// can produce neighbours separated by less than one f32 ULP (empty
    /// cells under large λ collapse to ε-spacing). Such cells carry ~zero
    /// probability, so nudging them to the next representable float does
    /// not change the quantizer measurably.
    pub fn from_f64_sanitized(levels: &[f64], bounds: &[f64]) -> Result<Codebook> {
        fn strictify(xs: &mut [f32]) {
            for i in 1..xs.len() {
                if xs[i] <= xs[i - 1] {
                    xs[i] = xs[i - 1].next_up();
                }
            }
        }
        let mut l: Vec<f32> = levels.iter().map(|&x| x as f32).collect();
        let mut b: Vec<f32> = bounds.iter().map(|&x| x as f32).collect();
        strictify(&mut l);
        strictify(&mut b);
        Codebook::new(l, b)
    }

    pub fn validate(&self) -> Result<()> {
        let mono = |xs: &[f32]| xs.windows(2).all(|w| w[0] < w[1]);
        if !mono(&self.levels) {
            return Err(Error::Quant("levels not strictly increasing".into()));
        }
        if !mono(&self.bounds) {
            return Err(Error::Quant("bounds not strictly increasing".into()));
        }
        if !self.levels.iter().chain(&self.bounds).all(|x| x.is_finite()) {
            return Err(Error::Quant("non-finite codebook entry".into()));
        }
        Ok(())
    }

    /// Number of levels `N = 2^b`.
    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// Nominal bit width `b = ceil(log2 N)`.
    pub fn bits(&self) -> u32 {
        usize::BITS - (self.num_levels() - 1).leading_zeros()
    }

    /// Cell `l` as `(lo, hi]` with infinite outer edges.
    pub fn cell(&self, l: usize) -> (f64, f64) {
        let lo = if l == 0 {
            f64::NEG_INFINITY
        } else {
            self.bounds[l - 1] as f64
        };
        let hi = if l == self.levels.len() - 1 {
            f64::INFINITY
        } else {
            self.bounds[l] as f64
        };
        (lo, hi)
    }

    /// Index of the cell containing `z`: `#{j : u_j < z}`.
    #[inline]
    pub fn index_of(&self, z: f32) -> u8 {
        if self.bounds.len() <= SMALL_MAX_BOUNDS {
            // branch-free compare-sum (mirrors the Pallas kernel)
            let mut idx = 0u8;
            for &u in &self.bounds {
                idx += (z > u) as u8;
            }
            idx
        } else {
            // #{j : u_j < z}: z exactly on a boundary maps to the lower
            // cell, matching the (u_l, u_{l+1}] semantics of §3.2.
            self.bounds.partition_point(|&u| u < z) as u8
        }
    }

    /// Quantize a normalized slice to symbol indices (hot path).
    pub fn quantize_slice(&self, z: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(z.len());
        if self.bounds.len() <= SMALL_MAX_BOUNDS {
            for &x in z {
                let mut idx = 0u8;
                for &u in &self.bounds {
                    idx += (x > u) as u8;
                }
                out.push(idx);
            }
        } else {
            for &x in z {
                out.push(self.bounds.partition_point(|&u| u < x) as u8);
            }
        }
    }

    /// Quantize raw gradients with affine normalization, mirroring the L1
    /// kernel: `idx = Q((g - mu)/max(sigma, floor))`.
    ///
    /// Hot path (§Perf): instead of normalizing every coordinate, the
    /// boundaries are transformed *once* into the raw-gradient domain
    /// (`z > u ⟺ g > σ·u + μ`, σ > 0), and the compare-sum runs over
    /// L1-cache-resident blocks with a fixed-trip inner loop — fully
    /// auto-vectorized, one load + 2^b−1 SIMD compares per coordinate and
    /// zero divisions.
    pub fn quantize_normalized(
        &self,
        g: &[f32],
        mu: f32,
        sigma: f32,
        out: &mut Vec<u8>,
    ) {
        let s = sigma.max(SIGMA_FLOOR);
        out.clear();
        out.resize(g.len(), 0);
        if self.bounds.len() <= SMALL_MAX_BOUNDS {
            // boundaries in the raw domain (f64 to avoid double-rounding
            // the affine map; result rounded once to f32) — stack-resident,
            // no per-call allocation
            let mut raw = [0f32; SMALL_MAX_BOUNDS];
            let raw = &mut raw[..self.bounds.len()];
            for (r, &u) in raw.iter_mut().zip(&self.bounds) {
                *r = (u as f64 * s as f64 + mu as f64) as f32;
            }
            // small alphabet: SIMD compare-sum over L1-resident blocks.
            // i32 accumulators keep the whole block in packed-SIMD form
            // (cmpps + psubd, 8 lanes); one narrowing pass at the end.
            const BLK: usize = 4096;
            let mut acc = [0i32; BLK];
            for (gb, ob) in g.chunks(BLK).zip(out.chunks_mut(BLK)) {
                let acc = &mut acc[..gb.len()];
                acc.fill(0);
                for &u in raw.iter() {
                    for (a, &x) in acc.iter_mut().zip(gb) {
                        *a += (x > u) as i32;
                    }
                }
                for (o, &a) in ob.iter_mut().zip(acc.iter()) {
                    *o = a as u8;
                }
            }
        } else if !self.bins.is_empty() {
            // wide alphabet (b ≥ 5): design-time binned lookup. The bin
            // table lives in the normalized domain (invariant under the
            // affine map), so the per-call cost is one division; each
            // coordinate is normalized (sub + mul), resolved to a bin
            // with one multiply + two loads, and finished by a short
            // compare loop over the bin's (widened) boundary bracket.
            // Result ≡ `index_of((x − μ)·inv)` for every input, including
            // NaN (→ symbol 0) and boundary-exact values.
            let inv = 1.0f32 / s;
            let lo = self.bin_lo;
            let scale = self.bin_scale;
            let bounds = &self.bounds[..];
            let bins = &self.bins[..];
            for (o, &x) in out.iter_mut().zip(g) {
                let z = (x - mu) * inv;
                let k = (((z - lo) * scale) as i32).clamp(0, BINS as i32 - 1)
                    as usize;
                let (min_c, max_c) = bins[k];
                let mut c = min_c;
                // rare: bracket straddles a boundary (plus the one-cell
                // widening margin)
                for j in min_c..max_c {
                    c += (bounds[j as usize] < z) as u8;
                }
                *o = c;
            }
        } else {
            // > 255 boundaries: no u8-indexed bin table; binary search
            let inv = 1.0f32 / s;
            for (o, &x) in out.iter_mut().zip(g) {
                *o = self.index_of((x - mu) * inv);
            }
        }
    }

    /// Scalar reference for [`quantize_normalized`]: the same per-width
    /// affine semantics with none of the blocking/binning machinery. The
    /// differential suite (`tests/quantizer_kernels.rs`) pins the fast
    /// kernels byte-identical to this oracle.
    pub fn quantize_normalized_reference(
        &self,
        g: &[f32],
        mu: f32,
        sigma: f32,
        out: &mut Vec<u8>,
    ) {
        let s = sigma.max(SIGMA_FLOOR);
        out.clear();
        out.reserve(g.len());
        if self.bounds.len() <= SMALL_MAX_BOUNDS {
            let raw: Vec<f32> = self
                .bounds
                .iter()
                .map(|&u| (u as f64 * s as f64 + mu as f64) as f32)
                .collect();
            for &x in g {
                let mut c = 0u8;
                for &u in &raw {
                    c += (x > u) as u8;
                }
                out.push(c);
            }
        } else {
            let inv = 1.0f32 / s;
            for &x in g {
                out.push(self.index_of((x - mu) * inv));
            }
        }
    }

    /// Reconstruction level of a symbol (the `Q_i^*` of eq. (11)).
    #[inline]
    pub fn level(&self, idx: u8) -> f32 {
        self.levels[idx as usize]
    }

    /// Premultiplied reconstruction table `t[l] = σ·s_l + μ` — the exact
    /// f32 expression the scalar path evaluates per coordinate, computed
    /// once per packet (≤ 256 entries) so dequantize is a single gather
    /// (+ add) per coordinate. Byte-identical by construction.
    #[inline]
    fn premul_table(&self, mu: f32, sigma: f32, t: &mut [f32; 256]) {
        let s = sigma.max(SIGMA_FLOOR);
        for (ti, &l) in t.iter_mut().zip(&self.levels) {
            *ti = s * l + mu;
        }
    }

    /// Owned premultiplied reconstruction table for deferred (fused)
    /// accumulation: the decode phase builds the table once per packet,
    /// and the replay phase does the gather-add without needing the
    /// codec alive. Entries beyond the live levels are 0 (unreachable:
    /// symbols are always `< levels.len()`).
    pub fn recon_table(&self, mu: f32, sigma: f32) -> Box<[f32; 256]> {
        let mut t = Box::new([0f32; 256]);
        self.premul_table(mu, sigma, &mut t);
        t
    }

    /// De-normalize symbols into `out[i] = sigma * s_idx + mu` (PS side).
    pub fn dequantize_into(
        &self,
        symbols: &[u8],
        mu: f32,
        sigma: f32,
        out: &mut [f32],
    ) {
        let mut t = [0f32; 256];
        self.premul_table(mu, sigma, &mut t);
        for (o, &i) in out.iter_mut().zip(symbols) {
            *o = t[i as usize];
        }
    }

    /// Accumulate de-normalized symbols: `acc[i] += sigma * s_idx + mu`.
    /// The PS aggregation path (avoids materializing per-client vectors).
    pub fn dequantize_accumulate(
        &self,
        symbols: &[u8],
        mu: f32,
        sigma: f32,
        acc: &mut [f32],
    ) {
        let mut t = [0f32; 256];
        self.premul_table(mu, sigma, &mut t);
        for (o, &i) in acc.iter_mut().zip(symbols) {
            *o += t[i as usize];
        }
    }

    /// Scalar reference for [`dequantize_into`] (differential oracle):
    /// evaluates `σ·s_idx + μ` per coordinate, no premultiplied table.
    pub fn dequantize_into_reference(
        &self,
        symbols: &[u8],
        mu: f32,
        sigma: f32,
        out: &mut [f32],
    ) {
        let s = sigma.max(SIGMA_FLOOR);
        for (o, &i) in out.iter_mut().zip(symbols) {
            *o = s * self.levels[i as usize] + mu;
        }
    }

    /// Scalar reference for [`dequantize_accumulate`] (differential oracle).
    pub fn dequantize_accumulate_reference(
        &self,
        symbols: &[u8],
        mu: f32,
        sigma: f32,
        acc: &mut [f32],
    ) {
        let s = sigma.max(SIGMA_FLOOR);
        for (o, &i) in acc.iter_mut().zip(symbols) {
            *o += s * self.levels[i as usize] + mu;
        }
    }

    /// Empirical MSE of this codebook on a normalized sample set.
    pub fn empirical_mse(&self, z: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &x in z {
            let q = self.level(self.index_of(x));
            let d = (x - q) as f64;
            acc += d * d;
        }
        acc / z.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn simple() -> Codebook {
        Codebook::new(
            vec![-1.5, -0.5, 0.5, 1.5],
            vec![-1.0, 0.0, 1.0],
        )
        .unwrap()
    }

    #[test]
    fn arity_checks() {
        assert!(Codebook::new(vec![], vec![]).is_err());
        assert!(Codebook::new(vec![0.0], vec![0.0]).is_err());
        assert!(Codebook::new(vec![0.0, 1.0], vec![]).is_err());
        assert!(Codebook::new(vec![1.0, 0.0], vec![0.5]).is_err());
        assert!(Codebook::new(vec![0.0, 1.0], vec![f32::NAN]).is_err());
    }

    #[test]
    fn paper_cell_semantics() {
        // Q(z) = s_l iff u_l < z <= u_{l+1}: boundary maps to lower cell
        let cb = simple();
        assert_eq!(cb.index_of(-1.0), 0);
        assert_eq!(cb.index_of(-0.999), 1);
        assert_eq!(cb.index_of(0.0), 1);
        assert_eq!(cb.index_of(1.0), 2);
        assert_eq!(cb.index_of(1.001), 3);
        assert_eq!(cb.index_of(-100.0), 0);
        assert_eq!(cb.index_of(100.0), 3);
    }

    #[test]
    fn cells_partition_the_line() {
        let cb = simple();
        assert_eq!(cb.cell(0), (f64::NEG_INFINITY, -1.0));
        assert_eq!(cb.cell(1), (-1.0, 0.0));
        assert_eq!(cb.cell(3), (1.0, f64::INFINITY));
    }

    #[test]
    fn bits() {
        assert_eq!(simple().bits(), 2);
        let cb8 = Codebook::from_f64(
            &(0..8).map(|i| i as f64).collect::<Vec<_>>(),
            &(0..7).map(|i| i as f64 + 0.5).collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(cb8.bits(), 3);
    }

    #[test]
    fn quantize_slice_matches_scalar() {
        let cb = simple();
        let mut rng = Rng::new(1);
        let mut z = vec![0f32; 1000];
        rng.fill_normal_f32(&mut z, 0.0, 1.2);
        let mut out = Vec::new();
        cb.quantize_slice(&z, &mut out);
        for (i, &x) in z.iter().enumerate() {
            assert_eq!(out[i], cb.index_of(x));
        }
    }

    #[test]
    fn linear_and_binary_paths_agree() {
        // 64-level codebook exercises the binary-search path
        let levels: Vec<f64> = (0..64).map(|i| (i as f64 - 31.5) / 8.0).collect();
        let bounds: Vec<f64> =
            levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        let cb = Codebook::from_f64(&levels, &bounds).unwrap();
        assert!(cb.bounds.len() > 16);
        let mut rng = Rng::new(2);
        for _ in 0..2000 {
            let z = rng.normal_with(0.0, 2.0) as f32;
            // reference linear scan
            let mut idx = 0u8;
            for &u in &cb.bounds {
                idx += (z > u) as u8;
            }
            assert_eq!(cb.index_of(z), idx, "z={z}");
        }
        // exact boundary values must map to the lower cell in both paths
        for (j, &u) in cb.bounds.iter().enumerate() {
            assert_eq!(cb.index_of(u) as usize, j, "boundary {j}");
        }
    }

    #[test]
    fn normalize_quantize_dequantize_roundtrip() {
        let cb = simple();
        let mut rng = Rng::new(3);
        let mut g = vec![0f32; 512];
        rng.fill_normal_f32(&mut g, 5.0, 2.0);
        let (mu, sigma) = crate::stats::moments::mean_std(&g);
        let mut sym = Vec::new();
        cb.quantize_normalized(&g, mu, sigma, &mut sym);
        let mut rec = vec![0f32; g.len()];
        cb.dequantize_into(&sym, mu, sigma, &mut rec);
        // reconstruction error bounded by sigma * max cell radius (inner)
        for (i, (&x, &r)) in g.iter().zip(&rec).enumerate() {
            let z = (x - mu) / sigma;
            if z.abs() < 1.4 {
                assert!((x - r).abs() <= sigma * 0.51,
                        "i={i} x={x} r={r}");
            }
        }
    }

    #[test]
    fn wide_path_matches_index_of() {
        // the design-time bin cache must reproduce `index_of((x−μ)·inv)`
        // exactly — including values far outside the boundary span and
        // exact interior boundaries
        let levels: Vec<f64> = (0..64).map(|i| (i as f64 - 31.5) / 8.0).collect();
        let bounds: Vec<f64> =
            levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        let cb = Codebook::from_f64(&levels, &bounds).unwrap();
        let (mu, sigma) = (0.3f32, 1.7f32);
        let s = sigma.max(SIGMA_FLOOR);
        let inv = 1.0f32 / s;
        let mut rng = Rng::new(7);
        let mut g = vec![0f32; 4096];
        rng.fill_normal_f32(&mut g, mu, sigma);
        g.extend_from_slice(&[-1e30, 1e30, f32::NAN, mu]);
        for &u in &cb.bounds {
            // place raw inputs so the normalized value is near/at u
            g.push(u * s + mu);
        }
        let mut sym = Vec::new();
        cb.quantize_normalized(&g, mu, sigma, &mut sym);
        for (i, &x) in g.iter().enumerate() {
            assert_eq!(sym[i], cb.index_of((x - mu) * inv), "i={i} x={x}");
        }
        // normalized passthrough (μ=0, σ=1): boundary-exact inputs must
        // land in the lower cell in the fast path too
        let mut zb = cb.bounds.clone();
        zb.push(f32::NAN);
        cb.quantize_normalized(&zb, 0.0, 1.0, &mut sym);
        for (j, _) in cb.bounds.iter().enumerate() {
            assert_eq!(sym[j] as usize, j, "boundary {j}");
        }
        assert_eq!(sym[cb.bounds.len()], 0, "NaN maps to symbol 0");
    }

    #[test]
    fn dequantize_matches_reference() {
        let cb = simple();
        let mut rng = Rng::new(9);
        let sym: Vec<u8> = (0..257).map(|_| (rng.next_u64() % 4) as u8).collect();
        let (mu, sigma) = (0.25f32, 2.5f32);
        let mut fast = vec![0f32; sym.len()];
        let mut slow = vec![0f32; sym.len()];
        cb.dequantize_into(&sym, mu, sigma, &mut fast);
        cb.dequantize_into_reference(&sym, mu, sigma, &mut slow);
        assert_eq!(
            fast.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            slow.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        let mut afast = vec![0.5f32; sym.len()];
        let mut aslow = vec![0.5f32; sym.len()];
        cb.dequantize_accumulate(&sym, mu, sigma, &mut afast);
        cb.dequantize_accumulate_reference(&sym, mu, sigma, &mut aslow);
        assert_eq!(
            afast.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            aslow.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn dequantize_accumulate_adds() {
        let cb = simple();
        let sym = vec![0u8, 1, 2, 3];
        let mut acc = vec![1.0f32; 4];
        cb.dequantize_accumulate(&sym, 0.0, 1.0, &mut acc);
        assert_eq!(acc, vec![1.0 - 1.5, 1.0 - 0.5, 1.5, 2.5]);
    }

    #[test]
    fn degenerate_sigma() {
        let cb = simple();
        let g = vec![3.0f32; 16];
        let mut sym = Vec::new();
        cb.quantize_normalized(&g, 3.0, 0.0, &mut sym);
        let mut rec = vec![0f32; 16];
        cb.dequantize_into(&sym, 3.0, 0.0, &mut rec);
        assert!(rec.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn empirical_mse_zero_on_levels() {
        let cb = simple();
        let z: Vec<f32> = cb.levels.clone();
        assert!(cb.empirical_mse(&z) < 1e-12);
    }
}
