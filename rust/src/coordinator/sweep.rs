//! Sharded multi-experiment sweep engine.
//!
//! Every figure in the paper is a *sweep*: a grid of
//! `schemes × bits × λ × datasets × seeds` operating points, each one a
//! full federated run ([`crate::coordinator::experiment::run_experiment`])
//! or a pure quantizer design. Before this module, every bench hand-rolled
//! its own serial loop over that grid; now the grid is declared once
//! ([`SweepGrid`] / [`DesignGrid`]), expanded into cells with
//! deterministic per-cell seeds, executed across a scoped worker pool
//! (same pattern as `scheduler::run_round`), stitched back in declaration
//! order, and emitted as CSV/JSON through one report type
//! ([`SweepReport`]).
//!
//! Cells share the process-wide **codebook design cache**
//! ([`crate::fl::compression::designed_codebook`]): the expensive
//! Lloyd/RC alternation runs once per distinct operating point and every
//! repeat (other seeds, other datasets, re-runs) is a cache hit. The
//! per-sweep hit/miss delta is part of the report, so reuse is
//! observable, not assumed.
//!
//! Results are independent of the worker count: each cell's experiment is
//! deterministic in its config, and stitching is by cell index.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::experiment::{
    run_experiment_on, ExperimentConfig, ExperimentReport,
};
use crate::coordinator::network::ChannelSpec;
use crate::data::FederatedDataset;
use crate::fl::compression::{
    design_cache_stats, designed_codebook, CompressionScheme,
    DesignCacheStats, RateAllocation, RateTarget, Transform, TransformCfg,
    WireCoder,
};
use crate::quant::codebook::Codebook;
use crate::quant::rcq::LengthModel;
use crate::quant::DesignReport;
use crate::util::csv::{CsvField, CsvWriter};
use crate::util::json::{num, obj, s, Json};
use crate::util::timer::Timer;
use crate::util::Result;

/// Resolve a requested worker count: 0 ⇒ hardware parallelism, always
/// clamped to the number of jobs and at least 1.
pub(crate) fn effective_threads(requested: usize, jobs: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    } else {
        requested
    };
    t.min(jobs.max(1)).max(1)
}

/// Run `f` over `items` on a scoped worker pool, preserving input order
/// in the output. Workers pull indices from a shared atomic counter
/// (work-stealing by index), so long cells don't convoy short ones.
///
/// `threads == 0` means hardware parallelism; `threads == 1` (or a
/// single item) runs inline with no pool.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = effective_threads(threads, n);
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner().unwrap().expect("worker filled every slot")
        })
        .collect()
}

// ---------------------------------------------------------------------
// Experiment sweeps (full federated runs)
// ---------------------------------------------------------------------

/// One downlink-axis cell: the server→client codec for the broadcast
/// ([`ExperimentConfig::down_scheme`]) plus, for joint-budget cells, the
/// [`RateTarget`] that drives both directions. A joint budget carries
/// the uplink share inside itself, so a cell that sets `rate_target`
/// *replaces* the grid's rate-target value — crossing the two axes
/// would otherwise duplicate every joint cell.
#[derive(Clone, Copy, Debug)]
pub struct DownlinkCell {
    /// broadcast codec (`None` ⇒ the legacy uncharged fp32 broadcast)
    pub scheme: Option<CompressionScheme>,
    /// replaces the cell's rate target when set (joint up+down budgets)
    pub rate_target: Option<RateTarget>,
}

impl DownlinkCell {
    /// The uncompressed reference point (legacy fp32 broadcast).
    pub fn off() -> DownlinkCell {
        DownlinkCell { scheme: None, rate_target: None }
    }

    /// A statically compressed broadcast (no joint budget).
    pub fn compressed(scheme: CompressionScheme) -> DownlinkCell {
        DownlinkCell { scheme: Some(scheme), rate_target: None }
    }

    /// Stable row-key label: the joint target when one is set, the
    /// downlink scheme when statically compressed, `"off"` otherwise.
    pub fn label(&self) -> String {
        match (&self.rate_target, &self.scheme) {
            (Some(rt), _) => rt.label(),
            (None, Some(s)) => s.label(),
            (None, None) => "off".into(),
        }
    }
}

/// Declarative experiment grid: `datasets × seeds × schemes`.
///
/// Each base config carries a dataset + protocol (rounds, sampling,
/// batch, …); the grid crosses every base with every seed and scheme.
/// Each base's dataset is built once and shared read-only across its
/// cells; what still scales with the worker count is the per-client
/// shard copies inside each *running* cell, so bound `threads` on
/// memory-tight machines when sweeping paper-scale datasets.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// dataset/protocol templates (one per dataset axis value)
    pub bases: Vec<ExperimentConfig>,
    pub schemes: Vec<CompressionScheme>,
    /// replicate seeds (empty ⇒ each base's own seed)
    pub seeds: Vec<u64>,
    /// channel-model axis (empty ⇒ each base's own channel): every base
    /// × seed × scheme cell is replicated per channel, so loss/deadline
    /// scenario grids are first-class sweep dimensions
    pub channels: Vec<ChannelSpec>,
    /// rate-target axis (empty ⇒ each base's own target, normally
    /// `Off`): crosses every cell with each closed-loop target, so
    /// target-rate curves are first-class sweep dimensions too
    pub rate_targets: Vec<RateTarget>,
    /// per-client allocation axis (empty ⇒ each base's own mode,
    /// normally `Uniform`): crosses every cell with each allocation, so
    /// budget curves are first-class sweep dimensions too
    pub allocs: Vec<RateAllocation>,
    /// transform-stage axis (empty ⇒ each base's own transform, normally
    /// identity): crosses every cell with each error-feedback /
    /// sparsification configuration
    pub transforms: Vec<TransformCfg>,
    /// wire-coder axis (empty ⇒ each base's own wire, normally Huffman):
    /// crosses every cell with each wire entropy coder, so the block
    /// throughput tier can ride the same grids as the paper coder
    pub wires: Vec<WireCoder>,
    /// downlink axis (empty ⇒ each base's own `down_scheme`, normally
    /// the uncharged legacy broadcast): crosses every cell with each
    /// downlink codec / joint-budget configuration
    pub downs: Vec<DownlinkCell>,
    /// sweep worker threads (0 ⇒ hardware)
    pub threads: usize,
    /// scheduler threads *inside* each cell. Defaults to 1: the sweep
    /// parallelizes across cells, so fanning clients out as well would
    /// oversubscribe the machine.
    pub inner_threads: usize,
}

impl SweepGrid {
    pub fn new(base: ExperimentConfig) -> SweepGrid {
        SweepGrid {
            bases: vec![base],
            schemes: Vec::new(),
            seeds: Vec::new(),
            channels: Vec::new(),
            rate_targets: Vec::new(),
            allocs: Vec::new(),
            transforms: Vec::new(),
            wires: Vec::new(),
            downs: Vec::new(),
            threads: 0,
            inner_threads: 1,
        }
    }

    /// Add another dataset/protocol axis value.
    pub fn dataset(mut self, base: ExperimentConfig) -> Self {
        self.bases.push(base);
        self
    }

    /// Add one scheme.
    pub fn scheme(mut self, scheme: CompressionScheme) -> Self {
        self.schemes.push(scheme);
        self
    }

    /// The paper's RC-FED λ-curve at a fixed bit-width (Huffman length
    /// model, matching the wire coder).
    pub fn rcfed_lambda_curve(mut self, bits: u32, lambdas: &[f64]) -> Self {
        for &lambda in lambdas {
            self.schemes.push(CompressionScheme::RcFed {
                bits,
                lambda,
                length_model: LengthModel::Huffman,
            });
        }
        self
    }

    /// The Fig. 1 baseline set (QSGD / Lloyd-Max / NQFL) at each
    /// bit-width.
    pub fn baselines(mut self, bits_list: &[u32]) -> Self {
        for &bits in bits_list {
            self.schemes.push(CompressionScheme::Qsgd { bits });
            self.schemes.push(CompressionScheme::Lloyd { bits });
            self.schemes.push(CompressionScheme::Nqfl { bits });
        }
        self
    }

    /// Replicate seeds (each scheme runs once per seed).
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    /// Add one channel-model axis value.
    pub fn channel(mut self, spec: ChannelSpec) -> Self {
        self.channels.push(spec);
        self
    }

    /// Scenario axis over i.i.d. packet-loss probabilities (each on an
    /// otherwise-ideal channel).
    pub fn loss_axis(mut self, probs: &[f64]) -> Self {
        for &p in probs {
            self.channels.push(ChannelSpec::lossy(p));
        }
        self
    }

    /// Scenario axis over straggler deadlines at a heterogeneous
    /// bandwidth model (`bps` mean, `spread` per-client factor range).
    pub fn deadline_axis(
        mut self,
        bps: f64,
        spread: f64,
        deadlines: &[f64],
    ) -> Self {
        for &d in deadlines {
            self.channels.push(ChannelSpec {
                uplink_bps: bps,
                bandwidth_spread: spread,
                deadline_s: d,
                ..ChannelSpec::ideal()
            });
        }
        self
    }

    /// Add one rate-target axis value.
    pub fn rate_target(mut self, target: RateTarget) -> Self {
        self.rate_targets.push(target);
        self
    }

    /// Scenario axis over closed-loop rate targets (bits/coordinate),
    /// all at one adaptation-window length. An explicit `Off` cell is
    /// *not* added — chain `.rate_target(RateTarget::Off)` for the
    /// static reference point.
    pub fn rate_target_axis(
        mut self,
        targets: &[f64],
        adapt_every: usize,
    ) -> Self {
        for &bits_per_coord in targets {
            self.rate_targets.push(RateTarget::Track {
                bits_per_coord,
                adapt_every,
            });
        }
        self
    }

    /// Add one allocation-mode axis value.
    pub fn alloc(mut self, alloc: RateAllocation) -> Self {
        self.allocs.push(alloc);
        self
    }

    /// Scenario axis over per-client allocation budgets (encoded
    /// bits/coordinate averaged over the round's clients), all at one
    /// adaptation-window length and width range. An explicit `Uniform`
    /// cell is *not* added — chain `.alloc(RateAllocation::Uniform)` for
    /// the shared-codebook reference point.
    pub fn budget_axis(
        mut self,
        budgets: &[f64],
        adapt_every: usize,
        min_bits: u32,
        max_bits: u32,
    ) -> Self {
        for &budget_bpc in budgets {
            self.allocs.push(RateAllocation::WaterFill {
                budget_bpc,
                adapt_every,
                min_bits,
                max_bits,
            });
        }
        self
    }

    /// Add one transform-stage axis value.
    pub fn transform(mut self, transform: TransformCfg) -> Self {
        self.transforms.push(transform);
        self
    }

    /// Scenario axis over top-k sparsification ratios, optionally with
    /// error feedback on every axis cell. An identity reference cell is
    /// *not* added — chain `.transform(TransformCfg::identity())` (or
    /// `.identity().with_ef()`) for the dense comparison point.
    pub fn topk_axis(mut self, ratios: &[f64], error_feedback: bool) -> Self {
        for &ratio in ratios {
            self.transforms.push(TransformCfg {
                kind: Transform::TopK { ratio },
                error_feedback,
            });
        }
        self
    }

    /// Add one wire-coder axis value. A Huffman reference cell is *not*
    /// added — chain `.wire(WireCoder::Huffman)` for the paper coder.
    pub fn wire(mut self, wire: WireCoder) -> Self {
        self.wires.push(wire);
        self
    }

    /// Add one downlink-axis cell. An uncompressed reference cell is
    /// *not* added — chain `.down(DownlinkCell::off())` for the legacy
    /// broadcast comparison point.
    pub fn down(mut self, cell: DownlinkCell) -> Self {
        self.downs.push(cell);
        self
    }

    /// Scenario axis over joint up+down budgets: each downlink target
    /// `d` becomes a [`RateTarget::Joint`] cell at total `up_bpc + d`
    /// with the uplink share pinned to `up_bpc`, broadcasting through
    /// `scheme` (must be rcfed — the joint loop drives the downlink λ).
    /// Chain `.down(DownlinkCell::off())` and a plain Track cell for the
    /// uncompressed and uplink-only reference points.
    pub fn down_target_axis(
        mut self,
        up_bpc: f64,
        down_targets: &[f64],
        adapt_every: usize,
        scheme: CompressionScheme,
    ) -> Self {
        for &d in down_targets {
            let total = up_bpc + d;
            self.downs.push(DownlinkCell {
                scheme: Some(scheme),
                rate_target: Some(RateTarget::Joint {
                    total_bpc: total,
                    split: up_bpc / total,
                    adapt_every,
                }),
            });
        }
        self
    }

    /// Sweep worker threads (0 ⇒ hardware).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Expand the grid into per-cell configs with deterministic per-cell
    /// seeds, in declaration order (bases → seeds → channels →
    /// rate targets → allocations → transforms → wires → downlinks →
    /// schemes).
    pub fn expand(&self) -> Vec<SweepCell> {
        let mut cells = Vec::new();
        for (base_index, base) in self.bases.iter().enumerate() {
            let seeds: Vec<u64> = if self.seeds.is_empty() {
                vec![base.seed]
            } else {
                self.seeds.clone()
            };
            let channels: Vec<ChannelSpec> = if self.channels.is_empty() {
                vec![base.channel]
            } else {
                self.channels.clone()
            };
            let rate_targets: Vec<RateTarget> = if self.rate_targets.is_empty()
            {
                vec![base.rate_target]
            } else {
                self.rate_targets.clone()
            };
            let allocs: Vec<RateAllocation> = if self.allocs.is_empty() {
                vec![base.alloc]
            } else {
                self.allocs.clone()
            };
            let transforms: Vec<TransformCfg> = if self.transforms.is_empty()
            {
                vec![base.transform]
            } else {
                self.transforms.clone()
            };
            let wires: Vec<WireCoder> = if self.wires.is_empty() {
                vec![base.wire]
            } else {
                self.wires.clone()
            };
            let downs: Vec<DownlinkCell> = if self.downs.is_empty() {
                vec![DownlinkCell {
                    scheme: base.down_scheme,
                    rate_target: None,
                }]
            } else {
                self.downs.clone()
            };
            for &seed in &seeds {
                for &channel in &channels {
                    for &rate_target in &rate_targets {
                        for &alloc in &allocs {
                            for &transform in &transforms {
                                for &wire in &wires {
                                    for &down in &downs {
                                        for &scheme in &self.schemes {
                                            let mut config = base.clone();
                                            config.scheme = scheme;
                                            config.seed = seed;
                                            config.channel = channel;
                                            config.rate_target = rate_target;
                                            config.alloc = alloc;
                                            config.transform = transform;
                                            config.wire = wire;
                                            config.down_scheme = down.scheme;
                                            if let Some(rt) = down.rate_target
                                            {
                                                config.rate_target = rt;
                                            }
                                            config.threads =
                                                self.inner_threads;
                                            cells.push(SweepCell {
                                                index: cells.len(),
                                                base_index,
                                                label: config.label(),
                                                dataset: base
                                                    .dataset
                                                    .kind
                                                    .name(),
                                                seed,
                                                channel: channel.label(),
                                                rate: config
                                                    .rate_target
                                                    .label(),
                                                alloc: alloc.label(),
                                                transform: transform.label(),
                                                wire: wire.name().to_string(),
                                                down: down.label(),
                                                config,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

/// One expanded grid cell, ready to run.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub index: usize,
    /// which [`SweepGrid::bases`] entry this cell came from (cells of
    /// one base share one prebuilt dataset during execution)
    pub base_index: usize,
    pub label: String,
    pub dataset: &'static str,
    pub seed: u64,
    /// channel-model label (`"ideal"` when no faults are configured)
    pub channel: String,
    /// rate-target label (`"off"` for the static design)
    pub rate: String,
    /// allocation label (`"uniform"` for the shared codebook)
    pub alloc: String,
    /// transform label (`"id"` for the identity stage)
    pub transform: String,
    /// wire-coder label (`"huffman"` for the paper coder)
    pub wire: String,
    /// downlink label (`"off"` for the legacy uncharged broadcast)
    pub down: String,
    pub config: ExperimentConfig,
}

/// One finished cell.
#[derive(Debug)]
pub struct SweepCellResult {
    pub label: String,
    pub dataset: &'static str,
    pub seed: u64,
    pub channel: String,
    /// rate-target label (`"off"` for the static design)
    pub rate: String,
    /// allocation label (`"uniform"` for the shared codebook)
    pub alloc: String,
    /// transform label (`"id"` for the identity stage)
    pub transform: String,
    /// wire-coder label (`"huffman"` for the paper coder)
    pub wire: String,
    /// downlink label (`"off"` for the legacy uncharged broadcast)
    pub down: String,
    pub scheme: CompressionScheme,
    pub report: ExperimentReport,
}

/// One cell that errored (the rest of the sweep is still reported).
#[derive(Debug)]
pub struct SweepCellFailure {
    pub label: String,
    pub dataset: &'static str,
    pub seed: u64,
    pub channel: String,
    pub rate: String,
    pub alloc: String,
    pub transform: String,
    pub wire: String,
    pub down: String,
    pub error: String,
}

/// Everything a sweep produced, in declaration order.
#[derive(Debug)]
pub struct SweepReport {
    pub cells: Vec<SweepCellResult>,
    /// cells that errored — successful cells are never discarded because
    /// one operating point failed (a 20-cell sweep is hours of work)
    pub failures: Vec<SweepCellFailure>,
    pub wall_secs: f64,
    /// worker threads the pool actually used
    pub threads: usize,
    /// codebook design-cache movement during this sweep
    pub design_cache: DesignCacheStats,
}

/// Execute a grid: expand, fan the cells out across the worker pool,
/// stitch results back in declaration order.
pub fn run_sweep(grid: &SweepGrid) -> Result<SweepReport> {
    let timer = Timer::start();
    let cells = grid.expand();
    let threads = effective_threads(grid.threads, cells.len());
    // one dataset per base, shared (read-only) across that base's cells —
    // concurrent cells must not each build and hold their own copy
    let datasets: Vec<FederatedDataset> = grid
        .bases
        .iter()
        .map(|base| FederatedDataset::build(&base.dataset))
        .collect();
    let before = design_cache_stats();
    let results = parallel_map(&cells, threads, |_, cell| {
        run_experiment_on(&cell.config, &datasets[cell.base_index])
    });
    let design_cache = design_cache_stats().since(&before);
    let mut out = Vec::with_capacity(cells.len());
    let mut failures = Vec::new();
    for (cell, result) in cells.into_iter().zip(results) {
        match result {
            Ok(report) => out.push(SweepCellResult {
                label: cell.label,
                dataset: cell.dataset,
                seed: cell.seed,
                channel: cell.channel,
                rate: cell.rate,
                alloc: cell.alloc,
                transform: cell.transform,
                wire: cell.wire,
                down: cell.down,
                scheme: cell.config.scheme,
                report,
            }),
            Err(e) => {
                crate::warn!(
                    "sweep cell {} (dataset {}, seed {}, channel {}, \
                     rate {}, alloc {}, transform {}, wire {}, down {}) \
                     failed: {e}",
                    cell.label, cell.dataset, cell.seed, cell.channel,
                    cell.rate, cell.alloc, cell.transform, cell.wire,
                    cell.down
                );
                failures.push(SweepCellFailure {
                    label: cell.label,
                    dataset: cell.dataset,
                    seed: cell.seed,
                    channel: cell.channel,
                    rate: cell.rate,
                    alloc: cell.alloc,
                    transform: cell.transform,
                    wire: cell.wire,
                    down: cell.down,
                    error: e.to_string(),
                });
            }
        }
    }
    if out.is_empty() && !failures.is_empty() {
        return Err(crate::util::Error::Config(format!(
            "all {} sweep cells failed; first: {} — {}",
            failures.len(), failures[0].label, failures[0].error
        )));
    }
    Ok(SweepReport {
        cells: out,
        failures,
        wall_secs: timer.secs(),
        threads,
        design_cache,
    })
}

impl SweepReport {
    /// The scheme-keyed base schema (identical to the pre-engine fig1a
    /// harness output). [`Self::write_csv`] uses `CSV_HEADER[0]` as the
    /// key column and `CSV_HEADER[1..]` as the metric columns, inserting
    /// `dataset`/`seed` columns between them for replicated grids.
    pub const CSV_HEADER: [&'static str; 5] =
        ["scheme", "final_acc", "best_acc", "gigabits", "wall_secs"];

    /// Write the standard per-cell CSV ([`Self::CSV_HEADER`] schema).
    ///
    /// Replicated grids would collapse under a scheme-keyed schema, so a
    /// `dataset`, `seed` and/or `channel` column is inserted after
    /// `scheme` whenever the report spans more than one of them — rows
    /// stay uniquely keyed without every caller having to remember the
    /// guard. Single-channel (ideal) grids emit exactly the pre-channel
    /// schema.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let distinct = |mut vals: Vec<&str>| {
            vals.sort_unstable();
            vals.dedup();
            vals.len() > 1
        };
        let multi_dataset =
            distinct(self.cells.iter().map(|c| c.dataset).collect());
        let multi_seed = {
            let mut seeds: Vec<u64> =
                self.cells.iter().map(|c| c.seed).collect();
            seeds.sort_unstable();
            seeds.dedup();
            seeds.len() > 1
        };
        let multi_channel =
            distinct(self.cells.iter().map(|c| c.channel.as_str()).collect());
        // rate/alloc columns appear as soon as any cell ran the closed
        // loop or a per-client allocation — all-static grids keep the
        // exact pre-pipeline schema bytes
        let with_rate = self.cells.iter().any(|c| c.rate != "off")
            || self.failures.iter().any(|f| f.rate != "off");
        let with_alloc = self.cells.iter().any(|c| c.alloc != "uniform")
            || self.failures.iter().any(|f| f.alloc != "uniform");
        let with_transform = self.cells.iter().any(|c| c.transform != "id")
            || self.failures.iter().any(|f| f.transform != "id");
        // the wire column appears as soon as any cell left the paper's
        // Huffman coder — all-huffman grids keep the exact schema bytes
        let with_wire = self.cells.iter().any(|c| c.wire != "huffman")
            || self.failures.iter().any(|f| f.wire != "huffman");
        // likewise the downlink columns, as soon as any cell compressed
        // the broadcast
        let with_down = self.cells.iter().any(|c| c.down != "off")
            || self.failures.iter().any(|f| f.down != "off");
        let mut header: Vec<&str> = vec![Self::CSV_HEADER[0]];
        if multi_dataset {
            header.push("dataset");
        }
        if multi_seed {
            header.push("seed");
        }
        if multi_channel {
            header.push("channel");
        }
        if with_rate {
            header.push("rate_target");
        }
        if with_alloc {
            header.push("alloc");
        }
        if with_transform {
            header.push("transform");
        }
        if with_wire {
            header.push("wire");
        }
        if with_down {
            header.push("down");
        }
        header.extend_from_slice(&Self::CSV_HEADER[1..]);
        if with_rate {
            header.extend_from_slice(&["realized_bpc", "downlink_gigabits"]);
        }
        if with_alloc {
            header.push("alloc_gini");
            if !with_rate {
                header.push("downlink_gigabits");
            }
        }
        if with_transform {
            header.push("sparsity");
        }
        if with_down {
            header.push("down_bpc");
            if !with_rate && !with_alloc {
                header.push("downlink_gigabits");
            }
        }
        let mut w = CsvWriter::create(path, &header)?;
        for c in &self.cells {
            let mut row = vec![CsvField::from(c.label.clone())];
            if multi_dataset {
                row.push(CsvField::from(c.dataset));
            }
            if multi_seed {
                row.push(CsvField::from(c.seed));
            }
            if multi_channel {
                row.push(CsvField::from(c.channel.clone()));
            }
            if with_rate {
                row.push(CsvField::from(c.rate.clone()));
            }
            if with_alloc {
                row.push(CsvField::from(c.alloc.clone()));
            }
            if with_transform {
                row.push(CsvField::from(c.transform.clone()));
            }
            if with_wire {
                row.push(CsvField::from(c.wire.clone()));
            }
            if with_down {
                row.push(CsvField::from(c.down.clone()));
            }
            row.push(CsvField::from(c.report.final_accuracy));
            row.push(CsvField::from(c.report.best_accuracy));
            row.push(CsvField::from(c.report.uplink_gigabits()));
            row.push(CsvField::from(c.report.wall_secs));
            if with_rate {
                row.push(CsvField::from(c.report.realized_bpc()));
                row.push(CsvField::from(
                    c.report.downlink_bits as f64 / 1e9,
                ));
            }
            if with_alloc {
                row.push(CsvField::from(c.report.alloc_gini()));
                if !with_rate {
                    row.push(CsvField::from(
                        c.report.downlink_bits as f64 / 1e9,
                    ));
                }
            }
            if with_transform {
                row.push(CsvField::from(c.report.metrics.final_sparsity()));
            }
            if with_down {
                row.push(CsvField::from(c.report.down_bpc()));
                if !with_rate && !with_alloc {
                    row.push(CsvField::from(
                        c.report.downlink_bits as f64 / 1e9,
                    ));
                }
            }
            w.row(&row)?;
        }
        w.flush()
    }

    /// Write a CSV with a caller-controlled schema (header + row
    /// projection), for harnesses with extra derived columns.
    pub fn write_csv_with<F>(
        &self,
        path: &str,
        header: &[&str],
        row: F,
    ) -> Result<()>
    where
        F: Fn(&SweepCellResult) -> Vec<CsvField>,
    {
        let mut w = CsvWriter::create(path, header)?;
        for cell in &self.cells {
            w.row(&row(cell))?;
        }
        w.flush()
    }

    /// Serialize the whole report (cells + pool + cache counters).
    pub fn to_json(&self) -> Json {
        fn num_or_null(x: f64) -> Json {
            if x.is_finite() {
                num(x)
            } else {
                Json::Null
            }
        }
        // channel fields appear only when some cell ran a non-ideal
        // channel, keeping ideal-grid JSON byte-identical to the
        // pre-channel schema; rate fields likewise only when some cell
        // ran the closed loop
        let with_channel = self.cells.iter().any(|c| c.channel != "ideal")
            || self.failures.iter().any(|f| f.channel != "ideal");
        let with_rate = self.cells.iter().any(|c| c.rate != "off")
            || self.failures.iter().any(|f| f.rate != "off");
        let with_alloc = self.cells.iter().any(|c| c.alloc != "uniform")
            || self.failures.iter().any(|f| f.alloc != "uniform");
        let with_transform = self.cells.iter().any(|c| c.transform != "id")
            || self.failures.iter().any(|f| f.transform != "id");
        let with_wire = self.cells.iter().any(|c| c.wire != "huffman")
            || self.failures.iter().any(|f| f.wire != "huffman");
        let with_down = self.cells.iter().any(|c| c.down != "off")
            || self.failures.iter().any(|f| f.down != "off");
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut fields = vec![
                    ("scheme", s(&c.label)),
                    ("dataset", s(c.dataset)),
                    ("seed", num(c.seed as f64)),
                ];
                if with_rate {
                    fields.push(("rate_target", s(&c.rate)));
                    fields.push((
                        "realized_bpc",
                        num_or_null(c.report.realized_bpc()),
                    ));
                    fields.push((
                        "downlink_bits",
                        num(c.report.downlink_bits as f64),
                    ));
                }
                if with_alloc {
                    fields.push(("alloc", s(&c.alloc)));
                    fields.push((
                        "alloc_gini",
                        num_or_null(c.report.alloc_gini()),
                    ));
                    if !with_rate {
                        fields.push((
                            "downlink_bits",
                            num(c.report.downlink_bits as f64),
                        ));
                    }
                    fields.push((
                        "alloc_hist",
                        Json::Arr(
                            c.report
                                .alloc_hist
                                .iter()
                                .map(|&(w, n)| {
                                    obj(vec![
                                        ("bits", num(w as f64)),
                                        ("clients", num(n as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                if with_transform {
                    fields.push(("transform", s(&c.transform)));
                    fields.push((
                        "sparsity",
                        num_or_null(c.report.metrics.final_sparsity()),
                    ));
                }
                if with_wire {
                    fields.push(("wire", s(&c.wire)));
                }
                if with_down {
                    fields.push(("down", s(&c.down)));
                    fields.push((
                        "down_bpc",
                        num_or_null(c.report.down_bpc()),
                    ));
                    if !with_rate && !with_alloc {
                        fields.push((
                            "downlink_bits",
                            num(c.report.downlink_bits as f64),
                        ));
                    }
                }
                if with_channel {
                    let st = &c.report.channel;
                    fields.push(("channel", s(&c.channel)));
                    fields.push((
                        "survivors",
                        obj(vec![
                            ("delivered", num(st.delivered as f64)),
                            ("lost", num(st.lost as f64)),
                            ("corrupted", num(st.corrupted as f64)),
                            (
                                "decode_errors",
                                num(st.decode_errors as f64),
                            ),
                            ("straggled", num(st.straggled as f64)),
                            ("unavailable", num(st.unavailable as f64)),
                        ]),
                    ));
                }
                fields.extend(vec![
                    ("final_acc", num_or_null(c.report.final_accuracy)),
                    ("best_acc", num_or_null(c.report.best_accuracy)),
                    ("gigabits", num(c.report.uplink_gigabits())),
                    ("total_bits", num(c.report.total_bits as f64)),
                    ("wall_secs", num(c.report.wall_secs)),
                ]);
                obj(fields)
            })
            .collect();
        let failures: Vec<Json> = self
            .failures
            .iter()
            .map(|f| {
                let mut fields = vec![
                    ("scheme", s(&f.label)),
                    ("dataset", s(f.dataset)),
                    ("seed", num(f.seed as f64)),
                ];
                if with_rate {
                    fields.push(("rate_target", s(&f.rate)));
                }
                if with_alloc {
                    fields.push(("alloc", s(&f.alloc)));
                }
                if with_transform {
                    fields.push(("transform", s(&f.transform)));
                }
                if with_wire {
                    fields.push(("wire", s(&f.wire)));
                }
                if with_down {
                    fields.push(("down", s(&f.down)));
                }
                if with_channel {
                    fields.push(("channel", s(&f.channel)));
                }
                fields.push(("error", s(&f.error)));
                obj(fields)
            })
            .collect();
        obj(vec![
            ("threads", num(self.threads as f64)),
            ("wall_secs", num(self.wall_secs)),
            (
                "design_cache",
                obj(vec![
                    ("hits", num(self.design_cache.hits as f64)),
                    ("misses", num(self.design_cache.misses as f64)),
                ]),
            ),
            ("cells", Json::Arr(cells)),
            ("failures", Json::Arr(failures)),
        ])
    }

    /// Write the JSON report (parent directories created as needed).
    pub fn write_json(&self, path: &str) -> Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Fig. 1's headline check: how many cells *outside* `prefix` are
    /// dominated (≥ accuracy − `acc_tol`, ≤ uplink) by some cell whose
    /// label starts with `prefix`. Returns `(dominated, total)`.
    pub fn pareto_dominance(
        &self,
        prefix: &str,
        acc_tol: f64,
    ) -> (usize, usize) {
        let curve: Vec<&SweepCellResult> = self
            .cells
            .iter()
            .filter(|c| c.label.starts_with(prefix))
            .collect();
        let mut dominated = 0;
        let mut total = 0;
        for base in self.cells.iter().filter(|c| !c.label.starts_with(prefix))
        {
            total += 1;
            if curve.iter().any(|p| {
                p.report.final_accuracy >= base.report.final_accuracy - acc_tol
                    && p.report.uplink_gigabits()
                        <= base.report.uplink_gigabits()
            }) {
                dominated += 1;
            }
        }
        (dominated, total)
    }

    /// One-line pool/cache summary for bench footers.
    pub fn summary(&self) -> String {
        let mut line = format!(
            "sweep: {} cells across {} workers in {:.1}s; design cache {}",
            self.cells.len(),
            self.threads,
            self.wall_secs,
            self.design_cache
        );
        if !self.failures.is_empty() {
            line.push_str(&format!("; {} cells FAILED", self.failures.len()));
        }
        line
    }
}

// ---------------------------------------------------------------------
// Design sweeps (quantizer design only, no training)
// ---------------------------------------------------------------------

/// Declarative quantizer-design grid — the rate–distortion benches'
/// core object. Cells run through the design cache, so overlapping
/// operating points across benches are designed once per process.
#[derive(Clone, Debug)]
pub struct DesignGrid {
    pub schemes: Vec<CompressionScheme>,
    /// worker threads (0 ⇒ hardware)
    pub threads: usize,
}

/// One designed operating point.
pub struct DesignCellResult {
    pub label: String,
    pub scheme: CompressionScheme,
    pub codebook: Codebook,
    pub report: DesignReport,
}

/// Design every scheme in the grid (parallel, cached, order-preserving).
pub fn run_design_sweep(grid: &DesignGrid) -> Result<Vec<DesignCellResult>> {
    let threads = effective_threads(grid.threads, grid.schemes.len());
    let results = parallel_map(&grid.schemes, threads, |_, &scheme| {
        designed_codebook(scheme)
    });
    let mut out = Vec::with_capacity(grid.schemes.len());
    for (&scheme, result) in grid.schemes.iter().zip(results) {
        let (codebook, report) = result?;
        out.push(DesignCellResult {
            label: scheme.label(),
            scheme,
            codebook,
            report,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::compression::CompressionScheme;

    fn tiny_base() -> ExperimentConfig {
        let mut base = ExperimentConfig::tiny();
        base.rounds = 6;
        base.eval_every = 3;
        base
    }

    fn small_grid() -> SweepGrid {
        SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Lloyd { bits: 3 })
            .scheme(CompressionScheme::Fp32)
            .seeds(&[11, 12])
    }

    #[test]
    fn expansion_is_ordered_and_deterministic() {
        let grid = small_grid();
        let cells = grid.expand();
        assert_eq!(cells.len(), 4); // 2 seeds × 2 schemes
        assert_eq!(cells[0].label, "lloyd_b3");
        assert_eq!(cells[0].seed, 11);
        assert_eq!(cells[1].label, "fp32");
        assert_eq!(cells[2].seed, 12);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.config.threads, 1, "inner rounds must stay serial");
        }
        let again = grid.expand();
        assert_eq!(again.len(), cells.len());
        assert_eq!(again[3].label, cells[3].label);
    }

    #[test]
    fn multi_dataset_grids_cross_every_base() {
        let mut femnist = ExperimentConfig::tiny();
        femnist.seed = 99;
        let grid = SweepGrid::new(tiny_base())
            .dataset(femnist)
            .scheme(CompressionScheme::Fp32);
        let cells = grid.expand();
        assert_eq!(cells.len(), 2);
        // with no explicit seeds each base contributes its own
        assert_eq!(cells[0].seed, tiny_base().seed);
        assert_eq!(cells[1].seed, 99);
    }

    #[test]
    fn channel_axis_crosses_every_scheme() {
        use crate::coordinator::network::ChannelSpec;
        let grid = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .scheme(CompressionScheme::Lloyd { bits: 3 })
            .channel(ChannelSpec::ideal())
            .loss_axis(&[0.1, 0.3]);
        let cells = grid.expand();
        assert_eq!(cells.len(), 6); // 3 channels × 2 schemes
        assert_eq!(cells[0].channel, "ideal");
        assert_eq!(cells[1].channel, "ideal");
        assert_eq!(cells[2].channel, "loss0.1");
        assert_eq!(cells[4].channel, "loss0.3");
        assert_eq!(cells[2].config.channel, ChannelSpec::lossy(0.1));
        // no channel axis ⇒ every cell inherits the base's (ideal) spec
        let plain = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .expand();
        assert_eq!(plain[0].channel, "ideal");
        assert_eq!(plain[0].config.channel, ChannelSpec::ideal());
    }

    #[test]
    fn lossy_sweep_reports_channel_column_and_survivors() {
        use crate::coordinator::network::ChannelSpec;
        let mut grid = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .channel(ChannelSpec::ideal())
            .channel(ChannelSpec::lossy(0.5));
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.cells[0].report.channel.lost, 0);
        assert!(report.cells[1].report.channel.lost > 0);
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_channel_{}", std::process::id()));
        let csv_path = dir.join("channels.csv");
        let json_path = dir.join("channels.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(
            csv.starts_with("scheme,channel,final_acc"),
            "channel column missing: {csv}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        let cells = v.req("cells").unwrap().as_arr().unwrap();
        assert!(cells[0].get("channel").is_some());
        assert!(cells[1].get("survivors").is_some());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rate_target_axis_crosses_and_reports_gated_columns() {
        use crate::fl::compression::RateTarget;
        use crate::quant::rcq::LengthModel;
        let rcfed = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        };
        let mut base = tiny_base();
        base.rounds = 6;
        let grid = SweepGrid::new(base)
            .scheme(rcfed)
            .rate_target(RateTarget::Off)
            .rate_target_axis(&[2.2], 3);
        let cells = grid.expand();
        assert_eq!(cells.len(), 2); // off + one target
        assert_eq!(cells[0].rate, "off");
        assert_eq!(cells[1].rate, "rt2.2w3");
        assert_eq!(
            cells[1].config.rate_target,
            RateTarget::Track { bits_per_coord: 2.2, adapt_every: 3 }
        );
        let mut grid = grid;
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.cells[0].report.downlink_bits, 0);
        assert!(report.cells[1].report.downlink_bits > 0);
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_rate_{}", std::process::id()));
        let csv_path = dir.join("rate.csv");
        let json_path = dir.join("rate.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(
            csv.starts_with("scheme,rate_target,final_acc"),
            "rate_target key column missing: {csv}"
        );
        assert!(
            csv.lines().next().unwrap().ends_with(
                "wall_secs,realized_bpc,downlink_gigabits"
            ),
            "rate metric columns missing: {csv}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        let jcells = v.req("cells").unwrap().as_arr().unwrap();
        assert!(jcells[0].get("rate_target").is_some());
        assert!(jcells[1].get("downlink_bits").is_some());
        std::fs::remove_dir_all(dir).ok();
        // a grid without the axis stays rate-free (no schema drift)
        let mut plain = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32);
        plain.threads = 1;
        let plain_report = run_sweep(&plain).unwrap();
        assert_eq!(plain_report.cells[0].rate, "off");
    }

    #[test]
    fn alloc_axis_crosses_and_reports_gated_columns() {
        use crate::fl::compression::RateAllocation;
        let mut base = tiny_base();
        base.rounds = 6;
        let grid = SweepGrid::new(base)
            .scheme(CompressionScheme::Lloyd { bits: 3 })
            .alloc(RateAllocation::Uniform)
            .budget_axis(&[2.2], 2, 1, 6);
        let cells = grid.expand();
        assert_eq!(cells.len(), 2); // uniform + one budget
        assert_eq!(cells[0].alloc, "uniform");
        assert_eq!(cells[1].alloc, "wf2.2w2b1-6");
        assert_eq!(
            cells[1].config.alloc,
            RateAllocation::WaterFill {
                budget_bpc: 2.2,
                adapt_every: 2,
                min_bits: 1,
                max_bits: 6,
            }
        );
        let mut grid = grid;
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert!(report.cells[0].report.alloc_hist.is_empty());
        assert!(!report.cells[1].report.alloc_hist.is_empty());
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_alloc_{}", std::process::id()));
        let csv_path = dir.join("alloc.csv");
        let json_path = dir.join("alloc.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(
            csv.starts_with("scheme,alloc,final_acc"),
            "alloc key column missing: {csv}"
        );
        assert!(
            csv.lines().next().unwrap().ends_with(
                "wall_secs,alloc_gini,downlink_gigabits"
            ),
            "alloc metric columns missing: {csv}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        let jcells = v.req("cells").unwrap().as_arr().unwrap();
        assert!(jcells[0].get("alloc").is_some());
        assert!(jcells[1].get("alloc_hist").is_some());
        std::fs::remove_dir_all(dir).ok();
        // a grid without the axis stays alloc-free (no schema drift)
        let plain = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .expand();
        assert_eq!(plain[0].alloc, "uniform");
    }

    #[test]
    fn transform_axis_crosses_and_reports_gated_columns() {
        use crate::fl::compression::TransformCfg;
        let mut base = tiny_base();
        base.rounds = 4;
        base.eval_every = 2;
        let grid = SweepGrid::new(base)
            .scheme(CompressionScheme::Lloyd { bits: 3 })
            .transform(TransformCfg::identity())
            .topk_axis(&[0.1], true);
        let cells = grid.expand();
        assert_eq!(cells.len(), 2); // identity + one topk+ef
        assert_eq!(cells[0].transform, "id");
        assert_eq!(cells[0].label, "lloyd_b3");
        assert_eq!(cells[1].transform, "topk0.1+ef");
        assert_eq!(cells[1].label, "lloyd_b3_topk0.1_ef");
        assert!(cells[1].config.transform.error_feedback);
        let mut grid = grid;
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        // the sparse cell must spend fewer uplink bits than the dense one
        assert!(
            report.cells[1].report.total_bits
                < report.cells[0].report.total_bits,
            "topk {} vs dense {}",
            report.cells[1].report.total_bits,
            report.cells[0].report.total_bits
        );
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_transform_{}", std::process::id()));
        let csv_path = dir.join("transform.csv");
        let json_path = dir.join("transform.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(
            csv.starts_with("scheme,transform,final_acc"),
            "transform key column missing: {csv}"
        );
        assert!(
            csv.lines().next().unwrap().ends_with("wall_secs,sparsity"),
            "sparsity metric column missing: {csv}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        let jcells = v.req("cells").unwrap().as_arr().unwrap();
        assert!(jcells[0].get("transform").is_some());
        assert!(jcells[1].get("sparsity").is_some());
        std::fs::remove_dir_all(dir).ok();
        // a grid without the axis stays transform-free (no schema drift)
        let plain = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .expand();
        assert_eq!(plain[0].transform, "id");
    }

    #[test]
    fn wire_axis_crosses_and_reports_gated_columns() {
        use crate::fl::compression::WireCoder;
        let mut base = tiny_base();
        base.rounds = 4;
        base.eval_every = 2;
        let grid = SweepGrid::new(base)
            .scheme(CompressionScheme::Lloyd { bits: 3 })
            .wire(WireCoder::Huffman)
            .wire(WireCoder::Block);
        let cells = grid.expand();
        assert_eq!(cells.len(), 2); // huffman + block
        assert_eq!(cells[0].wire, "huffman");
        assert_eq!(cells[0].label, "lloyd_b3");
        assert_eq!(cells[1].wire, "block");
        assert_eq!(cells[1].label, "lloyd_b3_wblock");
        assert_eq!(cells[1].config.wire, WireCoder::Block);
        let mut grid = grid;
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        // same symbols either way ⇒ identical trajectory and accuracy
        assert_eq!(
            report.cells[0].report.final_accuracy,
            report.cells[1].report.final_accuracy
        );
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_wire_{}", std::process::id()));
        let csv_path = dir.join("wire.csv");
        let json_path = dir.join("wire.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(
            csv.starts_with("scheme,wire,final_acc"),
            "wire key column missing: {csv}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        let jcells = v.req("cells").unwrap().as_arr().unwrap();
        assert!(jcells[0].get("wire").is_some());
        std::fs::remove_dir_all(dir).ok();
        // a grid without the axis stays wire-free (no schema drift)
        let plain = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .expand();
        assert_eq!(plain[0].wire, "huffman");
    }

    #[test]
    fn down_axis_crosses_and_reports_gated_columns() {
        use crate::fl::compression::RateTarget;
        use crate::quant::rcq::LengthModel;
        let rcfed = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        };
        let grid = SweepGrid::new(tiny_base())
            .scheme(rcfed)
            .down(DownlinkCell::off())
            .down_target_axis(2.5, &[1.5], 3, rcfed);
        let cells = grid.expand();
        assert_eq!(cells.len(), 2); // off + one joint budget
        assert_eq!(cells[0].down, "off");
        assert_eq!(cells[0].rate, "off");
        assert_eq!(cells[0].config.down_scheme, None);
        assert_eq!(cells[1].down, "jt4s0.625w3");
        // the joint cell replaces the rate target, so the rate label
        // reflects the final config, not the (empty) rate axis
        assert_eq!(cells[1].rate, "jt4s0.625w3");
        assert_eq!(cells[1].config.down_scheme, Some(rcfed));
        assert_eq!(
            cells[1].config.rate_target,
            RateTarget::Joint {
                total_bpc: 4.0,
                split: 0.625,
                adapt_every: 3,
            }
        );
        let mut grid = grid;
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.cells[0].report.downlink_bits, 0);
        assert!(report.cells[1].report.downlink_bits > 0);
        assert!(report.cells[1].report.down_bpc().is_finite());
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_down_{}", std::process::id()));
        let csv_path = dir.join("down.csv");
        let json_path = dir.join("down.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(
            csv.starts_with("scheme,rate_target,down,final_acc"),
            "down key column missing: {csv}"
        );
        assert!(
            csv.lines().next().unwrap().ends_with(
                "wall_secs,realized_bpc,downlink_gigabits,down_bpc"
            ),
            "down metric column missing: {csv}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        let jcells = v.req("cells").unwrap().as_arr().unwrap();
        assert!(jcells[0].get("down").is_some());
        assert!(jcells[1].get("down_bpc").is_some());
        std::fs::remove_dir_all(dir).ok();
        // a grid without the axis stays down-free (no schema drift)
        let plain = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Fp32)
            .expand();
        assert_eq!(plain[0].down, "off");
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..40).collect();
        let doubled = parallel_map(&items, 4, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(doubled, (0..40).map(|x| x * 2).collect::<Vec<_>>());
        // serial path agrees
        let serial = parallel_map(&items, 1, |_, &x| x * 2);
        assert_eq!(doubled, serial);
    }

    #[test]
    fn sweep_results_independent_of_worker_count() {
        let mut parallel = small_grid();
        parallel.threads = 2;
        let mut serial = small_grid();
        serial.threads = 1;
        let a = run_sweep(&parallel).unwrap();
        let b = run_sweep(&serial).unwrap();
        assert_eq!(a.cells.len(), b.cells.len());
        assert!(a.threads >= 1 && b.threads == 1);
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.report.total_bits, y.report.total_bits);
            assert_eq!(x.report.final_accuracy, y.report.final_accuracy);
        }
    }

    #[test]
    fn repeated_cells_hit_the_design_cache() {
        // one scheme × two seeds, serial pool: the second cell's design
        // must be a cache hit, and the report must expose it.
        let mut grid = SweepGrid::new(tiny_base()).seeds(&[21, 22]);
        grid.schemes.push(CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.0719, // unusual λ so the first cell is a real miss
            length_model: crate::quant::rcq::LengthModel::Huffman,
        });
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert!(
            report.design_cache.hits >= 1,
            "sweep report shows no design-cache hits: {:?}",
            report.design_cache
        );
        // a replicated report must not collapse under the default CSV
        // schema: the seed column is inserted automatically
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_seeds_{}", std::process::id()));
        let path = dir.join("replicated.csv");
        report.write_csv(path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&path).unwrap();
        assert!(
            csv.starts_with("scheme,seed,final_acc"),
            "replicated schema missing seed column: {csv}"
        );
        assert_eq!(csv.lines().count(), 3);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn failed_cells_do_not_discard_completed_work() {
        // a batch larger than the test set makes evaluation fail
        // deterministically in that cell only
        let mut bad = tiny_base();
        bad.batch = 100_000;
        bad.eval_every = 1;
        let mut grid = SweepGrid::new(tiny_base())
            .dataset(bad.clone())
            .scheme(CompressionScheme::Fp32);
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        assert_eq!(report.cells.len(), 1, "good cell must survive");
        assert_eq!(report.failures.len(), 1);
        assert!(
            report.failures[0].error.contains("test set"),
            "unexpected failure: {}",
            report.failures[0].error
        );
        assert!(report.summary().contains("FAILED"));
        // ... but a sweep where every cell fails is a hard error
        let mut all_bad =
            SweepGrid::new(bad).scheme(CompressionScheme::Fp32);
        all_bad.threads = 1;
        assert!(run_sweep(&all_bad).is_err());
    }

    #[test]
    fn csv_and_json_reports_roundtrip() {
        let mut grid = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::Lloyd { bits: 3 });
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("rcfed_sweep_{}", std::process::id()));
        let csv_path = dir.join("sweep.csv");
        let json_path = dir.join("sweep.json");
        report.write_csv(csv_path.to_str().unwrap()).unwrap();
        report.write_json(json_path.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(csv.starts_with("scheme,final_acc,best_acc,gigabits"));
        assert!(csv.lines().count() == 2);
        let json = std::fs::read_to_string(&json_path).unwrap();
        let v = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(
            v.req("cells").unwrap().as_arr().unwrap().len(),
            1
        );
        assert!(v.req("design_cache").unwrap().get("hits").is_some());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn design_sweep_runs_the_grid_in_order() {
        let grid = DesignGrid {
            schemes: vec![
                CompressionScheme::Lloyd { bits: 2 },
                CompressionScheme::Nqfl { bits: 2 },
                CompressionScheme::Uniform { bits: 2, clip: 4.0 },
            ],
            threads: 2,
        };
        let cells = run_design_sweep(&grid).unwrap();
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].label, "lloyd_b2");
        assert_eq!(cells[1].label, "nqfl_b2");
        for c in &cells {
            c.codebook.validate().unwrap();
            assert!(c.report.mse > 0.0);
        }
        // lloyd is MSE-optimal among these
        assert!(cells[0].report.mse <= cells[1].report.mse);
        assert!(cells[0].report.mse <= cells[2].report.mse);
    }

    #[test]
    fn pareto_dominance_counts() {
        let mut grid = SweepGrid::new(tiny_base())
            .scheme(CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.1,
                length_model: crate::quant::rcq::LengthModel::Huffman,
            })
            .scheme(CompressionScheme::Fp32);
        grid.threads = 1;
        let report = run_sweep(&grid).unwrap();
        // tolerance 1.0 ⇒ dominance reduces to the uplink ordering,
        // which is deterministic: rcfed b=3 ≪ fp32 bits
        let (dominated, total) = report.pareto_dominance("rcfed", 1.0);
        assert_eq!(total, 1); // fp32 is the only non-rcfed cell
        assert_eq!(dominated, 1);
        assert!(!report.summary().is_empty());
    }
}
