//! Layer-3 coordination: the simulated federation network with its exact
//! bit ledger ([`network`]), the parallel round scheduler ([`scheduler`]),
//! the experiment runner that drives full training runs ([`experiment`])
//! and the sharded multi-experiment sweep engine that fans whole grids of
//! experiments across a worker pool with a shared codebook design cache
//! ([`sweep`]).

pub mod experiment;
pub mod network;
pub mod scheduler;
pub mod sweep;
