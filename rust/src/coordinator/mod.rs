//! Layer-3 coordination: the simulated federation network with its exact
//! bit ledger ([`network`]), the parallel round scheduler ([`scheduler`])
//! and the experiment runner that drives full training runs and sweeps
//! ([`experiment`]).

pub mod experiment;
pub mod network;
pub mod scheduler;
