//! Layer-3 coordination: the simulated federation network with its exact
//! bit ledger and deterministic fault-injecting channel model
//! ([`network`]), the parallel round scheduler with partial-participation
//! selection ([`scheduler`]), the experiment runner that drives full
//! training runs through the channel ([`experiment`]) and the sharded
//! multi-experiment sweep engine that fans whole grids of experiments —
//! including loss/deadline scenario axes — across a worker pool with a
//! shared codebook design cache ([`sweep`]).

pub mod experiment;
pub mod network;
pub mod scheduler;
pub mod sweep;
