//! Simulated federation network: exact uplink accounting + a
//! deterministic fault-injecting channel model.
//!
//! The x-axis of Fig. 1 is *bits on the uplink*, which we account
//! exactly per packet. On top of the ledger sits a channel model
//! ([`ChannelSpec`]) expressing the imperfections real federated uplinks
//! have and that related work (Mitchell et al.; FedVQCS) evaluates
//! against:
//!
//! * **bandwidth heterogeneity** — each client gets a deterministic
//!   per-client uplink rate in `mean·[1−spread, 1+spread]`;
//! * **packet loss** — i.i.d. drops and Gilbert–Elliott burst loss
//!   (a two-state good/bad Markov chain evaluated per packet);
//! * **payload corruption** — bit flips or tail truncation of the real
//!   serialized wire bytes; the PS must surface these as decode `Err`s
//!   through `Packet::parse` → `decompress_accumulate`, never a panic;
//! * **straggler deadlines** — a client whose simulated transmit time
//!   exceeds the round deadline is dropped, paying only for the bits it
//!   pushed before the cut;
//! * **availability** — a sampled client skips the round entirely with
//!   probability `1 − availability` (partial participation beyond the
//!   scheduler's `clients_per_round` sampling).
//!
//! **Accounting policy.** Bits are charged for what the *client
//! transmits*, not what the PS decodes: lost and corrupted packets pay
//! full price, stragglers pay for the prefix sent before the deadline,
//! unavailable clients pay nothing.
//!
//! **Determinism.** All randomness flows from one seeded
//! [`crate::util::rng::Rng`]; a fixed `(spec, seed)` pair replays the
//! same survivor set, bit ledger and loss trajectory bit-exactly. With
//! [`ChannelSpec::ideal`] no random draw is ever made and every packet
//! is `Delivered`, so ideal-channel experiments are byte-identical to
//! the channel-less code path.

use std::collections::HashMap;

use crate::fl::packet::Packet;
use crate::util::rng::Rng;
use crate::util::{Error, Result};

/// Channel model configuration. `ideal()` disables every imperfection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelSpec {
    /// mean uplink bandwidth in bits/second (0 ⇒ infinite: accounting
    /// only, transmissions complete in `base_latency_s`)
    pub uplink_bps: f64,
    /// per-client bandwidth heterogeneity in [0, 1): client `c`'s rate
    /// is `uplink_bps · f_c` with `f_c` deterministic in `(seed, c)`,
    /// uniform over `[1−spread, 1+spread]`
    pub bandwidth_spread: f64,
    /// fixed per-message latency in seconds (e.g. RTT/2)
    pub base_latency_s: f64,
    /// i.i.d. packet-loss probability (the good-state loss rate)
    pub loss: f64,
    /// loss probability while the Gilbert–Elliott chain is in its bad
    /// (burst) state
    pub burst_loss: f64,
    /// per-packet probability of entering the bad state (0 ⇒ the burst
    /// model is disabled and only `loss` applies)
    pub burst_enter: f64,
    /// per-packet probability of leaving the bad state
    pub burst_exit: f64,
    /// per-packet probability of payload corruption (bit flips or tail
    /// truncation of the serialized bytes)
    pub corrupt: f64,
    /// bit flips applied to a corrupted packet (flip mode)
    pub corrupt_bits: u32,
    /// round deadline in seconds (0 ⇒ none): a client whose transmit
    /// time exceeds it is dropped as a straggler
    pub deadline_s: f64,
    /// probability a sampled client participates at all (1 ⇒ always)
    pub availability: f64,
}

impl ChannelSpec {
    /// The perfect channel: infinite bandwidth, no loss, no corruption,
    /// no deadline, full availability. Experiments under this spec are
    /// byte-identical to the pre-channel-model code path.
    pub const fn ideal() -> ChannelSpec {
        ChannelSpec {
            uplink_bps: 0.0,
            bandwidth_spread: 0.0,
            base_latency_s: 0.0,
            loss: 0.0,
            burst_loss: 0.0,
            burst_enter: 0.0,
            burst_exit: 0.0,
            corrupt: 0.0,
            corrupt_bits: 16,
            deadline_s: 0.0,
            availability: 1.0,
        }
    }

    /// Ideal channel with i.i.d. packet loss `p`.
    pub fn lossy(p: f64) -> ChannelSpec {
        ChannelSpec { loss: p, ..ChannelSpec::ideal() }
    }

    /// Whether any fault mechanism (loss, burst, corruption, deadline,
    /// partial availability) is enabled. Bandwidth/latency modelling
    /// alone is not a fault: it changes durations, never the survivor
    /// set or the ledger.
    pub fn is_faulty(&self) -> bool {
        self.loss > 0.0
            || self.burst_enter > 0.0
            || self.corrupt > 0.0
            || self.deadline_s > 0.0
            || self.availability < 1.0
    }

    /// Whether the loss model (i.i.d. or burst) needs a random draw.
    fn has_loss(&self) -> bool {
        self.loss > 0.0 || self.burst_enter > 0.0
    }

    /// Reject probabilities outside [0, 1] and negative rates.
    pub fn validate(&self) -> Result<()> {
        let probs = [
            ("loss", self.loss),
            ("burst-loss", self.burst_loss),
            ("burst-enter", self.burst_enter),
            ("burst-exit", self.burst_exit),
            ("corrupt", self.corrupt),
            ("availability", self.availability),
        ];
        for (name, p) in probs {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "channel {name} probability {p} outside [0, 1]")));
            }
        }
        if !(0.0..1.0).contains(&self.bandwidth_spread) {
            return Err(Error::Config(format!(
                "bandwidth spread {} outside [0, 1)", self.bandwidth_spread)));
        }
        for (name, x) in [
            ("uplink-bps", self.uplink_bps),
            ("latency", self.base_latency_s),
            ("deadline", self.deadline_s),
        ] {
            if !(x >= 0.0 && x.is_finite()) {
                return Err(Error::Config(format!(
                    "channel {name} {x} must be finite and >= 0")));
            }
        }
        // burst-model consistency, enforced here so library users (not
        // just the CLI) cannot configure a silent no-op or a permanent
        // blackout by accident
        if self.burst_enter > 0.0 && self.burst_exit <= 0.0 {
            return Err(Error::Config(
                "burst-enter > 0 requires burst-exit > 0 (the burst state \
                 would be absorbing)"
                    .into(),
            ));
        }
        if self.burst_loss > 0.0 && self.burst_enter <= 0.0 {
            return Err(Error::Config(
                "burst-loss > 0 has no effect with burst-enter = 0 \
                 (the bad state is never entered)"
                    .into(),
            ));
        }
        if self.deadline_s > 0.0
            && self.uplink_bps <= 0.0
            && self.base_latency_s <= 0.0
        {
            return Err(Error::Config(
                "deadline > 0 can never fire without a time model: set \
                 uplink-bps (and/or latency) so transmissions take time"
                    .into(),
            ));
        }
        if self.bandwidth_spread > 0.0 && self.uplink_bps <= 0.0 {
            return Err(Error::Config(
                "bandwidth-spread > 0 has no effect with uplink-bps = 0 \
                 (infinite bandwidth has no per-client heterogeneity)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Short stable label for CSV/JSON rows, e.g. `loss0.05_dl0.2`;
    /// `"ideal"` when nothing is enabled. Every field that can change
    /// outcomes appears in the label, so two distinct specs in one sweep
    /// never collapse onto the same row key.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if self.uplink_bps > 0.0 {
            // full-precision exponent form: 1.4e6 and 1e6 must not
            // collapse onto one row key
            parts.push(format!("bw{:e}", self.uplink_bps));
        }
        if self.bandwidth_spread > 0.0 {
            parts.push(format!("spread{}", self.bandwidth_spread));
        }
        if self.base_latency_s > 0.0 {
            parts.push(format!("lat{}", self.base_latency_s));
        }
        if self.loss > 0.0 {
            parts.push(format!("loss{}", self.loss));
        }
        if self.burst_enter > 0.0 {
            parts.push(format!(
                "burst{}e{}x{}",
                self.burst_loss, self.burst_enter, self.burst_exit
            ));
        }
        if self.corrupt > 0.0 {
            let mut c = format!("corr{}", self.corrupt);
            if self.corrupt_bits != 16 {
                c.push_str(&format!("b{}", self.corrupt_bits));
            }
            parts.push(c);
        }
        if self.deadline_s > 0.0 {
            parts.push(format!("dl{}", self.deadline_s));
        }
        if self.availability < 1.0 {
            parts.push(format!("avail{}", self.availability));
        }
        if parts.is_empty() {
            "ideal".into()
        } else {
            parts.join("_")
        }
    }
}

impl Default for ChannelSpec {
    fn default() -> Self {
        ChannelSpec::ideal()
    }
}

/// Outcome of pushing one packet through the channel.
#[derive(Debug)]
pub enum Delivery {
    /// Arrived intact after `secs` of simulated transmission.
    Delivered { secs: f64 },
    /// Arrived damaged: `bytes` are the serialized wire bytes after
    /// corruption; the receiver must go through the real
    /// `Packet::parse` → decode path and treat failures as recoverable.
    Corrupted { bytes: Vec<u8>, secs: f64 },
    /// Dropped in flight by the loss model (bits still paid for).
    Lost,
    /// Cut at the round deadline after `secs` of the transmission that
    /// would have taken longer (partial bits paid for).
    Straggled { secs: f64 },
}

/// Cumulative per-run channel outcome counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChannelStats {
    pub delivered: u64,
    pub lost: u64,
    pub corrupted: u64,
    pub straggled: u64,
    /// sampled clients that skipped the round (availability model)
    pub unavailable: u64,
    /// corrupted packets the receiver detected as decode `Err`s
    pub decode_errors: u64,
}

impl ChannelStats {
    /// Packets that reached the aggregator intact or as undetected noise.
    pub fn arrived(&self) -> u64 {
        self.delivered + self.corrupted - self.decode_errors
    }

    /// Total fault events injected by the channel.
    pub fn faults(&self) -> u64 {
        self.lost + self.corrupted + self.straggled + self.unavailable
    }
}

impl std::fmt::Display for ChannelStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} delivered / {} lost / {} corrupted ({} caught) / \
             {} straggled / {} unavailable",
            self.delivered, self.lost, self.corrupted, self.decode_errors,
            self.straggled, self.unavailable
        )
    }
}

/// Uplink ledger + deterministic fault-injecting channel.
///
/// Per-client state (bit ledgers, bandwidth factors) is keyed by client
/// id and materialized on first touch, so a network over a
/// million-client population costs memory proportional to the clients
/// that actually transmitted — the streamed round loop's O(active
/// cohort) discipline — not to the population.
#[derive(Debug)]
pub struct SimulatedNetwork {
    /// uplink bits per client, keyed by id (absent ⇒ never transmitted)
    per_client_bits: HashMap<usize, u64>,
    total_bits: u64,
    round_bits: Vec<u64>,
    /// server→client broadcast ledger (codebook re-publications from the
    /// adaptive pipeline; zero for static runs)
    downlink_bits: u64,
    round_downlink_bits: Vec<u64>,
    /// per-client unicast downlink (the rate allocator's per-client
    /// codebook publications), keyed by id
    per_client_down_bits: HashMap<usize, u64>,
    /// the channel configuration this network simulates
    pub spec: ChannelSpec,
    /// seed for the keyed per-client bandwidth-factor derivation
    seed: u64,
    rng: Rng,
    /// Gilbert–Elliott state: currently in the bad (burst) state?
    burst_bad: bool,
    /// outcome counters for reports
    pub stats: ChannelStats,
}

impl SimulatedNetwork {
    /// Ideal channel, accounting only (the pre-channel-model behavior).
    pub fn new(num_clients: usize) -> SimulatedNetwork {
        SimulatedNetwork::with_spec(num_clients, ChannelSpec::ideal(), 0)
    }

    /// Homogeneous bandwidth model (bits/s) with a base latency.
    pub fn with_bandwidth(num_clients: usize, bps: f64, latency_s: f64) -> Self {
        let spec = ChannelSpec {
            uplink_bps: bps,
            base_latency_s: latency_s,
            ..ChannelSpec::ideal()
        };
        SimulatedNetwork::with_spec(num_clients, spec, 0)
    }

    /// Full channel model. All randomness (loss, corruption,
    /// availability) derives from `seed`; per-client bandwidth factors
    /// are deterministic in `(seed, client)` and independent of traffic
    /// order. `num_clients` sizes nothing — every per-client structure
    /// is keyed and grows with the clients actually touched — but stays
    /// in the signature as the population contract.
    pub fn with_spec(
        _num_clients: usize,
        spec: ChannelSpec,
        seed: u64,
    ) -> SimulatedNetwork {
        SimulatedNetwork {
            per_client_bits: HashMap::new(),
            total_bits: 0,
            round_bits: Vec::new(),
            downlink_bits: 0,
            round_downlink_bits: Vec::new(),
            per_client_down_bits: HashMap::new(),
            spec,
            seed,
            rng: Rng::new(seed ^ 0x6E65_7477_6F72_6Bu64), // "network"
            burst_bad: false,
            stats: ChannelStats::default(),
        }
    }

    /// Uplink bandwidth of `client` in bits/s (None ⇒ infinite).
    pub fn client_bps(&self, client: usize) -> Option<f64> {
        if self.spec.uplink_bps <= 0.0 {
            return None;
        }
        Some(self.spec.uplink_bps * self.client_bandwidth_factor(client))
    }

    /// Relative uplink-bandwidth factor of `client` (1.0 under a
    /// homogeneous or infinite-bandwidth model) — the heterogeneity
    /// prior the rate allocator water-fills against. Derived on demand
    /// from `(seed, client)` — no per-population table — uniform over
    /// `[1−spread, 1+spread]`.
    pub fn client_bandwidth_factor(&self, client: usize) -> f64 {
        if self.spec.uplink_bps <= 0.0 || self.spec.bandwidth_spread <= 0.0 {
            return 1.0;
        }
        let mut r = Rng::new(
            self.seed
                ^ 0xBA2D_81F7_0C3A_55E1
                ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        1.0 + self.spec.bandwidth_spread * (2.0 * r.uniform() - 1.0)
    }

    /// Simulated transmit duration of `bits` from `client`.
    fn duration_of(&self, client: usize, bits: u64) -> f64 {
        self.spec.base_latency_s
            + self
                .client_bps(client)
                .map(|bps| bits as f64 / bps)
                .unwrap_or(0.0)
    }

    /// Charge `bits` to the ledger. Transmissions before the first
    /// `begin_round` open round 0 implicitly, so no bits are ever
    /// silently dropped from the per-round ledger.
    fn account(&mut self, client: usize, bits: u64) {
        *self.per_client_bits.entry(client).or_insert(0) += bits;
        self.total_bits += bits;
        if self.round_bits.is_empty() {
            self.round_bits.push(0);
        }
        *self.round_bits.last_mut().unwrap() += bits;
    }

    /// Bits the client can physically push before the round deadline:
    /// the whole packet when no deadline/time model caps it, otherwise
    /// the prefix transmitted by the cutoff (with infinite bandwidth
    /// everything leaves at t = 0).
    fn bits_within_deadline(&self, bits: u64, secs: f64) -> u64 {
        if self.spec.deadline_s <= 0.0 || secs <= self.spec.deadline_s {
            return bits;
        }
        let payload_secs = secs - self.spec.base_latency_s;
        if payload_secs > 0.0 {
            let budget =
                (self.spec.deadline_s - self.spec.base_latency_s).max(0.0);
            let frac = (budget / payload_secs).clamp(0.0, 1.0);
            (bits as f64 * frac) as u64
        } else {
            bits
        }
    }

    /// Record one uplink transmission (accounting only, no faults);
    /// returns its simulated duration.
    pub fn transmit(&mut self, packet: &Packet) -> f64 {
        let bits = packet.total_bits();
        let client = packet.client_id as usize;
        self.account(client, bits);
        self.duration_of(client, bits)
    }

    /// Availability model: does a sampled client participate this round?
    /// Draws from the channel RNG only when `availability < 1`.
    pub fn participates(&mut self) -> bool {
        if self.spec.availability >= 1.0 {
            return true;
        }
        let up = self.rng.uniform() < self.spec.availability;
        if !up {
            self.stats.unavailable += 1;
        }
        up
    }

    /// Push one packet through the channel: loss → deadline →
    /// corruption, charging the ledger per the accounting policy. With
    /// an ideal spec this is exactly [`Self::transmit`] and never draws
    /// randomness.
    pub fn deliver(&mut self, packet: &Packet) -> Delivery {
        let bits = packet.total_bits();
        let client = packet.client_id as usize;
        let secs = self.duration_of(client, bits);

        // 1. loss (i.i.d. or Gilbert–Elliott burst), drawn per packet
        if self.spec.has_loss() {
            if self.spec.burst_enter > 0.0 {
                self.burst_bad = if self.burst_bad {
                    !(self.rng.uniform() < self.spec.burst_exit)
                } else {
                    self.rng.uniform() < self.spec.burst_enter
                };
            }
            let p = if self.burst_bad {
                self.spec.burst_loss
            } else {
                self.spec.loss
            };
            if p > 0.0 && self.rng.uniform() < p {
                // the client transmitted; the drop is in flight — but
                // with a time model + deadline it can never have pushed
                // more than the deadline-capped prefix, so a lost
                // packet pays at most what a straggler would
                let paid = self.bits_within_deadline(bits, secs);
                self.account(client, paid);
                self.stats.lost += 1;
                return Delivery::Lost;
            }
        }

        // 2. straggler deadline: pay only for the prefix sent in time
        if self.spec.deadline_s > 0.0 && secs > self.spec.deadline_s {
            let sent = self.bits_within_deadline(bits, secs);
            self.account(client, sent);
            self.stats.straggled += 1;
            return Delivery::Straggled { secs: self.spec.deadline_s };
        }

        // 3. payload corruption of the real wire bytes
        if self.spec.corrupt > 0.0 && self.rng.uniform() < self.spec.corrupt {
            self.account(client, bits);
            self.stats.corrupted += 1;
            let bytes = self.corrupt_bytes(packet.to_bytes());
            return Delivery::Corrupted { bytes, secs };
        }

        self.account(client, bits);
        self.stats.delivered += 1;
        Delivery::Delivered { secs }
    }

    /// Damage a serialized packet: either truncate its tail (structural
    /// damage `Packet::parse` must reject) or flip `corrupt_bits`
    /// random bits anywhere in the buffer (which the decode layer may
    /// catch — or may pass through as gradient noise, like a real
    /// unchecksummed link).
    fn corrupt_bytes(&mut self, mut bytes: Vec<u8>) -> Vec<u8> {
        if bytes.is_empty() {
            return bytes;
        }
        if self.rng.below(2) == 0 {
            let cut = 1 + self.rng.below(4).min(bytes.len() - 1);
            bytes.truncate(bytes.len() - cut);
        } else {
            for _ in 0..self.spec.corrupt_bits.max(1) {
                let bit = self.rng.below(bytes.len() * 8);
                bytes[bit / 8] ^= 1 << (bit % 8);
            }
        }
        bytes
    }

    /// Record that a corrupted delivery was caught as a decode `Err`
    /// (called by the receiver, which owns the decode path).
    pub fn note_decode_error(&mut self) {
        self.stats.decode_errors += 1;
    }

    /// Charge a server→client broadcast of `bits_per_client` bits to
    /// `clients` receivers on the downlink ledger — the adaptive
    /// pipeline's codebook re-publications go through here, so reported
    /// communication totals stay honest. Returns the total charged.
    ///
    /// The downlink is modeled as a loss-free control channel (codebook
    /// updates are tiny and would be sent reliably in any deployment);
    /// only the accounting matters here.
    pub fn broadcast(&mut self, bits_per_client: u64, clients: usize) -> u64 {
        let bits = bits_per_client * clients as u64;
        self.charge_downlink(bits);
        bits
    }

    /// Charge a server→client *unicast* of `bits` to one receiver on the
    /// downlink ledger — the rate allocator's per-client codebook
    /// publications go through here, so only the clients whose width
    /// actually moved are charged (a broadcast would overcount).
    pub fn unicast(&mut self, client: usize, bits: u64) -> u64 {
        *self.per_client_down_bits.entry(client).or_insert(0) += bits;
        self.charge_downlink(bits);
        bits
    }

    fn charge_downlink(&mut self, bits: u64) {
        self.downlink_bits += bits;
        if self.round_downlink_bits.is_empty() {
            self.round_downlink_bits.push(0);
        }
        *self.round_downlink_bits.last_mut().unwrap() += bits;
    }

    /// Cumulative downlink bits unicast to `client` (codebook
    /// publications from the rate allocator; zero otherwise).
    pub fn client_downlink_bits(&self, client: usize) -> u64 {
        self.per_client_down_bits.get(&client).copied().unwrap_or(0)
    }

    /// Mark the start of a round (opens fresh round buckets on both
    /// ledgers).
    pub fn begin_round(&mut self) {
        self.round_bits.push(0);
        self.round_downlink_bits.push(0);
    }

    /// Close the current round: unconditionally pad BOTH per-round
    /// ledgers to the same bucket count, so downlink round indices
    /// always align with uplink rounds — even when one direction
    /// charged nothing all round, or a charge landed before the first
    /// [`Self::begin_round`] and lazily opened only its own round-0
    /// bucket.
    pub fn end_round(&mut self) {
        let rounds = self
            .round_bits
            .len()
            .max(self.round_downlink_bits.len())
            .max(1);
        self.round_bits.resize(rounds, 0);
        self.round_downlink_bits.resize(rounds, 0);
    }

    /// Bucket counts of the two per-round ledgers, `(uplink, downlink)`
    /// — equal after every [`Self::end_round`].
    pub fn round_ledger_lens(&self) -> (usize, usize) {
        (self.round_bits.len(), self.round_downlink_bits.len())
    }

    pub fn bits_this_round(&self) -> u64 {
        *self.round_bits.last().unwrap_or(&0)
    }

    /// Downlink bits charged this round (codebook broadcasts).
    pub fn downlink_bits_this_round(&self) -> u64 {
        *self.round_downlink_bits.last().unwrap_or(&0)
    }

    /// Cumulative server→client broadcast bits.
    pub fn downlink_bits(&self) -> u64 {
        self.downlink_bits
    }

    pub fn total_bits(&self) -> u64 {
        self.total_bits
    }

    pub fn total_gigabits(&self) -> f64 {
        self.total_bits as f64 / 1e9
    }

    pub fn client_bits(&self, client: usize) -> u64 {
        self.per_client_bits.get(&client).copied().unwrap_or(0)
    }

    /// Simulated duration of a round where `durations` are the per-client
    /// transmit times: parallel links ⇒ the slowest client gates.
    pub fn round_duration(durations: &[f64]) -> f64 {
        durations.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::packet::SchemeTag;

    fn pkt(client: u32, payload_bits: u64) -> Packet {
        Packet {
            client_id: client,
            round: 0,
            scheme: SchemeTag::RcFed,
            bits_per_symbol: 3,
            d: 10,
            side_info: vec![0.0, 1.0],
            payload: vec![0; payload_bits.div_ceil(8) as usize],
            payload_bits,
            table_bits: 0,
            index_bits: 0,
        }
    }

    fn lossy_spec() -> ChannelSpec {
        ChannelSpec { loss: 0.3, ..ChannelSpec::ideal() }
    }

    #[test]
    fn ledger_tracks_per_client_and_total() {
        let mut n = SimulatedNetwork::new(3);
        n.begin_round();
        n.transmit(&pkt(0, 1000));
        n.transmit(&pkt(2, 2000));
        let expected0 = pkt(0, 1000).total_bits();
        let expected2 = pkt(2, 2000).total_bits();
        assert_eq!(n.client_bits(0), expected0);
        assert_eq!(n.client_bits(1), 0);
        assert_eq!(n.client_bits(2), expected2);
        assert_eq!(n.total_bits(), expected0 + expected2);
        assert_eq!(n.bits_this_round(), expected0 + expected2);
        n.begin_round();
        assert_eq!(n.bits_this_round(), 0);
    }

    #[test]
    fn downlink_ledger_is_separate_and_per_round() {
        let mut n = SimulatedNetwork::new(4);
        assert_eq!(n.downlink_bits(), 0);
        n.begin_round();
        n.transmit(&pkt(0, 1000));
        // one 300-bit codebook published to all 4 clients
        assert_eq!(n.broadcast(300, 4), 1200);
        assert_eq!(n.downlink_bits(), 1200);
        assert_eq!(n.downlink_bits_this_round(), 1200);
        // downlink never leaks into the uplink ledger (Fig. 1's x-axis)
        assert_eq!(n.total_bits(), pkt(0, 1000).total_bits());
        n.begin_round();
        assert_eq!(n.downlink_bits_this_round(), 0);
        assert_eq!(n.downlink_bits(), 1200);
        // a broadcast before any begin_round opens round 0 implicitly
        let mut fresh = SimulatedNetwork::new(2);
        fresh.broadcast(100, 2);
        assert_eq!(fresh.downlink_bits_this_round(), 200);
    }

    #[test]
    fn unicast_charges_one_receiver_on_the_downlink_ledger() {
        let mut n = SimulatedNetwork::new(3);
        n.begin_round();
        assert_eq!(n.unicast(1, 500), 500);
        assert_eq!(n.unicast(1, 200), 200);
        assert_eq!(n.unicast(2, 100), 100);
        assert_eq!(n.downlink_bits(), 800);
        assert_eq!(n.downlink_bits_this_round(), 800);
        assert_eq!(n.client_downlink_bits(0), 0);
        assert_eq!(n.client_downlink_bits(1), 700);
        assert_eq!(n.client_downlink_bits(2), 100);
        // never leaks into the uplink ledger
        assert_eq!(n.total_bits(), 0);
        // receivers beyond the nominal population still charge both
        // ledgers (the keyed ledger has no bound to fall outside of)
        n.unicast(99, 50);
        assert_eq!(n.downlink_bits(), 850);
        assert_eq!(n.client_downlink_bits(99), 50);
        // a unicast before any begin_round opens round 0 implicitly
        let mut fresh = SimulatedNetwork::new(2);
        fresh.unicast(0, 40);
        assert_eq!(fresh.downlink_bits_this_round(), 40);
    }

    #[test]
    fn end_round_aligns_downlink_buckets_with_uplink_rounds() {
        // regression: `charge_downlink` only lazily opens a round-0
        // bucket, so a downlink charge before the first begin_round (or
        // a round with traffic on one direction only) used to leave the
        // two per-round ledgers at different lengths — downlink round
        // indices drifted off the uplink's
        let mut n = SimulatedNetwork::new(2);
        n.broadcast(100, 2);
        assert_eq!(n.round_ledger_lens(), (0, 1), "lazy open is one-sided");
        n.end_round();
        assert_eq!(n.round_ledger_lens(), (1, 1));
        assert_eq!(n.bits_this_round(), 0);
        assert_eq!(n.downlink_bits_this_round(), 200);

        // a round that charges no downlink bits still closes aligned
        n.begin_round();
        n.transmit(&pkt(0, 1000));
        n.end_round();
        assert_eq!(n.round_ledger_lens(), (2, 2));
        assert_eq!(n.downlink_bits_this_round(), 0);

        // the mirror case: an uplink charge before any begin_round
        let mut m = SimulatedNetwork::new(1);
        m.transmit(&pkt(0, 800));
        assert_eq!(m.round_ledger_lens(), (1, 0));
        m.end_round();
        assert_eq!(m.round_ledger_lens(), (1, 1));

        // and a fully idle round on a fresh network still opens buckets
        let mut idle = SimulatedNetwork::new(1);
        idle.end_round();
        assert_eq!(idle.round_ledger_lens(), (1, 1));
    }

    #[test]
    fn ledgers_grow_with_touched_clients_not_population() {
        // a network over a huge nominal population allocates nothing up
        // front; only the clients that actually transmit (or receive a
        // unicast) occupy ledger memory
        let mut n = SimulatedNetwork::with_spec(
            1_000_000_000,
            ChannelSpec::ideal(),
            0,
        );
        n.begin_round();
        n.transmit(&pkt(7, 100));
        n.transmit(&pkt(999_999_999, 100));
        n.unicast(7, 40);
        assert_eq!(n.per_client_bits.len(), 2);
        assert_eq!(n.per_client_down_bits.len(), 1);
        assert_eq!(n.client_bits(7), pkt(7, 100).total_bits());
        assert_eq!(n.client_bits(999_999_999), pkt(7, 100).total_bits());
        assert_eq!(n.client_bits(3), 0, "untouched clients read zero");
        assert_eq!(n.client_downlink_bits(3), 0);
    }

    #[test]
    fn bandwidth_factors_default_to_one() {
        let flat = SimulatedNetwork::new(4);
        for c in 0..4 {
            assert_eq!(flat.client_bandwidth_factor(c), 1.0);
        }
        let spec = ChannelSpec {
            uplink_bps: 1e6,
            bandwidth_spread: 0.5,
            ..ChannelSpec::ideal()
        };
        let het = SimulatedNetwork::with_spec(8, spec, 21);
        let mut distinct = false;
        for c in 0..8 {
            let f = het.client_bandwidth_factor(c);
            assert!((0.5..=1.5).contains(&f));
            assert_eq!(het.client_bps(c), Some(1e6 * f));
            if (f - 1.0).abs() > 1e-3 {
                distinct = true;
            }
        }
        assert!(distinct);
    }

    #[test]
    fn transmit_before_begin_round_opens_round_zero() {
        // regression: `round_bits.last_mut().unwrap_or(&mut 0)` used to
        // accumulate into a temporary, silently dropping the bits from
        // the per-round ledger when no round was open
        let mut n = SimulatedNetwork::new(1);
        n.transmit(&pkt(0, 800));
        let bits = pkt(0, 800).total_bits();
        assert_eq!(n.bits_this_round(), bits, "round-0 bits were dropped");
        assert_eq!(n.total_bits(), bits);
        // a later begin_round still opens a fresh bucket
        n.begin_round();
        assert_eq!(n.bits_this_round(), 0);
        n.transmit(&pkt(0, 8));
        assert_eq!(n.bits_this_round(), pkt(0, 8).total_bits());
    }

    #[test]
    fn bandwidth_model_durations() {
        let mut n = SimulatedNetwork::with_bandwidth(2, 1e6, 0.01);
        n.begin_round();
        let d = n.transmit(&pkt(0, 1_000_000));
        // ≈ 1 s of payload (+ header/side bits) + 10 ms latency
        assert!(d > 1.0 && d < 1.1, "{d}");
        assert_eq!(SimulatedNetwork::round_duration(&[0.1, 0.5, 0.3]), 0.5);
    }

    #[test]
    fn ideal_channel_delivers_everything_without_rng() {
        let mut n = SimulatedNetwork::with_spec(2, ChannelSpec::ideal(), 7);
        n.begin_round();
        for i in 0..20 {
            assert!(n.participates());
            match n.deliver(&pkt(i % 2, 1000)) {
                Delivery::Delivered { secs } => assert_eq!(secs, 0.0),
                other => panic!("ideal channel produced {other:?}"),
            }
        }
        assert_eq!(n.stats.delivered, 20);
        assert_eq!(n.stats.faults(), 0);
        // accounting identical to plain transmit
        assert_eq!(n.total_bits(), 20 * pkt(0, 1000).total_bits());
    }

    #[test]
    fn loss_replays_bit_exactly_from_seed() {
        let outcomes = |seed: u64| -> (Vec<bool>, ChannelStats, u64) {
            let mut n = SimulatedNetwork::with_spec(1, lossy_spec(), seed);
            n.begin_round();
            let seq: Vec<bool> = (0..200)
                .map(|_| matches!(n.deliver(&pkt(0, 512)),
                                  Delivery::Delivered { .. }))
                .collect();
            (seq, n.stats, n.total_bits())
        };
        let (a, sa, ba) = outcomes(11);
        let (b, sb, bb) = outcomes(11);
        assert_eq!(a, b, "same seed must replay the same survivor set");
        assert_eq!(sa, sb);
        assert_eq!(ba, bb);
        let (c, _, _) = outcomes(12);
        assert_ne!(a, c, "different seeds should differ");
        // lost packets still pay their bits
        assert!(sa.lost > 20, "loss 0.3 over 200 packets: {sa:?}");
        assert_eq!(ba, 200 * pkt(0, 512).total_bits());
    }

    #[test]
    fn burst_model_clusters_losses() {
        let spec = ChannelSpec {
            loss: 0.0,
            burst_loss: 1.0,
            burst_enter: 0.05,
            burst_exit: 0.3,
            ..ChannelSpec::ideal()
        };
        let mut n = SimulatedNetwork::with_spec(1, spec, 3);
        n.begin_round();
        let seq: Vec<bool> = (0..2000)
            .map(|_| matches!(n.deliver(&pkt(0, 64)), Delivery::Lost))
            .collect();
        let losses = seq.iter().filter(|&&l| l).count();
        assert!(losses > 50, "burst chain never engaged: {losses}");
        // burst losses arrive in runs: the number of loss→loss
        // adjacencies must far exceed the i.i.d. expectation
        let pairs = seq.windows(2).filter(|w| w[0] && w[1]).count();
        let p = losses as f64 / seq.len() as f64;
        let iid_pairs = p * p * seq.len() as f64;
        assert!(
            pairs as f64 > 3.0 * iid_pairs,
            "losses not bursty: {pairs} pairs vs iid {iid_pairs:.1}"
        );
    }

    #[test]
    fn bandwidth_spread_is_deterministic_per_client() {
        let spec = ChannelSpec {
            uplink_bps: 1e6,
            bandwidth_spread: 0.5,
            ..ChannelSpec::ideal()
        };
        let a = SimulatedNetwork::with_spec(8, spec, 21);
        let b = SimulatedNetwork::with_spec(8, spec, 21);
        let mut distinct = false;
        for c in 0..8 {
            let ba = a.client_bps(c).unwrap();
            assert_eq!(ba, b.client_bps(c).unwrap(), "client {c}");
            assert!(ba >= 0.5e6 && ba <= 1.5e6, "client {c}: {ba}");
            if (ba - 1e6).abs() > 1e3 {
                distinct = true;
            }
        }
        assert!(distinct, "spread produced no heterogeneity");
        // spread 0 ⇒ exactly the mean for every client
        let flat = SimulatedNetwork::with_bandwidth(4, 1e6, 0.0);
        for c in 0..4 {
            assert_eq!(flat.client_bps(c), Some(1e6));
        }
    }

    #[test]
    fn straggler_deadline_drops_and_charges_partial_bits() {
        // 1e4 bits at 1e3 bps = 10 s ≫ 1 s deadline
        let spec = ChannelSpec {
            uplink_bps: 1e3,
            deadline_s: 1.0,
            ..ChannelSpec::ideal()
        };
        let mut n = SimulatedNetwork::with_spec(1, spec, 5);
        n.begin_round();
        let p = pkt(0, 10_000);
        let full = p.total_bits();
        match n.deliver(&p) {
            Delivery::Straggled { secs } => assert_eq!(secs, 1.0),
            other => panic!("expected straggler, got {other:?}"),
        }
        assert_eq!(n.stats.straggled, 1);
        let paid = n.total_bits();
        assert!(paid > 0 && paid < full, "partial bits: {paid} of {full}");
        // the deadline buys 1 s × 1e3 bps = 1000 of the `full` bits
        let frac = paid as f64 / full as f64;
        assert!((frac - 1e3 / full as f64).abs() < 0.01, "fraction {frac}");
        // a fast packet under the same deadline is delivered
        match n.deliver(&pkt(0, 100)) {
            Delivery::Delivered { .. } => {}
            other => panic!("fast packet {other:?}"),
        }
    }

    #[test]
    fn lost_packets_pay_at_most_the_deadline_prefix() {
        // loss + deadline + bandwidth: a packet the deadline would have
        // cut cannot be charged full price just because the loss model
        // fired first — the client physically pushed only the prefix
        let spec = ChannelSpec {
            uplink_bps: 1e3,
            deadline_s: 1.0,
            loss: 1.0,
            ..ChannelSpec::ideal()
        };
        let mut n = SimulatedNetwork::with_spec(1, spec, 13);
        n.begin_round();
        let p = pkt(0, 10_000); // 10 s of transmit ≫ 1 s deadline
        let full = p.total_bits();
        match n.deliver(&p) {
            Delivery::Lost => {}
            other => panic!("loss=1.0 produced {other:?}"),
        }
        let paid = n.total_bits();
        assert!(
            paid > 0 && paid < full / 5,
            "lost packet paid {paid} of {full}, beyond the 1 s prefix"
        );
        // without a deadline, lost packets still pay full price
        let mut m =
            SimulatedNetwork::with_spec(1, ChannelSpec::lossy(1.0), 13);
        m.begin_round();
        assert!(matches!(m.deliver(&p), Delivery::Lost));
        assert_eq!(m.total_bits(), full);
    }

    #[test]
    fn corruption_damages_real_wire_bytes() {
        let spec = ChannelSpec { corrupt: 1.0, ..ChannelSpec::ideal() };
        let mut n = SimulatedNetwork::with_spec(1, spec, 9);
        n.begin_round();
        let p = pkt(0, 4096);
        let clean = p.to_bytes();
        let mut saw_truncation = false;
        let mut saw_flip = false;
        for _ in 0..32 {
            match n.deliver(&p) {
                Delivery::Corrupted { bytes, .. } => {
                    assert_ne!(bytes, clean, "corruption was a no-op");
                    if bytes.len() < clean.len() {
                        saw_truncation = true;
                    } else {
                        assert_eq!(bytes.len(), clean.len());
                        saw_flip = true;
                    }
                }
                other => panic!("corrupt=1.0 produced {other:?}"),
            }
        }
        assert!(saw_truncation && saw_flip, "both damage modes expected");
        assert_eq!(n.stats.corrupted, 32);
        // corrupted packets pay full price
        assert_eq!(n.total_bits(), 32 * p.total_bits());
    }

    #[test]
    fn availability_skips_clients_deterministically() {
        let spec = ChannelSpec { availability: 0.5, ..ChannelSpec::ideal() };
        let draw = |seed| -> Vec<bool> {
            let mut n = SimulatedNetwork::with_spec(1, spec, seed);
            (0..100).map(|_| n.participates()).collect()
        };
        let a = draw(31);
        assert_eq!(a, draw(31));
        let ups = a.iter().filter(|&&x| x).count();
        assert!(ups > 20 && ups < 80, "availability 0.5: {ups}/100");
    }

    #[test]
    fn spec_validation_and_labels() {
        assert!(ChannelSpec::ideal().validate().is_ok());
        assert!(!ChannelSpec::ideal().is_faulty());
        assert_eq!(ChannelSpec::ideal().label(), "ideal");
        let mut bad = ChannelSpec::ideal();
        bad.loss = 1.5;
        assert!(bad.validate().is_err());
        bad = ChannelSpec::ideal();
        bad.deadline_s = -1.0;
        assert!(bad.validate().is_err());
        let spec = ChannelSpec {
            loss: 0.05,
            deadline_s: 0.2,
            ..ChannelSpec::ideal()
        };
        assert!(spec.is_faulty());
        assert_eq!(spec.label(), "loss0.05_dl0.2");
        assert!(ChannelSpec::lossy(0.1).is_faulty());
        // burst-model consistency is a library-level invariant
        let absorbing = ChannelSpec {
            burst_loss: 1.0,
            burst_enter: 0.05,
            burst_exit: 0.0,
            ..ChannelSpec::ideal()
        };
        assert!(absorbing.validate().is_err());
        let noop_burst = ChannelSpec {
            burst_loss: 0.9,
            ..ChannelSpec::ideal()
        };
        assert!(noop_burst.validate().is_err());
        // silent no-ops are rejected: a deadline that can never fire, a
        // spread with no bandwidth model to spread
        let noop_deadline = ChannelSpec {
            deadline_s: 0.1,
            ..ChannelSpec::ideal()
        };
        assert!(noop_deadline.validate().is_err());
        let noop_spread = ChannelSpec {
            bandwidth_spread: 0.5,
            ..ChannelSpec::ideal()
        };
        assert!(noop_spread.validate().is_err());
        // distinct burst chains get distinct labels (row keys)
        let b1 = ChannelSpec {
            burst_loss: 0.8,
            burst_enter: 0.05,
            burst_exit: 0.3,
            ..ChannelSpec::ideal()
        };
        let mut b2 = b1;
        b2.burst_enter = 0.3;
        assert_ne!(b1.label(), b2.label());
        assert_eq!(b1.label(), "burst0.8e0.05x0.3");
    }
}
