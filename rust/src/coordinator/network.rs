//! Simulated federation network.
//!
//! The x-axis of Fig. 1 is *bits on the uplink*, which we account
//! exactly per packet. For latency-oriented diagnostics the network can
//! also model per-client uplink bandwidth: clients transmit in parallel,
//! so a round's transmission time is the max over its participants.

use crate::fl::packet::Packet;

/// Uplink ledger + optional bandwidth model.
#[derive(Debug)]
pub struct SimulatedNetwork {
    per_client_bits: Vec<u64>,
    total_bits: u64,
    /// uplink bandwidth per client in bits/second (None = accounting only)
    pub uplink_bps: Option<f64>,
    /// fixed per-message latency in seconds (e.g. RTT/2)
    pub base_latency_s: f64,
    round_bits: Vec<u64>,
}

impl SimulatedNetwork {
    pub fn new(num_clients: usize) -> SimulatedNetwork {
        SimulatedNetwork {
            per_client_bits: vec![0; num_clients],
            total_bits: 0,
            uplink_bps: None,
            base_latency_s: 0.0,
            round_bits: Vec::new(),
        }
    }

    /// With a bandwidth model (bits/s) and a base latency.
    pub fn with_bandwidth(num_clients: usize, bps: f64, latency_s: f64) -> Self {
        let mut n = SimulatedNetwork::new(num_clients);
        n.uplink_bps = Some(bps);
        n.base_latency_s = latency_s;
        n
    }

    /// Record one uplink transmission; returns its simulated duration.
    pub fn transmit(&mut self, packet: &Packet) -> f64 {
        let bits = packet.total_bits();
        let c = packet.client_id as usize;
        if c < self.per_client_bits.len() {
            self.per_client_bits[c] += bits;
        }
        self.total_bits += bits;
        *self.round_bits.last_mut().unwrap_or(&mut 0) += bits;
        self.base_latency_s
            + self.uplink_bps.map(|b| bits as f64 / b).unwrap_or(0.0)
    }

    /// Mark the start of a round (opens a fresh round-bits bucket).
    pub fn begin_round(&mut self) {
        self.round_bits.push(0);
    }

    pub fn bits_this_round(&self) -> u64 {
        *self.round_bits.last().unwrap_or(&0)
    }

    pub fn total_bits(&self) -> u64 {
        self.total_bits
    }

    pub fn total_gigabits(&self) -> f64 {
        self.total_bits as f64 / 1e9
    }

    pub fn client_bits(&self, client: usize) -> u64 {
        self.per_client_bits.get(client).copied().unwrap_or(0)
    }

    /// Simulated duration of a round where `durations` are the per-client
    /// transmit times: parallel links ⇒ the slowest client gates.
    pub fn round_duration(durations: &[f64]) -> f64 {
        durations.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::packet::SchemeTag;

    fn pkt(client: u32, payload_bits: u64) -> Packet {
        Packet {
            client_id: client,
            round: 0,
            scheme: SchemeTag::RcFed,
            bits_per_symbol: 3,
            d: 10,
            side_info: vec![0.0, 1.0],
            payload: vec![0; payload_bits.div_ceil(8) as usize],
            payload_bits,
            table_bits: 0,
        }
    }

    #[test]
    fn ledger_tracks_per_client_and_total() {
        let mut n = SimulatedNetwork::new(3);
        n.begin_round();
        n.transmit(&pkt(0, 1000));
        n.transmit(&pkt(2, 2000));
        let expected0 = pkt(0, 1000).total_bits();
        let expected2 = pkt(2, 2000).total_bits();
        assert_eq!(n.client_bits(0), expected0);
        assert_eq!(n.client_bits(1), 0);
        assert_eq!(n.client_bits(2), expected2);
        assert_eq!(n.total_bits(), expected0 + expected2);
        assert_eq!(n.bits_this_round(), expected0 + expected2);
        n.begin_round();
        assert_eq!(n.bits_this_round(), 0);
    }

    #[test]
    fn bandwidth_model_durations() {
        let mut n = SimulatedNetwork::with_bandwidth(2, 1e6, 0.01);
        n.begin_round();
        let d = n.transmit(&pkt(0, 1_000_000));
        // ≈ 1 s of payload (+ header/side bits) + 10 ms latency
        assert!(d > 1.0 && d < 1.1, "{d}");
        assert_eq!(SimulatedNetwork::round_duration(&[0.1, 0.5, 0.3]), 0.5);
    }
}
