//! Round scheduler: runs the sampled clients' local updates, in parallel
//! when the backend allows it (native models are pure functions of their
//! inputs; the PJRT CPU client is driven from one thread and parallelizes
//! internally via Eigen).

use crate::fl::client::{Client, ClientUpdate};
use crate::fl::compression::CompressionPipeline;
use crate::model::Backend;
use crate::util::Result;

/// Per-round execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct RoundPlan {
    pub round: u32,
    pub local_iters: usize,
    pub lr: f32,
    pub batch: usize,
    /// worker threads for the parallel path (0 ⇒ hardware parallelism)
    pub threads: usize,
}

/// Partial participation: borrow the sampled clients (by population
/// index) out of the full client slice, preserving client-index order.
/// Out-of-range indices are ignored. The round layer composes this with
/// the scheduler's `clients_per_round` sampling and the channel model's
/// availability draws.
pub fn select_clients<'a>(
    clients: &'a mut [Client],
    sampled: &[usize],
) -> Vec<&'a mut Client> {
    let mut flags = vec![false; clients.len()];
    for &i in sampled {
        if i < flags.len() {
            flags[i] = true;
        }
    }
    clients
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| flags[*i])
        .map(|(_, c)| c)
        .collect()
}

/// Run the sampled clients serially. The pipeline is shared immutably
/// during the round; the coordinator adapts it *between* rounds.
pub fn run_round_serial<B: Backend + ?Sized>(
    backend: &B,
    clients: &mut [&mut Client],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
) -> Result<Vec<ClientUpdate>> {
    clients
        .iter_mut()
        .map(|c| {
            c.round(
                backend, params, plan.round, plan.local_iters, plan.lr,
                plan.batch, pipeline,
            )
        })
        .collect()
}

/// Run the sampled clients across a scoped thread pool. Falls back to the
/// serial path when the backend is not thread-safe or for tiny rounds.
pub fn run_round<B: Backend + Sync + ?Sized>(
    backend: &B,
    clients: &mut [&mut Client],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
) -> Result<Vec<ClientUpdate>>
where
    CompressionPipeline: Sync,
{
    let n = clients.len();
    let threads = if plan.threads == 0 {
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    } else {
        plan.threads
    };
    let threads = threads.min(n.max(1));
    if !backend.supports_parallel() || threads <= 1 || n <= 1 {
        return run_round_serial(backend, clients, params, plan, pipeline);
    }
    // Partition the &mut Client slice across scoped workers; order of the
    // returned updates matches the input order (stitched by partition).
    let per = n.div_ceil(threads);
    let mut results: Vec<Result<Vec<ClientUpdate>>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for chunk in clients.chunks_mut(per) {
            handles.push(scope.spawn(move || {
                chunk
                    .iter_mut()
                    .map(|c| {
                        c.round(
                            backend, params, plan.round, plan.local_iters,
                            plan.lr, plan.batch, pipeline,
                        )
                    })
                    .collect::<Result<Vec<_>>>()
            }));
        }
        for h in handles {
            results.push(h.join().expect("worker panicked"));
        }
    });
    let mut out = Vec::with_capacity(n);
    for r in results {
        out.extend(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetConfig, FederatedDataset};
    use crate::fl::compression::{
        CompressionScheme, RateTarget, WireCoder,
    };
    use crate::model::native::NativeMlp;

    fn setup(nclients: usize) -> (NativeMlp, Vec<Client>, CompressionPipeline)
    {
        let mut cfg = DatasetConfig::tiny();
        cfg.num_clients = nclients;
        let ds = FederatedDataset::build(&cfg);
        let clients: Vec<Client> = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| Client::new(i as u32, s.clone(), 1000 + i as u64))
            .collect();
        let c = CompressionPipeline::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
            RateTarget::Off,
        )
        .unwrap();
        (NativeMlp::tiny(), clients, c)
    }

    #[test]
    fn parallel_matches_serial() {
        let (m, mut clients_a, c) = setup(8);
        let (_, mut clients_b, _) = setup(8);
        let params = crate::model::Backend::init_params(&m, 1);
        let plan = RoundPlan {
            round: 0,
            local_iters: 2,
            lr: 0.05,
            batch: 8,
            threads: 4,
        };
        let mut refs_a: Vec<&mut Client> = clients_a.iter_mut().collect();
        let mut refs_b: Vec<&mut Client> = clients_b.iter_mut().collect();
        let par = run_round(&m, &mut refs_a, &params, &plan, &c).unwrap();
        let ser =
            run_round_serial(&m, &mut refs_b, &params, &plan, &c).unwrap();
        assert_eq!(par.len(), ser.len());
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.packet.payload, b.packet.payload, "same seeds");
            assert_eq!(a.packet.client_id, b.packet.client_id);
        }
    }

    #[test]
    fn select_clients_preserves_index_order() {
        let (_, mut clients, _) = setup(5);
        let refs = select_clients(&mut clients, &[3, 0, 4]);
        let ids: Vec<u32> = refs.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 3, 4]);
        // out-of-range indices are ignored, duplicates collapse
        let refs = select_clients(&mut clients, &[1, 1, 99]);
        assert_eq!(refs.len(), 1);
        assert_eq!(refs[0].id, 1);
        assert!(select_clients(&mut clients, &[]).is_empty());
    }

    #[test]
    fn single_client_round() {
        let (m, mut clients, c) = setup(1);
        let params = crate::model::Backend::init_params(&m, 2);
        let plan = RoundPlan {
            round: 0,
            local_iters: 1,
            lr: 0.1,
            batch: 8,
            threads: 0,
        };
        let mut refs: Vec<&mut Client> = clients.iter_mut().collect();
        let ups = run_round(&m, &mut refs, &params, &plan, &c).unwrap();
        assert_eq!(ups.len(), 1);
    }
}
