//! Round scheduler: runs the sampled clients' local updates, in parallel
//! when the backend allows it (native models are pure functions of their
//! inputs; the PJRT CPU client is driven from one thread and parallelizes
//! internally via Eigen).
//!
//! Two executors share the per-client round body
//! ([`crate::fl::client::run_client_round`]):
//!
//! * **resident** ([`run_round`] / [`run_round_serial`]) — iterates
//!   pre-materialized `&mut Client`s (the historical path);
//! * **streamed** ([`stream_round`] / [`stream_round_serial`]) — checks
//!   durable state out of a [`ClientStore`], materializes each shard
//!   from a [`ShardSource`] for exactly the duration of the client's
//!   local step, and shards the cohort across the sweep engine's
//!   `parallel_map` pool with a deterministic ordered reduction, so
//!   memory is O(active cohort) while results stay byte-identical to
//!   the resident executor.

use std::sync::Mutex;

use crate::coordinator::sweep::parallel_map;
use crate::fl::client::{
    run_client_round, Client, ClientState, ClientUpdate, RoundScratch,
};
use crate::fl::compression::CompressionPipeline;
use crate::fl::store::{ClientStore, ShardSource};
use crate::model::Backend;
use crate::util::Result;

/// Per-round execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct RoundPlan {
    pub round: u32,
    pub local_iters: usize,
    pub lr: f32,
    pub batch: usize,
    /// worker threads for the parallel path (0 ⇒ hardware parallelism)
    pub threads: usize,
}

/// Partial participation: borrow the sampled clients (by population
/// index) out of the full client slice, preserving client-index order.
/// Out-of-range indices are ignored. The round layer composes this with
/// the scheduler's `clients_per_round` sampling and the channel model's
/// availability draws.
pub fn select_clients<'a>(
    clients: &'a mut [Client],
    sampled: &[usize],
) -> Vec<&'a mut Client> {
    let mut flags = vec![false; clients.len()];
    for &i in sampled {
        if i < flags.len() {
            flags[i] = true;
        }
    }
    clients
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| flags[*i])
        .map(|(_, c)| c)
        .collect()
}

/// Run the sampled clients serially. The pipeline is shared immutably
/// during the round; the coordinator adapts it *between* rounds.
pub fn run_round_serial<B: Backend + ?Sized>(
    backend: &B,
    clients: &mut [&mut Client],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
) -> Result<Vec<ClientUpdate>> {
    clients
        .iter_mut()
        .map(|c| {
            c.round(
                backend, params, plan.round, plan.local_iters, plan.lr,
                plan.batch, pipeline,
            )
        })
        .collect()
}

/// Run the sampled clients across a scoped thread pool. Falls back to the
/// serial path when the backend is not thread-safe or for tiny rounds.
pub fn run_round<B: Backend + Sync + ?Sized>(
    backend: &B,
    clients: &mut [&mut Client],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
) -> Result<Vec<ClientUpdate>>
where
    CompressionPipeline: Sync,
{
    let n = clients.len();
    let threads = if plan.threads == 0 {
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    } else {
        plan.threads
    };
    let threads = threads.min(n.max(1));
    if !backend.supports_parallel() || threads <= 1 || n <= 1 {
        return run_round_serial(backend, clients, params, plan, pipeline);
    }
    // Partition the &mut Client slice across scoped workers; order of the
    // returned updates matches the input order (stitched by partition).
    let per = n.div_ceil(threads);
    let mut results: Vec<Result<Vec<ClientUpdate>>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for chunk in clients.chunks_mut(per) {
            handles.push(scope.spawn(move || {
                chunk
                    .iter_mut()
                    .map(|c| {
                        c.round(
                            backend, params, plan.round, plan.local_iters,
                            plan.lr, plan.batch, pipeline,
                        )
                    })
                    .collect::<Result<Vec<_>>>()
            }));
        }
        for h in handles {
            results.push(h.join().expect("worker panicked"));
        }
    });
    let mut out = Vec::with_capacity(n);
    for r in results {
        out.extend(r?);
    }
    Ok(out)
}

/// Run a cohort through the streamed executor, serially: check each
/// client's durable state out of the store, materialize its shard, run
/// the round body with one shared scratch, spill the state back.
/// `cohort` holds population indices in ascending order (the same order
/// `select_clients` yields); updates come back in that order.
pub fn stream_round_serial<B: Backend + ?Sized>(
    backend: &B,
    source: &ShardSource<'_>,
    store: &mut ClientStore,
    cohort: &[usize],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
) -> Result<Vec<ClientUpdate>> {
    let mut scratch = RoundScratch::new();
    let mut out = Vec::with_capacity(cohort.len());
    for &idx in cohort {
        let mut state = store.checkout(idx);
        let shard = source.shard(idx);
        let r = run_client_round(
            backend, &shard, &mut state, &mut scratch, idx as u32, params,
            plan.round, plan.local_iters, plan.lr, plan.batch, pipeline,
        );
        // spill even when the round body errored: the stream position is
        // durable state regardless of what aborts the experiment next
        store.commit(idx, state);
        out.push(r?);
    }
    Ok(out)
}

/// Streamed cohort execution across a bounded worker pool.
///
/// The cohort is cut into `round_shards` contiguous chunks (`0` ⇒ auto:
/// 4 chunks per worker, so work-stealing smooths uneven local-step
/// costs); workers pull chunks via `parallel_map`, each with its own
/// [`RoundScratch`], materializing one shard at a time. The reduction is
/// ordered by construction — chunk `i`'s updates land before chunk
/// `i+1`'s — so the update sequence, and therefore aggregation order,
/// the bit ledger and survivor sets downstream, are byte-identical to
/// [`stream_round_serial`] and to the resident executor for any shard
/// count or thread count.
#[allow(clippy::too_many_arguments)]
pub fn stream_round<B: Backend + Sync + ?Sized>(
    backend: &B,
    source: &ShardSource<'_>,
    store: &mut ClientStore,
    cohort: &[usize],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
    round_shards: usize,
) -> Result<Vec<ClientUpdate>>
where
    CompressionPipeline: Sync,
{
    let n = cohort.len();
    let threads = if plan.threads == 0 {
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    } else {
        plan.threads
    };
    let threads = threads.min(n.max(1));
    if !backend.supports_parallel() || threads <= 1 || n <= 1 {
        return stream_round_serial(
            backend, source, store, cohort, params, plan, pipeline,
        );
    }

    let shards = if round_shards == 0 {
        (threads * 4).min(n)
    } else {
        round_shards.clamp(1, n)
    };
    let per = n.div_ceil(shards);

    // serial checkout in cohort order (the store is &mut; checkouts are
    // cheap map removals), then hand contiguous chunks to the pool
    let mut chunks: Vec<Mutex<Option<Vec<(usize, ClientState)>>>> =
        Vec::with_capacity(shards);
    let mut it = cohort.iter();
    loop {
        let chunk: Vec<(usize, ClientState)> = it
            .by_ref()
            .take(per)
            .map(|&idx| (idx, store.checkout(idx)))
            .collect();
        if chunk.is_empty() {
            break;
        }
        chunks.push(Mutex::new(Some(chunk)));
    }

    type ChunkOut = Result<Vec<(usize, ClientState, ClientUpdate)>>;
    let results: Vec<ChunkOut> = parallel_map(&chunks, threads, |_, slot| {
        let chunk =
            slot.lock().unwrap().take().expect("chunk consumed once");
        let mut scratch = RoundScratch::new();
        let mut done = Vec::with_capacity(chunk.len());
        for (idx, mut state) in chunk {
            let shard = source.shard(idx);
            let up = run_client_round(
                backend, &shard, &mut state, &mut scratch, idx as u32,
                params, plan.round, plan.local_iters, plan.lr, plan.batch,
                pipeline,
            )?;
            done.push((idx, state, up));
        }
        Ok(done)
    });

    // ordered reduction: chunks are contiguous cohort slices, so pushing
    // them back in chunk order restores exact cohort order
    let mut out = Vec::with_capacity(n);
    let mut first_err = None;
    for r in results {
        match r {
            Ok(batch) => {
                for (idx, state, up) in batch {
                    store.commit(idx, state);
                    out.push(up);
                }
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetConfig, FederatedDataset};
    use crate::fl::compression::{
        CompressionScheme, RateTarget, WireCoder,
    };
    use crate::model::native::NativeMlp;

    fn setup(nclients: usize) -> (NativeMlp, Vec<Client>, CompressionPipeline)
    {
        let mut cfg = DatasetConfig::tiny();
        cfg.num_clients = nclients;
        let ds = FederatedDataset::build(&cfg);
        let clients: Vec<Client> = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| Client::new(i as u32, s.clone(), 1000 + i as u64))
            .collect();
        let c = CompressionPipeline::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
            RateTarget::Off,
        )
        .unwrap();
        (NativeMlp::tiny(), clients, c)
    }

    #[test]
    fn parallel_matches_serial() {
        let (m, mut clients_a, c) = setup(8);
        let (_, mut clients_b, _) = setup(8);
        let params = crate::model::Backend::init_params(&m, 1);
        let plan = RoundPlan {
            round: 0,
            local_iters: 2,
            lr: 0.05,
            batch: 8,
            threads: 4,
        };
        let mut refs_a: Vec<&mut Client> = clients_a.iter_mut().collect();
        let mut refs_b: Vec<&mut Client> = clients_b.iter_mut().collect();
        let par = run_round(&m, &mut refs_a, &params, &plan, &c).unwrap();
        let ser =
            run_round_serial(&m, &mut refs_b, &params, &plan, &c).unwrap();
        assert_eq!(par.len(), ser.len());
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.packet.payload, b.packet.payload, "same seeds");
            assert_eq!(a.packet.client_id, b.packet.client_id);
        }
    }

    #[test]
    fn select_clients_preserves_index_order() {
        let (_, mut clients, _) = setup(5);
        let refs = select_clients(&mut clients, &[3, 0, 4]);
        let ids: Vec<u32> = refs.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 3, 4]);
        // out-of-range indices are ignored, duplicates collapse
        let refs = select_clients(&mut clients, &[1, 1, 99]);
        assert_eq!(refs.len(), 1);
        assert_eq!(refs[0].id, 1);
        assert!(select_clients(&mut clients, &[]).is_empty());
    }

    #[test]
    fn single_client_round() {
        let (m, mut clients, c) = setup(1);
        let params = crate::model::Backend::init_params(&m, 2);
        let plan = RoundPlan {
            round: 0,
            local_iters: 1,
            lr: 0.1,
            batch: 8,
            threads: 0,
        };
        let mut refs: Vec<&mut Client> = clients.iter_mut().collect();
        let ups = run_round(&m, &mut refs, &params, &plan, &c).unwrap();
        assert_eq!(ups.len(), 1);
    }

    /// The streamed executor must replay the resident executor exactly:
    /// same packets, same order, for any shard/thread count, across
    /// rounds where clients sit out (durable state spill/restore).
    #[test]
    fn streamed_matches_resident_across_rounds() {
        let seed = 4242u64;
        let mut cfg = DatasetConfig::tiny();
        cfg.num_clients = 8;
        let ds = FederatedDataset::build(&cfg);
        let mut resident: Vec<Client> = ds
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Client::new(i as u32, s.clone(), seed ^ ((i as u64) << 20))
            })
            .collect();
        let c = CompressionPipeline::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
            RateTarget::Off,
        )
        .unwrap();
        let m = NativeMlp::tiny();
        let params = crate::model::Backend::init_params(&m, 1);

        let source = ShardSource::Resident(&ds.shards);
        let mut store_par = ClientStore::new(seed);
        let mut store_ser = ClientStore::new(seed);
        // overlapping cohorts: clients 1 and 3 participate twice, so the
        // second round exercises state restore, not just fresh creation
        let cohorts: [&[usize]; 3] = [&[0, 1, 3, 5, 7], &[1, 2, 3], &[4]];
        for (round, cohort) in cohorts.iter().enumerate() {
            let plan = RoundPlan {
                round: round as u32,
                local_iters: 2,
                lr: 0.05,
                batch: 8,
                threads: 4,
            };
            let refs = select_clients(&mut resident, cohort);
            let mut refs: Vec<&mut Client> = refs;
            let want =
                run_round(&m, &mut refs, &params, &plan, &c).unwrap();
            let have = stream_round(
                &m, &source, &mut store_par, cohort, &params, &plan, &c, 3,
            )
            .unwrap();
            let have_ser = stream_round_serial(
                &m, &source, &mut store_ser, cohort, &params, &plan, &c,
            )
            .unwrap();
            assert_eq!(want.len(), have.len());
            for ((a, b), s) in want.iter().zip(&have).zip(&have_ser) {
                assert_eq!(a.packet.client_id, b.packet.client_id);
                assert_eq!(a.packet.payload, b.packet.payload);
                assert_eq!(a.mean_loss, b.mean_loss);
                assert_eq!(b.packet.payload, s.packet.payload);
            }
        }
        // only ever-selected clients hold spilled state
        assert_eq!(store_par.spilled(), 7); // all but client 6
    }

    /// Lazy shard materialization must not change results either.
    #[test]
    fn streamed_lazy_source_matches_resident_source() {
        let seed = 99u64;
        let mut cfg = DatasetConfig::tiny();
        cfg.num_clients = 6;
        let ds = FederatedDataset::build(&cfg);
        let gen = crate::data::synth::ShardGen::new(&cfg);
        let c = CompressionPipeline::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
            RateTarget::Off,
        )
        .unwrap();
        let m = NativeMlp::tiny();
        let params = crate::model::Backend::init_params(&m, 2);
        let plan = RoundPlan {
            round: 0,
            local_iters: 1,
            lr: 0.1,
            batch: 8,
            threads: 2,
        };
        let cohort = [0usize, 2, 5];
        let mut s1 = ClientStore::new(seed);
        let mut s2 = ClientStore::new(seed);
        let a = stream_round(
            &m, &ShardSource::Resident(&ds.shards), &mut s1, &cohort,
            &params, &plan, &c, 0,
        )
        .unwrap();
        let b = stream_round(
            &m, &ShardSource::Lazy(&gen), &mut s2, &cohort, &params, &plan,
            &c, 0,
        )
        .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.packet.payload, y.packet.payload);
        }
    }
}
