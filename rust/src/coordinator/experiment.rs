//! Experiment runner — one full federated training run per call.
//!
//! Wires together dataset, backend, compressor, clients, PS, network and
//! metrics; this is what the examples, the `rcfed` CLI and every figure
//! bench drive. Deterministic in `config.seed`.

use std::rc::Rc;

use crate::data::synth::ShardGen;
use crate::data::{DatasetConfig, DatasetKind, FederatedDataset};
use crate::fl::client::{Client, ClientUpdate};
use crate::fl::compression::{
    CompressionPipeline, CompressionScheme, DeltaCodec, Direction,
    RateAllocation, RateTarget, RoundAdaptation, TransformCfg, WireCoder,
};
use crate::fl::metrics::MetricsLog;
use crate::fl::packet::Packet;
use crate::fl::server::{LrSchedule, Server};
use crate::fl::store::{ClientStore, ShardSource};
use crate::model::native::NativeMlp;
use crate::model::pjrt::PjrtModel;
use crate::model::{Backend, ModelScratch};
use crate::coordinator::network::{
    ChannelSpec, ChannelStats, Delivery, SimulatedNetwork,
};
use crate::coordinator::scheduler::{
    run_round, run_round_serial, select_clients, stream_round,
    stream_round_serial, RoundPlan,
};
use crate::coordinator::sweep::{effective_threads, parallel_map};
use crate::util::mem::current_rss_kb;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::util::{Error, Result};

/// Re-export: the scheme enum doubles as the public experiment config.
pub use crate::fl::compression::CompressionScheme as SchemeConfig;

/// Which gradient engine computes client updates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// pure-rust MLP matched to the dataset (fast sweep path)
    Native,
    /// AOT JAX/Pallas graphs via PJRT (paper-faithful 3-layer path);
    /// the string names a model in `artifacts/manifest.json`
    Pjrt(String),
}

/// How a round's cohort is executed. Both modes are byte-identical in
/// every observable (aggregate, bit ledger, survivor sets, metrics) —
/// pinned by `tests/streaming_identity.rs` — so the choice is purely a
/// memory/throughput trade.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutionMode {
    /// Every client materialized for the whole run (`Vec<Client>`; the
    /// historical path). Memory O(population · shard).
    Resident,
    /// Cohorts stream through a bounded worker pool: shards materialize
    /// lazily per round, durable state spills to a keyed store between
    /// rounds. Memory O(active cohort) + O(ever-selected clients ·
    /// state). The default.
    Streamed,
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: DatasetConfig,
    pub backend: BackendChoice,
    pub scheme: CompressionScheme,
    pub wire: WireCoder,
    pub rounds: usize,
    /// clients sampled per round (0 ⇒ all clients)
    pub clients_per_round: usize,
    /// local iterations e
    pub local_iters: usize,
    pub batch: usize,
    pub lr: LrSchedule,
    pub seed: u64,
    /// evaluate every N rounds (and always on the final round)
    pub eval_every: usize,
    /// cap on test batches per evaluation (0 ⇒ full test set)
    pub eval_batches: usize,
    /// scheduler worker threads (0 ⇒ hardware)
    pub threads: usize,
    /// uplink channel model (loss, corruption, stragglers, availability);
    /// [`ChannelSpec::ideal`] reproduces the fault-free behavior exactly
    pub channel: ChannelSpec,
    /// closed-loop rate targeting ([`RateTarget::Off`] = the static
    /// §3.1 design, byte-identical to the pre-pipeline behavior)
    pub rate_target: RateTarget,
    /// per-client rate allocation under a global round budget
    /// ([`RateAllocation::Uniform`] = one shared codebook, byte-identical
    /// to the pre-allocator behavior)
    pub alloc: RateAllocation,
    /// transform stage ahead of quantization: identity (the default,
    /// byte-identical to the pre-codec behavior), error feedback and/or
    /// top-k sparsification
    pub transform: TransformCfg,
    /// server→client model-delta compression through the
    /// direction-agnostic [`DeltaCodec`] (`None` = the legacy uncharged
    /// fp32 broadcast, byte-identical to every pre-downlink run). Under
    /// a [`RateTarget::Joint`] budget this must be the rcfed scheme —
    /// the downlink dual-ascent loop drives its λ.
    pub down_scheme: Option<CompressionScheme>,
    /// round execution: streamed cohorts (default) or fully resident
    /// clients — byte-identical results either way
    pub mode: ExecutionMode,
    /// streamed mode: contiguous cohort chunks handed to the worker pool
    /// (0 ⇒ auto: 4 per worker). Any value yields identical results;
    /// this only tunes work-stealing granularity.
    pub round_shards: usize,
}

impl ExperimentConfig {
    /// The shared preset base: every field that is identical across the
    /// named presets lives here exactly once, so a new config axis
    /// cannot silently drift between them — presets override only what
    /// differs, via struct-update syntax.
    fn preset_base(dataset: DatasetConfig) -> ExperimentConfig {
        ExperimentConfig {
            dataset,
            backend: BackendChoice::Native,
            scheme: CompressionScheme::Lloyd { bits: 3 },
            wire: WireCoder::Huffman,
            rounds: 100,
            clients_per_round: 0,
            local_iters: 1,
            batch: 64,
            lr: LrSchedule::Const(0.02),
            seed: 42,
            eval_every: 5,
            eval_batches: 0,
            threads: 0,
            channel: ChannelSpec::ideal(),
            rate_target: RateTarget::Off,
            alloc: RateAllocation::Uniform,
            transform: TransformCfg::default(),
            down_scheme: None,
            mode: ExecutionMode::Streamed,
            round_shards: 0,
        }
    }

    /// Paper §5 CIFAR-10 protocol: K=10 clients, Dirichlet β=0.5,
    /// 100 rounds, e=1, batch 64. The paper uses η=0.01 with ResNet-18;
    /// our MLP substitute reaches the same mid-training accuracy band at
    /// η=0.02 (EXPERIMENTS.md §Substitutions).
    pub fn synth_cifar() -> ExperimentConfig {
        Self::preset_base(DatasetConfig::synth_cifar())
    }

    /// Paper §5 FEMNIST protocol: 3550 devices, 500 sampled per round,
    /// e=2, batch 32. Benches scale `num_clients`/`clients_per_round`
    /// down for CPU budgets (see EXPERIMENTS.md).
    pub fn synth_femnist() -> ExperimentConfig {
        ExperimentConfig {
            clients_per_round: 500,
            local_iters: 2,
            batch: 32,
            ..Self::preset_base(DatasetConfig::synth_femnist())
        }
    }

    /// Fast configuration for tests and the quickstart example.
    pub fn tiny() -> ExperimentConfig {
        ExperimentConfig {
            scheme: CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: crate::quant::rcq::LengthModel::Huffman,
            },
            rounds: 30,
            batch: 16,
            lr: LrSchedule::Const(0.05),
            ..Self::preset_base(DatasetConfig::tiny())
        }
    }

    /// Row-key label: the scheme label plus the transform suffix (empty
    /// for identity) — the ONE composition every report/CSV key uses, so
    /// per-round metric labels and sweep row keys cannot drift apart.
    /// The block wire tier adds a `_wblock` suffix; a compressed
    /// downlink adds `_down_<scheme>`; the historical configurations
    /// keep their pre-existing labels untouched.
    pub fn label(&self) -> String {
        let wire = match self.wire {
            WireCoder::Block => "_wblock",
            _ => "",
        };
        let down = match &self.down_scheme {
            Some(s) => format!("_down_{}", s.label()),
            None => String::new(),
        };
        format!(
            "{}{}{wire}{down}",
            self.scheme.label(),
            self.transform.suffix()
        )
    }

    fn native_backend(&self) -> NativeMlp {
        match self.dataset.kind {
            DatasetKind::SynthCifar => NativeMlp::synth_cifar(),
            DatasetKind::SynthFemnist => NativeMlp::synth_femnist(),
            DatasetKind::Tiny => NativeMlp::tiny(),
        }
    }
}

/// Everything a run produced.
#[derive(Debug)]
pub struct ExperimentReport {
    pub label: String,
    pub metrics: MetricsLog,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub num_params: usize,
    /// uplink bits (Fig. 1's x-axis)
    pub total_bits: u64,
    /// server→client codebook-broadcast bits (adaptive pipeline only;
    /// zero for static runs)
    pub downlink_bits: u64,
    pub wall_secs: f64,
    /// channel outcome counters (all-delivered under an ideal channel)
    pub channel: ChannelStats,
    /// final per-client width histogram `(width, clients)` from the rate
    /// allocator (empty for uniform-allocation runs)
    pub alloc_hist: Vec<(u32, usize)>,
    /// peak resident-set size observed across round boundaries, in KiB
    /// (0 where `/proc/self/status` is unavailable). The streamed path's
    /// flat-RSS claim is checked against this in CI.
    pub peak_rss_kb: u64,
}

impl ExperimentReport {
    pub fn uplink_gigabits(&self) -> f64 {
        self.total_bits as f64 / 1e9
    }

    /// Honest total: uplink plus the downlink codebook broadcasts the
    /// adaptive pipeline paid for its re-designs.
    pub fn total_comm_bits(&self) -> u64 {
        self.total_bits + self.downlink_bits
    }

    /// Measured uplink bits/coordinate of the last closed adaptation
    /// window (NaN for static runs or before the first window closed).
    pub fn realized_bpc(&self) -> f64 {
        self.metrics
            .rate_trace()
            .last()
            .map(|t| t.realized_bpc)
            .unwrap_or(f64::NAN)
    }

    /// Gini coefficient of the final per-client width allocation (NaN
    /// for uniform-allocation runs).
    pub fn alloc_gini(&self) -> f64 {
        self.metrics.final_alloc_gini()
    }

    /// Measured downlink bits/coordinate of the last round that
    /// delivered to a non-empty cohort (NaN when the broadcast is the
    /// legacy uncompressed path).
    pub fn down_bpc(&self) -> f64 {
        self.metrics
            .down_trace()
            .iter()
            .rev()
            .map(|t| t.down_bpc)
            .find(|b| !b.is_nan())
            .unwrap_or(f64::NAN)
    }
}

/// Evaluate accuracy over the test set (capped at `max_batches`).
fn evaluate<B: Backend + ?Sized>(
    backend: &B,
    params: &[f32],
    ds: &FederatedDataset,
    max_batches: usize,
) -> Result<f64> {
    let b = backend.batch_size();
    let mut correct = 0usize;
    let mut total = 0usize;
    // one workspace for the whole sweep over test batches (the native
    // backend's forward then allocates nothing per batch)
    let mut scratch = ModelScratch::new();
    for (i, (xs, ys)) in ds.test_batches(b).enumerate() {
        if max_batches > 0 && i >= max_batches {
            break;
        }
        correct += backend.eval_with(params, xs, ys, &mut scratch)?;
        total += ys.len();
    }
    if total == 0 {
        return Err(Error::Config(format!(
            "test set smaller than one batch ({b})")));
    }
    Ok(correct as f64 / total as f64)
}

/// Run a full experiment; the core entry point of the library.
///
/// In streamed mode (the default) the dataset is **never fully
/// materialized**: only the compact [`ShardGen`] recipe and the test set
/// exist up front, and each round materializes exactly its cohort's
/// shards. This is what makes million-client populations runnable.
pub fn run_experiment(config: &ExperimentConfig) -> Result<ExperimentReport> {
    match config.mode {
        ExecutionMode::Streamed => {
            let gen = ShardGen::new(&config.dataset);
            let eval_ds = gen.eval_dataset();
            let mut exec = Executor::Streamed {
                source: ShardSource::Lazy(&gen),
                store: ClientStore::new(config.seed),
                round_shards: config.round_shards,
            };
            run_with_executor(config, &eval_ds, &mut exec)
        }
        ExecutionMode::Resident => {
            let ds = FederatedDataset::build(&config.dataset);
            run_experiment_on(config, &ds)
        }
    }
}

/// Like [`run_experiment`], but reusing a prebuilt dataset. The sweep
/// engine builds each base's dataset once and shares it across that
/// base's cells, instead of rebuilding (and holding) one copy per
/// concurrently running cell. In streamed mode the cohort borrows shards
/// straight out of `ds` (no per-client clone — the historical resident
/// path copied every shard into its `Client`).
///
/// `ds` must have been built from exactly `config.dataset` (checked).
pub fn run_experiment_on(
    config: &ExperimentConfig,
    ds: &FederatedDataset,
) -> Result<ExperimentReport> {
    if ds.config != config.dataset {
        return Err(Error::Config(format!(
            "dataset mismatch: built from {:?}, config wants {:?}",
            ds.config, config.dataset
        )));
    }
    match config.mode {
        ExecutionMode::Streamed => {
            let mut exec = Executor::Streamed {
                source: ShardSource::Resident(&ds.shards),
                store: ClientStore::new(config.seed),
                round_shards: config.round_shards,
            };
            run_with_executor(config, ds, &mut exec)
        }
        ExecutionMode::Resident => {
            // clients (deterministic per-client seeds)
            let mut clients: Vec<Client> = ds
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    Client::new(
                        i as u32, s.clone(), config.seed ^ (i as u64) << 20)
                })
                .collect();
            let mut exec = Executor::Resident(&mut clients);
            run_with_executor(config, ds, &mut exec)
        }
    }
}

/// Shared tail of both entry points: validate, design the pipeline,
/// dispatch on backend, log the outcome. `ds` is used for evaluation
/// only in streamed mode (its `shards` may be empty).
fn run_with_executor(
    config: &ExperimentConfig,
    ds: &FederatedDataset,
    exec: &mut Executor<'_>,
) -> Result<ExperimentReport> {
    config.channel.validate()?;
    // a joint budget steers both directions: the downlink half needs a
    // delta codec whose λ the controller can move
    if config.rate_target.down_params().is_some() {
        match config.down_scheme {
            Some(CompressionScheme::RcFed { .. }) => {}
            Some(other) => {
                return Err(Error::Config(format!(
                    "a joint rate budget drives the downlink λ, which \
                     requires the rcfed down-scheme; got {other:?}"
                )));
            }
            None => {
                return Err(Error::Config(
                    "a joint rate budget needs a compressed downlink; \
                     set down_scheme (CLI: --down-scheme / --down-target)"
                        .into(),
                ));
            }
        }
    }
    let total_timer = Timer::start();
    let mut pipeline = CompressionPipeline::design_full(
        config.scheme, config.wire, config.rate_target, config.alloc,
        config.transform)?;
    // identity transforms suffix nothing, keeping every pre-codec label
    let label = config.label();
    let mut sampler = Rng::new(config.seed.wrapping_mul(0x2545F4914F6CDD1D));

    // backend + server. The native path fans clients out across a scoped
    // thread pool; the PJRT engine is single-threaded host-side (XLA
    // parallelizes internally), so it uses the serial runners.
    let report = match &config.backend {
        BackendChoice::Native => {
            let backend = config.native_backend();
            drive(config, ds, exec, &mut sampler, &mut pipeline, &backend,
                  run_round::<NativeMlp>, stream_round::<NativeMlp>)?
        }
        BackendChoice::Pjrt(model) => {
            let engine = Rc::new(crate::runtime::Engine::from_default_dir()?);
            let backend = PjrtModel::new(engine, model)?;
            if backend.batch_size() != config.batch {
                crate::warn!(
                    "pjrt model batch {} overrides configured batch {}",
                    backend.batch_size(), config.batch);
            }
            drive(config, ds, exec, &mut sampler, &mut pipeline, &backend,
                  run_round_serial::<PjrtModel>,
                  stream_round_serial_shim::<PjrtModel>)?
        }
    };
    if config.alloc.is_on() {
        crate::info!(
            "{label}: acc={:.4} uplink={:.4} Gb + downlink={:.6} Gb \
             (alloc {}, gini {:.3}) in {:.1}s",
            report.final_accuracy,
            report.uplink_gigabits(),
            report.downlink_bits as f64 / 1e9,
            config.alloc.label(),
            report.alloc_gini(),
            total_timer.secs()
        );
    } else if report.downlink_bits > 0 {
        crate::info!(
            "{label}: acc={:.4} uplink={:.4} Gb + downlink={:.6} Gb \
             (λ={:.4}, realized {:.3} b/coord) in {:.1}s",
            report.final_accuracy,
            report.uplink_gigabits(),
            report.downlink_bits as f64 / 1e9,
            pipeline.lambda(),
            report.realized_bpc(),
            total_timer.secs()
        );
    } else {
        crate::info!(
            "{label}: acc={:.4} uplink={:.4} Gb in {:.1}s",
            report.final_accuracy,
            report.uplink_gigabits(),
            total_timer.secs()
        );
    }
    Ok(report)
}

/// How [`drive`] obtains a round's updates: the resident `Vec<Client>`
/// (historical path) or the streamed store-backed cohort pipeline.
enum Executor<'a> {
    Resident(&'a mut Vec<Client>),
    Streamed {
        source: ShardSource<'a>,
        store: ClientStore,
        round_shards: usize,
    },
}

/// Last downlink model version client `idx` acknowledged — 0 (the
/// agreed zero model) for clients that have never participated. Both
/// executors answer from the same durable state the round loop spills.
fn client_model_version(exec: &Executor<'_>, idx: usize) -> u32 {
    match exec {
        Executor::Resident(clients) => clients[idx].model_version(),
        Executor::Streamed { store, .. } => store.model_version(idx),
    }
}

/// Record a downlink delivery (incremental delta or full resync) in
/// client `idx`'s durable state.
fn set_client_model_version(exec: &mut Executor<'_>, idx: usize, v: u32) {
    match exec {
        Executor::Resident(clients) => clients[idx].set_model_version(v),
        Executor::Streamed { store, .. } => store.set_model_version(idx, v),
    }
}

/// The signature of a resident round runner (`run_round` for thread-safe
/// backends, `run_round_serial` otherwise). Runners share the pipeline
/// immutably; adaptation happens between rounds in [`drive`].
type Runner<B> = fn(
    &B,
    &mut [&mut Client],
    &[f32],
    &RoundPlan,
    &CompressionPipeline,
) -> Result<Vec<ClientUpdate>>;

/// The streamed counterpart (`stream_round` for thread-safe backends,
/// [`stream_round_serial_shim`] otherwise).
type StreamRunner<B> = fn(
    &B,
    &ShardSource<'_>,
    &mut ClientStore,
    &[usize],
    &[f32],
    &RoundPlan,
    &CompressionPipeline,
    usize,
) -> Result<Vec<ClientUpdate>>;

/// Adapter giving `stream_round_serial` the [`StreamRunner`] shape (the
/// serial path has no use for a shard count).
#[allow(clippy::too_many_arguments)]
fn stream_round_serial_shim<B: Backend + ?Sized>(
    backend: &B,
    source: &ShardSource<'_>,
    store: &mut ClientStore,
    cohort: &[usize],
    params: &[f32],
    plan: &RoundPlan,
    pipeline: &CompressionPipeline,
    _round_shards: usize,
) -> Result<Vec<ClientUpdate>> {
    stream_round_serial(
        backend, source, store, cohort, params, plan, pipeline,
    )
}

/// One update's channel outcome after the serial delivery pass.
/// Classification stays serial because [`SimulatedNetwork::deliver`]
/// draws the channel RNG per packet — the parallel decode path is only
/// byte-identical to the serial one if the draw order matches.
enum Outcome<'a> {
    /// intact delivery: decode the original packet (a decode failure
    /// here is a run error, exactly as on the serial path)
    Intact(&'a ClientUpdate),
    /// corrupted but parseable: decode the re-parsed packet; failures
    /// are channel noise, not run errors
    Reparsed(&'a ClientUpdate, Packet),
    /// corrupted beyond parsing: decode-error bookkeeping only
    Unparseable(&'a ClientUpdate, Error),
}

/// Channel delivery + decode + accumulate for one round of updates.
/// Returns `(survivors, Σ mean_loss over survivors, Σ coords sent)`.
///
/// With `threads > 1` the per-packet decodes fan out across
/// [`parallel_map`] while everything order-sensitive stays serial:
/// the channel draws (phase 1), then an ordered replay of the decoded
/// packets into the accumulator (phase 3). Each worker runs the split
/// decode ([`CompressionPipeline::decode_body`]) — validation, entropy
/// decode, reconstruction table — and the replay performs the fused
/// gather-adds in delivery order, so the accumulator sees the exact
/// f32 additions of the serial path in the same order — byte-identical
/// by construction ([`Server::accumulate_decoded`] spells out the
/// argument; `tests/streaming_identity.rs` pins it). Peak extra memory
/// is `O(threads · d)` bytes for codebook schemes (symbols, not f32
/// reconstructions): decode batches advance chunk by chunk.
fn deliver_round(
    round: usize,
    updates: &[ClientUpdate],
    network: &mut SimulatedNetwork,
    server: &mut Server,
    pipeline: &mut CompressionPipeline,
    threads: usize,
) -> Result<(usize, f64, u64)> {
    let mut loss_acc = 0f64;
    let mut survivors = 0usize;
    let mut coords_sent = 0u64;
    // `threads == 0` means hardware parallelism, as everywhere else
    let workers = effective_threads(threads, updates.len());
    if workers <= 1 || updates.len() <= 1 {
        // serial reference path
        for up in updates {
            coords_sent += up.packet.d as u64;
            match network.deliver(&up.packet) {
                Delivery::Delivered { .. } => {
                    // intact delivery decodes, or the run is broken
                    server.receive(&*pipeline, &up.packet)?;
                    // the stats sample (and the allocator's per-client
                    // energy) ride with the packet, so only packets the
                    // server actually ingested steer either controller
                    pipeline.observe_delivery(&up.packet, &up.sample);
                    survivors += 1;
                    loss_acc += up.mean_loss as f64;
                }
                Delivery::Corrupted { bytes, .. } => {
                    // the real wire path: parse → decode; failures are
                    // channel noise, not run errors
                    match server.receive_bytes(&*pipeline, &bytes) {
                        Ok(()) => {
                            pipeline.observe_delivery(&up.packet, &up.sample);
                            survivors += 1;
                            loss_acc += up.mean_loss as f64;
                        }
                        Err(e) => {
                            network.note_decode_error();
                            crate::debug!(
                                "round {round}: client {} corrupt packet \
                                 rejected: {e}",
                                up.packet.client_id
                            );
                        }
                    }
                }
                Delivery::Lost => {
                    crate::debug!(
                        "round {round}: client {} packet lost",
                        up.packet.client_id
                    );
                }
                Delivery::Straggled { secs } => {
                    crate::debug!(
                        "round {round}: client {} straggled ({secs:.3}s \
                         deadline)",
                        up.packet.client_id
                    );
                }
            }
        }
        return Ok((survivors, loss_acc, coords_sent));
    }
    // phase 1 (serial): channel draws + wire parse, in delivery order
    let mut outcomes: Vec<Outcome<'_>> = Vec::with_capacity(updates.len());
    for up in updates {
        coords_sent += up.packet.d as u64;
        match network.deliver(&up.packet) {
            Delivery::Delivered { .. } => {
                outcomes.push(Outcome::Intact(up));
            }
            Delivery::Corrupted { bytes, .. } => match Packet::parse(&bytes) {
                Ok(pkt) => outcomes.push(Outcome::Reparsed(up, pkt)),
                Err(e) => outcomes.push(Outcome::Unparseable(up, e)),
            },
            Delivery::Lost => {
                crate::debug!(
                    "round {round}: client {} packet lost",
                    up.packet.client_id
                );
            }
            Delivery::Straggled { secs } => {
                crate::debug!(
                    "round {round}: client {} straggled ({secs:.3}s \
                     deadline)",
                    up.packet.client_id
                );
            }
        }
    }
    let d = server.dim();
    for chunk in outcomes.chunks(workers) {
        // phase 2 (parallel): split-decode this chunk's packets —
        // symbols + reconstruction table per packet, no accumulation
        let todo: Vec<&Packet> = chunk
            .iter()
            .filter_map(|o| match o {
                Outcome::Intact(up) => Some(&up.packet),
                Outcome::Reparsed(_, pkt) => Some(pkt),
                Outcome::Unparseable(..) => None,
            })
            .collect();
        let dec: &CompressionPipeline = pipeline;
        let mut decoded = parallel_map(&todo, workers, |_, pkt: &&Packet| {
            if pkt.d as usize != d {
                // mirror Server::receive's pre-decode dimension check
                return Err(Error::Coding(format!(
                    "packet d={} vs model d={}", pkt.d, d)));
            }
            dec.decode_body(pkt)
        })
        .into_iter();
        // phase 3 (serial): fused gather-add replay in delivery order
        for outcome in chunk {
            match outcome {
                Outcome::Intact(up) => {
                    let dp = decoded.next().expect("one result per packet")?;
                    server.accumulate_decoded(&dp)?;
                    pipeline.observe_delivery(&up.packet, &up.sample);
                    survivors += 1;
                    loss_acc += up.mean_loss as f64;
                }
                Outcome::Reparsed(up, _) => {
                    match decoded.next().expect("one result per packet") {
                        Ok(dp) => {
                            server.accumulate_decoded(&dp)?;
                            pipeline.observe_delivery(&up.packet, &up.sample);
                            survivors += 1;
                            loss_acc += up.mean_loss as f64;
                        }
                        Err(e) => {
                            network.note_decode_error();
                            crate::debug!(
                                "round {round}: client {} corrupt packet \
                                 rejected: {e}",
                                up.packet.client_id
                            );
                        }
                    }
                }
                Outcome::Unparseable(up, e) => {
                    network.note_decode_error();
                    crate::debug!(
                        "round {round}: client {} corrupt packet \
                         rejected: {e}",
                        up.packet.client_id
                    );
                }
            }
        }
    }
    Ok((survivors, loss_acc, coords_sent))
}

/// The round loop, generic over backend.
#[allow(clippy::too_many_arguments)]
fn drive<B: Backend>(
    config: &ExperimentConfig,
    ds: &FederatedDataset,
    exec: &mut Executor<'_>,
    sampler: &mut Rng,
    pipeline: &mut CompressionPipeline,
    backend: &B,
    runner: Runner<B>,
    stream_runner: StreamRunner<B>,
) -> Result<ExperimentReport> {
    let total_timer = Timer::start();
    let batch = if let BackendChoice::Pjrt(_) = config.backend {
        backend.batch_size()
    } else {
        config.batch
    };
    let d = backend.num_params();
    let mut server = Server::new(
        backend.init_params(config.seed ^ 0xA5A5_5A5A),
        config.lr,
    );
    // population size comes from the config, not from materialized
    // shards: the streamed path may never materialize any
    let k_all = config.dataset.num_clients;
    let mut network = SimulatedNetwork::with_spec(
        k_all,
        config.channel,
        config.seed ^ 0xC4A2_2E1B_9D5F_7733,
    );
    let mut metrics = MetricsLog::new();
    let mut peak_rss_kb = 0u64;
    // downlink delta codec: None keeps the legacy uncharged fp32
    // broadcast and draws nothing — byte-identical to pre-downlink runs
    let mut downlink = match config.down_scheme {
        Some(scheme) => Some(DeltaCodec::design_with_target(
            Direction::Downlink,
            scheme,
            config.wire,
            d,
            config.rate_target.down_params(),
        )?),
        None => None,
    };
    // the PS's private encode stream (only QSGD-like kernels would draw
    // from it; constructing it is free and draws nothing when unused)
    let mut down_rng = Rng::new(config.seed ^ 0x3C6E_F372_FE94_F82A);
    // bind the rate allocator (if any) to this population: the channel
    // model's per-client bandwidth factors seed the initial water-fill
    // (a free no-op under Alloc::Uniform)
    if pipeline.is_allocated() {
        let factors: Vec<f64> =
            (0..k_all).map(|c| network.client_bandwidth_factor(c)).collect();
        pipeline.bind_clients(k_all, &factors)?;
    }
    let k_round = if config.clients_per_round == 0 {
        k_all
    } else {
        config.clients_per_round.min(k_all)
    };

    for round in 0..config.rounds {
        let round_timer = Timer::start();
        network.begin_round();
        server.begin_round();
        let plan = RoundPlan {
            round: round as u32,
            local_iters: config.local_iters,
            lr: server.lr(),
            batch,
            threads: config.threads,
        };
        // client sampling (§5: "K devices are randomly sampled"), then
        // the availability model drops sampled-but-offline clients
        // before any local compute is spent on them (participates() is
        // always true — and draws nothing — at availability 1)
        let mut sampled = sampler.sample_indices(k_all, k_round);
        sampled.retain(|_| network.participates());
        // the effective cohort both executors run: ascending population
        // index, duplicates collapsed, out-of-range dropped (exactly
        // what `select_clients` yields from `sampled`)
        let mut cohort = sampled.clone();
        cohort.retain(|&i| i < k_all);
        cohort.sort_unstable();
        cohort.dedup();
        // downlink: with a delta codec, the server encodes θ_t − θ_{t−1}
        // through the same Transform → Kernel → WireCoder stages as the
        // uplink, charges the measured bits, and the cohort *dequantizes
        // the broadcast* — clients train on θ̂_v, never on raw θ. A
        // client whose acknowledged version lags (sampled after sitting
        // out version bumps) cannot apply the incremental delta: it gets
        // one fp32 resync unicast of θ̂_v instead.
        let (params_snapshot, down_round_bits) = match &mut downlink {
            None => (server.params.clone(), 0u64),
            Some(dc) => {
                let pkt = dc.encode_round(
                    &server.params, round as u32, &mut down_rng)?;
                let new_ver = dc.version();
                let mut incremental = 0usize;
                let mut charged = 0u64;
                for &idx in &cohort {
                    if client_model_version(exec, idx) + 1 == new_ver {
                        incremental += 1;
                    } else {
                        network.unicast(idx, dc.resync_bits());
                        charged += dc.resync_bits();
                    }
                    set_client_model_version(exec, idx, new_ver);
                }
                network.broadcast(pkt.total_bits(), incremental);
                charged += pkt.total_bits() * incremental as u64;
                dc.observe_round(charged, (d * cohort.len()) as u64);
                let snap = dc.decode_current(&pkt)?.to_vec();
                (snap, charged)
            }
        };
        let updates = match exec {
            Executor::Resident(clients) => {
                let mut selected = select_clients(clients, &sampled);
                runner(backend, &mut selected, &params_snapshot, &plan,
                       &*pipeline)?
            }
            Executor::Streamed { source, store, round_shards } => {
                stream_runner(
                    backend, source, store, &cohort, &params_snapshot,
                    &plan, &*pipeline, *round_shards,
                )?
            }
        };
        // uplink: every update goes through the channel; only survivors
        // reach the aggregate, which the server averages over `received`
        // so it stays unbiased over whoever made it through
        let (survivors, loss_acc, coords_sent) = deliver_round(
            round, &updates, &mut network, &mut server, pipeline,
            config.threads,
        )?;
        if survivors > 0 {
            server.step()?;
        } else {
            // the channel wiped the round out: θ holds, schedule advances
            server.skip_round();
        }
        // adaptation between rounds: feed the controller the ledger's
        // measured bits; at window ends the Track loop moves λ and
        // re-designs (one codebook broadcast to every client — any of
        // them may be sampled next round), while the rate allocator
        // re-solves the per-client widths (each *changed* client is
        // unicast its new codebook). Stale versions are rejected on
        // decode; every publication is charged to the downlink ledger.
        pipeline.observe_round(network.bits_this_round(), coords_sent);
        match pipeline.end_round(round)? {
            RoundAdaptation::None => {}
            RoundAdaptation::Broadcast { bits_per_client } => {
                network.broadcast(bits_per_client, k_all);
                crate::debug!(
                    "round {round}: codebook v{} published (λ={:.4}, \
                     realized {:.3} b/coord)",
                    pipeline.version(),
                    pipeline.lambda(),
                    pipeline.last_realized()
                );
            }
            RoundAdaptation::PerClient { publications } => {
                let moved = publications.len();
                for (client, bits) in publications {
                    network.unicast(client as usize, bits);
                }
                crate::debug!(
                    "round {round}: allocation re-solved, {moved} clients \
                     moved width"
                );
            }
        }
        // the downlink half of a joint budget closes its window on the
        // same boundary: dual ascent on the downlink λ, then the
        // re-designed delta codebook goes to every client (any of them
        // may be sampled next round and must keep decoding)
        if let Some(dc) = &mut downlink {
            if let Some(bits) = dc.end_round(round)? {
                network.broadcast(bits, k_all);
                crate::debug!(
                    "round {round}: downlink delta codebook re-designed \
                     (λ={:.4}, realized {:.3} b/coord)",
                    dc.lambda(),
                    dc.last_realized()
                );
            }
        }
        let train_loss = if survivors > 0 {
            (loss_acc / survivors as f64) as f32
        } else {
            f32::NAN
        };

        let is_eval = config.eval_every > 0
            && (round % config.eval_every == config.eval_every - 1
                || round + 1 == config.rounds);
        let acc = if is_eval {
            evaluate(backend, &server.params, ds, config.eval_batches)?
        } else {
            f64::NAN
        };
        metrics.push(
            round,
            train_loss,
            acc,
            network.bits_this_round(),
            round_timer.secs(),
        );
        // in-memory stream trace (never written to the CSV): cohort
        // size, survivors and the RSS sample behind the flat-memory
        // claim. Identical across execution modes by construction —
        // except rss_kb, which is measurement, not simulation state.
        let rss_kb = current_rss_kb();
        peak_rss_kb = peak_rss_kb.max(rss_kb);
        metrics.push_stream(updates.len(), survivors, rss_kb);
        if pipeline.is_adaptive() {
            metrics.push_rate(
                pipeline.lambda(),
                pipeline.last_realized(),
                network.downlink_bits_this_round(),
            );
        }
        if let Some(snap) = pipeline.alloc_snapshot() {
            metrics.push_alloc(
                snap.gini,
                snap.mean_bits,
                network.downlink_bits_this_round(),
            );
        }
        if config.transform.is_active() {
            // mean over this round's *computed* updates (EF banks its
            // residual client-side whether or not the packet survived,
            // so the trace reflects every compress, not just survivors)
            let (mut ef, mut sp) = (0f64, 0f64);
            let (mut n_ef, mut n_sp) = (0usize, 0usize);
            for up in &updates {
                if up.ef_norm.is_finite() {
                    ef += up.ef_norm;
                    n_ef += 1;
                }
                if up.sparsity.is_finite() {
                    sp += up.sparsity;
                    n_sp += 1;
                }
            }
            metrics.push_transform(
                if n_ef > 0 { ef / n_ef as f64 } else { f64::NAN },
                if n_sp > 0 { sp / n_sp as f64 } else { f64::NAN },
            );
        }
        if let Some(dc) = &downlink {
            // charged delta/resync bits per delivered coordinate; the
            // per-window codebook republish rides on `bits_down` in the
            // rate trace, not here
            let bpc = if cohort.is_empty() {
                f64::NAN
            } else {
                down_round_bits as f64 / (d * cohort.len()) as f64
            };
            metrics.push_down(bpc, dc.last_ef_norm());
        }
        // keep the downlink round buckets index-aligned with the uplink
        // rounds even when this round charged no downlink bits
        network.end_round();
        if is_eval {
            crate::debug!(
                "round {round}: loss={train_loss:.4} acc={acc:.4} \
                 cum={:.4} Gb",
                network.total_gigabits()
            );
        }
    }
    Ok(ExperimentReport {
        label: config.label(),
        final_accuracy: metrics.final_accuracy(),
        best_accuracy: metrics.best_accuracy(),
        num_params: d,
        total_bits: metrics.total_bits(),
        downlink_bits: network.downlink_bits(),
        wall_secs: total_timer.secs(),
        channel: network.stats,
        alloc_hist: pipeline.alloc_histogram(),
        peak_rss_kb,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rcq::LengthModel;

    #[test]
    fn tiny_experiment_learns() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 40;
        let report = run_experiment(&cfg).unwrap();
        assert!(report.final_accuracy > 0.5,
                "acc={}", report.final_accuracy);
        assert!(report.total_bits > 0);
        assert_eq!(report.metrics.rounds.len(), 40);
        // loss should drop
        let first = report.metrics.rounds[0].train_loss;
        let last = report.metrics.rounds.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig::tiny();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
    }

    #[test]
    fn rcfed_uses_fewer_bits_than_lloyd_same_accuracy_class() {
        let mut base = ExperimentConfig::tiny();
        base.rounds = 25;
        let mut rc = base.clone();
        rc.scheme = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.1,
            length_model: LengthModel::Huffman,
        };
        let mut ll = base.clone();
        ll.scheme = CompressionScheme::Lloyd { bits: 3 };
        let rep_rc = run_experiment(&rc).unwrap();
        let rep_ll = run_experiment(&ll).unwrap();
        assert!(
            rep_rc.total_bits < rep_ll.total_bits,
            "rcfed {} vs lloyd {}",
            rep_rc.total_bits,
            rep_ll.total_bits
        );
        // λ=0.1 costs little accuracy on this easy task
        assert!(rep_rc.final_accuracy > rep_ll.final_accuracy - 0.15);
    }

    #[test]
    fn client_sampling_reduces_round_bits() {
        let mut all = ExperimentConfig::tiny();
        all.rounds = 4;
        all.dataset.num_clients = 8;
        let mut half = all.clone();
        half.clients_per_round = 4;
        let rep_all = run_experiment(&all).unwrap();
        let rep_half = run_experiment(&half).unwrap();
        assert!(
            (rep_half.total_bits as f64) < 0.6 * rep_all.total_bits as f64
        );
    }

    #[test]
    fn ideal_channel_is_the_default_and_faultless() {
        let cfg = ExperimentConfig::tiny();
        assert_eq!(cfg.channel, crate::coordinator::network::ChannelSpec::ideal());
        let rep = run_experiment(&cfg).unwrap();
        assert_eq!(rep.channel.faults(), 0);
        assert!(rep.channel.delivered > 0);
    }

    #[test]
    fn lossy_run_replays_bit_exactly_from_seed() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 12;
        cfg.channel = crate::coordinator::network::ChannelSpec {
            loss: 0.25,
            ..crate::coordinator::network::ChannelSpec::ideal()
        };
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert!(a.channel.lost > 0, "loss 0.25 never fired: {:?}", a.channel);
        assert_eq!(a.channel, b.channel, "survivor set must replay");
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        // identical per-round bit + loss trajectory
        for (ra, rb) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
            assert_eq!(ra.bits_up, rb.bits_up);
            assert!(
                ra.train_loss == rb.train_loss
                    || (ra.train_loss.is_nan() && rb.train_loss.is_nan())
            );
        }
        // lost packets still pay for their uplink bits. Under fp32 the
        // packet size is data-independent, so the lossy ledger charges
        // exactly the fault-free total (entropy-coded schemes diverge in
        // trajectory, hence in payload sizes — only fp32 is comparable)
        let mut fp = cfg.clone();
        fp.scheme = CompressionScheme::Fp32;
        let lossy_fp = run_experiment(&fp).unwrap();
        assert!(lossy_fp.channel.lost > 0);
        let mut fp_ideal = fp.clone();
        fp_ideal.channel = crate::coordinator::network::ChannelSpec::ideal();
        let ideal_fp = run_experiment(&fp_ideal).unwrap();
        assert_eq!(lossy_fp.total_bits, ideal_fp.total_bits);
    }

    #[test]
    fn total_loss_blacks_out_rounds_without_erroring() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 5;
        cfg.eval_every = 0;
        cfg.channel = crate::coordinator::network::ChannelSpec::lossy(1.0);
        let rep = run_experiment(&cfg).unwrap();
        assert_eq!(rep.channel.delivered, 0);
        assert_eq!(rep.metrics.rounds.len(), 5);
        assert!(rep.total_bits > 0, "lost packets still pay bits");
        assert!(
            rep.metrics.rounds.iter().all(|r| r.train_loss.is_nan()),
            "no survivors ⇒ no train loss"
        );
    }

    #[test]
    fn straggler_deadline_cuts_uplink_bits() {
        let mut base = ExperimentConfig::tiny();
        base.rounds = 4;
        base.eval_every = 0;
        let ideal = run_experiment(&base).unwrap();
        // a deadline far below any transmit time drops everyone early
        let mut tight = base.clone();
        tight.channel = crate::coordinator::network::ChannelSpec {
            uplink_bps: 1e6,
            deadline_s: 1e-4,
            ..crate::coordinator::network::ChannelSpec::ideal()
        };
        let cut = run_experiment(&tight).unwrap();
        assert!(cut.channel.straggled > 0);
        assert!(
            cut.total_bits < ideal.total_bits,
            "stragglers must pay only partial bits: {} vs {}",
            cut.total_bits,
            ideal.total_bits
        );
    }

    #[test]
    fn partial_availability_reduces_participation() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 10;
        cfg.eval_every = 0;
        cfg.channel = crate::coordinator::network::ChannelSpec {
            availability: 0.5,
            ..crate::coordinator::network::ChannelSpec::ideal()
        };
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.channel.unavailable > 0);
        let full = {
            let mut c = cfg.clone();
            c.channel = crate::coordinator::network::ChannelSpec::ideal();
            run_experiment(&c).unwrap()
        };
        assert!(rep.total_bits < full.total_bits);
    }

    #[test]
    fn rate_target_off_is_default_and_draws_no_downlink() {
        let cfg = ExperimentConfig::tiny();
        assert_eq!(cfg.rate_target, RateTarget::Off);
        let rep = run_experiment(&cfg).unwrap();
        assert_eq!(rep.downlink_bits, 0);
        assert_eq!(rep.total_comm_bits(), rep.total_bits);
        assert!(rep.realized_bpc().is_nan());
        assert!(rep.metrics.rate_trace().is_empty());
    }

    #[test]
    fn adaptive_run_is_deterministic_and_pays_downlink() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 12;
        cfg.rate_target =
            RateTarget::Track { bits_per_coord: 2.2, adapt_every: 3 };
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        // deterministic replay, adaptation and all
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.downlink_bits, b.downlink_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        // 12 rounds / window 3 ⇒ 4 windows, each republishing once
        assert!(a.downlink_bits > 0, "no codebook broadcast charged");
        assert!(a.total_comm_bits() > a.total_bits);
        assert_eq!(a.metrics.rate_trace().len(), 12);
        assert_eq!(a.metrics.total_downlink_bits(), a.downlink_bits);
        assert!(a.realized_bpc().is_finite());
    }

    #[test]
    fn uniform_allocation_is_default_and_identical() {
        let cfg = ExperimentConfig::tiny();
        assert_eq!(cfg.alloc, RateAllocation::Uniform);
        let a = run_experiment(&cfg).unwrap();
        let mut explicit = cfg.clone();
        explicit.alloc = RateAllocation::Uniform;
        let b = run_experiment(&explicit).unwrap();
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        assert_eq!(a.downlink_bits, 0);
        assert!(a.alloc_hist.is_empty());
        assert!(a.alloc_gini().is_nan());
        assert!(a.metrics.alloc_trace().is_empty());
    }

    #[test]
    fn waterfill_run_is_deterministic_and_pays_per_client_downlink() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 12;
        cfg.eval_every = 4;
        cfg.channel = crate::coordinator::network::ChannelSpec {
            uplink_bps: 1e6,
            bandwidth_spread: 0.5,
            ..crate::coordinator::network::ChannelSpec::ideal()
        };
        cfg.alloc = RateAllocation::WaterFill {
            budget_bpc: 2.5,
            adapt_every: 3,
            min_bits: 1,
            max_bits: 6,
        };
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.downlink_bits, b.downlink_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        // one alloc-trace row per round, and the final histogram covers
        // every client
        assert_eq!(a.metrics.alloc_trace().len(), 12);
        let clients: usize = a.alloc_hist.iter().map(|&(_, n)| n).sum();
        assert_eq!(clients, cfg.dataset.num_clients);
        // the heterogeneous channel skews the very first assignment, so
        // the width spread shows up in the Gini column
        assert!(a.alloc_gini() >= 0.0, "gini {}", a.alloc_gini());
        assert_eq!(a.metrics.total_downlink_bits(), a.downlink_bits);
        assert_eq!(a.total_comm_bits(), a.total_bits + a.downlink_bits);
        // allocation without a rate target records no λ trace
        assert!(a.metrics.rate_trace().is_empty());
    }

    #[test]
    fn waterfill_on_qsgd_or_with_rate_target_is_rejected() {
        let wf = RateAllocation::WaterFill {
            budget_bpc: 2.5,
            adapt_every: 2,
            min_bits: 1,
            max_bits: 6,
        };
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheme = CompressionScheme::Qsgd { bits: 3 };
        cfg.alloc = wf;
        assert!(run_experiment(&cfg).is_err());
        let mut both = ExperimentConfig::tiny();
        both.rate_target =
            RateTarget::Track { bits_per_coord: 2.0, adapt_every: 2 };
        both.alloc = wf;
        assert!(run_experiment(&both).is_err());
    }

    #[test]
    fn rate_target_on_non_rcfed_scheme_is_rejected() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheme = CompressionScheme::Lloyd { bits: 3 };
        cfg.rate_target =
            RateTarget::Track { bits_per_coord: 2.0, adapt_every: 2 };
        assert!(run_experiment(&cfg).is_err());
    }

    #[test]
    fn legacy_broadcast_is_default_and_records_no_down_trace() {
        let cfg = ExperimentConfig::tiny();
        assert!(cfg.down_scheme.is_none());
        assert_eq!(cfg.label(), cfg.scheme.label(), "label must not move");
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.metrics.down_trace().is_empty());
        assert!(rep.down_bpc().is_nan());
        assert_eq!(rep.downlink_bits, 0);
    }

    #[test]
    fn compressed_downlink_charges_the_ledger_and_still_learns() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 30;
        cfg.down_scheme = Some(cfg.scheme);
        assert!(cfg.label().contains("_down_"));
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.total_bits, b.total_bits, "deterministic replay");
        assert_eq!(a.downlink_bits, b.downlink_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        assert!(a.downlink_bits > 0, "delta broadcasts must be charged");
        assert_eq!(a.metrics.down_trace().len(), 30);
        assert!(a.down_bpc().is_finite() && a.down_bpc() > 0.0);
        assert!(a.total_comm_bits() > a.total_bits);
        // lossy broadcasts cost some accuracy on tiny, but the run must
        // still train (EF keeps the replica error bounded)
        assert!(a.final_accuracy > 0.5, "acc={}", a.final_accuracy);
    }

    #[test]
    fn joint_budget_requires_a_compressed_rcfed_downlink() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rate_target = RateTarget::Joint {
            total_bpc: 4.0,
            split: 0.625,
            adapt_every: 3,
        };
        assert!(run_experiment(&cfg).is_err(), "no down scheme");
        cfg.down_scheme = Some(CompressionScheme::Fp32);
        assert!(run_experiment(&cfg).is_err(), "non-rcfed down scheme");
    }

    #[test]
    fn joint_budget_runs_both_controllers_deterministically() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 12;
        cfg.eval_every = 6;
        cfg.rate_target = RateTarget::Joint {
            total_bpc: 4.0,
            split: 0.625,
            adapt_every: 3,
        };
        cfg.down_scheme = Some(cfg.scheme);
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.downlink_bits, b.downlink_bits);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        // both traces recorded every round
        assert_eq!(a.metrics.rate_trace().len(), 12);
        assert_eq!(a.metrics.down_trace().len(), 12);
        assert!(a.realized_bpc().is_finite(), "uplink window closed");
        assert!(a.down_bpc().is_finite(), "downlink delivered");
        assert!(a.downlink_bits > 0);
    }

    #[test]
    fn streamed_is_default_and_matches_resident() {
        let cfg = ExperimentConfig::tiny();
        assert_eq!(cfg.mode, ExecutionMode::Streamed);
        let streamed = run_experiment(&cfg).unwrap();
        let mut res = cfg.clone();
        res.mode = ExecutionMode::Resident;
        let resident = run_experiment(&res).unwrap();
        assert_eq!(streamed.total_bits, resident.total_bits);
        assert_eq!(streamed.final_accuracy, resident.final_accuracy);
        assert_eq!(streamed.channel, resident.channel);
    }

    #[test]
    fn population_larger_than_cohort_streams_with_bounded_state() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.dataset.num_clients = 512;
        cfg.clients_per_round = 16;
        cfg.rounds = 3;
        cfg.eval_every = 0;
        let rep = run_experiment(&cfg).unwrap();
        assert_eq!(rep.metrics.rounds.len(), 3);
        assert!(rep.total_bits > 0);
        let st = rep.metrics.stream_trace();
        assert_eq!(st.len(), 3);
        assert!(st.iter().all(|r| r.cohort == 16), "{st:?}");
        assert!(st.iter().all(|r| r.survivors == 16));
    }

    #[test]
    fn round_shards_do_not_change_results() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 6;
        let base = run_experiment(&cfg).unwrap();
        for shards in [1usize, 2, 7] {
            let mut c = cfg.clone();
            c.round_shards = shards;
            let rep = run_experiment(&c).unwrap();
            assert_eq!(rep.total_bits, base.total_bits, "shards={shards}");
            assert_eq!(rep.final_accuracy, base.final_accuracy);
        }
    }

    #[test]
    fn block_wire_reproduces_huffman_trajectory_within_table_overhead() {
        // the block wire changes payload *bytes* but decodes to the same
        // symbols, so under an ideal channel the model trajectory — and
        // the final accuracy — must match the Huffman wire exactly; only
        // the ledger moves, and only by bounded per-block table refreshes
        let mut h = ExperimentConfig::tiny();
        h.rounds = 6;
        h.eval_every = 3;
        let mut b = h.clone();
        b.wire = WireCoder::Block;
        assert!(b.label().ends_with("_wblock"));
        assert_eq!(h.label(), h.scheme.label(), "huffman label must not move");
        let rh = run_experiment(&h).unwrap();
        let rb = run_experiment(&b).unwrap();
        assert_eq!(rb.final_accuracy, rh.final_accuracy);
        let (lo, hi) =
            (0.9 * rh.total_bits as f64, 1.1 * rh.total_bits as f64);
        let got = rb.total_bits as f64;
        assert!(lo <= got && got <= hi,
                "block bits {got} outside [{lo}, {hi}]");
    }

    #[test]
    fn fp32_baseline_runs() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 10;
        cfg.scheme = CompressionScheme::Fp32;
        let rep = run_experiment(&cfg).unwrap();
        // ~32 bits/coordinate/client/round
        let d = rep.num_params as u64;
        let clients = 4;
        let lower = 32 * d * clients * 10;
        assert!(rep.total_bits >= lower, "{} vs {lower}", rep.total_bits);
    }
}
