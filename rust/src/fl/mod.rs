//! Federated-learning core: the staged client-side codec ([`codec`]:
//! Transform → Quantize → Code, with the closed-loop pipeline and the
//! per-client rate allocator on top), the wire format with exact bit
//! accounting ([`packet`]), client local training ([`client`]), the
//! parameter server ([`server`]) and per-round metrics ([`metrics`]).
//!
//! This module implements Algorithm 1 of the paper end-to-end:
//! transform → normalize → quantize (Q*) → entropy-encode → transmit →
//! decode → de-normalize → aggregate → SGD step.

pub mod client;
pub mod codec;
pub mod metrics;
pub mod packet;
pub mod server;
pub mod store;

/// Back-compat shim: the staged [`codec`] subsystem replaced the old
/// `fl/compression.rs` god-module. Every pre-existing import path
/// (`rcfed::fl::compression::…`) keeps compiling through these
/// re-exports; new code should prefer `rcfed::fl::codec`.
pub mod compression {
    pub use super::codec::*;
}
