//! Federated-learning core: the client-side compression pipeline
//! ([`compression`]), the wire format with exact bit accounting
//! ([`packet`]), client local training ([`client`]), the parameter
//! server ([`server`]) and per-round metrics ([`metrics`]).
//!
//! This module implements Algorithm 1 of the paper end-to-end:
//! normalize → quantize (Q*) → entropy-encode → transmit → decode →
//! de-normalize → aggregate → SGD step.

pub mod client;
pub mod compression;
pub mod metrics;
pub mod packet;
pub mod server;
