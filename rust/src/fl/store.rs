//! Keyed spill store for durable per-client state, and the shard source
//! abstraction behind the streamed round loop.
//!
//! The resident path owns every client for the whole experiment:
//! `Vec<Client>` holds each client's shard, RNG stream, EF residual and
//! scratch buffers — O(population) memory whether or not a client is ever
//! sampled. At paper scale ("heavy traffic from millions of users") that
//! is the binding constraint, not compute.
//!
//! The streamed path splits a client into its three parts (see
//! [`crate::fl::client`]):
//!
//! * **shard** — re-materialized per round from a [`ShardSource`]
//!   (borrowed from a resident dataset, or generated on demand by a
//!   [`ShardGen`] recipe);
//! * **durable state** — spilled into this [`ClientStore`] between
//!   rounds, keyed by client id, so only clients that have *ever
//!   participated* occupy memory (a fresh checkout derives the exact
//!   seed the resident constructor would have used — byte-identity does
//!   not depend on which path created the state);
//! * **scratch** — owned by the round executor's workers, never stored.

use std::borrow::Cow;
use std::collections::HashMap;

use crate::data::synth::ShardGen;
use crate::data::Shard;
use crate::fl::client::ClientState;

/// Where a round gets its cohort's shards from.
pub enum ShardSource<'a> {
    /// Borrow from an already-materialized dataset (sweep cells share one
    /// `FederatedDataset` read-only; streaming over it avoids the
    /// historical per-client `Shard` clone).
    Resident(&'a [Shard]),
    /// Generate on demand from the compact recipe — nothing but the
    /// active cohort's shards ever exists in memory.
    Lazy(&'a ShardGen),
}

impl<'a> ShardSource<'a> {
    pub fn num_clients(&self) -> usize {
        match self {
            ShardSource::Resident(shards) => shards.len(),
            ShardSource::Lazy(gen) => gen.num_clients(),
        }
    }

    /// The shard for population index `i` — borrowed when resident,
    /// freshly materialized when lazy. `&self`: workers call this
    /// concurrently.
    pub fn shard(&self, i: usize) -> Cow<'a, Shard> {
        match self {
            ShardSource::Resident(shards) => Cow::Borrowed(&shards[i]),
            ShardSource::Lazy(gen) => Cow::Owned(gen.shard(i)),
        }
    }
}

/// Compact keyed store for durable per-client state (RNG stream + codec
/// transform state). Memory is O(clients ever selected), not
/// O(population): a client that never participates costs nothing.
pub struct ClientStore {
    /// experiment seed; per-client streams derive from it exactly as the
    /// resident constructor does: `Client::new(i, _, seed ^ (i << 20))`
    seed: u64,
    durable: HashMap<u32, ClientState>,
}

impl ClientStore {
    pub fn new(seed: u64) -> ClientStore {
        ClientStore { seed, durable: HashMap::new() }
    }

    /// Take client `idx`'s durable state out of the store, creating it
    /// on first participation with the canonical seed derivation.
    pub fn checkout(&mut self, idx: usize) -> ClientState {
        let id = idx as u32;
        self.durable.remove(&id).unwrap_or_else(|| {
            ClientState::new(id, self.seed ^ ((idx as u64) << 20))
        })
    }

    /// Return client `idx`'s state after a round (advanced RNG, updated
    /// EF residual) so its next participation resumes the exact stream.
    pub fn commit(&mut self, idx: usize, state: ClientState) {
        self.durable.insert(idx as u32, state);
    }

    /// Number of clients currently holding spilled state.
    pub fn spilled(&self) -> usize {
        self.durable.len()
    }

    /// Read-only view of a client's spilled state (diagnostics/tests).
    pub fn peek(&self, idx: usize) -> Option<&ClientState> {
        self.durable.get(&(idx as u32))
    }

    /// Last downlink model version client `idx` acknowledged (0 for
    /// clients that have never participated — the agreed zero model).
    pub fn model_version(&self, idx: usize) -> u32 {
        self.durable
            .get(&(idx as u32))
            .map_or(0, |s| s.model_version)
    }

    /// Record a downlink delivery for client `idx`, materializing its
    /// durable state (with the canonical seed derivation) on first
    /// contact so the version survives until its next participation.
    pub fn set_model_version(&mut self, idx: usize, version: u32) {
        let mut state = self.checkout(idx);
        state.model_version = version;
        self.commit(idx, state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetConfig;

    #[test]
    fn fresh_checkout_matches_resident_seed_derivation() {
        let seed = 42u64;
        let mut store = ClientStore::new(seed);
        for idx in [0usize, 3, 17] {
            let mut state = store.checkout(idx);
            let mut resident =
                ClientState::new(idx as u32, seed ^ ((idx as u64) << 20));
            for _ in 0..16 {
                assert_eq!(
                    state.rng.next_u64(),
                    resident.rng.next_u64(),
                    "client {idx} stream diverged"
                );
            }
        }
    }

    #[test]
    fn commit_checkout_roundtrip_preserves_the_stream() {
        let mut store = ClientStore::new(7);
        let mut a = store.checkout(5);
        // advance the stream mid-experiment, then spill
        let drawn: Vec<u64> = (0..4).map(|_| a.rng.next_u64()).collect();
        let mut reference = a.rng.clone();
        store.commit(5, a);
        assert_eq!(store.spilled(), 1);
        let mut b = store.checkout(5);
        assert_eq!(store.spilled(), 0);
        for _ in 0..8 {
            assert_eq!(b.rng.next_u64(), reference.next_u64());
        }
        // the draws really happened before the spill
        assert_eq!(drawn.len(), 4);
    }

    #[test]
    fn model_versions_persist_and_default_to_zero() {
        let mut store = ClientStore::new(9);
        assert_eq!(store.model_version(3), 0);
        store.set_model_version(3, 7);
        assert_eq!(store.model_version(3), 7);
        // first-contact materialization keeps the canonical seed
        // derivation, so recording a broadcast never forks the stream
        let mut state = store.checkout(3);
        assert_eq!(state.model_version, 7);
        let mut resident = ClientState::new(3, 9 ^ (3u64 << 20));
        for _ in 0..8 {
            assert_eq!(state.rng.next_u64(), resident.rng.next_u64());
        }
    }

    #[test]
    fn shard_source_lazy_matches_resident() {
        let cfg = DatasetConfig::tiny();
        let ds = crate::data::FederatedDataset::build(&cfg);
        let gen = ShardGen::new(&cfg);
        let resident = ShardSource::Resident(&ds.shards);
        let lazy = ShardSource::Lazy(&gen);
        assert_eq!(resident.num_clients(), lazy.num_clients());
        for i in 0..cfg.num_clients {
            let a = resident.shard(i);
            let b = lazy.shard(i);
            assert_eq!(a.xs, b.xs, "shard {i}");
            assert_eq!(a.ys, b.ys, "shard {i}");
        }
    }
}
