//! Per-round metrics + the uplink bit ledger that produces Fig. 1's
//! x-axis.

use crate::util::csv::{CsvField, CsvWriter};
use crate::util::Result;

/// Metrics of one communication round.
#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub round: usize,
    /// mean client training loss this round
    pub train_loss: f32,
    /// test accuracy (NaN on rounds without evaluation)
    pub test_accuracy: f64,
    /// uplink bits this round (all sampled clients)
    pub bits_up: u64,
    /// cumulative uplink bits since round 0
    pub bits_cum: u64,
    /// wallclock seconds for the round
    pub wall_secs: f64,
}

/// One round of the adaptive pipeline's controller trace. Only recorded
/// when rate targeting is on, so static runs carry — and emit — nothing.
#[derive(Clone, Copy, Debug)]
pub struct RateTraceRow {
    /// multiplier λ in force during the round
    pub lambda: f64,
    /// measured uplink bits/coordinate of the last closed adaptation
    /// window (NaN until the first window closes)
    pub realized_bpc: f64,
    /// downlink bits charged this round (codebook broadcasts)
    pub bits_down: u64,
}

/// One round of the rate allocator's trace. Only recorded when a
/// per-client allocation is active, so uniform runs carry — and emit —
/// nothing.
#[derive(Clone, Copy, Debug)]
pub struct AllocTraceRow {
    /// Gini coefficient of the per-client codebook widths (0 = uniform)
    pub gini: f64,
    /// mean assigned width in bits
    pub mean_bits: f64,
    /// downlink bits charged this round (per-client codebook unicasts)
    pub bits_down: u64,
}

/// One round of the transform-stage trace. Only recorded when a
/// transform (error feedback and/or sparsification) is active, so plain
/// runs carry — and emit — nothing.
#[derive(Clone, Copy, Debug)]
pub struct TransformTraceRow {
    /// mean client ‖EF residual‖₂ this round (NaN when EF is off)
    pub ef_residual_norm: f64,
    /// mean transmitted-coordinate fraction this round (1 when dense)
    pub sparsity: f64,
}

/// One round of the downlink delta-codec trace. Only recorded when the
/// server→client broadcast is compressed, so legacy runs carry — and
/// emit — nothing.
#[derive(Clone, Copy, Debug)]
pub struct DownTraceRow {
    /// charged downlink bits/coordinate delivered this round (NaN on
    /// rounds with an empty cohort)
    pub down_bpc: f64,
    /// ‖server EF residual‖₂ after this round's delta encode (NaN for
    /// residual-free schemes)
    pub down_ef_norm: f64,
}

/// One round of the cohort-streaming trace: how many clients computed,
/// how many survived the channel, and the RSS sample behind the streamed
/// path's flat-memory claim. Recorded every round on every run, but kept
/// **in memory only** — never emitted to the CSV, whose schema is pinned
/// (`rss_kb` is measurement noise, not simulation state, so it must not
/// enter byte-compared artifacts).
#[derive(Clone, Copy, Debug)]
pub struct StreamTraceRow {
    /// clients that computed an update this round (post-availability)
    pub cohort: usize,
    /// packets the server actually ingested
    pub survivors: usize,
    /// resident-set size at the round boundary, KiB (0 off-Linux)
    pub rss_kb: u64,
}

/// Accumulates the experiment's metric history and bit ledger.
#[derive(Debug, Default)]
pub struct MetricsLog {
    pub rounds: Vec<RoundMetrics>,
    bits_cum: u64,
    bits_down_cum: u64,
    rate: Vec<RateTraceRow>,
    alloc: Vec<AllocTraceRow>,
    transform: Vec<TransformTraceRow>,
    down: Vec<DownTraceRow>,
    stream: Vec<StreamTraceRow>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(
        &mut self,
        round: usize,
        train_loss: f32,
        test_accuracy: f64,
        bits_up: u64,
        wall_secs: f64,
    ) {
        self.bits_cum += bits_up;
        self.rounds.push(RoundMetrics {
            round,
            train_loss,
            test_accuracy,
            bits_up,
            bits_cum: self.bits_cum,
            wall_secs,
        });
    }

    /// Record the controller trace for the round just pushed. Call once
    /// per round, after [`push`](Self::push), only on adaptive runs —
    /// the CSV schema grows the rate columns exactly when every round
    /// has a trace row.
    pub fn push_rate(&mut self, lambda: f64, realized_bpc: f64, bits_down: u64) {
        self.bits_down_cum += bits_down;
        self.rate.push(RateTraceRow { lambda, realized_bpc, bits_down });
    }

    /// Per-round controller trace (empty on static runs).
    pub fn rate_trace(&self) -> &[RateTraceRow] {
        &self.rate
    }

    /// Record the allocation trace for the round just pushed. Call once
    /// per round, after [`push`](Self::push), only when a per-client
    /// allocation is active — the CSV schema grows the allocation
    /// columns exactly when every round has a trace row.
    pub fn push_alloc(&mut self, gini: f64, mean_bits: f64, bits_down: u64) {
        self.bits_down_cum += bits_down;
        self.alloc.push(AllocTraceRow { gini, mean_bits, bits_down });
    }

    /// Per-round allocation trace (empty on uniform runs).
    pub fn alloc_trace(&self) -> &[AllocTraceRow] {
        &self.alloc
    }

    /// Gini coefficient of the final allocation (NaN on uniform runs).
    pub fn final_alloc_gini(&self) -> f64 {
        self.alloc.last().map(|a| a.gini).unwrap_or(f64::NAN)
    }

    /// Record the transform trace for the round just pushed. Call once
    /// per round, after [`push`](Self::push), only when the transform
    /// stage is active — the CSV schema grows the `ef_residual_norm` /
    /// `sparsity` columns exactly when every round has a trace row.
    pub fn push_transform(&mut self, ef_residual_norm: f64, sparsity: f64) {
        self.transform
            .push(TransformTraceRow { ef_residual_norm, sparsity });
    }

    /// Per-round transform trace (empty on identity runs).
    pub fn transform_trace(&self) -> &[TransformTraceRow] {
        &self.transform
    }

    /// Transmitted-coordinate fraction of the final round (NaN when the
    /// transform stage is inactive).
    pub fn final_sparsity(&self) -> f64 {
        self.transform.last().map(|t| t.sparsity).unwrap_or(f64::NAN)
    }

    /// Record the downlink delta-codec trace for the round just pushed.
    /// Call once per round, after [`push`](Self::push), only when the
    /// broadcast is compressed — the CSV schema grows the `down_bpc` /
    /// `down_ef_norm` columns exactly when every round has a trace row.
    pub fn push_down(&mut self, down_bpc: f64, down_ef_norm: f64) {
        self.down.push(DownTraceRow { down_bpc, down_ef_norm });
    }

    /// Per-round downlink trace (empty on legacy-broadcast runs).
    pub fn down_trace(&self) -> &[DownTraceRow] {
        &self.down
    }

    /// Record the streaming trace for the round just pushed. Call once
    /// per round, after [`push`](Self::push). Unlike the other traces
    /// this one never reaches the CSV (see [`StreamTraceRow`]).
    pub fn push_stream(
        &mut self,
        cohort: usize,
        survivors: usize,
        rss_kb: u64,
    ) {
        self.stream.push(StreamTraceRow { cohort, survivors, rss_kb });
    }

    /// Per-round streaming trace (in-memory diagnostics only).
    pub fn stream_trace(&self) -> &[StreamTraceRow] {
        &self.stream
    }

    /// Peak RSS sample across the run's round boundaries, KiB.
    pub fn peak_rss_kb(&self) -> u64 {
        self.stream.iter().map(|r| r.rss_kb).max().unwrap_or(0)
    }

    pub fn total_bits(&self) -> u64 {
        self.bits_cum
    }

    /// Cumulative downlink (codebook-broadcast) bits; zero on static
    /// runs.
    pub fn total_downlink_bits(&self) -> u64 {
        self.bits_down_cum
    }

    pub fn total_gigabits(&self) -> f64 {
        self.bits_cum as f64 / 1e9
    }

    /// Latest non-NaN accuracy.
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .map(|r| r.test_accuracy)
            .find(|a| !a.is_nan())
            .unwrap_or(f64::NAN)
    }

    /// Best accuracy over the run.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_accuracy)
            .filter(|a| !a.is_nan())
            .fold(f64::NAN, f64::max)
    }

    /// Append all rounds to a CSV. The base schema is unchanged from the
    /// static path; the controller columns (`lambda`, `realized_bpc`,
    /// `bits_down`), the allocation columns, the transform columns
    /// (`ef_residual_norm`, `sparsity`) and the downlink columns
    /// (`down_bpc`, `down_ef_norm`) appear only when the matching trace
    /// was recorded for every round, so static-run CSVs stay
    /// byte-identical.
    pub fn write_csv(&self, path: &str, label: &str) -> Result<()> {
        let with_rate =
            !self.rate.is_empty() && self.rate.len() == self.rounds.len();
        // exclusive with the rate columns (the pipeline validates the two
        // controllers apart; if a caller still populates both traces, the
        // rate columns win and header/rows stay consistent)
        let with_alloc = !with_rate
            && !self.alloc.is_empty()
            && self.alloc.len() == self.rounds.len();
        // the transform stage composes with either controller, so its
        // columns gate independently
        let with_transform = !self.transform.is_empty()
            && self.transform.len() == self.rounds.len();
        // the downlink codec composes with everything above and its
        // columns always come last
        let with_down =
            !self.down.is_empty() && self.down.len() == self.rounds.len();
        let mut header = vec![
            "scheme", "round", "train_loss", "test_acc", "bits_up",
            "bits_cum", "wall_secs",
        ];
        if with_rate {
            header.extend_from_slice(&["lambda", "realized_bpc",
                                       "bits_down"]);
        }
        if with_alloc {
            header.extend_from_slice(&["alloc_gini", "alloc_mean_bits",
                                       "bits_down"]);
        }
        if with_transform {
            header.extend_from_slice(&["ef_residual_norm", "sparsity"]);
        }
        if with_down {
            header.extend_from_slice(&["down_bpc", "down_ef_norm"]);
        }
        let mut w = CsvWriter::create(path, &header)?;
        for (i, r) in self.rounds.iter().enumerate() {
            let mut row: Vec<CsvField> = vec![
                CsvField::from(label),
                CsvField::from(r.round),
                CsvField::from(r.train_loss as f64),
                CsvField::from(r.test_accuracy),
                CsvField::from(r.bits_up),
                CsvField::from(r.bits_cum),
                CsvField::from(r.wall_secs),
            ];
            if with_rate {
                let t = &self.rate[i];
                row.push(CsvField::from(t.lambda));
                row.push(CsvField::from(t.realized_bpc));
                row.push(CsvField::from(t.bits_down));
            }
            if with_alloc {
                let t = &self.alloc[i];
                row.push(CsvField::from(t.gini));
                row.push(CsvField::from(t.mean_bits));
                row.push(CsvField::from(t.bits_down));
            }
            if with_transform {
                let t = &self.transform[i];
                row.push(CsvField::from(t.ef_residual_norm));
                row.push(CsvField::from(t.sparsity));
            }
            if with_down {
                let t = &self.down[i];
                row.push(CsvField::from(t.down_bpc));
                row.push(CsvField::from(t.down_ef_norm));
            }
            w.row(&row)?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut m = MetricsLog::new();
        m.push(0, 1.0, f64::NAN, 100, 0.1);
        m.push(1, 0.9, 0.5, 150, 0.1);
        m.push(2, 0.8, 0.6, 150, 0.1);
        assert_eq!(m.total_bits(), 400);
        assert_eq!(m.rounds[2].bits_cum, 400);
        assert_eq!(m.final_accuracy(), 0.6);
        assert_eq!(m.best_accuracy(), 0.6);
    }

    #[test]
    fn final_accuracy_skips_nan() {
        let mut m = MetricsLog::new();
        m.push(0, 1.0, 0.4, 10, 0.0);
        m.push(1, 0.9, f64::NAN, 10, 0.0);
        assert_eq!(m.final_accuracy(), 0.4);
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_{}", std::process::id()));
        let path = dir.join("m.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, 0.5, 42, 0.01);
        m.write_csv(path.to_str().unwrap(), "test_scheme").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("test_scheme,0,"));
        // the static schema carries no controller columns
        assert!(
            text.starts_with(
                "scheme,round,train_loss,test_acc,bits_up,bits_cum,\
                 wall_secs\n"
            ),
            "static header drifted: {text}"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stream_trace_never_reaches_the_csv() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_stream_{}", std::process::id()));
        let path = dir.join("s.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, 0.5, 42, 0.01);
        m.push_stream(16, 14, 120_000);
        assert_eq!(m.stream_trace().len(), 1);
        assert_eq!(m.stream_trace()[0].cohort, 16);
        assert_eq!(m.peak_rss_kb(), 120_000);
        m.write_csv(path.to_str().unwrap(), "s").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // schema must stay byte-identical to the static path
        assert!(
            text.starts_with(
                "scheme,round,train_loss,test_acc,bits_up,bits_cum,\
                 wall_secs\n"
            ),
            "stream trace leaked into the CSV: {text}"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn alloc_trace_gates_extra_csv_columns() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_alloc_{}", std::process::id()));
        let path = dir.join("al.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, f64::NAN, 100, 0.01);
        m.push_alloc(0.0, 3.0, 0);
        m.push(1, 0.9, 0.6, 90, 0.01);
        m.push_alloc(0.25, 3.0, 1200);
        assert_eq!(m.total_downlink_bits(), 1200);
        assert_eq!(m.alloc_trace().len(), 2);
        assert!((m.final_alloc_gini() - 0.25).abs() < 1e-12);
        m.write_csv(path.to_str().unwrap(), "rcfed_b3").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(
            header.ends_with("wall_secs,alloc_gini,alloc_mean_bits,bits_down"),
            "allocation columns missing: {header}"
        );
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(dir).ok();
        // uniform runs carry no trace and no gini
        assert!(MetricsLog::new().final_alloc_gini().is_nan());
    }

    #[test]
    fn transform_trace_gates_extra_csv_columns() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_transform_{}", std::process::id()));
        let path = dir.join("tf.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, f64::NAN, 100, 0.01);
        m.push_transform(0.5, 0.1);
        m.push(1, 0.9, 0.6, 90, 0.01);
        m.push_transform(0.25, 0.1);
        assert_eq!(m.transform_trace().len(), 2);
        assert!((m.final_sparsity() - 0.1).abs() < 1e-12);
        m.write_csv(path.to_str().unwrap(), "rcfed_b3_topk0.1_ef").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(
            header.ends_with("wall_secs,ef_residual_norm,sparsity"),
            "transform columns missing: {header}"
        );
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(dir).ok();
        // identity runs carry no trace and no sparsity
        assert!(MetricsLog::new().final_sparsity().is_nan());

        // the transform columns compose with the rate columns
        let mut both = MetricsLog::new();
        both.push(0, 1.0, f64::NAN, 100, 0.01);
        both.push_rate(0.05, f64::NAN, 0);
        both.push_transform(f64::NAN, 0.2);
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_transform_rate_{}", std::process::id()));
        let path = dir.join("tfr.csv");
        both.write_csv(path.to_str().unwrap(), "x").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().next().unwrap().ends_with(
            "lambda,realized_bpc,bits_down,ef_residual_norm,sparsity"
        ));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn down_trace_gates_extra_csv_columns() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_down_{}", std::process::id()));
        let path = dir.join("dn.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, f64::NAN, 100, 0.01);
        m.push_down(1.4, 0.3);
        m.push(1, 0.9, 0.6, 90, 0.01);
        m.push_down(1.5, 0.2);
        assert_eq!(m.down_trace().len(), 2);
        m.write_csv(path.to_str().unwrap(), "rcfed_b3_down").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(
            header.ends_with("wall_secs,down_bpc,down_ef_norm"),
            "downlink columns missing: {header}"
        );
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(dir).ok();

        // the downlink columns come last, after the rate columns
        let mut both = MetricsLog::new();
        both.push(0, 1.0, f64::NAN, 100, 0.01);
        both.push_rate(0.05, f64::NAN, 0);
        both.push_down(1.4, f64::NAN);
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_down_rate_{}", std::process::id()));
        let path = dir.join("dnr.csv");
        both.write_csv(path.to_str().unwrap(), "x").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().next().unwrap().ends_with(
            "lambda,realized_bpc,bits_down,down_bpc,down_ef_norm"
        ));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rate_trace_gates_extra_csv_columns() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_rate_{}", std::process::id()));
        let path = dir.join("rt.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, f64::NAN, 100, 0.01);
        m.push_rate(0.05, f64::NAN, 0);
        m.push(1, 0.9, 0.6, 90, 0.01);
        m.push_rate(0.08, 2.4, 352);
        assert_eq!(m.total_downlink_bits(), 352);
        assert_eq!(m.rate_trace().len(), 2);
        m.write_csv(path.to_str().unwrap(), "rcfed_b3").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(
            header.ends_with("wall_secs,lambda,realized_bpc,bits_down"),
            "rate columns missing: {header}"
        );
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(dir).ok();
    }
}
