//! Per-round metrics + the uplink bit ledger that produces Fig. 1's
//! x-axis.

use crate::util::csv::CsvWriter;
use crate::util::Result;

/// Metrics of one communication round.
#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub round: usize,
    /// mean client training loss this round
    pub train_loss: f32,
    /// test accuracy (NaN on rounds without evaluation)
    pub test_accuracy: f64,
    /// uplink bits this round (all sampled clients)
    pub bits_up: u64,
    /// cumulative uplink bits since round 0
    pub bits_cum: u64,
    /// wallclock seconds for the round
    pub wall_secs: f64,
}

/// Accumulates the experiment's metric history and bit ledger.
#[derive(Debug, Default)]
pub struct MetricsLog {
    pub rounds: Vec<RoundMetrics>,
    bits_cum: u64,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(
        &mut self,
        round: usize,
        train_loss: f32,
        test_accuracy: f64,
        bits_up: u64,
        wall_secs: f64,
    ) {
        self.bits_cum += bits_up;
        self.rounds.push(RoundMetrics {
            round,
            train_loss,
            test_accuracy,
            bits_up,
            bits_cum: self.bits_cum,
            wall_secs,
        });
    }

    pub fn total_bits(&self) -> u64 {
        self.bits_cum
    }

    pub fn total_gigabits(&self) -> f64 {
        self.bits_cum as f64 / 1e9
    }

    /// Latest non-NaN accuracy.
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .map(|r| r.test_accuracy)
            .find(|a| !a.is_nan())
            .unwrap_or(f64::NAN)
    }

    /// Best accuracy over the run.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_accuracy)
            .filter(|a| !a.is_nan())
            .fold(f64::NAN, f64::max)
    }

    /// Append all rounds to a CSV (schema: see header below).
    pub fn write_csv(&self, path: &str, label: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["scheme", "round", "train_loss", "test_acc", "bits_up",
              "bits_cum", "wall_secs"],
        )?;
        for r in &self.rounds {
            crate::csv_row!(
                w,
                label,
                r.round,
                r.train_loss as f64,
                r.test_accuracy,
                r.bits_up,
                r.bits_cum,
                r.wall_secs
            )?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut m = MetricsLog::new();
        m.push(0, 1.0, f64::NAN, 100, 0.1);
        m.push(1, 0.9, 0.5, 150, 0.1);
        m.push(2, 0.8, 0.6, 150, 0.1);
        assert_eq!(m.total_bits(), 400);
        assert_eq!(m.rounds[2].bits_cum, 400);
        assert_eq!(m.final_accuracy(), 0.6);
        assert_eq!(m.best_accuracy(), 0.6);
    }

    #[test]
    fn final_accuracy_skips_nan() {
        let mut m = MetricsLog::new();
        m.push(0, 1.0, 0.4, 10, 0.0);
        m.push(1, 0.9, f64::NAN, 10, 0.0);
        assert_eq!(m.final_accuracy(), 0.4);
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join(format!(
            "rcfed_metrics_{}", std::process::id()));
        let path = dir.join("m.csv");
        let mut m = MetricsLog::new();
        m.push(0, 1.0, 0.5, 42, 0.01);
        m.write_csv(path.to_str().unwrap(), "test_scheme").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("test_scheme,0,"));
        std::fs::remove_dir_all(dir).ok();
    }
}
