//! Client-side local training (Algorithm 1, inner loop).
//!
//! Each sampled client receives `θ_t`, runs `e` local SGD iterations on
//! its shard, and reports the *effective gradient*
//! `ĝ = (θ_t − θ_{k,e}) / η_t` (for `e = 1` this is exactly the
//! mini-batch gradient the paper's Algorithm 1 transmits; for `e > 1` it
//! is the FedAvg-style accumulated update the convergence analysis in §4
//! covers). The effective gradient is what gets compressed.

use crate::data::Shard;
use crate::fl::compression::{CompressionPipeline, TransformState};
use crate::fl::packet::Packet;
use crate::model::Backend;
use crate::util::rng::Rng;
use crate::util::Result;

/// One federated client.
pub struct Client {
    pub id: u32,
    pub shard: Shard,
    rng: Rng,
    /// per-client transform state (error-feedback residual etc.) —
    /// survives rounds, untouched by packet loss downstream
    codec: TransformState,
    // scratch buffers reused across rounds (hot path: no allocation)
    grad: Vec<f32>,
    local: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<i32>,
}

/// Result of one client round before/after compression.
pub struct ClientUpdate {
    pub packet: Packet,
    pub mean_loss: f32,
    /// strided sample of the normalized effective gradient for the
    /// pipeline's stats pass (empty when rate targeting is off)
    pub sample: Vec<f32>,
    /// ‖residual‖₂ after this round's compress (NaN when error feedback
    /// is off)
    pub ef_norm: f64,
    /// transmitted-coordinate fraction (1 for dense schemes, NaN when
    /// the transform stage is inactive)
    pub sparsity: f64,
}

impl Client {
    pub fn new(id: u32, shard: Shard, seed: u64) -> Client {
        Client {
            id,
            shard,
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            codec: TransformState::new(),
            grad: Vec::new(),
            local: Vec::new(),
            xs: Vec::new(),
            ys: Vec::new(),
        }
    }

    /// Run `e` local iterations from `params` and return the compressed
    /// effective gradient (plus the pipeline's stats sample when rate
    /// targeting is on — free otherwise).
    #[allow(clippy::too_many_arguments)]
    pub fn round<B: Backend + ?Sized>(
        &mut self,
        backend: &B,
        params: &[f32],
        round: u32,
        local_iters: usize,
        lr: f32,
        batch: usize,
        pipeline: &CompressionPipeline,
    ) -> Result<ClientUpdate> {
        let d = backend.num_params();
        self.grad.resize(d, 0.0);
        self.local.clear();
        self.local.extend_from_slice(params);
        let mut loss_acc = 0f64;
        for _ in 0..local_iters.max(1) {
            self.shard.sample_batch(
                &mut self.rng, batch, &mut self.xs, &mut self.ys);
            let loss =
                backend.grad(&self.local, &self.xs, &self.ys, &mut self.grad)?;
            loss_acc += loss as f64;
            for (p, &g) in self.local.iter_mut().zip(&self.grad) {
                *p -= lr * g;
            }
        }
        // effective gradient: (θ_t − θ_{k,e}) / η_t
        let inv_lr = 1.0 / lr;
        for (g, (&p0, &pl)) in self
            .grad
            .iter_mut()
            .zip(params.iter().zip(&self.local))
        {
            *g = (p0 - pl) * inv_lr;
        }
        let packet = pipeline.compress_with(
            &mut self.codec, self.id, round, &self.grad, &mut self.rng)?;
        // stats sample: the staged path captured a working-set sample
        // when a transform is active; otherwise reuse the (μ, σ) the
        // compressor just computed over the dense gradient
        let sample = match self.codec.take_sample() {
            Some(sample) => sample,
            None => pipeline.grad_sample_from(&self.grad, &packet),
        };
        Ok(ClientUpdate {
            packet,
            mean_loss: (loss_acc / local_iters.max(1) as f64) as f32,
            sample,
            ef_norm: self.codec.last_ef_norm,
            sparsity: self.codec.last_sparsity,
        })
    }

    /// Raw (uncompressed) effective gradient — used by tests and the
    /// quantization-error diagnostics.
    pub fn last_gradient(&self) -> &[f32] {
        &self.grad
    }

    /// The client's transform state (EF residual diagnostics).
    pub fn codec_state(&self) -> &TransformState {
        &self.codec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetConfig, FederatedDataset};
    use crate::fl::compression::{
        CompressionScheme, RateTarget, WireCoder,
    };
    use crate::model::native::NativeMlp;
    use crate::model::Backend;

    fn setup() -> (NativeMlp, FederatedDataset, CompressionPipeline) {
        let ds = FederatedDataset::build(&DatasetConfig::tiny());
        let m = NativeMlp::tiny();
        let c = CompressionPipeline::design(
            CompressionScheme::Fp32,
            WireCoder::Huffman,
            RateTarget::Off,
        )
        .unwrap();
        (m, ds, c)
    }

    #[test]
    fn single_local_iter_equals_minibatch_gradient() {
        let (m, ds, c) = setup();
        let params = m.init_params(1);
        let mut client = Client::new(0, ds.shards[0].clone(), 99);
        let up = client
            .round(&m, &params, 0, 1, 0.1, 16, &c)
            .unwrap();
        assert!(up.mean_loss.is_finite());
        // fp32 packet should reconstruct last_gradient exactly
        let mut acc = vec![0f32; m.num_params()];
        c.decompress_accumulate(&up.packet, &mut acc).unwrap();
        assert_eq!(acc, client.last_gradient());
        // and the effective gradient is a genuine gradient (non-zero)
        assert!(acc.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn multi_local_iters_accumulate() {
        let (m, ds, c) = setup();
        let params = m.init_params(2);
        let mut c1 = Client::new(0, ds.shards[0].clone(), 5);
        let mut c2 = Client::new(0, ds.shards[0].clone(), 5);
        let u1 = c1.round(&m, &params, 0, 1, 0.05, 16, &c).unwrap();
        let u2 = c2.round(&m, &params, 0, 4, 0.05, 16, &c).unwrap();
        let n1: f64 = {
            let mut a = vec![0f32; m.num_params()];
            c.decompress_accumulate(&u1.packet, &mut a).unwrap();
            a.iter().map(|&x| (x as f64).powi(2)).sum()
        };
        let n2: f64 = {
            let mut a = vec![0f32; m.num_params()];
            c.decompress_accumulate(&u2.packet, &mut a).unwrap();
            a.iter().map(|&x| (x as f64).powi(2)).sum()
        };
        // 4 accumulated steps should carry more total signal than 1
        assert!(n2 > n1, "{n2} vs {n1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (m, ds, c) = setup();
        let params = m.init_params(3);
        let mut a = Client::new(1, ds.shards[1].clone(), 7);
        let mut b = Client::new(1, ds.shards[1].clone(), 7);
        let ua = a.round(&m, &params, 0, 2, 0.1, 8, &c).unwrap();
        let ub = b.round(&m, &params, 0, 2, 0.1, 8, &c).unwrap();
        assert_eq!(ua.packet.payload, ub.packet.payload);
    }
}
