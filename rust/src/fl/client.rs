//! Client-side local training (Algorithm 1, inner loop).
//!
//! Each sampled client receives `θ_t`, runs `e` local SGD iterations on
//! its shard, and reports the *effective gradient*
//! `ĝ = (θ_t − θ_{k,e}) / η_t` (for `e = 1` this is exactly the
//! mini-batch gradient the paper's Algorithm 1 transmits; for `e > 1` it
//! is the FedAvg-style accumulated update the convergence analysis in §4
//! covers). The effective gradient is what gets compressed.
//!
//! The round body is a free function ([`run_client_round`]) over three
//! separable pieces:
//!
//! * the **shard** (data) — resident or materialized lazily per round,
//! * the **durable state** ([`ClientState`]: RNG stream + EF residual /
//!   codec versions) — must survive rounds the client sits out,
//! * the **scratch** ([`RoundScratch`]: gradient, local params, batch
//!   buffers) — per-worker, reusable across *different* clients.
//!
//! [`Client`] bundles all three for the resident path; the streamed
//! round loop (`coordinator::scheduler::stream_round`) checks durable
//! state out of a `ClientStore` and shares scratch across the cohort.

use crate::data::Shard;
use crate::fl::compression::{
    CodecScratch, CompressionPipeline, TransformState,
};
use crate::fl::packet::Packet;
use crate::model::{kernels, Backend, ModelScratch};
use crate::util::rng::Rng;
use crate::util::Result;

/// Durable per-client state: everything that must persist across rounds
/// for byte-identical replay — the client's private RNG stream (batch
/// sampling + stochastic-rounding draws advance it every participation)
/// and the codec transform state (error-feedback residual, adaptive
/// codebook versions).
#[derive(Debug)]
pub struct ClientState {
    pub rng: Rng,
    pub codec: TransformState,
    /// last model version this client acknowledged from the downlink
    /// delta codec (0 = the agreed zero model; see
    /// [`crate::fl::codec::downlink::DeltaCodec`]). Unused — and zero —
    /// when the downlink broadcast is the legacy uncharged fp32 path.
    pub model_version: u32,
}

impl ClientState {
    /// Seed derivation is the identity-critical contract: the stream for
    /// client `id` is `Rng::new(seed ^ id·φ64)` regardless of whether the
    /// client lives in a resident `Vec` or a spill store.
    pub fn new(id: u32, seed: u64) -> ClientState {
        ClientState {
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            codec: TransformState::new(),
            model_version: 0,
        }
    }
}

/// Per-worker scratch reused across clients and rounds (hot path: no
/// allocation). Safe to share across clients: `Backend::grad` zero-fills
/// the gradient buffer and the other buffers are cleared or overwritten
/// before use, so no state leaks between clients.
#[derive(Default)]
pub struct RoundScratch {
    pub grad: Vec<f32>,
    local: Vec<f32>,
    xs: Vec<f32>,
    ys: Vec<i32>,
    /// encode-side symbol/recon buffers (see [`CodecScratch`])
    codec: CodecScratch,
    /// model-side activation/delta workspace (see [`ModelScratch`])
    model: ModelScratch,
}

impl RoundScratch {
    pub fn new() -> RoundScratch {
        RoundScratch::default()
    }
}

/// One federated client (resident representation: owns its shard,
/// durable state and scratch for the lifetime of the experiment).
pub struct Client {
    pub id: u32,
    pub shard: Shard,
    state: ClientState,
    scratch: RoundScratch,
}

/// Result of one client round before/after compression.
pub struct ClientUpdate {
    pub packet: Packet,
    pub mean_loss: f32,
    /// strided sample of the normalized effective gradient for the
    /// pipeline's stats pass (empty when rate targeting is off)
    pub sample: Vec<f32>,
    /// ‖residual‖₂ after this round's compress (NaN when error feedback
    /// is off)
    pub ef_norm: f64,
    /// transmitted-coordinate fraction (1 for dense schemes, NaN when
    /// the transform stage is inactive)
    pub sparsity: f64,
}

/// Run `e` local iterations from `params` and return the compressed
/// effective gradient (plus the pipeline's stats sample when rate
/// targeting is on — free otherwise).
#[allow(clippy::too_many_arguments)]
pub fn run_client_round<B: Backend + ?Sized>(
    backend: &B,
    shard: &Shard,
    state: &mut ClientState,
    scratch: &mut RoundScratch,
    id: u32,
    params: &[f32],
    round: u32,
    local_iters: usize,
    lr: f32,
    batch: usize,
    pipeline: &CompressionPipeline,
) -> Result<ClientUpdate> {
    let d = backend.num_params();
    scratch.grad.resize(d, 0.0);
    scratch.local.clear();
    scratch.local.extend_from_slice(params);
    let mut loss_acc = 0f64;
    for _ in 0..local_iters.max(1) {
        shard.sample_batch(
            &mut state.rng, batch, &mut scratch.xs, &mut scratch.ys);
        let loss = backend.grad_with(
            &scratch.local,
            &scratch.xs,
            &scratch.ys,
            &mut scratch.grad,
            &mut scratch.model,
        )?;
        loss_acc += loss as f64;
        kernels::sgd_step(&mut scratch.local, &scratch.grad, lr);
    }
    // effective gradient: (θ_t − θ_{k,e}) / η_t
    let inv_lr = 1.0 / lr;
    for (g, (&p0, &pl)) in scratch
        .grad
        .iter_mut()
        .zip(params.iter().zip(&scratch.local))
    {
        *g = (p0 - pl) * inv_lr;
    }
    let packet = pipeline.compress_with_scratch(
        &mut state.codec,
        &mut scratch.codec,
        id,
        round,
        &scratch.grad,
        &mut state.rng,
    )?;
    // stats sample: the staged path captured a working-set sample
    // when a transform is active; otherwise reuse the (μ, σ) the
    // compressor just computed over the dense gradient
    let sample = match state.codec.take_sample() {
        Some(sample) => sample,
        None => pipeline.grad_sample_from(&scratch.grad, &packet),
    };
    Ok(ClientUpdate {
        packet,
        mean_loss: (loss_acc / local_iters.max(1) as f64) as f32,
        sample,
        ef_norm: state.codec.last_ef_norm,
        sparsity: state.codec.last_sparsity,
    })
}

impl Client {
    pub fn new(id: u32, shard: Shard, seed: u64) -> Client {
        Client {
            id,
            shard,
            state: ClientState::new(id, seed),
            scratch: RoundScratch::new(),
        }
    }

    /// Re-assemble a client around previously spilled durable state
    /// (`ClientStore` checkout on the streamed path).
    pub fn from_state(id: u32, shard: Shard, state: ClientState) -> Client {
        Client { id, shard, state, scratch: RoundScratch::new() }
    }

    /// Tear down into the durable state worth keeping between rounds.
    pub fn into_state(self) -> ClientState {
        self.state
    }

    /// Run `e` local iterations from `params` (see [`run_client_round`]).
    #[allow(clippy::too_many_arguments)]
    pub fn round<B: Backend + ?Sized>(
        &mut self,
        backend: &B,
        params: &[f32],
        round: u32,
        local_iters: usize,
        lr: f32,
        batch: usize,
        pipeline: &CompressionPipeline,
    ) -> Result<ClientUpdate> {
        run_client_round(
            backend,
            &self.shard,
            &mut self.state,
            &mut self.scratch,
            self.id,
            params,
            round,
            local_iters,
            lr,
            batch,
            pipeline,
        )
    }

    /// Raw (uncompressed) effective gradient — used by tests and the
    /// quantization-error diagnostics.
    pub fn last_gradient(&self) -> &[f32] {
        &self.scratch.grad
    }

    /// The client's transform state (EF residual diagnostics).
    pub fn codec_state(&self) -> &TransformState {
        &self.state.codec
    }

    /// Last downlink model version this client acknowledged.
    pub fn model_version(&self) -> u32 {
        self.state.model_version
    }

    /// Record a downlink delivery (incremental delta or full resync).
    pub fn set_model_version(&mut self, version: u32) {
        self.state.model_version = version;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetConfig, FederatedDataset};
    use crate::fl::compression::{
        CompressionScheme, RateTarget, WireCoder,
    };
    use crate::model::native::NativeMlp;
    use crate::model::Backend;

    fn setup() -> (NativeMlp, FederatedDataset, CompressionPipeline) {
        let ds = FederatedDataset::build(&DatasetConfig::tiny());
        let m = NativeMlp::tiny();
        let c = CompressionPipeline::design(
            CompressionScheme::Fp32,
            WireCoder::Huffman,
            RateTarget::Off,
        )
        .unwrap();
        (m, ds, c)
    }

    #[test]
    fn single_local_iter_equals_minibatch_gradient() {
        let (m, ds, c) = setup();
        let params = m.init_params(1);
        let mut client = Client::new(0, ds.shards[0].clone(), 99);
        let up = client
            .round(&m, &params, 0, 1, 0.1, 16, &c)
            .unwrap();
        assert!(up.mean_loss.is_finite());
        // fp32 packet should reconstruct last_gradient exactly
        let mut acc = vec![0f32; m.num_params()];
        c.decompress_accumulate(&up.packet, &mut acc).unwrap();
        assert_eq!(acc, client.last_gradient());
        // and the effective gradient is a genuine gradient (non-zero)
        assert!(acc.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn multi_local_iters_accumulate() {
        let (m, ds, c) = setup();
        let params = m.init_params(2);
        let mut c1 = Client::new(0, ds.shards[0].clone(), 5);
        let mut c2 = Client::new(0, ds.shards[0].clone(), 5);
        let u1 = c1.round(&m, &params, 0, 1, 0.05, 16, &c).unwrap();
        let u2 = c2.round(&m, &params, 0, 4, 0.05, 16, &c).unwrap();
        let n1: f64 = {
            let mut a = vec![0f32; m.num_params()];
            c.decompress_accumulate(&u1.packet, &mut a).unwrap();
            a.iter().map(|&x| (x as f64).powi(2)).sum()
        };
        let n2: f64 = {
            let mut a = vec![0f32; m.num_params()];
            c.decompress_accumulate(&u2.packet, &mut a).unwrap();
            a.iter().map(|&x| (x as f64).powi(2)).sum()
        };
        // 4 accumulated steps should carry more total signal than 1
        assert!(n2 > n1, "{n2} vs {n1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (m, ds, c) = setup();
        let params = m.init_params(3);
        let mut a = Client::new(1, ds.shards[1].clone(), 7);
        let mut b = Client::new(1, ds.shards[1].clone(), 7);
        let ua = a.round(&m, &params, 0, 2, 0.1, 8, &c).unwrap();
        let ub = b.round(&m, &params, 0, 2, 0.1, 8, &c).unwrap();
        assert_eq!(ua.packet.payload, ub.packet.payload);
    }

    #[test]
    fn free_round_fn_matches_resident_client() {
        // the streamed path (shared scratch + spilled state) and the
        // resident path must produce identical packets
        let (m, ds, c) = setup();
        let params = m.init_params(4);
        let mut resident = Client::new(2, ds.shards[2].clone(), 11);
        let mut state = ClientState::new(2, 11);
        let mut scratch = RoundScratch::new();
        // dirty the scratch with another client's round first
        run_client_round(
            &m, &ds.shards[0], &mut ClientState::new(0, 11), &mut scratch,
            0, &params, 0, 1, 0.1, 8, &c,
        )
        .unwrap();
        for round in 0..3 {
            let ua = resident
                .round(&m, &params, round, 2, 0.1, 8, &c)
                .unwrap();
            let ub = run_client_round(
                &m, &ds.shards[2], &mut state, &mut scratch, 2, &params,
                round, 2, 0.1, 8, &c,
            )
            .unwrap();
            assert_eq!(ua.packet.payload, ub.packet.payload);
            assert_eq!(ua.mean_loss, ub.mean_loss);
        }
    }
}
