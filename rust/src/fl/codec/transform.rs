//! The **Transform** stage: what happens to the raw effective gradient
//! *before* quantization.
//!
//! Three behaviors, freely composable with every quantize/code backend:
//!
//! * **identity** — the working set *is* the gradient; zero wire effect,
//!   zero cost (the pre-codec hot path is taken unchanged);
//! * **error feedback** — a per-client residual (the quantization error
//!   banked from previous rounds) is added to the gradient before
//!   quantization, and re-banked from the fresh reconstruction after it.
//!   The residual lives client-side in [`TransformState`], so a packet
//!   lost downstream never touches it;
//! * **top-k sparsification** — keep the `ceil(ratio·d)` largest-|value|
//!   coordinates; their indices travel at the head of the payload as a
//!   packed `ceil(log2 d)`-bit stream and are charged honestly to
//!   `Packet::index_bits`.
//!
//! EF composes with top-k (classic EF-SGD): untransmitted coordinates
//! accumulate in the residual until they win a top-k slot.

use crate::coding::bitio::{BitReader, BitWriter};
use crate::util::{Error, Result};

use super::scheme::CompressionScheme;

/// Which transform precedes quantization.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Transform {
    /// the working set is the gradient itself
    #[default]
    Identity,
    /// top-k magnitude sparsification: keep `ceil(ratio·d)` coordinates
    TopK { ratio: f64 },
}

/// Transform-stage configuration: the kind plus the orthogonal
/// error-feedback switch.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct TransformCfg {
    pub kind: Transform,
    /// carry the quantization error across rounds in a per-client
    /// residual (requires `compress_with` + a [`TransformState`])
    pub error_feedback: bool,
}

impl TransformCfg {
    pub fn identity() -> TransformCfg {
        TransformCfg::default()
    }

    pub fn topk(ratio: f64) -> TransformCfg {
        TransformCfg { kind: Transform::TopK { ratio }, error_feedback: false }
    }

    pub fn with_ef(mut self) -> TransformCfg {
        self.error_feedback = true;
        self
    }

    /// Anything beyond the plain identity pass-through?
    pub fn is_active(&self) -> bool {
        self.error_feedback || !matches!(self.kind, Transform::Identity)
    }

    /// Does the working set carry an index stream on the wire?
    pub fn is_sparse(&self) -> bool {
        matches!(self.kind, Transform::TopK { .. })
    }

    /// Scheme-label suffix, empty when inactive so every pre-transform
    /// label (CSV keys, golden snapshots) stays byte-identical.
    pub fn suffix(&self) -> String {
        match (self.kind, self.error_feedback) {
            (Transform::Identity, false) => String::new(),
            (Transform::Identity, true) => "_ef".into(),
            (Transform::TopK { ratio }, false) => format!("_topk{ratio}"),
            (Transform::TopK { ratio }, true) => format!("_topk{ratio}_ef"),
        }
    }

    /// Stable axis label for sweep rows, `"id"` when inactive.
    pub fn label(&self) -> String {
        match (self.kind, self.error_feedback) {
            (Transform::Identity, false) => "id".into(),
            (Transform::Identity, true) => "ef".into(),
            (Transform::TopK { ratio }, false) => format!("topk{ratio}"),
            (Transform::TopK { ratio }, true) => format!("topk{ratio}+ef"),
        }
    }

    /// Reject nonsensical ratios and unsupported scheme combinations up
    /// front, so a bad configuration is a config error, not a silent
    /// no-op or a decode-time surprise.
    pub fn validate(&self, scheme: &CompressionScheme) -> Result<()> {
        if let Transform::TopK { ratio } = self.kind {
            if !(ratio > 0.0 && ratio <= 1.0 && ratio.is_finite()) {
                return Err(Error::Config(format!(
                    "topk ratio {ratio} must be in (0, 1]")));
            }
            if matches!(scheme, CompressionScheme::Qsgd { .. }) {
                return Err(Error::Config(
                    "topk sparsification is not supported for qsgd (its \
                     bucketed norms assume the dense layout); use a \
                     designed-codebook scheme or fp32"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

/// Per-client transform state, owned by the client and threaded mutably
/// through `compress_with`. It survives rounds by construction, and
/// survives packet loss because nothing downstream of compression ever
/// touches it — the satellite property the EF tests pin down.
#[derive(Debug)]
pub struct TransformState {
    /// EF residual in the raw gradient domain (empty until first use)
    residual: Vec<f32>,
    /// EF working copy: gradient + residual
    scratch: Vec<f32>,
    /// sparse working set of the last forward pass
    values: Vec<f32>,
    indices: Vec<u32>,
    /// top-k selection scratch (candidate index set)
    order: Vec<u32>,
    /// top-k pivot-sample scratch (strided |value| subsample)
    pivot: Vec<f32>,
    /// stats sample captured by the staged path on adaptive runs
    sample: Option<Vec<f32>>,
    /// ‖residual‖₂ after the last compress (NaN while EF is off)
    pub last_ef_norm: f64,
    /// transmitted-coordinate fraction of the last compress (1 when
    /// dense, NaN before the first staged compress)
    pub last_sparsity: f64,
}

/// `Default` and [`TransformState::new`] are the same construction: the
/// diagnostics start at their NaN "no compress yet" sentinels, so no
/// construction path can leak a bogus 0.0 into the metrics means.
impl Default for TransformState {
    fn default() -> TransformState {
        TransformState {
            residual: Vec::new(),
            scratch: Vec::new(),
            values: Vec::new(),
            indices: Vec::new(),
            order: Vec::new(),
            pivot: Vec::new(),
            sample: None,
            last_ef_norm: f64::NAN,
            last_sparsity: f64::NAN,
        }
    }
}

impl TransformState {
    pub fn new() -> TransformState {
        TransformState::default()
    }

    /// The banked error-feedback residual (empty until the first EF
    /// compress).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    pub(crate) fn set_sample(&mut self, sample: Vec<f32>) {
        self.sample = Some(sample);
    }

    /// The stats sample the staged encoder captured for the adaptive
    /// controller, if any (consumed).
    pub fn take_sample(&mut self) -> Option<Vec<f32>> {
        self.sample.take()
    }
}

/// The working set a transform hands to the quantize stage.
pub(crate) enum WorkingSet<'a> {
    Dense(&'a [f32]),
    Sparse { indices: &'a [u32], values: &'a [f32] },
}

/// Stage-1 forward pass: residual injection (EF), then selection
/// (top-k). Returns a working set borrowing either `grad` (identity) or
/// the state's scratch buffers — allocation-free after warm-up.
pub(crate) fn forward<'a>(
    cfg: TransformCfg,
    grad: &'a [f32],
    state: &'a mut TransformState,
) -> WorkingSet<'a> {
    if cfg.error_feedback {
        let TransformState { residual, scratch, .. } = &mut *state;
        residual.resize(grad.len(), 0.0);
        scratch.clear();
        scratch.reserve(grad.len());
        for (&g, &r) in grad.iter().zip(residual.iter()) {
            scratch.push(g + r);
        }
    }
    match cfg.kind {
        Transform::Identity => {
            if cfg.error_feedback {
                WorkingSet::Dense(&state.scratch)
            } else {
                WorkingSet::Dense(grad)
            }
        }
        Transform::TopK { ratio } => {
            let TransformState {
                scratch, values, indices, order, pivot, ..
            } = state;
            let src: &[f32] =
                if cfg.error_feedback { scratch.as_slice() } else { grad };
            let k = topk_count(src.len(), ratio);
            select_topk(src, k, order, pivot, indices, values);
            WorkingSet::Sparse { indices: &*indices, values: &*values }
        }
    }
}

/// Stage-1 epilogue, after quantization: bank the fresh quantization
/// error into the residual (EF) and record the round diagnostics.
/// `recon` reconstructs the working *values* in the raw gradient domain
/// (length k for sparse, d for dense; ignored when EF is off).
pub(crate) fn absorb(
    cfg: TransformCfg,
    d: usize,
    recon: &[f32],
    state: &mut TransformState,
) {
    state.last_sparsity = if cfg.is_sparse() {
        state.indices.len() as f64 / d.max(1) as f64
    } else {
        1.0
    };
    if !cfg.error_feedback {
        state.last_ef_norm = f64::NAN;
        return;
    }
    // scratch = grad + residual_old (filled by forward); the new
    // residual is whatever of it the wire did not carry
    let norm = {
        let TransformState { residual, scratch, indices, .. } = &mut *state;
        if cfg.is_sparse() {
            residual.copy_from_slice(scratch);
            for (&i, &q) in indices.iter().zip(recon) {
                residual[i as usize] -= q;
            }
        } else {
            for ((r, &s), &q) in
                residual.iter_mut().zip(scratch.iter()).zip(recon)
            {
                *r = s - q;
            }
        }
        residual
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    };
    state.last_ef_norm = norm;
}

/// Kept-coordinate count for dimension `d` at `ratio`: `ceil(ratio·d)`,
/// at least 1 for a non-empty gradient.
pub(crate) fn topk_count(d: usize, ratio: f64) -> usize {
    if d == 0 {
        return 0;
    }
    ((d as f64 * ratio).ceil() as usize).clamp(1, d)
}

/// Strided pivot-sample budget for the threshold-first top-k pass.
const PIVOT_SAMPLE: usize = 1024;

/// Deterministic top-k selection by |value|, ties broken toward the
/// lower index (a strict total order, so the selected *set* is unique
/// however the partition shuffles). Output indices ascend. `order` and
/// `pivot_buf` are caller-owned scratch (the hot path reuses the
/// state's buffers, so selection is allocation-free after warm-up).
///
/// §Perf (threshold-first): for large `d`, feeding all `d` indices to
/// `select_nth_unstable_by` costs an O(d) partition over an
/// index-indirect comparator. Instead a strided |value| sample picks a
/// pivot at twice the keep fraction's rank (safety margin), one
/// branch-free pass collects the candidates that survive the pivot —
/// typically ≈ 2k ≪ d — and only the candidate set enters the
/// selection. The candidate test `!(|v| < pivot)` keeps every NaN (NaN
/// magnitudes rank above +∞ under `total_cmp`, so they are always
/// selected first), and a pivot that overshoots (fewer than k
/// candidates) falls back to the full index set. Because the selected
/// set is unique under the strict total order, the fast path is
/// byte-identical to the reference (`select_topk_reference`, test-only)
/// on every input — the in-module differential tests pin this.
fn select_topk(
    src: &[f32],
    k: usize,
    order: &mut Vec<u32>,
    pivot_buf: &mut Vec<f32>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) {
    indices.clear();
    values.clear();
    let d = src.len();
    if k == 0 || d == 0 {
        return;
    }
    let cmp = |a: &u32, b: &u32| {
        let ma = src[*a as usize].abs();
        let mb = src[*b as usize].abs();
        mb.total_cmp(&ma).then_with(|| a.cmp(b))
    };
    order.clear();
    if k < d && d > PIVOT_SAMPLE {
        let stride = d.div_ceil(PIVOT_SAMPLE).max(1);
        pivot_buf.clear();
        pivot_buf.extend(src.iter().step_by(stride).map(|v| v.abs()));
        let m = pivot_buf.len();
        // pivot rank: 2× the keep fraction, so the expected candidate
        // count is ≈ 2k — cheap insurance against sampling error
        let frac = k as f64 / d as f64;
        let r = ((2.0 * frac * m as f64) as usize).min(m - 1);
        pivot_buf.select_nth_unstable_by(r, |a, b| b.total_cmp(a));
        let pivot = pivot_buf[r];
        // negated compare: NaN fails `<`, so NaNs stay candidates; a
        // NaN pivot admits everything (degenerates to full selection)
        for (i, &v) in src.iter().enumerate() {
            if !(v.abs() < pivot) {
                order.push(i as u32);
            }
        }
        if order.len() < k {
            // the unsampled tail was heavier than the sample suggested:
            // correctness first, take the full index set
            order.clear();
            order.extend(0..d as u32);
        }
    } else {
        order.extend(0..d as u32);
    }
    if k < order.len() {
        order.select_nth_unstable_by(k - 1, cmp);
        order.truncate(k);
    }
    order.sort_unstable();
    indices.extend_from_slice(order);
    values.extend(order.iter().map(|&i| src[i as usize]));
}

/// Scalar reference for [`select_topk`]: full `d`-element selection, no
/// pivot pre-pass. The differential tests pin the fast path's output
/// byte-identical to this oracle.
#[cfg(test)]
fn select_topk_reference(
    src: &[f32],
    k: usize,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) {
    indices.clear();
    values.clear();
    let d = src.len();
    if k == 0 || d == 0 {
        return;
    }
    let mut order: Vec<u32> = (0..d as u32).collect();
    let cmp = |a: &u32, b: &u32| {
        let ma = src[*a as usize].abs();
        let mb = src[*b as usize].abs();
        mb.total_cmp(&ma).then_with(|| a.cmp(b))
    };
    if k < d {
        order.select_nth_unstable_by(k - 1, cmp);
        order.truncate(k);
    }
    order.sort_unstable();
    indices.extend_from_slice(&order);
    values.extend(order.iter().map(|&i| src[i as usize]));
}

/// Bits per packed index for dimension `d`: `ceil(log2 d)`, min 1.
pub(crate) fn index_width(d: usize) -> u32 {
    (usize::BITS - (d.max(2) - 1).leading_zeros()).max(1)
}

/// Serialize the sparse index block: `k` as u32 LE, then `k` packed
/// [`index_width`]-bit indices, byte-padded. Returns `(bytes, bits)` —
/// `bits` is the exact wire cost charged to `Packet::index_bits`.
pub(crate) fn pack_indices(d: usize, indices: &[u32]) -> (Vec<u8>, u64) {
    let w = index_width(d);
    let mut bw = BitWriter::new();
    for &i in indices {
        bw.push(i as u64, w);
    }
    let body = bw.finish();
    let bits = 32 + body.len() as u64 * 8;
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    (out, bits)
}

/// Parse and validate the index block at a payload head. Returns the
/// indices and the bytes consumed. Malformed blocks — truncation, `k`
/// outside `1..=d`, out-of-range or non-increasing indices (a corrupted
/// stream decodes to *something*, so monotonicity is the integrity
/// check) — are recoverable `Err`s, never panics.
pub(crate) fn unpack_indices(
    d: usize,
    payload: &[u8],
) -> Result<(Vec<u32>, usize)> {
    if payload.len() < 4 {
        return Err(Error::Coding("sparse payload too short".into()));
    }
    let k = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    if k == 0 || k > d {
        return Err(Error::Coding(format!(
            "sparse packet keeps {k} of {d} coordinates")));
    }
    let w = index_width(d);
    let body_bytes = (k as u64 * w as u64).div_ceil(8) as usize;
    if payload.len() < 4 + body_bytes {
        return Err(Error::Coding("sparse index block truncated".into()));
    }
    let mut r = BitReader::new(&payload[4..4 + body_bytes]);
    let mut indices = Vec::with_capacity(k);
    let mut prev: i64 = -1;
    for _ in 0..k {
        let i = r.read(w) as u32;
        if i as usize >= d || i as i64 <= prev {
            return Err(Error::Coding(format!(
                "sparse index stream corrupt (index {i} after {prev}, \
                 d={d})")));
        }
        prev = i as i64;
        indices.push(i);
    }
    Ok((indices, 4 + body_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_suffixes_are_stable() {
        assert_eq!(TransformCfg::identity().suffix(), "");
        assert_eq!(TransformCfg::identity().label(), "id");
        assert!(!TransformCfg::identity().is_active());
        let ef = TransformCfg::identity().with_ef();
        assert_eq!(ef.suffix(), "_ef");
        assert_eq!(ef.label(), "ef");
        assert!(ef.is_active() && !ef.is_sparse());
        let tk = TransformCfg::topk(0.1);
        assert_eq!(tk.suffix(), "_topk0.1");
        assert_eq!(tk.label(), "topk0.1");
        assert!(tk.is_active() && tk.is_sparse());
        assert_eq!(tk.with_ef().suffix(), "_topk0.1_ef");
        assert_eq!(tk.with_ef().label(), "topk0.1+ef");
    }

    #[test]
    fn validation_rejects_bad_ratios_and_qsgd() {
        let lloyd = CompressionScheme::Lloyd { bits: 3 };
        assert!(TransformCfg::topk(0.5).validate(&lloyd).is_ok());
        assert!(TransformCfg::topk(1.0).validate(&lloyd).is_ok());
        assert!(TransformCfg::topk(0.0).validate(&lloyd).is_err());
        assert!(TransformCfg::topk(1.5).validate(&lloyd).is_err());
        assert!(TransformCfg::topk(f64::NAN).validate(&lloyd).is_err());
        let qsgd = CompressionScheme::Qsgd { bits: 3 };
        assert!(TransformCfg::topk(0.5).validate(&qsgd).is_err());
        // EF alone is fine everywhere, qsgd included
        assert!(TransformCfg::identity().with_ef().validate(&qsgd).is_ok());
    }

    #[test]
    fn topk_selection_is_deterministic_with_index_tiebreak() {
        let src = [1.0f32, -3.0, 2.0, -2.0, 0.5, 2.0];
        let (mut order, mut pivot) = (Vec::new(), Vec::new());
        let (mut idx, mut vals) = (Vec::new(), Vec::new());
        select_topk(&src, 3, &mut order, &mut pivot, &mut idx, &mut vals);
        // |−3| > |2| (index 2 beats the tied index 5) > |−2|
        assert_eq!(idx, vec![1, 2, 3]);
        assert_eq!(vals, vec![-3.0, 2.0, -2.0]);
        // k = d keeps everything, ascending
        select_topk(&src, 6, &mut order, &mut pivot, &mut idx, &mut vals);
        assert_eq!(idx, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(vals.len(), 6);
    }

    /// Fast (threshold-first) path vs full-selection oracle, byte-level.
    fn assert_topk_matches_reference(src: &[f32], k: usize, tag: &str) {
        let (mut order, mut pivot) = (Vec::new(), Vec::new());
        let (mut idx, mut vals) = (Vec::new(), Vec::new());
        select_topk(src, k, &mut order, &mut pivot, &mut idx, &mut vals);
        let (mut ridx, mut rvals) = (Vec::new(), Vec::new());
        select_topk_reference(src, k, &mut ridx, &mut rvals);
        assert_eq!(idx, ridx, "{tag}: index set diverged (k={k})");
        let a: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = rvals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "{tag}: values diverged bitwise (k={k})");
    }

    #[test]
    fn topk_threshold_path_matches_reference() {
        // d > PIVOT_SAMPLE so the pivot pre-pass engages
        let d = 5000usize;
        // deterministic pseudo-random values with sign flips and a
        // heavy-tailed spread (no external RNG in unit tests)
        let mut x = 0x243F6A8885A308D3u64;
        let src: Vec<f32> = (0..d)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                let u = (x >> 40) as f32 / (1u64 << 24) as f32; // [0,1)
                (u - 0.5) * (1.0 + (x & 0xF) as f32)
            })
            .collect();
        for k in [1usize, 10, 50, 500, 2500, 4999, 5000] {
            assert_topk_matches_reference(&src, k, "random");
        }
        // exact ties everywhere: selection must resolve by index alone
        let ties: Vec<f32> =
            (0..d).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        for k in [1usize, 100, 2048] {
            assert_topk_matches_reference(&ties, k, "ties");
        }
        // all zeros: pivot is 0, every coordinate is a candidate
        let zeros = vec![0f32; d];
        assert_topk_matches_reference(&zeros, 37, "zeros");
        // NaNs scattered in: NaN magnitudes sort above everything under
        // total_cmp and must survive the candidate filter
        let mut nans = src.clone();
        for i in (0..d).step_by(701) {
            nans[i] = f32::NAN;
        }
        for k in [3usize, 64, 1500] {
            assert_topk_matches_reference(&nans, k, "nan");
        }
        // mostly-zero input with a few spikes: the pivot collapses to 0
        // and the fallback logic must not drop the spikes
        let mut spikes = vec![0f32; d];
        spikes[7] = 9.0;
        spikes[4096] = -11.0;
        for k in [1usize, 2, 100] {
            assert_topk_matches_reference(&spikes, k, "spikes");
        }
    }

    #[test]
    fn topk_count_bounds() {
        assert_eq!(topk_count(0, 0.5), 0);
        assert_eq!(topk_count(10, 0.1), 1);
        assert_eq!(topk_count(10, 0.25), 3);
        assert_eq!(topk_count(10, 1.0), 10);
        assert_eq!(topk_count(10, 0.0001), 1);
    }

    #[test]
    fn index_width_is_ceil_log2() {
        assert_eq!(index_width(1), 1);
        assert_eq!(index_width(2), 1);
        assert_eq!(index_width(3), 2);
        assert_eq!(index_width(64), 6);
        assert_eq!(index_width(65), 7);
        assert_eq!(index_width(4096), 12);
    }

    #[test]
    fn index_block_roundtrips_and_rejects_corruption() {
        let d = 1000;
        let idx = vec![0u32, 7, 512, 999];
        let (bytes, bits) = pack_indices(d, &idx);
        assert_eq!(bits, 32 + ((4 * 10) as u64).div_ceil(8) * 8);
        assert_eq!(bytes.len() as u64 * 8, bits);
        let (back, consumed) = unpack_indices(d, &bytes).unwrap();
        assert_eq!(back, idx);
        assert_eq!(consumed, bytes.len());
        // truncated head / body
        assert!(unpack_indices(d, &bytes[..3]).is_err());
        assert!(unpack_indices(d, &bytes[..5]).is_err());
        // k out of range
        let mut bad = bytes.clone();
        bad[0..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(unpack_indices(d, &bad).is_err());
        bad[0..4].copy_from_slice(&(d as u32 + 1).to_le_bytes());
        assert!(unpack_indices(d, &bad).is_err());
        // non-increasing stream: duplicate the first index over the second
        let dup = vec![7u32, 7, 512, 999];
        let (dup_bytes, _) = pack_indices(d, &dup);
        assert!(unpack_indices(d, &dup_bytes).is_err());
    }

    #[test]
    fn ef_forward_absorb_banks_the_quantization_error() {
        let cfg = TransformCfg::identity().with_ef();
        let mut state = TransformState::new();
        let grad = vec![1.0f32, -2.0, 0.5];
        {
            let ws = forward(cfg, &grad, &mut state);
            match ws {
                WorkingSet::Dense(v) => assert_eq!(v, &grad[..]),
                _ => panic!("identity+ef must stay dense"),
            }
        }
        // pretend the quantizer reconstructed with error +0.1 everywhere
        let recon: Vec<f32> = grad.iter().map(|&g| g + 0.1).collect();
        absorb(cfg, grad.len(), &recon, &mut state);
        for &r in state.residual() {
            assert!((r + 0.1).abs() < 1e-6, "residual {r}");
        }
        assert!((state.last_sparsity - 1.0).abs() < 1e-12);
        assert!(state.last_ef_norm > 0.0);
        // next round the residual rides along
        {
            let ws = forward(cfg, &grad, &mut state);
            let WorkingSet::Dense(v) = ws else { panic!() };
            for (x, &g) in v.iter().zip(&grad) {
                assert!((x - (g - 0.1)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ef_topk_residual_keeps_untransmitted_mass() {
        let cfg = TransformCfg::topk(0.5).with_ef();
        let grad = vec![4.0f32, 0.1, -3.0, 0.2];
        let mut state = TransformState::new();
        {
            let ws = forward(cfg, &grad, &mut state);
            let WorkingSet::Sparse { indices, values } = ws else {
                panic!()
            };
            assert_eq!(indices, &[0, 2]);
            assert_eq!(values, &[4.0, -3.0]);
        }
        // exact reconstruction of the kept values
        absorb(cfg, grad.len(), &[4.0, -3.0], &mut state);
        assert_eq!(state.residual(), &[0.0, 0.1, 0.0, 0.2]);
        assert!((state.last_sparsity - 0.5).abs() < 1e-12);
        // the dropped coordinates come back next round
        {
            let ws = forward(cfg, &[0.0f32; 4], &mut state);
            let WorkingSet::Sparse { indices, values } = ws else {
                panic!()
            };
            assert_eq!(indices, &[1, 3]);
            assert_eq!(values, &[0.1, 0.2]);
        }
    }
}
