//! Scheme + wire-coder configuration: the *what* of the quantize and
//! code stages (the *how* lives in [`super::quantize`]).

use crate::fl::packet::SchemeTag;
use crate::quant::rcq::LengthModel;

/// Which wire entropy coder carries the symbols.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCoder {
    /// canonical Huffman (paper default)
    Huffman,
    /// static arithmetic coding (Shannon-bound reference)
    Arithmetic,
    /// per-block canonical Huffman with table refresh + optional MTF
    /// front end (the throughput tier, [`crate::coding::block`])
    Block,
}

impl WireCoder {
    /// Stable CLI / CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            WireCoder::Huffman => "huffman",
            WireCoder::Arithmetic => "arithmetic",
            WireCoder::Block => "block",
        }
    }
}

/// Scheme selection + hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressionScheme {
    /// the paper's contribution: rate-constrained quantization
    RcFed { bits: u32, lambda: f64, length_model: LengthModel },
    /// Lloyd-Max baseline [16]
    Lloyd { bits: u32 },
    /// NQFL companding baseline [14]
    Nqfl { bits: u32 },
    /// QSGD baseline [8]
    Qsgd { bits: u32 },
    /// plain uniform grid over ±clip
    Uniform { bits: u32, clip: f64 },
    /// uncompressed float32 reference
    Fp32,
    /// sign quantization (FedTern-style floor): 1 bit/coordinate plus a
    /// per-packet mean-|x| scale — the cheapest baseline either link
    /// direction can run
    Sign,
}

impl CompressionScheme {
    pub fn tag(&self) -> SchemeTag {
        match self {
            CompressionScheme::RcFed { .. } => SchemeTag::RcFed,
            CompressionScheme::Lloyd { .. } => SchemeTag::Lloyd,
            CompressionScheme::Nqfl { .. } => SchemeTag::Nqfl,
            CompressionScheme::Qsgd { .. } => SchemeTag::Qsgd,
            CompressionScheme::Uniform { .. } => SchemeTag::Uniform,
            CompressionScheme::Fp32 => SchemeTag::Fp32,
            CompressionScheme::Sign => SchemeTag::Sign,
        }
    }

    pub fn bits(&self) -> u32 {
        match *self {
            CompressionScheme::RcFed { bits, .. }
            | CompressionScheme::Lloyd { bits }
            | CompressionScheme::Nqfl { bits }
            | CompressionScheme::Qsgd { bits }
            | CompressionScheme::Uniform { bits, .. } => bits,
            CompressionScheme::Fp32 => 32,
            CompressionScheme::Sign => 1,
        }
    }

    /// The same scheme with its bit-width rebound — how the rate
    /// allocator derives a client's per-width operating point from the
    /// configured base scheme. A no-op for `Fp32` and `Sign` (neither
    /// has a width to rebind).
    pub fn with_bits(self, bits: u32) -> CompressionScheme {
        match self {
            CompressionScheme::RcFed { lambda, length_model, .. } => {
                CompressionScheme::RcFed { bits, lambda, length_model }
            }
            CompressionScheme::Lloyd { .. } => {
                CompressionScheme::Lloyd { bits }
            }
            CompressionScheme::Nqfl { .. } => CompressionScheme::Nqfl { bits },
            CompressionScheme::Qsgd { .. } => CompressionScheme::Qsgd { bits },
            CompressionScheme::Uniform { clip, .. } => {
                CompressionScheme::Uniform { bits, clip }
            }
            CompressionScheme::Fp32 => CompressionScheme::Fp32,
            CompressionScheme::Sign => CompressionScheme::Sign,
        }
    }

    /// Short label for CSVs/logs, e.g. `rcfed_b3_l0.050`.
    pub fn label(&self) -> String {
        match *self {
            CompressionScheme::RcFed { bits, lambda, .. } => {
                format!("rcfed_b{bits}_l{lambda:.3}")
            }
            CompressionScheme::Lloyd { bits } => format!("lloyd_b{bits}"),
            CompressionScheme::Nqfl { bits } => format!("nqfl_b{bits}"),
            CompressionScheme::Qsgd { bits } => format!("qsgd_b{bits}"),
            CompressionScheme::Uniform { bits, .. } => format!("uniform_b{bits}"),
            CompressionScheme::Fp32 => "fp32".into(),
            CompressionScheme::Sign => "sign".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_labels_are_stable() {
        assert_eq!(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman
            }
            .label(),
            "rcfed_b3_l0.050"
        );
        assert_eq!(CompressionScheme::Qsgd { bits: 6 }.label(), "qsgd_b6");
    }

    #[test]
    fn with_bits_rebinds_every_width_scheme() {
        let rc = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.1,
            length_model: LengthModel::Huffman,
        };
        assert_eq!(rc.with_bits(5).bits(), 5);
        assert_eq!(CompressionScheme::Lloyd { bits: 2 }.with_bits(4).bits(), 4);
        assert_eq!(CompressionScheme::Fp32.with_bits(4), CompressionScheme::Fp32);
        assert_eq!(CompressionScheme::Sign.with_bits(4), CompressionScheme::Sign);
        assert_eq!(CompressionScheme::Sign.bits(), 1);
        assert_eq!(CompressionScheme::Sign.label(), "sign");
        assert_eq!(
            CompressionScheme::Uniform { bits: 3, clip: 4.0 }.with_bits(6),
            CompressionScheme::Uniform { bits: 6, clip: 4.0 }
        );
    }
}
