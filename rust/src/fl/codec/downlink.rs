//! Direction-agnostic delta codec: the server→client model broadcast
//! flowing through the same Transform → Kernel → WireCoder stages as
//! the uplink gradients.
//!
//! The historical round loop broadcast `θ_t` as an *uncharged* fp32
//! side channel — the ledger modeled gradient uplink only. This module
//! symmetrizes the codec: the server encodes the model **delta**
//! `θ_t − θ_{t−1}` through a [`Compressor`] with a server-owned
//! error-feedback [`TransformState`], and every up-to-date client
//! dequantizes the broadcast into its replica `θ̂_v`.
//!
//! The protocol is the EF induction: with residual `r` and reference
//! `θ̂` both starting at zero (version 0 is the agreed "zero model"),
//! round `t` quantizes `w_t = (θ_t − θ_{t−1}) + r_{t−1}` into `q_t`,
//! banks `r_t = w_t − q_t`, and every client applies `θ̂_t = θ̂_{t−1} +
//! q_t` — so `θ_t − θ̂_t = r_t` by induction and **one** server-side
//! residual serves the whole population; no per-client replica state
//! exists anywhere. Clients that missed broadcasts (never sampled while
//! versions advanced) are behind `θ̂_v`; the round layer detects this
//! via the version word on the wire and resyncs them with one fp32
//! unicast of `θ̂_v` ([`DeltaCodec::resync_bits`]) — stale deltas are
//! *rejected*, never silently applied.
//!
//! Under a [`super::pipeline::RateTarget::Joint`] budget the codec also
//! runs the downlink half of the dual-ascent controller: measured
//! ledger bits over delivered coordinates steer a private λ, and each
//! window end re-designs the delta codebook against the window's
//! empirical samples — the exact machinery the uplink Track loop uses,
//! pointed the other way.

use crate::coding::arithmetic::ArithmeticCoder;
use crate::coding::huffman::HuffmanCode;
use crate::fl::packet::{Packet, HEADER_BITS};
use crate::stats::empirical::EmpiricalPdf;
use crate::stats::moments::Welford;
use crate::util::rng::Rng;
use crate::util::{Error, Result};

use super::compressor::Compressor;
use super::design::{codebook_broadcast_bits, designed_adaptive_codebook};
use super::pipeline::{
    MAX_WINDOW_SAMPLES, STEP_GROW, STEP_INIT, STEP_MAX, STEP_MIN,
    STEP_SHRINK,
};
use super::quantize::{CodecScratch, Kernel};
use super::scheme::{CompressionScheme, WireCoder};
use super::transform::{TransformCfg, TransformState};

/// Which way a codec context points. The stage graph is identical in
/// both directions; the direction only names the ledger the bits are
/// charged to and the party that owns the EF residual.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// client → server (gradients; residual owned by each client)
    Uplink,
    /// server → client (model deltas; residual owned by the server)
    Downlink,
}

impl Direction {
    /// Stable label for CSVs and error messages.
    pub fn label(&self) -> &'static str {
        match self {
            Direction::Uplink => "up",
            Direction::Downlink => "down",
        }
    }
}

/// The downlink half of a joint rate budget: a private dual-ascent
/// state mirroring the uplink Track loop in
/// [`super::pipeline::CompressionPipeline`].
struct DeltaCtl {
    target: f64,
    window: usize,
    lambda: f64,
    step: f64,
    prev_err: f64,
    adapt_step: u32,
    window_bits: u64,
    window_coords: u64,
    samples: Vec<f32>,
    moments: Welford,
    last_realized: f64,
}

/// Versioned delta codec over one model vector (see module docs).
pub struct DeltaCodec {
    direction: Direction,
    compressor: Compressor,
    /// the EF residual (server-owned for the downlink direction)
    state: TransformState,
    scratch: CodecScratch,
    /// raw params at the last encode (`θ_{t−1}`)
    prev: Vec<f32>,
    /// the reconstructed replica `θ̂_v` every up-to-date peer holds
    reference: Vec<f32>,
    /// model version: bumped on every encode; v0 is the zero model
    version: u32,
    d: usize,
    /// encode-side delta scratch
    delta: Vec<f32>,
    ctl: Option<DeltaCtl>,
}

impl DeltaCodec {
    /// Static delta codec: one designed compressor, no rate controller.
    pub fn design(
        direction: Direction,
        scheme: CompressionScheme,
        wire: WireCoder,
        d: usize,
    ) -> Result<DeltaCodec> {
        DeltaCodec::design_with_target(direction, scheme, wire, d, None)
    }

    /// Like [`Self::design`], with the optional closed-loop operating
    /// point `(target bits/coord, window)` — the
    /// [`super::pipeline::RateTarget::down_params`] share of a joint
    /// budget. A target requires the rcfed scheme (λ is the control
    /// variable, exactly as on the uplink).
    pub fn design_with_target(
        direction: Direction,
        scheme: CompressionScheme,
        wire: WireCoder,
        d: usize,
        target: Option<(f64, usize)>,
    ) -> Result<DeltaCodec> {
        if d == 0 {
            return Err(Error::Config(
                "delta codec needs a non-empty model".into()));
        }
        if matches!(scheme, CompressionScheme::Qsgd { .. }) {
            return Err(Error::Config(format!(
                "{}link delta coding does not support qsgd (its bucketed \
                 norms leave no room for the version word); use a \
                 designed-codebook scheme, sign or fp32",
                direction.label()
            )));
        }
        if let Some((bpc, window)) = target {
            if !(bpc > 0.0 && bpc.is_finite()) {
                return Err(Error::Config(format!(
                    "{}link rate target {bpc} must be finite and > 0",
                    direction.label()
                )));
            }
            if window == 0 {
                return Err(Error::Config(format!(
                    "{}link rate target needs adapt-every >= 1",
                    direction.label()
                )));
            }
            if !matches!(scheme, CompressionScheme::RcFed { .. }) {
                return Err(Error::Config(format!(
                    "{}link rate targeting requires the rcfed scheme (λ \
                     is the control variable); got {scheme:?}",
                    direction.label()
                )));
            }
        }
        // fp32 deltas are lossless, so the residual is identically zero;
        // skip the EF stage there and bank it everywhere else
        let transform = if matches!(scheme, CompressionScheme::Fp32) {
            TransformCfg::identity()
        } else {
            TransformCfg::identity().with_ef()
        };
        let compressor =
            Compressor::design_with_transform(scheme, wire, transform)?;
        let lambda = match scheme {
            CompressionScheme::RcFed { lambda, .. } => lambda,
            _ => 0.0,
        };
        Ok(DeltaCodec {
            direction,
            compressor,
            state: TransformState::new(),
            scratch: CodecScratch::new(),
            prev: vec![0f32; d],
            reference: vec![0f32; d],
            version: 0,
            d,
            delta: vec![0f32; d],
            ctl: target.map(|(target, window)| DeltaCtl {
                target,
                window,
                lambda,
                step: STEP_INIT,
                prev_err: f64::NAN,
                adapt_step: 0,
                window_bits: 0,
                window_coords: 0,
                samples: Vec::new(),
                moments: Welford::default(),
                last_realized: f64::NAN,
            }),
        })
    }

    pub fn direction(&self) -> Direction {
        self.direction
    }

    /// Current model version (`θ̂_v`; v0 is the agreed zero model).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// The reconstructed replica every up-to-date peer holds.
    pub fn reference(&self) -> &[f32] {
        &self.reference
    }

    /// Wire cost of resyncing one lagging peer: a raw fp32 unicast of
    /// `θ̂_v` under the standard packet header.
    pub fn resync_bits(&self) -> u64 {
        HEADER_BITS + 32 * self.d as u64
    }

    /// ‖EF residual‖₂ after the last encode (NaN before the first, and
    /// always for fp32, which carries no residual).
    pub fn last_ef_norm(&self) -> f64 {
        self.state.last_ef_norm
    }

    pub fn is_adaptive(&self) -> bool {
        self.ctl.is_some()
    }

    /// Current multiplier of the downlink controller (NaN when static).
    pub fn lambda(&self) -> f64 {
        self.ctl.as_ref().map_or(f64::NAN, |c| c.lambda)
    }

    /// Measured downlink bits/coordinate of the last closed window (NaN
    /// when static or before the first window closes).
    pub fn last_realized(&self) -> f64 {
        self.ctl.as_ref().map_or(f64::NAN, |c| c.last_realized)
    }

    /// Encode this round's model delta `params − prev` (plus the banked
    /// EF residual) into a versioned packet and advance to version
    /// `v+1`. `rng` mirrors the uplink signature (the deterministic
    /// schemes draw nothing).
    pub fn encode_round(
        &mut self,
        params: &[f32],
        round: u32,
        rng: &mut Rng,
    ) -> Result<Packet> {
        if params.len() != self.d {
            return Err(Error::Config(format!(
                "model {} coords vs delta codec d {}",
                params.len(),
                self.d
            )));
        }
        for (dl, (&p, &q)) in
            self.delta.iter_mut().zip(params.iter().zip(&self.prev))
        {
            *dl = p - q;
        }
        let capture = self.ctl.is_some();
        let mut pkt = self.compressor.compress_with_sample(
            &mut self.state,
            &mut self.scratch,
            u32::MAX, // the PS, not a client
            round,
            &self.delta,
            rng,
            capture,
        )?;
        // the version rides as the LAST side-info word, after whatever
        // the kernel wrote — the same convention as the uplink pipeline
        pkt.side_info.push((self.version + 1) as f32);
        self.version += 1;
        self.prev.copy_from_slice(params);
        if let Some(ctl) = &mut self.ctl {
            if let Some(sample) = self.state.take_sample() {
                for &z in &sample {
                    if !z.is_finite() {
                        continue;
                    }
                    ctl.moments.push(z as f64);
                    if ctl.samples.len() < MAX_WINDOW_SAMPLES {
                        ctl.samples.push(z);
                    }
                }
            }
        }
        Ok(pkt)
    }

    /// Decode a current-version delta into the shared replica and
    /// return `θ̂_v`. A packet whose version word does not match the
    /// codec's current version is a **recoverable reject** (the peer
    /// must be resynced), never a silent mis-decode.
    pub fn decode_current(&mut self, packet: &Packet) -> Result<&[f32]> {
        if packet.d as usize != self.d {
            return Err(Error::Coding(format!(
                "delta packet d={} vs model d={}", packet.d, self.d)));
        }
        let ver = packet.last_side_version()?;
        if ver != self.version {
            return Err(Error::Coding(format!(
                "stale {}link delta v{ver} (current v{})",
                self.direction.label(),
                self.version
            )));
        }
        match &self.compressor.kernel {
            Kernel::Codebook { .. } => {
                if packet.side_info.len() != 3 {
                    return Err(Error::Coding(format!(
                        "delta packet carries {} side-info values, \
                         expected 3 (μ, σ, version)",
                        packet.side_info.len()
                    )));
                }
                let (mu, sigma) =
                    (packet.side_info[0], packet.side_info[1]);
                self.compressor.decode_codebook_accumulate(
                    packet, mu, sigma, &mut self.reference)?;
            }
            Kernel::Sign => {
                if packet.side_info.len() != 2 {
                    return Err(Error::Coding(format!(
                        "sign delta packet carries {} side-info values, \
                         expected 2 (scale, version)",
                        packet.side_info.len()
                    )));
                }
                self.compressor.decode_sign_accumulate(
                    packet, packet.side_info[0], &mut self.reference)?;
            }
            Kernel::Fp32 => {
                // fp32 reads no side info beyond the version word
                self.compressor
                    .decompress_accumulate(packet, &mut self.reference)?;
            }
            Kernel::Qsgd(_) => {
                return Err(Error::Coding(
                    "qsgd delta packets are rejected at design time"
                        .into(),
                ));
            }
        }
        Ok(&self.reference)
    }

    /// Report one round's ledger movement: `bits` as charged by the
    /// network for this direction, over `coords` delivered coordinates
    /// (model dim × receivers). A no-op for static codecs.
    pub fn observe_round(&mut self, bits: u64, coords: u64) {
        if let Some(ctl) = &mut self.ctl {
            ctl.window_bits += bits;
            ctl.window_coords += coords;
        }
    }

    /// Close round `round` (0-based). On a window boundary the
    /// controller runs dual ascent on the downlink λ and re-designs the
    /// delta codebook against the window's empirical samples; the
    /// returned bits are the publication cost the caller must charge
    /// (every client needs the new codebook to keep decoding).
    pub fn end_round(&mut self, round: usize) -> Result<Option<u64>> {
        let Some(ctl) = &mut self.ctl else {
            return Ok(None);
        };
        if (round + 1) % ctl.window != 0 {
            return Ok(None);
        }
        if ctl.window_coords == 0 || ctl.samples.is_empty() {
            // nothing delivered this window (empty cohorts): hold λ and
            // keep accumulating — same guard as the uplink loop
            return Ok(None);
        }
        let realized = ctl.window_bits as f64 / ctl.window_coords as f64;
        ctl.last_realized = realized;
        let err = realized - ctl.target;
        if ctl.prev_err.is_finite() {
            ctl.step *= if err.signum() == ctl.prev_err.signum() {
                STEP_GROW
            } else {
                STEP_SHRINK
            };
            ctl.step = ctl.step.clamp(STEP_MIN, STEP_MAX);
        }
        ctl.prev_err = err;
        ctl.lambda = (ctl.lambda + ctl.step * err).max(0.0);
        let CompressionScheme::RcFed { bits, length_model, .. } =
            self.compressor.scheme
        else {
            return Err(Error::Config(
                "rate-constrained delta codec without an rcfed scheme"
                    .into(),
            ));
        };
        let samples = std::mem::take(&mut ctl.samples);
        let moments = (
            ctl.moments.mean(),
            ctl.moments.stddev(),
            ctl.moments.count(),
        );
        let pdf = EmpiricalPdf::from_samples(&samples);
        ctl.adapt_step += 1;
        let warm = self.compressor.codebook().cloned();
        let (cb, rep) = designed_adaptive_codebook(
            bits,
            ctl.lambda,
            length_model,
            ctl.adapt_step,
            moments,
            &pdf,
            warm.as_ref(),
        )?;
        let huffman = HuffmanCode::from_probs(&rep.probs)?;
        let arith = ArithmeticCoder::from_probs(&rep.probs)?;
        let broadcast = codebook_broadcast_bits(&cb);
        self.compressor.kernel =
            Kernel::Codebook { codebook: cb, huffman, arith };
        self.compressor.design_mse = Some(rep.mse);
        self.compressor.design_rate = Some(rep.huffman_rate);
        ctl.window_bits = 0;
        ctl.window_coords = 0;
        ctl.moments = Welford::default();
        Ok(Some(broadcast))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rcq::LengthModel;

    fn rcfed_scheme() -> CompressionScheme {
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        }
    }

    fn walk(params: &mut [f32], rng: &mut Rng, scale: f32) {
        let mut step = vec![0f32; params.len()];
        rng.fill_normal_f32(&mut step, 0.0, scale);
        for (p, s) in params.iter_mut().zip(&step) {
            *p += s;
        }
    }

    #[test]
    fn direction_labels_are_stable() {
        assert_eq!(Direction::Uplink.label(), "up");
        assert_eq!(Direction::Downlink.label(), "down");
    }

    #[test]
    fn ef_chain_tracks_the_model_within_the_residual() {
        // the module invariant: θ_t − θ̂_t = r_t after every round, so
        // replica error never exceeds the banked residual
        let d = 2048;
        let mut dc = DeltaCodec::design(
            Direction::Downlink, rcfed_scheme(), WireCoder::Huffman, d,
        )
        .unwrap();
        let mut rng = Rng::new(51);
        let mut model_rng = Rng::new(52);
        let mut params = vec![0f32; d];
        walk(&mut params, &mut model_rng, 1.0);
        for round in 0..8 {
            let pkt =
                dc.encode_round(&params, round, &mut rng).unwrap();
            assert_eq!(pkt.client_id, u32::MAX);
            assert_eq!(dc.version(), round + 1);
            let replica =
                dc.decode_current(&pkt).unwrap().to_vec();
            // θ − θ̂ must equal the residual the encoder banked
            let residual = dc.state.residual();
            let err_norm: f64 = params
                .iter()
                .zip(&replica)
                .map(|(&p, &q)| f64::from(p - q).powi(2))
                .sum::<f64>()
                .sqrt();
            let res_norm: f64 = residual
                .iter()
                .map(|&r| f64::from(r).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(
                (err_norm - res_norm).abs() < 1e-3 * (1.0 + res_norm),
                "round {round}: replica error {err_norm} vs banked \
                 residual {res_norm}"
            );
            assert!((dc.last_ef_norm() - res_norm).abs() < 1e-9);
            walk(&mut params, &mut model_rng, 0.05);
        }
    }

    #[test]
    fn stale_delta_is_a_recoverable_reject() {
        let d = 512;
        let mut dc = DeltaCodec::design(
            Direction::Downlink, rcfed_scheme(), WireCoder::Huffman, d,
        )
        .unwrap();
        let mut rng = Rng::new(61);
        let params = vec![0.5f32; d];
        let v1 = dc.encode_round(&params, 0, &mut rng).unwrap();
        dc.decode_current(&v1).unwrap();
        let before = dc.reference().to_vec();
        let _v2 = dc.encode_round(&params, 1, &mut rng).unwrap();
        // the v1 packet is now stale: rejected, replica untouched
        let err = dc.decode_current(&v1);
        assert!(err.is_err(), "stale delta accepted");
        assert_eq!(dc.reference(), &before[..]);
        // wire-parsed stale packets reject the same way (never panic)
        let parsed = Packet::parse(&v1.to_bytes()).unwrap();
        assert!(dc.decode_current(&parsed).is_err());
    }

    #[test]
    fn fp32_delta_is_lossless_and_residual_free() {
        let d = 300;
        let mut dc = DeltaCodec::design(
            Direction::Downlink,
            CompressionScheme::Fp32,
            WireCoder::Huffman,
            d,
        )
        .unwrap();
        let mut rng = Rng::new(71);
        let mut model_rng = Rng::new(72);
        let mut params = vec![0f32; d];
        for round in 0..4 {
            walk(&mut params, &mut model_rng, 0.3);
            let pkt = dc.encode_round(&params, round, &mut rng).unwrap();
            let replica = dc.decode_current(&pkt).unwrap();
            assert_eq!(replica, &params[..], "fp32 deltas must be exact");
        }
        assert!(dc.last_ef_norm().is_nan(), "fp32 banks no residual");
        assert_eq!(dc.resync_bits(), HEADER_BITS + 32 * d as u64);
    }

    #[test]
    fn sign_delta_roundtrips_with_versioned_side_info() {
        let d = 1024;
        let mut dc = DeltaCodec::design(
            Direction::Downlink,
            CompressionScheme::Sign,
            WireCoder::Huffman,
            d,
        )
        .unwrap();
        let mut rng = Rng::new(81);
        let params = vec![0.25f32; d];
        let pkt = dc.encode_round(&params, 0, &mut rng).unwrap();
        assert_eq!(pkt.side_info.len(), 2, "(scale, version)");
        assert_eq!(pkt.payload_bits, d as u64);
        let replica = dc.decode_current(&pkt).unwrap();
        assert!(replica.iter().all(|x| x.is_finite()));
        assert!(dc.last_ef_norm() > 0.0, "sign must bank a residual");
    }

    #[test]
    fn design_rejects_qsgd_and_bad_targets() {
        let d = 64;
        assert!(DeltaCodec::design(
            Direction::Downlink,
            CompressionScheme::Qsgd { bits: 3 },
            WireCoder::Huffman,
            d,
        )
        .is_err());
        assert!(DeltaCodec::design(
            Direction::Downlink, rcfed_scheme(), WireCoder::Huffman, 0,
        )
        .is_err());
        // a target needs rcfed and a sane operating point
        assert!(DeltaCodec::design_with_target(
            Direction::Downlink,
            CompressionScheme::Sign,
            WireCoder::Huffman,
            d,
            Some((1.5, 2)),
        )
        .is_err());
        for bad in [(0.0, 2), (f64::NAN, 2), (1.5, 0)] {
            assert!(DeltaCodec::design_with_target(
                Direction::Downlink,
                rcfed_scheme(),
                WireCoder::Huffman,
                d,
                Some(bad),
            )
            .is_err());
        }
    }

    #[test]
    fn controller_moves_lambda_and_pays_for_republication() {
        let d = 4096;
        let mut dc = DeltaCodec::design_with_target(
            Direction::Downlink,
            rcfed_scheme(),
            WireCoder::Huffman,
            d,
            Some((0.5, 1)), // far below what 3-bit rcfed realizes
        )
        .unwrap();
        assert!(dc.is_adaptive());
        let lam0 = dc.lambda();
        let mut rng = Rng::new(91);
        let mut model_rng = Rng::new(92);
        let mut params = vec![0f32; d];
        walk(&mut params, &mut model_rng, 1.0);
        let pkt = dc.encode_round(&params, 0, &mut rng).unwrap();
        dc.decode_current(&pkt).unwrap();
        dc.observe_round(pkt.total_bits(), d as u64);
        let pub_bits = dc.end_round(0).unwrap();
        assert!(pub_bits.unwrap() > 0, "redesign must cost downlink bits");
        assert!(
            dc.lambda() > lam0,
            "realized ≫ target must raise λ: {} vs {lam0}",
            dc.lambda()
        );
        assert!(dc.last_realized() > 0.5);
        // the next delta encodes against the redesigned codebook and
        // still roundtrips under the version protocol
        walk(&mut params, &mut model_rng, 0.05);
        let pkt2 = dc.encode_round(&params, 1, &mut rng).unwrap();
        dc.decode_current(&pkt2).unwrap();
        // a window with no deliveries holds λ and publishes nothing
        let held = dc.lambda();
        assert!(dc.end_round(1).unwrap().is_none());
        assert_eq!(dc.lambda(), held);
    }

    #[test]
    fn uplink_direction_runs_the_same_stage_graph() {
        // the codec is direction-agnostic: an Uplink context delta-codes
        // a client→server stream with identical machinery
        let d = 256;
        let mut dc = DeltaCodec::design(
            Direction::Uplink, rcfed_scheme(), WireCoder::Huffman, d,
        )
        .unwrap();
        assert_eq!(dc.direction(), Direction::Uplink);
        let mut rng = Rng::new(93);
        let params = vec![1.0f32; d];
        let pkt = dc.encode_round(&params, 0, &mut rng).unwrap();
        let replica = dc.decode_current(&pkt).unwrap();
        assert!(replica.iter().all(|x| x.is_finite()));
    }
}
