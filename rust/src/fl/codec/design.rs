//! Process-wide codebook design cache + the design entry points.
//!
//! Every codebook scheme is designed against the *universal* N(0,1) model
//! (§3.1), so the designed codebook is a pure function of the scheme
//! hyper-parameters. A multi-experiment sweep (coordinator::sweep) would
//! otherwise re-run the expensive Lloyd/RC alternation — Huffman rebuild
//! per iteration × up to 300 iterations, × 24 bisection steps under
//! `design_for_target_rate` — once per sweep cell. The cache keys the
//! finished (codebook, report) pair on the scheme tag, bit-width,
//! quantized λ and length model, behind `OnceLock<Mutex<HashMap>>`, and
//! counts hits/misses so sweep reports can prove reuse.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::coding::huffman::HuffmanCode;
use crate::quant::codebook::Codebook;
use crate::quant::lloyd::LloydMax;
use crate::quant::nqfl::nqfl_codebook;
use crate::quant::rcq::{LengthModel, RateConstrainedQuantizer};
use crate::quant::uniform::uniform_codebook;
use crate::quant::DesignReport;
use crate::stats::empirical::EmpiricalPdf;
use crate::stats::entropy::entropy_bits;
use crate::stats::gaussian::StdGaussian;
use crate::util::{Error, Result};

use super::scheme::CompressionScheme;

/// λ/clip resolution of the cache key (1e-9): designs whose multipliers
/// differ by less than this are numerically indistinguishable.
fn quantize_key_f64(x: f64) -> i64 {
    (x * 1e9).round() as i64
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum DesignKey {
    RcFed { bits: u32, lambda_q: i64, huffman_lengths: bool },
    Lloyd { bits: u32 },
    Nqfl { bits: u32 },
    Uniform { bits: u32, clip_q: i64 },
    /// One adaptation window of the closed-loop pipeline: λ after the
    /// dual-ascent step, the window ordinal, the quantized moments of
    /// the window's sample set and a fingerprint of the warm-start
    /// codebook. Unlike the universal keys the empirical design target
    /// is not derivable from the key alone — it rides along into
    /// [`designed_adaptive_codebook`] and is only consulted on a miss;
    /// the moment + warm fingerprints make two cells that agree on the
    /// whole key deterministic replays of the same run state (same
    /// seed, same windows, same design inputs), so sharing one design
    /// is sound even across concurrent sweep workers.
    Adaptive {
        bits: u32,
        lambda_q: i64,
        step: u32,
        mean_q: i64,
        std_q: i64,
        count: u64,
        warm_fp: u64,
        huffman_lengths: bool,
    },
}

/// Order-sensitive FNV-1a over a codebook's f32 bit patterns — a cheap
/// fingerprint that distinguishes warm-start inputs inside
/// [`DesignKey::Adaptive`], so two sweep cells whose controllers happen
/// to agree on (λ, window, moments) but arrive with different previous
/// codebooks cannot collide on one cache slot.
fn codebook_fingerprint(cb: &Codebook) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in cb.levels.iter().chain(&cb.bounds) {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[derive(Clone)]
struct CachedDesign {
    codebook: Codebook,
    report: DesignReport,
}

/// Per-key slot: the map only guards slot creation, so concurrent first
/// lookups of the *same* key block on one design (no duplicate work, one
/// deterministic miss) while different keys design in parallel. Errors
/// are cached as strings — the design is deterministic, so a failure is
/// permanent for its key.
type DesignSlot =
    std::sync::Arc<OnceLock<std::result::Result<CachedDesign, String>>>;

static DESIGN_CACHE: OnceLock<Mutex<HashMap<DesignKey, DesignSlot>>> =
    OnceLock::new();
static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Cumulative process-wide design-cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DesignCacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl DesignCacheStats {
    /// Counter movement since an earlier snapshot.
    pub fn since(&self, earlier: &DesignCacheStats) -> DesignCacheStats {
        DesignCacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
        }
    }
}

impl std::fmt::Display for DesignCacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} hits / {} misses", self.hits, self.misses)
    }
}

/// Snapshot the process-wide design-cache counters.
pub fn design_cache_stats() -> DesignCacheStats {
    DesignCacheStats {
        hits: CACHE_HITS.load(Ordering::Relaxed),
        misses: CACHE_MISSES.load(Ordering::Relaxed),
    }
}

fn design_key(scheme: &CompressionScheme) -> Option<DesignKey> {
    match *scheme {
        CompressionScheme::RcFed { bits, lambda, length_model } => {
            Some(DesignKey::RcFed {
                bits,
                lambda_q: quantize_key_f64(lambda),
                huffman_lengths: length_model == LengthModel::Huffman,
            })
        }
        CompressionScheme::Lloyd { bits } => Some(DesignKey::Lloyd { bits }),
        CompressionScheme::Nqfl { bits } => Some(DesignKey::Nqfl { bits }),
        CompressionScheme::Uniform { bits, clip } => {
            Some(DesignKey::Uniform { bits, clip_q: quantize_key_f64(clip) })
        }
        CompressionScheme::Qsgd { .. }
        | CompressionScheme::Fp32
        | CompressionScheme::Sign => None,
    }
}

/// Run the actual design for a codebook scheme (no caching).
fn design_codebook_uncached(
    scheme: &CompressionScheme,
) -> Result<(Codebook, DesignReport)> {
    match *scheme {
        CompressionScheme::RcFed { bits, lambda, length_model } => {
            let rc = RateConstrainedQuantizer {
                lambda,
                length_model,
                ..Default::default()
            };
            rc.design(&StdGaussian, bits)
        }
        CompressionScheme::Lloyd { bits } => {
            LloydMax::default().design(&StdGaussian, bits)
        }
        CompressionScheme::Nqfl { bits } => {
            let cb = nqfl_codebook(bits)?;
            closed_form_report(cb)
        }
        CompressionScheme::Uniform { bits, clip } => {
            let cb = uniform_codebook(bits, clip)?;
            closed_form_report(cb)
        }
        CompressionScheme::Qsgd { .. }
        | CompressionScheme::Fp32
        | CompressionScheme::Sign => Err(Error::Quant(format!(
            "scheme {scheme:?} has no designed codebook"))),
    }
}

/// Evaluate a closed-form codebook (NQFL / Uniform) against N(0,1) into
/// the same report shape the iterative designers produce.
fn closed_form_report(cb: Codebook) -> Result<(Codebook, DesignReport)> {
    let (mse, probs) = crate::quant::evaluate(&StdGaussian, &cb);
    let huffman = HuffmanCode::from_probs(&probs)?;
    let report = DesignReport {
        mse,
        entropy_bits: entropy_bits(&probs),
        huffman_rate: huffman.expected_length(&probs),
        probs,
        iterations: 1,
    };
    Ok((cb, report))
}

/// Serve one design key from the process-wide cache, running `design`
/// only on a miss. The map lock covers only slot lookup/creation, never
/// the design itself: exactly one caller per key runs it; racers block
/// on the slot and then read the finished value, so hit/miss counts are
/// deterministic.
fn cached_design<F>(
    key: DesignKey,
    design: F,
) -> Result<(Codebook, DesignReport)>
where
    F: FnOnce() -> Result<(Codebook, DesignReport)>,
{
    let cache = DESIGN_CACHE.get_or_init(Default::default);
    let slot: DesignSlot = {
        // A sweep worker that panics while holding this lock poisons the
        // mutex; recovering is sound because the critical section only
        // inserts a fresh slot (the map cannot be left half-mutated), and
        // it keeps one panicked cell from aborting every later run in the
        // process.
        let mut map = cache.lock().unwrap_or_else(|e| e.into_inner());
        map.entry(key).or_default().clone()
    };
    let mut designed_here = false;
    let value = slot.get_or_init(|| {
        designed_here = true;
        design()
            .map(|(codebook, report)| CachedDesign { codebook, report })
            .map_err(|e| e.to_string())
    });
    if designed_here {
        CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    } else {
        CACHE_HITS.fetch_add(1, Ordering::Relaxed);
    }
    match value {
        Ok(cached) => Ok((cached.codebook.clone(), cached.report.clone())),
        Err(msg) => Err(Error::Quant(msg.clone())),
    }
}

/// Designed codebook + report for a codebook-backed scheme, served from
/// the process-wide design cache. Errors for QSGD/Fp32 (no codebook).
///
/// Only the universal N(0,1) design target (§3.1) goes through this
/// path; per-client empirical designs (`LloydMax::design(&EmpiricalPdf,
/// …)`) are data-dependent and must stay uncached.
pub fn designed_codebook(
    scheme: CompressionScheme,
) -> Result<(Codebook, DesignReport)> {
    let Some(key) = design_key(&scheme) else {
        return Err(Error::Quant(format!(
            "scheme {scheme:?} has no designed codebook")));
    };
    cached_design(key, || design_codebook_uncached(&scheme))
}

/// Designed codebook + report for one adaptation window of the
/// [`super::pipeline::CompressionPipeline`], served from the same
/// process-wide cache under a [`DesignKey::Adaptive`] key.
///
/// `moments` are `(mean, std, count)` of the window's normalized sample
/// set; `warm` seeds the alternation with the previous window's
/// codebook (see [`RateConstrainedQuantizer::design_warm`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn designed_adaptive_codebook(
    bits: u32,
    lambda: f64,
    length_model: LengthModel,
    step: u32,
    moments: (f64, f64, u64),
    pdf: &EmpiricalPdf,
    warm: Option<&Codebook>,
) -> Result<(Codebook, DesignReport)> {
    let key = DesignKey::Adaptive {
        bits,
        lambda_q: quantize_key_f64(lambda),
        step,
        mean_q: quantize_key_f64(moments.0),
        std_q: quantize_key_f64(moments.1),
        count: moments.2,
        warm_fp: warm.map(codebook_fingerprint).unwrap_or(0),
        huffman_lengths: length_model == LengthModel::Huffman,
    };
    cached_design(key, || {
        let rc = RateConstrainedQuantizer {
            lambda,
            length_model,
            ..Default::default()
        };
        rc.design_warm(pdf, bits, warm)
    })
}

/// Wire cost of publishing one codebook version to one client: `2^b`
/// levels + `2^b − 1` boundaries at f32, the version tag, the new
/// multiplier, and the canonical code-length table clients need to
/// entropy-encode against the new codebook (5 bits per symbol,
/// byte-padded — the same format QSGD's travelling table uses; the
/// empirical cell probabilities are not derivable from levels/bounds
/// alone, so the table is genuine traffic).
pub(crate) fn codebook_broadcast_bits(cb: &Codebook) -> u64 {
    let n = cb.levels.len() as u64;
    let table_bits = (5 * n).div_ceil(8) * 8;
    32 * (n + cb.bounds.len() as u64) + 32 + 32 + table_bits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_cache_returns_identical_codebooks() {
        // an unusual clip keeps this key private to the test
        let scheme = CompressionScheme::Uniform { bits: 5, clip: 3.1372 };
        let before = design_cache_stats();
        let (cb1, rep1) = designed_codebook(scheme).unwrap();
        let (cb2, rep2) = designed_codebook(scheme).unwrap();
        let delta = design_cache_stats().since(&before);
        assert_eq!(cb1, cb2);
        assert_eq!(rep1.probs, rep2.probs);
        assert_eq!(rep1.mse, rep2.mse);
        // the second call must have hit (other tests only add counts)
        assert!(delta.hits >= 1, "no cache hit recorded: {delta:?}");
        assert!(delta.misses >= 1, "first design not counted: {delta:?}");
    }

    #[test]
    fn cached_design_matches_direct_design() {
        let scheme = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.0832, // unusual λ: first call is a genuine miss
            length_model: LengthModel::Huffman,
        };
        let (cb_cached, rep_cached) = designed_codebook(scheme).unwrap();
        let rc = RateConstrainedQuantizer {
            lambda: 0.0832,
            length_model: LengthModel::Huffman,
            ..Default::default()
        };
        let (cb_direct, rep_direct) = rc.design(&StdGaussian, 3).unwrap();
        assert_eq!(cb_cached, cb_direct);
        assert_eq!(rep_cached.probs, rep_direct.probs);
        assert_eq!(rep_cached.huffman_rate, rep_direct.huffman_rate);
    }

    #[test]
    fn poisoned_cache_mutex_recovers() {
        // regression: a panicked sweep worker used to poison the design
        // cache's map mutex, turning every later designed_codebook call
        // in the process into a PoisonError unwrap panic
        let t = std::thread::spawn(|| {
            let _guard = DESIGN_CACHE
                .get_or_init(Default::default)
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            panic!("simulated sweep-worker panic while holding the lock");
        });
        assert!(t.join().is_err(), "the poisoning thread must panic");
        // an unusual clip keeps this key private to the test; the call
        // must succeed despite the poisoned mutex
        let scheme = CompressionScheme::Uniform { bits: 4, clip: 2.9173 };
        let (cb, _) = designed_codebook(scheme).unwrap();
        cb.validate().unwrap();
        // and the cache still serves hits afterwards
        let before = design_cache_stats();
        designed_codebook(scheme).unwrap();
        assert!(design_cache_stats().since(&before).hits >= 1);
    }

    #[test]
    fn uncachable_schemes_are_rejected() {
        assert!(designed_codebook(CompressionScheme::Fp32).is_err());
        assert!(
            designed_codebook(CompressionScheme::Qsgd { bits: 3 }).is_err()
        );
        assert!(designed_codebook(CompressionScheme::Sign).is_err());
    }
}
