//! Heterogeneity-aware per-client rate allocation (water-filling a
//! global uplink budget across clients), sitting on top of the staged
//! quantize/code path.

use crate::coding::arithmetic::ArithmeticCoder;
use crate::coding::block::BlockCoder;
use crate::coding::huffman::HuffmanCode;
use crate::fl::packet::Packet;
use crate::quant::codebook::Codebook;
use crate::util::rng::Rng;
use crate::util::{Error, Result};

use super::design::{codebook_broadcast_bits, designed_codebook};
use super::pipeline::{DecodedBody, RateTarget};
use super::quantize::{
    encode_staged, CodebookCodec, CodecScratch, QuantBackend,
};
use super::scheme::{CompressionScheme, WireCoder};
use super::transform::{TransformCfg, TransformState};

/// Per-client rate-allocation mode.
///
/// `Uniform` (the default) keeps today's behavior exactly: every client
/// encodes against the single shared codebook, no extra side
/// information, no allocation state, no downlink traffic — runs are
/// byte-identical to the pre-allocator code path.
///
/// `WaterFill` splits a global per-round uplink budget *across* clients
/// (the per-client/per-group precision assignment of FedFQ, and the
/// rate–distortion budget framing of Mitchell et al. 2022): each client
/// gets its own codebook bit-width, solved by greedy water-filling over
/// the clients' observed gradient second moments and their
/// [`crate::coordinator::network::ChannelSpec`] bandwidth factors, and
/// re-solved every `adapt_every` rounds as gradient energies drift.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum RateAllocation {
    /// one shared codebook for every client (the §3.1 baseline)
    #[default]
    Uniform,
    /// water-filling under a global round budget
    WaterFill {
        /// round uplink budget, expressed as the expected *encoded*
        /// payload bits per gradient coordinate averaged over the
        /// round's clients (the encoded rate — not the nominal width —
        /// is what RC-FED constrains). The solver enforces
        /// `mean_c rate(b_c) <= budget_bpc` over the client population,
        /// so a uniformly sampled round meets the budget in expectation
        /// (exactly under full participation).
        budget_bpc: f64,
        /// re-solve the allocation every this many rounds
        adapt_every: usize,
        /// smallest grantable codebook width (bits)
        min_bits: u32,
        /// largest grantable codebook width (bits)
        max_bits: u32,
    },
}

impl RateAllocation {
    pub fn is_on(&self) -> bool {
        !matches!(self, RateAllocation::Uniform)
    }

    /// Stable row-key label for CSVs, `"uniform"` when disabled.
    pub fn label(&self) -> String {
        match *self {
            RateAllocation::Uniform => "uniform".into(),
            RateAllocation::WaterFill {
                budget_bpc, adapt_every, min_bits, max_bits,
            } => {
                format!("wf{budget_bpc}w{adapt_every}b{min_bits}-{max_bits}")
            }
        }
    }

    /// Reject nonsensical budgets and unsupported scheme/controller
    /// combinations up front, so a bad configuration is a config error,
    /// not a silent no-op.
    pub fn validate(
        &self,
        scheme: &CompressionScheme,
        target: &RateTarget,
    ) -> Result<()> {
        let RateAllocation::WaterFill {
            budget_bpc, adapt_every, min_bits, max_bits,
        } = *self
        else {
            return Ok(());
        };
        if !(budget_bpc > 0.0 && budget_bpc.is_finite()) {
            return Err(Error::Config(format!(
                "allocation budget {budget_bpc} must be finite and > 0")));
        }
        if adapt_every == 0 {
            return Err(Error::Config(
                "allocation needs adapt-every >= 1".into()));
        }
        if !(1..=8).contains(&min_bits) || !(1..=8).contains(&max_bits)
            || min_bits > max_bits
        {
            return Err(Error::Config(format!(
                "allocation width range {min_bits}..={max_bits} must \
                 satisfy 1 <= min <= max <= 8 (symbols are u8)")));
        }
        match scheme {
            CompressionScheme::Qsgd { .. }
            | CompressionScheme::Fp32
            | CompressionScheme::Sign => {
                return Err(Error::Config(format!(
                    "rate allocation needs a designed-codebook scheme \
                     (rcfed|lloyd|nqfl|uniform); got {scheme:?}")));
            }
            _ => {}
        }
        if target.is_on() {
            return Err(Error::Config(
                "rate allocation and closed-loop rate targeting both \
                 steer the codebook; run one controller at a time".into(),
            ));
        }
        Ok(())
    }
}

/// Allocation diagnostics for one round, surfaced into the metrics log
/// and sweep reports.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AllocSnapshot {
    /// Gini coefficient of the current per-client widths
    pub gini: f64,
    /// mean assigned width (bits)
    pub mean_bits: f64,
    /// narrowest / widest assigned widths
    pub min_bits: u32,
    pub max_bits: u32,
}

/// One designed operating point of the allocator's width ladder: the
/// universal N(0,1) design of the base scheme rebound to `width` bits,
/// with its wire codes and the design statistics the solver needs.
struct WidthDesign {
    width: u32,
    codebook: Codebook,
    huffman: HuffmanCode,
    arith: ArithmeticCoder,
    /// design MSE on the normalized source (scales by σ_c² per client)
    mse: f64,
    /// expected encoded bits/coordinate under the configured wire coder
    rate: f64,
    /// downlink cost of publishing this codebook to one client
    broadcast_bits: u64,
}

impl WidthDesign {
    fn codec(&self, wire: WireCoder) -> CodebookCodec<'_> {
        CodebookCodec {
            codebook: &self.codebook,
            huffman: &self.huffman,
            arith: &self.arith,
            wire,
        }
    }
}

/// One candidate width upgrade in the greedy water-filling heap, ordered
/// by distortion-reduction per budget bit (ties broken toward the lower
/// client index, so the solve is deterministic).
#[derive(Clone, Copy)]
struct Upgrade {
    ratio: f64,
    client: usize,
    /// ladder index the client would move to
    next: usize,
}

impl PartialEq for Upgrade {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Upgrade {}
impl PartialOrd for Upgrade {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Upgrade {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.ratio
            .total_cmp(&other.ratio)
            .then_with(|| other.client.cmp(&self.client))
    }
}

/// Heterogeneity-aware per-client rate allocator (the `WaterFill` mode
/// of [`RateAllocation`]).
///
/// The allocator turns the pipeline's single-shared-codebook assumption
/// into a per-client one:
///
/// * every width in `[min_bits, max_bits]` is designed once against the
///   universal N(0,1) model and served from the process-wide design
///   cache — an allocated width-`b` codebook is *identical* to the
///   static width-`b` design, so allocation shares cache entries with
///   static sweeps instead of needing private keys;
/// * each adaptation window it water-fills the budget greedily: start
///   every client at `min_bits`, then repeatedly grant the width
///   upgrade with the best marginal distortion reduction per encoded
///   bit, where client `c`'s priority is `E_c · f_c` (`E_c` its observed
///   per-coordinate gradient second moment, `f_c` its channel bandwidth
///   factor — fast, energetic clients earn wide codebooks, slow or
///   quiescent ones cheap narrow ones);
/// * per-client codebook *versions* travel as the third side-info word
///   of every packet; the PS rejects packets whose version does not
///   match the sender's current assignment, and only clients whose
///   width actually changed are charged a codebook publication on the
///   downlink ledger.
///
/// A transform stage composes per client: the allocator quantizes the
/// transformed working set against the sender's assigned codebook; the
/// budget solve keeps its dense-rate semantics while the measured
/// ledger (and `realized_bpc`) reflect the index+value bits actually
/// sent.
pub struct RateAllocator {
    base: CompressionScheme,
    wire: WireCoder,
    transform: TransformCfg,
    budget_bpc: f64,
    adapt_every: usize,
    min_bits: u32,
    /// width ladder, ascending `min_bits..=max_bits`
    table: Vec<WidthDesign>,
    /// per-client assigned widths (empty until [`Self::bind`])
    pub(crate) widths: Vec<u32>,
    /// per-client codebook versions (bumped when a client's width moves)
    versions: Vec<u32>,
    /// per-client bandwidth factors, normalized to mean 1
    factors: Vec<f64>,
    /// per-client gradient second moments, **keyed by client id**:
    /// `sum`/`n` accumulate the current window and fold into `last` at
    /// each window end; a client absent from the map carries the flat
    /// prior 1.0. Keyed rather than index-dense so memory is O(clients
    /// ever ingested) — not O(population) — and a client's estimate
    /// survives every round it sits out, however large the population.
    moments: std::collections::HashMap<u32, Moment>,
    /// packets observed in the current adaptation window
    window_obs: u64,
}

/// One client's windowed second-moment tracker (see
/// [`RateAllocator::moments`]).
#[derive(Clone, Copy, Debug)]
struct Moment {
    /// σ² sum of the current window
    sum: f64,
    /// packets in the current window
    n: u64,
    /// latest folded per-window estimate (the solver's `E_c`)
    last: f64,
}

impl RateAllocator {
    pub(crate) fn design(
        scheme: CompressionScheme,
        wire: WireCoder,
        transform: TransformCfg,
        budget_bpc: f64,
        adapt_every: usize,
        min_bits: u32,
        max_bits: u32,
    ) -> Result<RateAllocator> {
        let mut table = Vec::with_capacity((max_bits - min_bits + 1) as usize);
        for width in min_bits..=max_bits {
            let (codebook, rep) = designed_codebook(scheme.with_bits(width))?;
            let huffman = HuffmanCode::from_probs(&rep.probs)?;
            let arith = ArithmeticCoder::from_probs(&rep.probs)?;
            let rate = match wire {
                WireCoder::Huffman => rep.huffman_rate,
                WireCoder::Arithmetic => rep.entropy_bits,
                // per-block coding pays a table refresh every block;
                // amortize it into the design rate so the water-fill
                // budgets against what the ledger will actually charge
                WireCoder::Block => {
                    let coder =
                        BlockCoder::new(huffman.lengths().len())?;
                    rep.huffman_rate
                        + coder.table_bits() as f64
                            / coder.block_len() as f64
                }
            };
            let broadcast_bits = codebook_broadcast_bits(&codebook);
            table.push(WidthDesign {
                width,
                codebook,
                huffman,
                arith,
                mse: rep.mse,
                rate,
                broadcast_bits,
            });
        }
        if budget_bpc < table[0].rate {
            return Err(Error::Config(format!(
                "allocation budget {budget_bpc} bits/coord is below the \
                 min-width (b={min_bits}) encoded rate {:.4}",
                table[0].rate
            )));
        }
        Ok(RateAllocator {
            base: scheme,
            wire,
            transform,
            budget_bpc,
            adapt_every,
            min_bits,
            table,
            widths: Vec::new(),
            versions: Vec::new(),
            factors: Vec::new(),
            moments: std::collections::HashMap::new(),
            window_obs: 0,
        })
    }

    fn design_of(&self, width: u32) -> Result<&WidthDesign> {
        self.table
            .get(width.checked_sub(self.min_bits).map_or(usize::MAX, |i| {
                i as usize
            }))
            .ok_or_else(|| {
                Error::Coding(format!(
                    "width {width} outside the allocation ladder \
                     [{}..={}]",
                    self.min_bits,
                    self.table.last().map(|d| d.width).unwrap_or(0)
                ))
            })
    }

    /// Bind the allocator to a client population: record the per-client
    /// bandwidth factors and solve the initial allocation (flat energy
    /// prior `E_c = 1`, so the first assignment skews by bandwidth only
    /// — exactly what is known before any gradient is seen). The initial
    /// codebooks are part of training setup and are not charged to the
    /// downlink, matching the uncharged initial §3.1 broadcast.
    pub(crate) fn bind(
        &mut self,
        num_clients: usize,
        factors: &[f64],
    ) -> Result<()> {
        if num_clients == 0 {
            return Err(Error::Config(
                "rate allocation needs at least one client".into()));
        }
        let mean = if factors.is_empty() {
            1.0
        } else {
            factors.iter().sum::<f64>() / factors.len() as f64
        };
        self.factors = (0..num_clients)
            .map(|c| {
                let f = factors.get(c).copied().unwrap_or(mean);
                if mean > 0.0 && f > 0.0 {
                    f / mean
                } else {
                    1.0
                }
            })
            .collect();
        // Learned energy estimates are keyed by client id and survive a
        // re-bind (a client's estimate must outlive the rounds — and
        // cohorts — it sits out); only the in-flight window restarts.
        for m in self.moments.values_mut() {
            m.sum = 0.0;
            m.n = 0;
        }
        self.versions = vec![0; num_clients];
        self.window_obs = 0;
        let priority = self.factors.clone();
        self.widths = self.solve(&priority);
        Ok(())
    }

    pub(crate) fn bound(&self) -> bool {
        !self.widths.is_empty()
    }

    /// Greedy water-filling: start every client at the ladder floor,
    /// then grant one-step width upgrades in order of marginal
    /// distortion reduction per encoded budget bit until the budget is
    /// exhausted. The marginal gains `p_c · (mse_i − mse_{i+1})` are
    /// decreasing along each client's ladder (the design MSE roughly
    /// quarters per bit), so the greedy solution is the integer
    /// water-filling optimum up to the final partial increment.
    fn solve(&self, priority: &[f64]) -> Vec<u32> {
        let k = priority.len();
        let budget_total = self.budget_bpc * k as f64;
        let mut widths = vec![self.min_bits; k];
        let mut spent = self.table[0].rate * k as f64;
        let mut heap = std::collections::BinaryHeap::with_capacity(k);
        let upgrade = |client: usize, next: usize| -> Upgrade {
            let gain = (self.table[next - 1].mse - self.table[next].mse)
                .max(0.0)
                * priority[client].max(1e-12);
            let cost =
                (self.table[next].rate - self.table[next - 1].rate).max(1e-9);
            Upgrade { ratio: gain / cost, client, next }
        };
        if self.table.len() > 1 {
            for c in 0..k {
                heap.push(upgrade(c, 1));
            }
        }
        while let Some(u) = heap.pop() {
            let cost = (self.table[u.next].rate
                - self.table[u.next - 1].rate)
                .max(1e-9);
            if spent + cost > budget_total + 1e-9 {
                // this client's next step no longer fits; a narrower
                // step from another client still might
                continue;
            }
            spent += cost;
            widths[u.client] = self.table[u.next].width;
            if u.next + 1 < self.table.len() {
                heap.push(upgrade(u.client, u.next + 1));
            }
        }
        widths
    }

    /// Fold one ingested packet's (μ, σ) into the sender's energy
    /// accumulator. Only packets the server actually decoded count, so
    /// lost/corrupt uplinks cannot steer the allocation.
    pub(crate) fn observe_packet(&mut self, packet: &Packet) {
        let c = packet.client_id;
        if (c as usize) >= self.factors.len()
            || packet.side_info.len() < 2
        {
            return;
        }
        let sigma = packet.side_info[1] as f64;
        if !sigma.is_finite() {
            return;
        }
        let m = self
            .moments
            .entry(c)
            .or_insert(Moment { sum: 0.0, n: 0, last: 1.0 });
        m.sum += sigma * sigma;
        m.n += 1;
        self.window_obs += 1;
    }

    /// The client's latest folded energy estimate, or the flat prior 1.0
    /// when it has never been observed.
    pub(crate) fn moment_estimate(&self, client: u32) -> f64 {
        self.moments.get(&client).map_or(1.0, |m| m.last)
    }

    /// Close round `round` (0-based). On an adaptation-window boundary,
    /// re-solve the allocation against the observed energies; returns
    /// the per-client publication costs when any width moved. A window
    /// in which no packet was ingested (channel blackout) holds the
    /// current allocation.
    pub(crate) fn end_round(&mut self, round: usize) -> Option<Vec<(u32, u64)>> {
        if (round + 1) % self.adapt_every != 0 || !self.bound() {
            return None;
        }
        if self.window_obs == 0 {
            return None;
        }
        self.window_obs = 0;
        // fold the window's observations into the per-client estimate
        // (unseen clients keep their previous one) and reset the window
        for m in self.moments.values_mut() {
            if m.n > 0 {
                m.last = m.sum / m.n as f64;
                m.sum = 0.0;
                m.n = 0;
            }
        }
        // priority is built in ascending client-index order, never map
        // iteration order, so the solve input is deterministic
        let priority: Vec<f64> = self
            .factors
            .iter()
            .enumerate()
            .map(|(c, &f)| f * self.moment_estimate(c as u32))
            .collect();
        let new = self.solve(&priority);
        if new == self.widths {
            return None;
        }
        let mut publications = Vec::new();
        for (c, (&w_new, w_old)) in
            new.iter().zip(self.widths.iter()).enumerate()
        {
            if w_new != *w_old {
                self.versions[c] += 1;
                let bits = self
                    .design_of(w_new)
                    .map(|d| d.broadcast_bits)
                    .unwrap_or(0);
                publications.push((c as u32, bits));
            }
        }
        self.widths = new;
        Some(publications)
    }

    /// Compress a flat gradient against the sender's assigned codebook.
    /// Packets carry the client's allocation version as a third
    /// side-info word and the assigned width in the `bits_per_symbol`
    /// header field. The transform stage (if any) runs against the
    /// caller's per-client state.
    pub(crate) fn compress_with(
        &self,
        state: &mut TransformState,
        scratch: &mut CodecScratch,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
    ) -> Result<Packet> {
        let width =
            self.widths.get(client_id as usize).copied().ok_or_else(|| {
                Error::Config(format!(
                    "client {client_id} outside the bound allocation \
                     ({} clients); was bind_clients called?",
                    self.widths.len()
                ))
            })?;
        let design = self.design_of(width)?;
        if self.transform.is_active() {
            let backend = QuantBackend::Codebook(design.codec(self.wire));
            let mut pkt = encode_staged(
                &backend,
                self.transform,
                state,
                scratch,
                client_id,
                round,
                grad,
                rng,
                self.base.tag(),
                width as u8,
                false,
            )?;
            pkt.side_info.push(self.versions[client_id as usize] as f32);
            return Ok(pkt);
        }
        let (mu, sigma, payload, payload_bits) =
            design.codec(self.wire).encode(grad, &mut scratch.symbols)?;
        Ok(Packet {
            client_id,
            round,
            scheme: self.base.tag(),
            bits_per_symbol: width as u8,
            d: grad.len() as u32,
            side_info: vec![
                mu,
                sigma,
                self.versions[client_id as usize] as f32,
            ],
            payload,
            payload_bits,
            table_bits: 0, // universal design-time codes (§3.1)
            index_bits: 0,
        })
    }

    /// PS side: decode against the *sender's* codebook (width from the
    /// packet header, checked against the current assignment) and
    /// accumulate. Stale allocation versions are rejected as recoverable
    /// `Err`s — a packet encoded under an old width would otherwise
    /// silently reconstruct garbage.
    pub(crate) fn decompress_accumulate(
        &self,
        packet: &Packet,
        acc: &mut [f32],
    ) -> Result<()> {
        let d = packet.d as usize;
        if acc.len() != d {
            return Err(Error::Coding(format!(
                "accumulator {} != packet d {d}", acc.len())));
        }
        let (codec, mu, sigma) = self.checked_codec(packet)?;
        if self.transform.is_sparse() {
            codec.decode_sparse_accumulate(packet, mu, sigma, acc)
        } else {
            codec.decode_accumulate(packet, mu, sigma, acc)
        }
    }

    /// Split decode for the deferred-accumulate server path (same
    /// validation + decode as [`Self::decompress_accumulate`], no
    /// accumulator writes).
    pub(crate) fn decode_body(&self, packet: &Packet) -> Result<DecodedBody> {
        let (codec, mu, sigma) = self.checked_codec(packet)?;
        if self.transform.is_sparse() {
            let (indices, symbols, table) =
                codec.decode_sparse_body(packet, mu, sigma)?;
            Ok(DecodedBody::Sparse { indices, symbols, table })
        } else {
            let (symbols, table) = codec.decode_dense_body(packet, mu, sigma)?;
            Ok(DecodedBody::Symbols { symbols, table })
        }
    }

    /// Shared packet validation for the two decode paths: side-info
    /// arity, allocation version, width-vs-assignment — returning the
    /// *sender's* codec and the packet's (μ, σ).
    fn checked_codec(
        &self,
        packet: &Packet,
    ) -> Result<(CodebookCodec<'_>, f32, f32)> {
        if packet.side_info.len() != 3 {
            return Err(Error::Coding(format!(
                "allocated packet carries {} side-info values, expected \
                 3 (μ, σ, version)",
                packet.side_info.len()
            )));
        }
        let c = packet.client_id as usize;
        let Some(&expected_version) = self.versions.get(c) else {
            return Err(Error::Coding(format!(
                "client {} outside the bound allocation", packet.client_id
            )));
        };
        let version = packet.side_version()?;
        if version != expected_version {
            return Err(Error::Coding(format!(
                "stale allocation version {version} from client {} \
                 (current {expected_version})",
                packet.client_id
            )));
        }
        let width = packet.bits_per_symbol as u32;
        if self.widths[c] != width {
            return Err(Error::Coding(format!(
                "client {} sent width {width}, allocation says {}",
                packet.client_id, self.widths[c]
            )));
        }
        let design = self.design_of(width)?;
        let (mu, sigma) = (packet.side_info[0], packet.side_info[1]);
        Ok((design.codec(self.wire), mu, sigma))
    }

    /// Current width histogram `(width, clients)`, ascending.
    pub(crate) fn histogram(&self) -> Vec<(u32, usize)> {
        self.table
            .iter()
            .map(|d| {
                (
                    d.width,
                    self.widths.iter().filter(|&&w| w == d.width).count(),
                )
            })
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    pub(crate) fn mean_bits(&self) -> f64 {
        if self.widths.is_empty() {
            return f64::NAN;
        }
        self.widths.iter().map(|&w| w as f64).sum::<f64>()
            / self.widths.len() as f64
    }

    /// Gini coefficient of the assigned widths — 0 for a uniform
    /// allocation, growing as the budget concentrates on few clients.
    pub(crate) fn gini(&self) -> f64 {
        let n = self.widths.len();
        if n == 0 {
            return 0.0;
        }
        let mut xs: Vec<f64> = self.widths.iter().map(|&w| w as f64).collect();
        xs.sort_by(f64::total_cmp);
        let sum: f64 = xs.iter().sum();
        if sum <= 0.0 {
            return 0.0;
        }
        let weighted: f64 = xs
            .iter()
            .enumerate()
            .map(|(i, &x)| (i as f64 + 1.0) * x)
            .sum();
        (2.0 * weighted) / (n as f64 * sum) - (n as f64 + 1.0) / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::pipeline::{CompressionPipeline, RoundAdaptation};
    use super::*;
    use crate::quant::rcq::LengthModel;

    fn gaussian_grad(n: usize, mu: f32, sigma: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g, mu, sigma);
        g
    }

    fn rcfed_scheme() -> CompressionScheme {
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        }
    }

    fn waterfill(budget: f64) -> RateAllocation {
        RateAllocation::WaterFill {
            budget_bpc: budget,
            adapt_every: 1,
            min_bits: 1,
            max_bits: 6,
        }
    }

    // `allocation_validation` lives in `tests/rate_allocation.rs`
    // (public API only), next to the other allocator acceptance tests.

    #[test]
    fn uniform_allocation_is_bit_identical_to_the_plain_pipeline() {
        let plain = CompressionPipeline::design(
            rcfed_scheme(), WireCoder::Huffman, RateTarget::Off)
        .unwrap();
        let mut alloc = CompressionPipeline::design_alloc(
            rcfed_scheme(),
            WireCoder::Huffman,
            RateTarget::Off,
            RateAllocation::Uniform,
        )
        .unwrap();
        assert!(!alloc.is_allocated());
        // binding is a free no-op without an allocation
        alloc.bind_clients(4, &[1.0; 4]).unwrap();
        assert!(alloc.alloc_snapshot().is_none());
        assert!(alloc.alloc_histogram().is_empty());
        let g = gaussian_grad(4096, 0.0, 0.5, 91);
        let mut r1 = Rng::new(92);
        let mut r2 = Rng::new(92);
        let p1 = plain.compress(0, 3, &g, &mut r1).unwrap();
        let p2 = alloc.compress(0, 3, &g, &mut r2).unwrap();
        assert_eq!(p1.to_bytes(), p2.to_bytes());
        assert_eq!(alloc.end_round(0).unwrap(), RoundAdaptation::None);
    }

    #[test]
    fn waterfill_assigns_wider_codebooks_to_energetic_clients() {
        let mut pipe = CompressionPipeline::design_alloc(
            rcfed_scheme(),
            WireCoder::Huffman,
            RateTarget::Off,
            waterfill(2.5),
        )
        .unwrap();
        assert!(pipe.is_allocated());
        // compressing before bind_clients is a config error, not a panic
        let g = gaussian_grad(2048, 0.0, 1.0, 93);
        let mut rng = Rng::new(94);
        assert!(pipe.compress(0, 0, &g, &mut rng).is_err());
        pipe.bind_clients(4, &[1.0; 4]).unwrap();
        // flat priors + flat bandwidth ⇒ near-uniform initial allocation
        let snap = pipe.alloc_snapshot().unwrap();
        assert!(snap.max_bits - snap.min_bits <= 1, "{snap:?}");

        // one window of heterogeneous energies: client 3 ≫ the rest
        let sigmas = [0.01f32, 0.01, 0.01, 2.0];
        for (c, &s) in sigmas.iter().enumerate() {
            let mut grad = vec![0f32; 2048];
            Rng::new(100 + c as u64).fill_normal_f32(&mut grad, 0.0, s);
            let pkt = pipe.compress(c as u32, 0, &grad, &mut rng).unwrap();
            assert_eq!(pkt.side_info.len(), 3, "version word missing");
            let mut acc = vec![0f32; grad.len()];
            pipe.decompress_accumulate(&pkt, &mut acc).unwrap();
            pipe.observe_delivery(&pkt, &[]);
        }
        let stale_probe = pipe.compress(3, 0, &g, &mut rng).unwrap();
        match pipe.end_round(0).unwrap() {
            RoundAdaptation::PerClient { publications } => {
                assert!(!publications.is_empty());
                assert!(publications.iter().all(|&(_, bits)| bits > 0));
            }
            other => panic!("expected per-client publications, got {other:?}"),
        }
        // the energetic client earns the widest codebook
        let w3 = pipe.client_width(3).unwrap();
        for c in 0..3 {
            assert!(
                pipe.client_width(c).unwrap() < w3,
                "client {c} width {} vs energetic client {w3}",
                pipe.client_width(c).unwrap()
            );
        }
        let snap = pipe.alloc_snapshot().unwrap();
        assert!(snap.gini > 0.0, "skewed allocation must show in Gini");
        assert!(!pipe.alloc_histogram().is_empty());
        // packets from before the re-allocation are stale and rejected
        let mut acc = vec![0f32; g.len()];
        assert!(pipe.decompress_accumulate(&stale_probe, &mut acc).is_err());
        // fresh packets carry — and pass — the sender's new version
        let fresh = pipe.compress(3, 1, &g, &mut rng).unwrap();
        pipe.decompress_accumulate(&fresh, &mut acc).unwrap();
        // a wrong-width packet (header tampered) is rejected
        let mut forged = fresh.clone();
        forged.bits_per_symbol = pipe.client_width(0).unwrap() as u8;
        assert!(pipe.decompress_accumulate(&forged, &mut acc).is_err());
    }

    // `waterfill_respects_the_budget_and_bandwidth_priors` and the
    // allocated-top-k roundtrip live in `tests/rate_allocation.rs`
    // (public API only).

    #[test]
    fn allocation_blackout_window_holds_the_assignment() {
        // the allocator's own blackout guard: a window with no ingested
        // packet must hold widths, versions and publish nothing
        let mut pipe = CompressionPipeline::design_alloc(
            rcfed_scheme(),
            WireCoder::Huffman,
            RateTarget::Off,
            waterfill(2.5),
        )
        .unwrap();
        pipe.bind_clients(3, &[1.0; 3]).unwrap();
        let before: Vec<u32> =
            (0..3).map(|c| pipe.client_width(c).unwrap()).collect();
        assert_eq!(pipe.end_round(0).unwrap(), RoundAdaptation::None);
        let after: Vec<u32> =
            (0..3).map(|c| pipe.client_width(c).unwrap()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn moment_estimates_survive_rounds_a_client_sits_out() {
        use crate::fl::packet::SchemeTag;
        // a minimal decoded-uplink probe: only client_id and the σ
        // side-info word matter to the allocator's moment tracker
        let probe = |client: u32, sigma: f32| Packet {
            client_id: client,
            round: 0,
            scheme: SchemeTag::RcFed,
            bits_per_symbol: 3,
            d: 1,
            side_info: vec![0.0, sigma, 0.0],
            payload: Vec::new(),
            payload_bits: 0,
            table_bits: 0,
            index_bits: 0,
        };
        let mut alloc = RateAllocator::design(
            rcfed_scheme(),
            WireCoder::Huffman,
            TransformCfg::default(),
            2.5,
            1,
            1,
            6,
        )
        .unwrap();
        alloc.bind(4, &[1.0; 4]).unwrap();
        // window 0: every client reports; client 3 is the energetic one
        for (c, sigma) in [(0u32, 0.1f32), (1, 0.1), (2, 0.1), (3, 2.0)] {
            alloc.observe_packet(&probe(c, sigma));
        }
        alloc.end_round(0);
        let e3 = alloc.moment_estimate(3);
        assert!((e3 - 4.0).abs() < 1e-9, "E_3 = σ² = 4, got {e3}");
        let w3 = alloc.widths[3];
        assert!(w3 > alloc.widths[0], "energetic client earns width");

        // windows 1..=3: client 3 sits out every cohort. Its folded
        // estimate — and therefore its wide codebook — must survive,
        // not decay to the flat prior as a dense re-initialized window
        // tracker would.
        for round in 1..4usize {
            for c in 0..3u32 {
                alloc.observe_packet(&probe(c, 0.1));
            }
            alloc.end_round(round);
            assert_eq!(alloc.moment_estimate(3), e3, "round {round}");
            assert_eq!(alloc.widths[3], w3, "round {round}");
        }

        // a never-observed client reads the flat prior, and the tracker
        // holds exactly the clients ever ingested, not the population
        assert_eq!(alloc.moment_estimate(99), 1.0);
        assert_eq!(alloc.moments.len(), 4);

        // re-binding (e.g. a sweep leg reusing the allocator) keeps the
        // learned estimates and restarts only the in-flight window
        alloc.observe_packet(&probe(0, 9.0));
        alloc.bind(4, &[1.0; 4]).unwrap();
        assert_eq!(alloc.moment_estimate(3), e3);
        assert_eq!(alloc.moment_estimate(0), 0.1f32 as f64 * 0.1f32 as f64);
        assert_eq!(alloc.window_obs, 0);
    }
}
