//! Staged client↔PS gradient codec: the decomposition of the former
//! `fl/compression.rs` god-module into an explicit stage graph.
//!
//! ```text
//!             client side                                  PS side
//!  grad ──▶ [ Transform ] ──▶ [ Quantize ] ──▶ [ Code ] ──▶ wire ──▶ decode
//!             identity          codebook         huffman              │
//!             error-feedback    (rcfed/lloyd/    arithmetic           ▼
//!             top-k (+indices)   nqfl/uniform)                   de-transform
//!                               qsgd / fp32                     (scatter) + Σ
//!
//!  on top:  Compressor            — static composition (§3.1, design once)
//!           CompressionPipeline   — + closed-loop λ control (RateTarget)
//!           RateAllocator         — + per-client widths (RateAllocation)
//! ```
//!
//! * [`transform`] — the pre-quantization stage: identity, per-client
//!   error-feedback residuals ([`TransformState`]), top-k magnitude
//!   sparsification with packed index coding;
//! * [`quantize`] — the designed quantize backends and the fused
//!   quantize+code wire path shared by every composition, plus the
//!   staged encoder/decoders for transform-active packets;
//! * [`design`] — the process-wide codebook design cache (§3.1's
//!   universal N(0,1) designs, plus the adaptive per-window keys);
//! * [`compressor`] — the static [`Compressor`];
//! * [`downlink`] — the direction-agnostic [`DeltaCodec`]: the same
//!   stage graph pointed server→client (versioned model deltas with a
//!   server-owned EF residual, plus the downlink half of a joint rate
//!   budget);
//! * [`pipeline`] — the round-loop [`CompressionPipeline`], the
//!   closed-loop [`RateTarget`] controller and [`PacketDecoder`];
//! * [`alloc`] — the water-filling per-client [`RateAllocation`].
//!
//! **Wire compatibility:** every pre-codec scheme × wire-coder
//! combination is byte-identical through this tree (the golden e2e and
//! bit-exact replay suites are the oracle). The transform stage only
//! changes the wire when explicitly enabled: sparse packets prepend a
//! `k + packed-indices` block to the payload, charged to
//! `Packet::index_bits`; error feedback has zero wire effect.
//!
//! The old import path `rcfed::fl::compression` keeps working through a
//! re-export shim in [`crate::fl`].

pub mod alloc;
pub mod compressor;
pub mod design;
pub mod downlink;
pub mod pipeline;
pub mod quantize;
pub mod scheme;
pub mod transform;

pub use alloc::{AllocSnapshot, RateAllocation, RateAllocator};
pub use compressor::Compressor;
pub use downlink::{DeltaCodec, Direction};
pub use design::{design_cache_stats, designed_codebook, DesignCacheStats};
pub use pipeline::{
    CompressionPipeline, DecodedPacket, PacketDecoder, RateTarget,
    RoundAdaptation,
};
pub use quantize::CodecScratch;
pub use scheme::{CompressionScheme, WireCoder};
pub use transform::{Transform, TransformCfg, TransformState};
