//! The static [`Compressor`]: one designed quantize/code backend plus a
//! transform configuration, bound at construction (the "computed once at
//! the beginning of the training phase" property of §3.1).

use crate::coding::arithmetic::ArithmeticCoder;
use crate::coding::huffman::HuffmanCode;
use crate::fl::packet::{Packet, SchemeTag};
use crate::quant::codebook::Codebook;
use crate::quant::qsgd::Qsgd;
use crate::util::rng::Rng;
use crate::util::{Error, Result};

use super::design::designed_codebook;
use super::pipeline::DecodedBody;
use super::quantize::{
    decode_sparse_fp32, encode_staged, qsgd_encode, qsgd_table_bytes,
    sign_decode_into, sign_encode, sign_scale, CodebookCodec, CodecScratch,
    Kernel, QuantBackend,
};
use super::scheme::{CompressionScheme, WireCoder};
use super::transform::{self, TransformCfg, TransformState};

/// A ready-to-use compressor (design done once at construction).
pub struct Compressor {
    pub scheme: CompressionScheme,
    pub wire: WireCoder,
    /// the transform stage ahead of quantization (identity by default)
    pub transform: TransformCfg,
    pub(crate) kernel: Kernel,
    /// design-time diagnostics for codebook schemes
    pub design_mse: Option<f64>,
    pub design_rate: Option<f64>,
}

impl Compressor {
    /// Design the quantizer + wire code against the universal N(0,1)
    /// model (§3.1). Deterministic; no data needed. Codebook schemes are
    /// served from the process-wide design cache (see
    /// [`designed_codebook`]), so repeated sweep cells reuse the
    /// expensive Lloyd/RC alternation instead of re-running it.
    pub fn design(scheme: CompressionScheme, wire: WireCoder) -> Result<Compressor> {
        Compressor::design_with_transform(
            scheme, wire, TransformCfg::default())
    }

    /// Like [`Self::design`], with an explicit transform stage.
    /// `TransformCfg::identity()` is byte-identical to [`Self::design`].
    pub fn design_with_transform(
        scheme: CompressionScheme,
        wire: WireCoder,
        transform: TransformCfg,
    ) -> Result<Compressor> {
        transform.validate(&scheme)?;
        let (kernel, mse, rate) = match scheme {
            CompressionScheme::Qsgd { bits } => {
                (Kernel::Qsgd(Qsgd::new(bits)), None, None)
            }
            CompressionScheme::Fp32 => (Kernel::Fp32, None, None),
            CompressionScheme::Sign => (Kernel::Sign, None, None),
            _ => {
                let (cb, rep) = designed_codebook(scheme)?;
                let huffman = HuffmanCode::from_probs(&rep.probs)?;
                let arith = ArithmeticCoder::from_probs(&rep.probs)?;
                (
                    Kernel::Codebook { codebook: cb, huffman, arith },
                    Some(rep.mse),
                    Some(rep.huffman_rate),
                )
            }
        };
        Ok(Compressor {
            scheme,
            wire,
            transform,
            kernel,
            design_mse: mse,
            design_rate: rate,
        })
    }

    /// The designed codebook (None for QSGD/Fp32).
    pub fn codebook(&self) -> Option<&Codebook> {
        match &self.kernel {
            Kernel::Codebook { codebook, .. } => Some(codebook),
            _ => None,
        }
    }

    /// Borrowed quantize-backend view for the staged encoder.
    pub(crate) fn backend(&self) -> QuantBackend<'_> {
        match &self.kernel {
            Kernel::Codebook { codebook, huffman, arith } => {
                QuantBackend::Codebook(CodebookCodec {
                    codebook,
                    huffman,
                    arith,
                    wire: self.wire,
                })
            }
            Kernel::Qsgd(q) => QuantBackend::Qsgd(q),
            Kernel::Fp32 => QuantBackend::Fp32,
            Kernel::Sign => QuantBackend::Sign,
        }
    }

    /// Compress a flat gradient into an uplink packet. `rng` drives
    /// QSGD's stochastic rounding (unused by deterministic schemes).
    /// With an active non-EF transform this runs the staged path on a
    /// throwaway state; error feedback *requires* per-client state, so
    /// it must go through [`Self::compress_with`].
    pub fn compress(
        &self,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
    ) -> Result<Packet> {
        if self.transform.is_active() {
            if self.transform.error_feedback {
                return Err(Error::Config(
                    "error feedback carries per-client state; call \
                     compress_with"
                        .into(),
                ));
            }
            let mut tmp = TransformState::new();
            return self.compress_with(&mut tmp, client_id, round, grad, rng);
        }
        let mut scratch = CodecScratch::new();
        let (pkt, _) =
            self.compress_dense(&mut scratch, client_id, round, grad, rng, false)?;
        Ok(pkt)
    }

    /// Compress through the full staged path, threading the caller's
    /// per-client [`TransformState`]. Identical to [`Self::compress`]
    /// when the transform is inactive (the state is untouched).
    pub fn compress_with(
        &self,
        state: &mut TransformState,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
    ) -> Result<Packet> {
        let mut scratch = CodecScratch::new();
        self.compress_with_sample(
            state, &mut scratch, client_id, round, grad, rng, false)
    }

    /// [`Self::compress_with`] plus the adaptive controller's stats
    /// capture (the sample lands in `state`; see
    /// [`TransformState::take_sample`]) and the caller's reusable
    /// [`CodecScratch`] (the round loop threads one per worker, so the
    /// hot path allocates nothing after warm-up).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn compress_with_sample(
        &self,
        state: &mut TransformState,
        scratch: &mut CodecScratch,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
        capture_sample: bool,
    ) -> Result<Packet> {
        if !self.transform.is_active() {
            let (pkt, sample) = self.compress_dense(
                scratch, client_id, round, grad, rng, capture_sample)?;
            if let Some(sample) = sample {
                state.set_sample(sample);
            }
            return Ok(pkt);
        }
        encode_staged(
            &self.backend(),
            self.transform,
            state,
            scratch,
            client_id,
            round,
            grad,
            rng,
            self.scheme.tag(),
            self.scheme.bits() as u8,
            capture_sample,
        )
    }

    /// The legacy dense hot path — byte-identical to the pre-codec
    /// module for every scheme. The quantize stage writes into the
    /// caller's reusable symbol buffer. With `capture_sample` the
    /// codebook arm folds the adaptive controller's stats sample into
    /// the moments pass (byte-identical to the old re-walk via
    /// `grad_sample_from`); the other kernels return `None` and the
    /// caller's fallback sampler applies.
    fn compress_dense(
        &self,
        scratch: &mut CodecScratch,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
        capture_sample: bool,
    ) -> Result<(Packet, Option<Vec<f32>>)> {
        match &self.kernel {
            Kernel::Codebook { codebook, huffman, arith } => {
                let codec = CodebookCodec {
                    codebook,
                    huffman,
                    arith,
                    wire: self.wire,
                };
                let (mu, sigma, sample) = if capture_sample {
                    let (mu, sigma, s) =
                        codec.quantize_sampling(grad, &mut scratch.symbols);
                    (mu, sigma, Some(s))
                } else {
                    let (mu, sigma) =
                        codec.quantize(grad, &mut scratch.symbols);
                    (mu, sigma, None)
                };
                let (payload, payload_bits) = codec.code(&scratch.symbols)?;
                Ok((
                    Packet {
                        client_id,
                        round,
                        scheme: self.scheme.tag(),
                        bits_per_symbol: self.scheme.bits() as u8,
                        d: grad.len() as u32,
                        side_info: vec![mu, sigma],
                        payload,
                        payload_bits,
                        table_bits: 0, // universal design-time code (§3.1)
                        index_bits: 0,
                    },
                    sample,
                ))
            }
            Kernel::Qsgd(q) => {
                let e = qsgd_encode(q, grad, rng)?;
                Ok((
                    Packet {
                        client_id,
                        round,
                        scheme: SchemeTag::Qsgd,
                        bits_per_symbol: self.scheme.bits() as u8,
                        d: grad.len() as u32,
                        // one 32-bit ‖v‖ per bucket — bucketing's real
                        // cost
                        side_info: e.msg.norms,
                        payload: e.payload,
                        payload_bits: e.payload_bits,
                        table_bits: e.table_bits,
                        index_bits: 0,
                    },
                    None,
                ))
            }
            Kernel::Fp32 => {
                let mut payload = Vec::with_capacity(grad.len() * 4);
                for &x in grad {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
                Ok((
                    Packet {
                        client_id,
                        round,
                        scheme: SchemeTag::Fp32,
                        bits_per_symbol: 32,
                        d: grad.len() as u32,
                        side_info: vec![],
                        payload,
                        payload_bits: grad.len() as u64 * 32,
                        table_bits: 0,
                        index_bits: 0,
                    },
                    None,
                ))
            }
            Kernel::Sign => {
                let scale = sign_scale(grad);
                let (payload, payload_bits) = sign_encode(grad);
                Ok((
                    Packet {
                        client_id,
                        round,
                        scheme: SchemeTag::Sign,
                        bits_per_symbol: 1,
                        d: grad.len() as u32,
                        side_info: vec![scale],
                        payload,
                        payload_bits,
                        table_bits: 0,
                        index_bits: 0,
                    },
                    None,
                ))
            }
        }
    }

    /// PS side: decode a packet and accumulate the reconstructed gradient
    /// into `acc` (eq. (11) then the sum of §3.4).
    pub fn decompress_accumulate(
        &self,
        packet: &Packet,
        acc: &mut [f32],
    ) -> Result<()> {
        let d = packet.d as usize;
        if acc.len() != d {
            return Err(Error::Coding(format!(
                "accumulator {} != packet d {d}", acc.len())));
        }
        match &self.kernel {
            Kernel::Codebook { .. } => {
                // (μ, σ) side info — a corrupted packet can carry any
                // count or value, so validate before touching it
                if packet.side_info.len() != 2 {
                    return Err(Error::Coding(format!(
                        "codebook packet carries {} side-info values, \
                         expected 2 (μ, σ)",
                        packet.side_info.len()
                    )));
                }
                let (mu, sigma) = (packet.side_info[0], packet.side_info[1]);
                self.decode_codebook_accumulate(packet, mu, sigma, acc)?;
            }
            Kernel::Qsgd(q) => {
                // read the code-length table from the payload head, then
                // decode the symbol stream with the rebuilt canonical code
                // (table geometry shared with `qsgd_encode`)
                let table_bytes = qsgd_table_bytes(q.num_symbols());
                if packet.payload.len() < table_bytes {
                    return Err(Error::Coding("qsgd packet too short".into()));
                }
                let mut r =
                    crate::coding::bitio::BitReader::new(&packet.payload);
                let lens: Vec<u32> = (0..q.num_symbols())
                    .map(|_| r.read(5) as u32)
                    .collect();
                let code = HuffmanCode::from_lengths(&lens)?;
                // hold the coded tail to the exact-accounting contract:
                // it must cover the declared bits and consume exactly
                // that many (a zero-filled truncated tail is a reject)
                let coded = &packet.payload[table_bytes..];
                Packet::ensure_covers(coded, packet.payload_bits)?;
                let mut symbols = vec![0u8; d];
                code.decode_exact(coded, &mut symbols, packet.payload_bits)?;
                if packet.side_info.len() != q.num_buckets(d) {
                    return Err(Error::Coding(format!(
                        "qsgd: {} norms for {} buckets",
                        packet.side_info.len(),
                        q.num_buckets(d)
                    )));
                }
                if !packet.side_info.iter().all(|n| n.is_finite()) {
                    return Err(Error::Coding(
                        "qsgd: non-finite bucket norm".into()));
                }
                let msg = crate::quant::qsgd::QsgdMessage {
                    norms: packet.side_info.clone(),
                    symbols,
                };
                q.decode_accumulate(&msg, acc);
            }
            Kernel::Fp32 => {
                if self.transform.is_sparse() {
                    decode_sparse_fp32(packet, acc)?;
                    return Ok(());
                }
                // a truncated/corrupted packet may carry fewer payload
                // bytes than its claimed dimension needs
                if packet.payload.len() < 4 * d {
                    return Err(Error::Coding(format!(
                        "fp32 payload {} bytes < 4·d = {}",
                        packet.payload.len(),
                        4 * d
                    )));
                }
                for (i, a) in acc.iter_mut().enumerate() {
                    let off = i * 4;
                    *a += f32::from_le_bytes(
                        packet.payload[off..off + 4].try_into().unwrap(),
                    );
                }
            }
            Kernel::Sign => {
                // a single scale word — validated like (μ, σ) above
                if packet.side_info.len() != 1 {
                    return Err(Error::Coding(format!(
                        "sign packet carries {} side-info values, \
                         expected 1 (scale)",
                        packet.side_info.len()
                    )));
                }
                self.decode_sign_accumulate(packet, packet.side_info[0], acc)?;
            }
        }
        Ok(())
    }

    /// Decode a sign-scheme payload and accumulate with the given scale
    /// — shared by the static 1-word side-info path above and the
    /// versioned delta-codec path (which validates and strips the
    /// version word before delegating here). Sparse (top-k) packets
    /// route through the index-block decoder.
    pub(crate) fn decode_sign_accumulate(
        &self,
        packet: &Packet,
        scale: f32,
        acc: &mut [f32],
    ) -> Result<()> {
        let d = packet.d as usize;
        if acc.len() != d {
            return Err(Error::Coding(format!(
                "accumulator {} != packet d {d}", acc.len())));
        }
        let mut vals = Vec::new();
        if self.transform.is_sparse() {
            let (indices, consumed) =
                transform::unpack_indices(d, &packet.payload)?;
            sign_decode_into(
                &packet.payload[consumed..],
                indices.len(),
                scale,
                &mut vals,
            )?;
            for (&i, &v) in indices.iter().zip(&vals) {
                acc[i as usize] += v;
            }
        } else {
            sign_decode_into(&packet.payload, d, scale, &mut vals)?;
            for (a, &v) in acc.iter_mut().zip(&vals) {
                *a += v;
            }
        }
        Ok(())
    }

    /// Split decode for the deferred-accumulate server path: everything
    /// [`Self::decompress_accumulate`] does except the accumulator
    /// writes. Codebook packets decode to symbols + an owned
    /// reconstruction table; the raw-value schemes (fp32, sign, qsgd)
    /// fall back to their direct decoder into a private zeroed buffer —
    /// exactly what the parallel delivery path did per worker before
    /// the split.
    pub(crate) fn decode_body(&self, packet: &Packet) -> Result<DecodedBody> {
        match &self.kernel {
            Kernel::Codebook { .. } => {
                // (μ, σ) side info — a corrupted packet can carry any
                // count or value, so validate before touching it
                if packet.side_info.len() != 2 {
                    return Err(Error::Coding(format!(
                        "codebook packet carries {} side-info values, \
                         expected 2 (μ, σ)",
                        packet.side_info.len()
                    )));
                }
                let (mu, sigma) = (packet.side_info[0], packet.side_info[1]);
                self.decode_codebook_body(packet, mu, sigma)
            }
            _ => {
                let mut recon = vec![0f32; packet.d as usize];
                self.decompress_accumulate(packet, &mut recon)?;
                Ok(DecodedBody::Recon(recon))
            }
        }
    }

    /// Split-decode twin of [`Self::decode_codebook_accumulate`]: same
    /// (μ, σ) contract, deferred accumulation.
    pub(crate) fn decode_codebook_body(
        &self,
        packet: &Packet,
        mu: f32,
        sigma: f32,
    ) -> Result<DecodedBody> {
        let Kernel::Codebook { codebook, huffman, arith } = &self.kernel
        else {
            return Err(Error::Coding(format!(
                "scheme {:?} is not codebook-backed", self.scheme)));
        };
        let codec = CodebookCodec { codebook, huffman, arith, wire: self.wire };
        if self.transform.is_sparse() {
            let (indices, symbols, table) =
                codec.decode_sparse_body(packet, mu, sigma)?;
            Ok(DecodedBody::Sparse { indices, symbols, table })
        } else {
            let (symbols, table) = codec.decode_dense_body(packet, mu, sigma)?;
            Ok(DecodedBody::Symbols { symbols, table })
        }
    }

    /// Decode a codebook-scheme payload and accumulate with the given
    /// (μ, σ) — shared by the static 2-word side-info path above and the
    /// pipeline's versioned 3-word path (which validates and strips the
    /// version before delegating here, without cloning the payload).
    /// Sparse (top-k) packets route through the index-block decoder.
    pub(crate) fn decode_codebook_accumulate(
        &self,
        packet: &Packet,
        mu: f32,
        sigma: f32,
        acc: &mut [f32],
    ) -> Result<()> {
        let d = packet.d as usize;
        if acc.len() != d {
            return Err(Error::Coding(format!(
                "accumulator {} != packet d {d}", acc.len())));
        }
        let Kernel::Codebook { codebook, huffman, arith } = &self.kernel
        else {
            return Err(Error::Coding(format!(
                "scheme {:?} is not codebook-backed", self.scheme)));
        };
        let codec = CodebookCodec { codebook, huffman, arith, wire: self.wire };
        if self.transform.is_sparse() {
            codec.decode_sparse_accumulate(packet, mu, sigma, acc)
        } else {
            codec.decode_accumulate(packet, mu, sigma, acc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rcq::LengthModel;

    fn gaussian_grad(n: usize, mu: f32, sigma: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g, mu, sigma);
        g
    }

    #[test]
    fn fused_sampling_quantize_is_bitwise_identical() {
        // quantize_sampling folds the stats capture into the moments
        // pass; (μ, σ), the symbol stream AND the normalized sample must
        // match the unfused quantize + sample_normalized pair bit for
        // bit — including the empty-gradient and stride-1 edges
        let c = Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Huffman,
        )
        .unwrap();
        let QuantBackend::Codebook(codec) = c.backend() else {
            panic!("rcfed must be codebook-backed");
        };
        for n in [0usize, 1, 100, 2048, 5000] {
            let g = gaussian_grad(n, 0.02, 0.3, 90 + n as u64);
            let mut sym_a = Vec::new();
            let (mu_a, sg_a) = codec.quantize(&g, &mut sym_a);
            let expect = super::super::quantize::sample_normalized(
                &g, mu_a, sg_a);
            let mut sym_b = Vec::new();
            let (mu_b, sg_b, sample) =
                codec.quantize_sampling(&g, &mut sym_b);
            assert_eq!(mu_a.to_bits(), mu_b.to_bits(), "n={n}");
            assert_eq!(sg_a.to_bits(), sg_b.to_bits(), "n={n}");
            assert_eq!(sym_a, sym_b, "n={n}");
            let ea: Vec<u32> = expect.iter().map(|x| x.to_bits()).collect();
            let eb: Vec<u32> = sample.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ea, eb, "n={n}");
        }
    }

    #[test]
    fn rcfed_compress_decompress_roundtrip() {
        let c = Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Huffman,
        )
        .unwrap();
        let g = gaussian_grad(10_000, 0.01, 0.002, 1);
        let mut rng = Rng::new(2);
        let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
        let mut acc = vec![0f32; g.len()];
        c.decompress_accumulate(&pkt, &mut acc).unwrap();
        // reconstruction must track the gradient to within ~quantizer MSE
        let sigma = 0.002f64;
        let mse: f64 = g
            .iter()
            .zip(&acc)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / g.len() as f64;
        let design = c.design_mse.unwrap() * sigma * sigma;
        assert!(mse < 4.0 * design, "mse={mse} design={design}");
    }

    #[test]
    fn payload_bits_match_design_rate() {
        let c = Compressor::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
        )
        .unwrap();
        let g = gaussian_grad(50_000, 0.0, 1.0, 3);
        let mut rng = Rng::new(4);
        let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
        let bps = pkt.payload_bits as f64 / g.len() as f64;
        let design = c.design_rate.unwrap();
        assert!((bps - design).abs() < 0.05, "bps={bps} design={design}");
    }

    #[test]
    fn rcfed_cheaper_than_lloyd_at_same_bits() {
        // the paper's headline mechanism: rate constraint lowers the
        // encoded bits/symbol at equal b
        let rc = Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.1,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Huffman,
        )
        .unwrap();
        let ll = Compressor::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
        )
        .unwrap();
        let g = gaussian_grad(50_000, 0.0, 1.0, 5);
        let mut rng = Rng::new(6);
        let b_rc = rc.compress(0, 0, &g, &mut rng).unwrap().total_bits();
        let b_ll = ll.compress(0, 0, &g, &mut rng).unwrap().total_bits();
        assert!(b_rc < b_ll, "rcfed {b_rc} vs lloyd {b_ll}");
    }

    #[test]
    fn fp32_is_lossless() {
        let c = Compressor::design(CompressionScheme::Fp32, WireCoder::Huffman)
            .unwrap();
        let g = gaussian_grad(100, 0.0, 1.0, 7);
        let mut rng = Rng::new(8);
        let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
        assert_eq!(pkt.payload_bits, 3200);
        let mut acc = vec![0f32; g.len()];
        c.decompress_accumulate(&pkt, &mut acc).unwrap();
        assert_eq!(acc, g);
    }

    #[test]
    fn arithmetic_wire_is_at_most_huffman() {
        let g = gaussian_grad(50_000, 0.0, 1.0, 9);
        let mut rng = Rng::new(10);
        let h = Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Huffman,
        )
        .unwrap();
        let a = Compressor::design(
            CompressionScheme::RcFed {
                bits: 3,
                lambda: 0.05,
                length_model: LengthModel::Huffman,
            },
            WireCoder::Arithmetic,
        )
        .unwrap();
        let bh = h.compress(0, 0, &g, &mut rng).unwrap().payload_bits;
        let ba = a.compress(0, 0, &g, &mut rng).unwrap().payload_bits;
        assert!(ba <= bh + 64, "arith {ba} vs huffman {bh}");
        // and arithmetic wire still roundtrips
        let pkt = a.compress(0, 0, &g, &mut rng).unwrap();
        let mut acc = vec![0f32; g.len()];
        a.decompress_accumulate(&pkt, &mut acc).unwrap();
        let mse: f64 = g.iter().zip(&acc)
            .map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>()
            / g.len() as f64;
        assert!(mse < 0.1);
    }

    #[test]
    fn block_wire_roundtrips_through_real_bytes() {
        let g = gaussian_grad(50_000, 0.0, 1.0, 14);
        let mut rng = Rng::new(15);
        let scheme = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        };
        let h = Compressor::design(scheme, WireCoder::Huffman).unwrap();
        let b = Compressor::design(scheme, WireCoder::Block).unwrap();
        let ph = h.compress(0, 0, &g, &mut rng).unwrap();
        let pb = b.compress(0, 0, &g, &mut rng).unwrap();
        // block coding pays its per-block table refresh but must stay
        // within that overhead of the design-time Huffman payload
        let blocks = (g.len() as u64)
            .div_ceil(crate::coding::block::DEFAULT_BLOCK_LEN as u64);
        let coder = crate::coding::block::BlockCoder::new(8).unwrap();
        assert!(
            pb.payload_bits <= ph.payload_bits + blocks * coder.table_bits(),
            "block {} vs huffman {} (+{} blocks of table)",
            pb.payload_bits,
            ph.payload_bits,
            blocks
        );
        // through the real wire bytes, with exact-accounting decode
        let parsed = Packet::parse(&pb.to_bytes()).unwrap();
        let mut acc = vec![0f32; g.len()];
        b.decompress_accumulate(&parsed, &mut acc).unwrap();
        let mse: f64 = g
            .iter()
            .zip(&acc)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / g.len() as f64;
        assert!(mse < 0.1, "block-wire reconstruction mse {mse}");
        // and a truncated block payload is a recoverable reject
        let mut cut = parsed.clone();
        cut.payload.truncate(cut.payload.len() / 2);
        cut.payload_bits = cut.payload.len() as u64 * 8 + 1;
        assert!(b.decompress_accumulate(&cut, &mut acc).is_err());
    }

    #[test]
    fn qsgd_roundtrip_with_inline_table() {
        // Bucketed QSGD variance is ~(√bucket/s)·‖v‖² per bucket, so at
        // b=7 (s=127) the reconstruction correlates strongly; at b=3 it
        // is noisier but clearly aligned (unbiasedness is asserted in
        // `qsgd_unbiased_through_the_wire`).
        let g = gaussian_grad(8192, 0.0, 0.5, 11);
        let mut rng = Rng::new(12);
        for (bits, min_cos) in [(7u32, 0.9), (3, 0.4)] {
            let c = Compressor::design(
                CompressionScheme::Qsgd { bits },
                WireCoder::Huffman,
            )
            .unwrap();
            let pkt = c.compress(3, 9, &g, &mut rng).unwrap();
            // one 32-bit norm per 512-coordinate bucket
            assert_eq!(pkt.side_info.len(), 8192 / 512);
            assert!(pkt.table_bits > 0 && pkt.table_bits % 8 == 0);
            let mut acc = vec![0f32; g.len()];
            c.decompress_accumulate(&pkt, &mut acc).unwrap();
            let dot: f64 =
                g.iter().zip(&acc).map(|(&a, &b)| (a * b) as f64).sum();
            let na: f64 = g.iter().map(|&a| (a * a) as f64).sum();
            let nb: f64 = acc.iter().map(|&b| (b * b) as f64).sum();
            let cos = dot / (na.sqrt() * nb.sqrt());
            assert!(cos > min_cos, "b={bits} cosine {cos}");
        }
    }

    #[test]
    fn qsgd_unbiased_through_the_wire() {
        let c = Compressor::design(
            CompressionScheme::Qsgd { bits: 2 },
            WireCoder::Huffman,
        )
        .unwrap();
        let g = vec![0.25f32, -0.5, 0.75, -0.1];
        let mut rng = Rng::new(13);
        let mut mean = vec![0f64; g.len()];
        let trials = 4000;
        for _ in 0..trials {
            let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
            let mut acc = vec![0f32; g.len()];
            c.decompress_accumulate(&pkt, &mut acc).unwrap();
            for (m, &a) in mean.iter_mut().zip(&acc) {
                *m += a as f64 / trials as f64;
            }
        }
        for (i, (&want, &got)) in g.iter().zip(&mean).enumerate() {
            assert!((want as f64 - got).abs() < 0.02, "coord {i}: {got} vs {want}");
        }
    }

    #[test]
    fn compressor_design_goes_through_the_cache() {
        use super::super::design::design_cache_stats;
        let scheme = CompressionScheme::Lloyd { bits: 6 };
        // prime the key, then measure a full Compressor::design
        designed_codebook(scheme).unwrap();
        let before = design_cache_stats();
        let c = Compressor::design(scheme, WireCoder::Huffman).unwrap();
        let delta = design_cache_stats().since(&before);
        assert!(delta.hits >= 1, "Compressor::design bypassed the cache");
        assert!(c.codebook().is_some());
    }

    #[test]
    fn topk_compressor_roundtrips_and_charges_index_bits() {
        let dense = Compressor::design(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
        )
        .unwrap();
        let sparse = Compressor::design_with_transform(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
            TransformCfg::topk(0.1),
        )
        .unwrap();
        let g = gaussian_grad(4096, 0.0, 1.0, 21);
        let mut rng = Rng::new(22);
        let pd = dense.compress(0, 0, &g, &mut rng).unwrap();
        let ps = sparse.compress(0, 0, &g, &mut rng).unwrap();
        let k = 410; // ceil(0.1 · 4096)
        assert_eq!(ps.d, 4096);
        assert!(ps.index_bits >= 32 + (k as u64 * 12),
                "index bits {}", ps.index_bits);
        assert!(ps.total_bits() < pd.total_bits(),
                "topk {} vs dense {}", ps.total_bits(), pd.total_bits());
        // through the real wire bytes
        let parsed = Packet::parse(&ps.to_bytes()).unwrap();
        let mut acc = vec![0f32; g.len()];
        sparse.decompress_accumulate(&parsed, &mut acc).unwrap();
        // only kept coordinates are touched, and the reconstruction
        // aligns with the gradient's largest entries
        let dot: f64 =
            g.iter().zip(&acc).map(|(&a, &b)| (a * b) as f64).sum();
        assert!(dot > 0.0, "anti-correlated sparse reconstruction");
    }

    #[test]
    fn all_constant_gradient_yields_decodable_packets() {
        use super::super::pipeline::{CompressionPipeline, RateTarget};
        let rcfed = CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        };
        // regression (σ = 0 side-info path): `compress` normalizes by
        // mean_std(grad); an all-constant gradient has σ = 0 and must
        // still produce a finite, parse-able, decodable packet — for
        // every scheme and for the versioned pipeline path
        for scheme in [
            rcfed,
            CompressionScheme::Lloyd { bits: 3 },
            CompressionScheme::Nqfl { bits: 3 },
            CompressionScheme::Qsgd { bits: 3 },
            CompressionScheme::Uniform { bits: 3, clip: 4.0 },
            CompressionScheme::Fp32,
            CompressionScheme::Sign,
        ] {
            for value in [0.0f32, 0.25, -3.5] {
                let g = vec![value; 600];
                let c =
                    Compressor::design(scheme, WireCoder::Huffman).unwrap();
                let mut rng = Rng::new(76);
                let pkt = c.compress(0, 0, &g, &mut rng).unwrap();
                assert!(
                    pkt.side_info.iter().all(|x| x.is_finite()),
                    "{scheme:?} value {value}: non-finite side info"
                );
                // through the real wire bytes
                let parsed = Packet::parse(&pkt.to_bytes()).unwrap();
                let mut acc = vec![0f32; g.len()];
                c.decompress_accumulate(&parsed, &mut acc).unwrap();
                assert!(
                    acc.iter().all(|x| x.is_finite()),
                    "{scheme:?} value {value}: NaN reconstruction"
                );
                // for the normalize-by-σ schemes, σ = 0 means every
                // coordinate reconstructs to ≈ μ = value (exactly for
                // fp32); QSGD is only unbiased, not exact, so it is
                // covered by the finiteness assertions above
                if !matches!(scheme, CompressionScheme::Qsgd { .. }) {
                    for &x in &acc {
                        assert!(
                            (x - value).abs() < 1e-3,
                            "{scheme:?}: {x} vs {value}"
                        );
                    }
                }
            }
        }
        // the adaptive stats pass must not divide by zero either
        let pipe = CompressionPipeline::design(
            rcfed,
            WireCoder::Huffman,
            RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 },
        )
        .unwrap();
        let sample = pipe.grad_sample(&[1.5f32; 300]);
        assert!(sample.iter().all(|z| z.is_finite()));
    }

    #[test]
    fn ef_requires_stateful_compress() {
        let c = Compressor::design_with_transform(
            CompressionScheme::Lloyd { bits: 3 },
            WireCoder::Huffman,
            TransformCfg::identity().with_ef(),
        )
        .unwrap();
        let g = gaussian_grad(256, 0.0, 1.0, 23);
        let mut rng = Rng::new(24);
        assert!(c.compress(0, 0, &g, &mut rng).is_err());
        let mut state = TransformState::new();
        let pkt = c.compress_with(&mut state, 0, 0, &g, &mut rng).unwrap();
        assert_eq!(pkt.index_bits, 0, "dense EF has zero wire effect");
        assert!(state.last_ef_norm > 0.0);
        let mut acc = vec![0f32; g.len()];
        c.decompress_accumulate(&pkt, &mut acc).unwrap();
    }

    #[test]
    fn sign_roundtrip_is_one_bit_per_coord() {
        let c = Compressor::design(CompressionScheme::Sign, WireCoder::Huffman)
            .unwrap();
        let g = gaussian_grad(10_000, 0.0, 1.0, 31);
        let mut rng = Rng::new(32);
        let pkt = c.compress(3, 1, &g, &mut rng).unwrap();
        assert_eq!(pkt.scheme, SchemeTag::Sign);
        assert_eq!(pkt.payload_bits, g.len() as u64);
        assert_eq!(pkt.side_info.len(), 1);
        let scale = pkt.side_info[0];
        let mean_abs: f64 =
            g.iter().map(|&x| f64::from(x.abs())).sum::<f64>() / g.len() as f64;
        assert!((f64::from(scale) - mean_abs).abs() < 1e-6);
        // through the real wire bytes: every coordinate comes back ±scale
        let parsed = Packet::parse(&pkt.to_bytes()).unwrap();
        let mut acc = vec![0f32; g.len()];
        c.decompress_accumulate(&parsed, &mut acc).unwrap();
        for (&x, &r) in g.iter().zip(&acc) {
            assert_eq!(r, if x < 0.0 { -scale } else { scale });
        }
        // truncated payloads are recoverable rejects, not zero fill
        let mut short = parsed.clone();
        short.payload.truncate(short.payload.len() - 1);
        let mut acc2 = vec![0f32; g.len()];
        assert!(c.decompress_accumulate(&short, &mut acc2).is_err());
    }

    #[test]
    fn sign_error_feedback_banks_residual() {
        let c = Compressor::design_with_transform(
            CompressionScheme::Sign,
            WireCoder::Huffman,
            TransformCfg::identity().with_ef(),
        )
        .unwrap();
        let g = gaussian_grad(512, 0.0, 1.0, 41);
        let mut rng = Rng::new(42);
        let mut state = TransformState::new();
        let pkt = c.compress_with(&mut state, 0, 0, &g, &mut rng).unwrap();
        assert_eq!(pkt.payload_bits, 512);
        assert!(state.last_ef_norm > 0.0);
        let mut acc = vec![0f32; g.len()];
        c.decompress_accumulate(&pkt, &mut acc).unwrap();
        let scale = pkt.side_info[0];
        assert!(acc.iter().all(|&v| v == scale || v == -scale));
    }
}
