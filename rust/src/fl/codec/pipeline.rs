//! The closed-loop [`CompressionPipeline`]: the stateful composition of
//! the Transform → Quantize → Code stages that the round loop drives,
//! plus the rate-target controller and the PS-side decode dispatch.

use crate::coding::arithmetic::ArithmeticCoder;
use crate::coding::huffman::HuffmanCode;
use crate::fl::packet::Packet;
use crate::stats::empirical::EmpiricalPdf;
use crate::stats::moments::{mean_std, Welford};
use crate::util::rng::Rng;
use crate::util::{Error, Result};

use super::alloc::{AllocSnapshot, RateAllocation, RateAllocator};
use super::compressor::Compressor;
use super::design::{codebook_broadcast_bits, designed_adaptive_codebook};
use super::quantize::{sample_normalized, CodecScratch, Kernel};
use super::scheme::{CompressionScheme, WireCoder};
use super::transform::{TransformCfg, TransformState};

/// Rate-target configuration for the closed-loop pipeline.
///
/// `Off` (the default) reproduces the static §3.1 behavior exactly: one
/// codebook designed against N(0,1) before round 0, no stats pass, no
/// extra side information, no downlink traffic, no random draw — runs
/// are byte-identical to the pre-pipeline code path.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum RateTarget {
    /// static design; nothing adapts
    #[default]
    Off,
    /// Closed-loop control (the constrained form (5) solved online):
    /// dual ascent on λ every `adapt_every` rounds drives the *measured*
    /// uplink bits/coordinate — ledger bits over transmitted
    /// coordinates, headers, side info and tables included — toward
    /// `bits_per_coord`.
    Track {
        /// target uplink bits per gradient coordinate
        bits_per_coord: f64,
        /// adaptation window length in rounds
        adapt_every: usize,
    },
    /// Joint up+down budget: `total_bpc` is split between the uplink
    /// Track loop (which targets `total_bpc · split`) and the downlink
    /// delta codec (which targets `total_bpc · (1 − split)`), each
    /// direction running its own dual ascent against *measured* ledger
    /// bits. Requires the rcfed scheme on both directions (λ is the
    /// control variable on each).
    Joint {
        /// total bits per coordinate across both directions
        total_bpc: f64,
        /// uplink fraction of the total, in (0, 1)
        split: f64,
        /// adaptation window length in rounds (shared by both loops)
        adapt_every: usize,
    },
}

impl RateTarget {
    pub fn is_on(&self) -> bool {
        !matches!(self, RateTarget::Off)
    }

    /// Stable row-key label for CSVs, `"off"` when disabled.
    pub fn label(&self) -> String {
        match *self {
            RateTarget::Off => "off".into(),
            RateTarget::Track { bits_per_coord, adapt_every } => {
                format!("rt{bits_per_coord}w{adapt_every}")
            }
            RateTarget::Joint { total_bpc, split, adapt_every } => {
                format!("jt{total_bpc}s{split}w{adapt_every}")
            }
        }
    }

    /// The uplink Track operating point as `(target bits/coord, window)`
    /// — the direct target for `Track`, the uplink share for `Joint`,
    /// `None` when off. The ONE place both variants resolve to the dual
    /// ascent the pipeline runs.
    pub fn track_params(&self) -> Option<(f64, usize)> {
        match *self {
            RateTarget::Off => None,
            RateTarget::Track { bits_per_coord, adapt_every } => {
                Some((bits_per_coord, adapt_every))
            }
            RateTarget::Joint { total_bpc, split, adapt_every } => {
                Some((total_bpc * split, adapt_every))
            }
        }
    }

    /// The downlink share of a `Joint` budget as `(target bits/coord,
    /// window)`; `None` for `Off` and the uplink-only `Track`.
    pub fn down_params(&self) -> Option<(f64, usize)> {
        match *self {
            RateTarget::Joint { total_bpc, split, adapt_every } => {
                Some((total_bpc * (1.0 - split), adapt_every))
            }
            _ => None,
        }
    }

    /// Reject nonsensical targets and unsupported schemes up front, so a
    /// bad configuration is a config error, not a silent no-op.
    pub fn validate(&self, scheme: &CompressionScheme) -> Result<()> {
        if let RateTarget::Joint { total_bpc, split, .. } = *self {
            if !(total_bpc > 0.0 && total_bpc.is_finite()) {
                return Err(Error::Config(format!(
                    "joint budget {total_bpc} must be finite and > 0")));
            }
            if !(split > 0.0 && split < 1.0) {
                return Err(Error::Config(format!(
                    "joint split {split} must lie strictly in (0, 1)")));
            }
        }
        let Some((bits_per_coord, adapt_every)) = self.track_params() else {
            return Ok(());
        };
        if !(bits_per_coord > 0.0 && bits_per_coord.is_finite()) {
            return Err(Error::Config(format!(
                "rate target {bits_per_coord} must be finite and > 0")));
        }
        if adapt_every == 0 {
            return Err(Error::Config(
                "rate target needs adapt-every >= 1".into()));
        }
        match scheme {
            CompressionScheme::RcFed { .. } => Ok(()),
            other => Err(Error::Config(format!(
                "rate targeting requires the rcfed scheme (λ is the \
                 control variable); got {other:?}"))),
        }
    }
}

/// Dual-ascent step schedule: sign-adaptive — grow while the rate error
/// keeps one sign (λ still marching toward the crossing), halve on a
/// flip (bracketing the crossing).
pub(crate) const STEP_INIT: f64 = 0.02;
pub(crate) const STEP_GROW: f64 = 1.5;
pub(crate) const STEP_SHRINK: f64 = 0.5;
pub(crate) const STEP_MIN: f64 = 1e-3;
pub(crate) const STEP_MAX: f64 = 0.25;
/// Cap on buffered normalized samples per adaptation window (shared
/// with the downlink delta codec's controller).
pub(crate) const MAX_WINDOW_SAMPLES: usize = 65_536;

/// What the pipeline did at a round boundary — returned to the round
/// layer, which owns the downlink ledger.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RoundAdaptation {
    /// nothing published this round
    None,
    /// the closed-loop controller re-designed the shared codebook; one
    /// copy goes to every client
    Broadcast { bits_per_client: u64 },
    /// the rate allocator moved some clients to new widths; each changed
    /// client receives its own codebook (`(client, bits)` per receiver)
    PerClient { publications: Vec<(u32, u64)> },
}

/// Closed-loop compression pipeline — the stateful replacement for
/// threading a static [`Compressor`] through the round loop.
///
/// With [`RateTarget::Off`] it is a transparent wrapper: `compress` and
/// `decompress_accumulate` delegate to the inner static compressor and
/// every adaptive entry point is a no-op. With [`RateTarget::Track`] it
/// closes the loop the paper leaves open (§3.1 designs once, before
/// training; Mitchell et al. 2022 show the gradient distribution drifts
/// over training):
///
/// 1. each round, clients hand back a strided sample of their
///    *normalized* gradient coordinates ([`Self::grad_sample`] →
///    [`Self::observe_samples`]; only samples from packets the server
///    actually ingested count) and the round layer reports the uplink
///    ledger's measured bits ([`Self::observe_round`]).
///    **Accounting policy:** the stats subsample (≤ 2048 coords/update)
///    is control-plane metadata piggybacked on the uplink and is *not*
///    charged to the gradient bit ledger — the same modeling choice as
///    the uncharged θ broadcast (the ledger is Fig. 1's gradient-uplink
///    x-axis, not a full traffic model); at paper-scale `d` the sample
///    is orders of magnitude below the payload it steers;
/// 2. at each window end ([`Self::end_round`]) dual ascent moves λ by
///    the measured bits/coordinate error against the target, and the
///    RC-FED codebook is re-designed against an [`EmpiricalPdf`] of the
///    window's samples — warm-started from the previous codebook and
///    served through the process-wide design cache;
/// 3. the new codebook is versioned: uplink packets carry the version
///    as a third side-info word (32 bits, honestly charged) and stale
///    versions are rejected on decode; the publish cost is returned to
///    the caller, which charges it to the downlink ledger.
///
/// The transform stage rides along on every path: an active transform
/// (error feedback, top-k) runs the staged encoder against per-client
/// [`TransformState`]s threaded through [`Self::compress_with`], and
/// its index+value bits land on the same measured ledger the controller
/// steers by.
pub struct CompressionPipeline {
    compressor: Compressor,
    target: RateTarget,
    adaptive: bool,
    /// the transform stage shared by every path (mirrors the inner
    /// compressor's configuration; the allocator carries its own copy)
    transform: TransformCfg,
    /// per-client rate allocator (`None` = the shared-codebook path)
    alloc: Option<RateAllocator>,
    version: u32,
    lambda: f64,
    /// windows adapted so far (part of the design-cache key)
    adapt_step: u32,
    step: f64,
    prev_err: f64,
    window_bits: u64,
    window_coords: u64,
    samples: Vec<f32>,
    moments: Welford,
    last_realized: f64,
}

impl CompressionPipeline {
    /// Design the initial compressor and wire the controller. `target`
    /// other than `Off` requires the RC-FED scheme (checked).
    pub fn design(
        scheme: CompressionScheme,
        wire: WireCoder,
        target: RateTarget,
    ) -> Result<CompressionPipeline> {
        CompressionPipeline::design_alloc(
            scheme, wire, target, RateAllocation::Uniform)
    }

    /// Like [`Self::design`], with a per-client rate-allocation mode.
    /// `RateAllocation::Uniform` is byte-identical to [`Self::design`];
    /// `WaterFill` builds the width ladder up front (every width served
    /// from the design cache) and waits for [`Self::bind_clients`].
    pub fn design_alloc(
        scheme: CompressionScheme,
        wire: WireCoder,
        target: RateTarget,
        alloc: RateAllocation,
    ) -> Result<CompressionPipeline> {
        CompressionPipeline::design_full(
            scheme, wire, target, alloc, TransformCfg::default())
    }

    /// The full constructor: scheme, wire coder, rate-target controller,
    /// per-client allocation and transform stage. Every reduced
    /// constructor delegates here with the remaining axes at their
    /// byte-identical defaults.
    pub fn design_full(
        scheme: CompressionScheme,
        wire: WireCoder,
        target: RateTarget,
        alloc: RateAllocation,
        transform: TransformCfg,
    ) -> Result<CompressionPipeline> {
        target.validate(&scheme)?;
        alloc.validate(&scheme, &target)?;
        transform.validate(&scheme)?;
        let allocator = match alloc {
            RateAllocation::Uniform => None,
            RateAllocation::WaterFill {
                budget_bpc, adapt_every, min_bits, max_bits,
            } => Some(RateAllocator::design(
                scheme, wire, transform, budget_bpc, adapt_every, min_bits,
                max_bits,
            )?),
        };
        let lambda = match scheme {
            CompressionScheme::RcFed { lambda, .. } => lambda,
            _ => 0.0,
        };
        Ok(CompressionPipeline {
            compressor: Compressor::design_with_transform(
                scheme, wire, transform)?,
            target,
            adaptive: target.is_on(),
            transform,
            alloc: allocator,
            version: 0,
            lambda,
            adapt_step: 0,
            step: STEP_INIT,
            prev_err: f64::NAN,
            window_bits: 0,
            window_coords: 0,
            samples: Vec::new(),
            moments: Welford::default(),
            last_realized: f64::NAN,
        })
    }

    /// Wrap an already-designed static compressor ([`RateTarget::Off`]).
    pub fn from_compressor(compressor: Compressor) -> CompressionPipeline {
        let transform = compressor.transform;
        CompressionPipeline {
            compressor,
            target: RateTarget::Off,
            adaptive: false,
            transform,
            alloc: None,
            version: 0,
            lambda: 0.0,
            adapt_step: 0,
            step: STEP_INIT,
            prev_err: f64::NAN,
            window_bits: 0,
            window_coords: 0,
            samples: Vec::new(),
            moments: Welford::default(),
            last_realized: f64::NAN,
        }
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    pub fn target(&self) -> RateTarget {
        self.target
    }

    /// The configured transform stage.
    pub fn transform(&self) -> TransformCfg {
        self.transform
    }

    /// Current multiplier (the initial λ until the first window closes).
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Current codebook version (bumped on every redesign).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Measured uplink bits/coordinate of the last closed window (NaN
    /// before the first window closes).
    pub fn last_realized(&self) -> f64 {
        self.last_realized
    }

    /// The inner compressor (design diagnostics, codebook access).
    pub fn compressor(&self) -> &Compressor {
        &self.compressor
    }

    /// Compress a flat gradient. Adaptive packets carry the codebook
    /// version as one extra side-info word (exact as f32 for any
    /// realistic version count); allocated packets are encoded against
    /// the sender's assigned codebook; `Off`/`Uniform` packets are
    /// byte-identical to the static compressor's.
    ///
    /// Stateless entry point: fine for identity and pure-sparsification
    /// transforms (a throwaway state is used); error feedback *needs*
    /// per-client state and must go through [`Self::compress_with`].
    pub fn compress(
        &self,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
    ) -> Result<Packet> {
        if self.transform.error_feedback {
            return Err(Error::Config(
                "error feedback carries per-client state; call \
                 compress_with"
                    .into(),
            ));
        }
        let mut tmp = TransformState::new();
        self.compress_with(&mut tmp, client_id, round, grad, rng)
    }

    /// Compress through the staged path with the caller's per-client
    /// [`TransformState`]. Identical to [`Self::compress`] when the
    /// transform is inactive (the state is untouched). On adaptive runs
    /// with an active transform, the controller's stats sample of the
    /// *working set* is stashed into the state
    /// ([`TransformState::take_sample`]).
    pub fn compress_with(
        &self,
        state: &mut TransformState,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
    ) -> Result<Packet> {
        let mut scratch = CodecScratch::new();
        self.compress_with_scratch(
            state, &mut scratch, client_id, round, grad, rng)
    }

    /// The round loop's hot entry point: [`Self::compress_with`] plus
    /// the worker's reusable [`CodecScratch`], so a warm worker encodes
    /// without allocating symbol/recon buffers. Byte-identical to
    /// [`Self::compress_with`] — scratch is a buffer-reuse knob, never a
    /// results knob.
    pub fn compress_with_scratch(
        &self,
        state: &mut TransformState,
        scratch: &mut CodecScratch,
        client_id: u32,
        round: u32,
        grad: &[f32],
        rng: &mut Rng,
    ) -> Result<Packet> {
        if let Some(alloc) = &self.alloc {
            return alloc.compress_with(
                state, scratch, client_id, round, grad, rng);
        }
        let mut pkt = self.compressor.compress_with_sample(
            state, scratch, client_id, round, grad, rng, self.adaptive)?;
        if self.adaptive {
            pkt.side_info.push(self.version as f32);
        }
        Ok(pkt)
    }

    /// Whether a per-client rate allocation is active.
    pub fn is_allocated(&self) -> bool {
        self.alloc.is_some()
    }

    /// Bind the allocator to the run's client population: per-client
    /// bandwidth factors (from the channel model) seed the initial
    /// water-fill. A no-op — and free — without an allocation.
    pub fn bind_clients(
        &mut self,
        num_clients: usize,
        bandwidth_factors: &[f64],
    ) -> Result<()> {
        if let Some(alloc) = &mut self.alloc {
            alloc.bind(num_clients, bandwidth_factors)?;
        }
        Ok(())
    }

    /// Record one *ingested* update: the Track controller's sample pass
    /// and the allocator's per-client energy pass, in one call. The
    /// round layer calls this only for packets the server actually
    /// decoded, so channel faults steer neither controller.
    pub fn observe_delivery(&mut self, packet: &Packet, sample: &[f32]) {
        self.observe_samples(sample);
        if let Some(alloc) = &mut self.alloc {
            alloc.observe_packet(packet);
        }
    }

    /// The width currently assigned to `client` (None without an
    /// allocation or before [`Self::bind_clients`]).
    pub fn client_width(&self, client: usize) -> Option<u32> {
        self.alloc.as_ref()?.widths.get(client).copied()
    }

    /// Current allocation diagnostics (None when allocation is off or
    /// unbound).
    pub fn alloc_snapshot(&self) -> Option<AllocSnapshot> {
        let alloc = self.alloc.as_ref()?;
        if !alloc.bound() {
            return None;
        }
        Some(AllocSnapshot {
            gini: alloc.gini(),
            mean_bits: alloc.mean_bits(),
            min_bits: *alloc.widths.iter().min().unwrap(),
            max_bits: *alloc.widths.iter().max().unwrap(),
        })
    }

    /// Current width histogram `(width, clients)` (empty when allocation
    /// is off).
    pub fn alloc_histogram(&self) -> Vec<(u32, usize)> {
        self.alloc.as_ref().map(|a| a.histogram()).unwrap_or_default()
    }

    /// Client-side stats pass: a deterministic strided subsample of the
    /// *normalized* gradient coordinates (what the quantizer actually
    /// sees). Empty — and free — when the pipeline is not adaptive.
    pub fn grad_sample(&self, grad: &[f32]) -> Vec<f32> {
        if !self.adaptive || grad.is_empty() {
            return Vec::new();
        }
        let (mu, sigma) = mean_std(grad);
        self.sample_with(grad, mu, sigma)
    }

    /// Like [`Self::grad_sample`], but reusing the (μ, σ) the
    /// compressor already wrote into `packet`'s side info — the client
    /// hot path calls this to avoid a second O(d) moments pass over the
    /// gradient it just compressed.
    pub fn grad_sample_from(&self, grad: &[f32], packet: &Packet) -> Vec<f32> {
        if !self.adaptive || grad.is_empty() || packet.side_info.len() < 2 {
            return Vec::new();
        }
        self.sample_with(grad, packet.side_info[0], packet.side_info[1])
    }

    fn sample_with(&self, grad: &[f32], mu: f32, sigma: f32) -> Vec<f32> {
        sample_normalized(grad, mu, sigma)
    }

    /// Fold one update's normalized sample into the window accumulator.
    pub fn observe_samples(&mut self, sample: &[f32]) {
        if !self.adaptive {
            return;
        }
        for &z in sample {
            if !z.is_finite() {
                continue;
            }
            self.moments.push(z as f64);
            if self.samples.len() < MAX_WINDOW_SAMPLES {
                self.samples.push(z);
            }
        }
    }

    /// Report one round's uplink-ledger movement: `bits` as actually
    /// charged by [`crate::coordinator::network::SimulatedNetwork`]
    /// (headers, side info, tables, index blocks, partial straggler
    /// prefixes — the measured rate, not the design-time estimate), over
    /// `coords` transmitted gradient coordinates.
    pub fn observe_round(&mut self, bits: u64, coords: u64) {
        if !self.adaptive {
            return;
        }
        self.window_bits += bits;
        self.window_coords += coords;
    }

    /// Close round `round` (0-based). On an adaptation-window boundary
    /// the active controller acts: the Track loop runs dual ascent on λ,
    /// re-designs empirically and bumps the shared codebook version; the
    /// rate allocator re-solves the per-client widths. The returned
    /// [`RoundAdaptation`] carries what must be charged to the caller's
    /// downlink ledger.
    pub fn end_round(&mut self, round: usize) -> Result<RoundAdaptation> {
        if let Some(alloc) = &mut self.alloc {
            return Ok(match alloc.end_round(round) {
                Some(publications) => {
                    RoundAdaptation::PerClient { publications }
                }
                None => RoundAdaptation::None,
            });
        }
        let Some((bits_per_coord, adapt_every)) = self.target.track_params()
        else {
            return Ok(RoundAdaptation::None);
        };
        if (round + 1) % adapt_every != 0 {
            return Ok(RoundAdaptation::None);
        }
        if self.window_coords == 0 || self.samples.is_empty() {
            // nothing transmitted this window (e.g. a channel blackout):
            // hold λ and keep accumulating into the next window
            return Ok(RoundAdaptation::None);
        }
        let realized = self.window_bits as f64 / self.window_coords as f64;
        self.last_realized = realized;
        // dual ascent on the rate constraint: λ ← [λ + η·(R − R*)]₊
        let err = realized - bits_per_coord;
        if self.prev_err.is_finite() {
            self.step *= if err.signum() == self.prev_err.signum() {
                STEP_GROW
            } else {
                STEP_SHRINK
            };
            self.step = self.step.clamp(STEP_MIN, STEP_MAX);
        }
        self.prev_err = err;
        self.lambda = (self.lambda + self.step * err).max(0.0);

        // re-design against the window's empirical pdf, warm-started
        // from the codebook currently on the wire
        let CompressionScheme::RcFed { bits, length_model, .. } =
            self.compressor.scheme
        else {
            return Err(Error::Config(
                "adaptive pipeline without an rcfed scheme".into()));
        };
        let samples = std::mem::take(&mut self.samples);
        let moments = (
            self.moments.mean(),
            self.moments.stddev(),
            self.moments.count(),
        );
        let pdf = EmpiricalPdf::from_samples(&samples);
        self.adapt_step += 1;
        let warm = self.compressor.codebook().cloned();
        let (cb, rep) = designed_adaptive_codebook(
            bits,
            self.lambda,
            length_model,
            self.adapt_step,
            moments,
            &pdf,
            warm.as_ref(),
        )?;
        let huffman = HuffmanCode::from_probs(&rep.probs)?;
        let arith = ArithmeticCoder::from_probs(&rep.probs)?;
        let broadcast = codebook_broadcast_bits(&cb);
        self.compressor.kernel =
            Kernel::Codebook { codebook: cb, huffman, arith };
        self.compressor.design_mse = Some(rep.mse);
        self.compressor.design_rate = Some(rep.huffman_rate);
        self.version += 1;
        self.window_bits = 0;
        self.window_coords = 0;
        self.moments = Welford::default();
        Ok(RoundAdaptation::Broadcast { bits_per_client: broadcast })
    }

    /// PS side: decode and accumulate. Adaptive and allocated packets
    /// must carry the *current* codebook version — a stale packet
    /// decoded against a newer codebook would silently reconstruct
    /// garbage, so it is rejected as a recoverable `Err` instead;
    /// allocated packets additionally decode against the *sender's*
    /// codebook, not a shared one.
    pub fn decompress_accumulate(
        &self,
        packet: &Packet,
        acc: &mut [f32],
    ) -> Result<()> {
        if let Some(alloc) = &self.alloc {
            return alloc.decompress_accumulate(packet, acc);
        }
        if !self.adaptive {
            return self.compressor.decompress_accumulate(packet, acc);
        }
        if packet.side_info.len() != 3 {
            return Err(Error::Coding(format!(
                "versioned packet carries {} side-info values, expected \
                 3 (μ, σ, version)",
                packet.side_info.len()
            )));
        }
        let (mu, sigma) = (packet.side_info[0], packet.side_info[1]);
        let ver = packet.side_version()?;
        if ver != self.version {
            return Err(Error::Coding(format!(
                "stale codebook version {ver} (current {})", self.version)));
        }
        self.compressor.decode_codebook_accumulate(packet, mu, sigma, acc)
    }

    /// PS side, split decode: run every per-packet stage —
    /// validation, entropy decode, reconstruction-table build — but
    /// defer the accumulator writes into the returned
    /// [`DecodedPacket`]. The parallel delivery path decodes packets
    /// concurrently with this (1 byte/coordinate of decode output for
    /// codebook schemes instead of a 4-byte recon vector) and replays
    /// the fused gather-adds serially in arrival order.
    ///
    /// `decode_body(p)` + `accumulate_into(acc)` is byte-identical to
    /// [`Self::decompress_accumulate`] — both run the same shared
    /// decode bodies, and the gather-add is the exact f32 expression
    /// the direct path evaluates.
    pub fn decode_body(&self, packet: &Packet) -> Result<DecodedPacket> {
        let body = if let Some(alloc) = &self.alloc {
            alloc.decode_body(packet)?
        } else if !self.adaptive {
            self.compressor.decode_body(packet)?
        } else {
            if packet.side_info.len() != 3 {
                return Err(Error::Coding(format!(
                    "versioned packet carries {} side-info values, expected \
                     3 (μ, σ, version)",
                    packet.side_info.len()
                )));
            }
            let (mu, sigma) = (packet.side_info[0], packet.side_info[1]);
            let ver = packet.side_version()?;
            if ver != self.version {
                return Err(Error::Coding(format!(
                    "stale codebook version {ver} (current {})",
                    self.version
                )));
            }
            self.compressor.decode_codebook_body(packet, mu, sigma)?
        };
        Ok(DecodedPacket { d: packet.d as usize, body })
    }
}

/// A packet after the decode phase but before the accumulate phase:
/// entropy-decoded symbols plus an owned reconstruction table (or a
/// dense reconstruction for the raw-value schemes). Owning the table —
/// 256 f32s — keeps the value independent of the pipeline, whose
/// codebook may be redesigned (adaptive re-design, allocator re-fill)
/// between decode and replay.
#[derive(Debug)]
pub struct DecodedPacket {
    d: usize,
    body: DecodedBody,
}

/// The scheme-shaped decode output behind [`DecodedPacket`].
#[derive(Debug)]
pub(crate) enum DecodedBody {
    /// raw reconstruction (fp32 / sign / qsgd fall back to the direct
    /// decoder — their decode already materializes values)
    Recon(Vec<f32>),
    /// dense codebook packet: one symbol per coordinate + premultiplied
    /// reconstruction table
    Symbols { symbols: Vec<u8>, table: Box<[f32; 256]> },
    /// sparse (top-k) codebook packet: coordinate indices + symbols
    Sparse {
        indices: Vec<u32>,
        symbols: Vec<u8>,
        table: Box<[f32; 256]>,
    },
}

impl DecodedPacket {
    /// The packet's declared model dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Replay phase: the fused gather-add (`acc[i] += t[sym[i]]`) into
    /// the server accumulator — the same per-coordinate f32 adds, in
    /// the same order, as the direct decode-accumulate path.
    pub fn accumulate_into(&self, acc: &mut [f32]) -> Result<()> {
        if acc.len() != self.d {
            return Err(Error::Coding(format!(
                "accumulator {} != decoded d {}", acc.len(), self.d)));
        }
        match &self.body {
            DecodedBody::Recon(recon) => {
                for (a, &v) in acc.iter_mut().zip(recon) {
                    *a += v;
                }
            }
            DecodedBody::Symbols { symbols, table } => {
                for (a, &s) in acc.iter_mut().zip(symbols) {
                    *a += table[s as usize];
                }
            }
            DecodedBody::Sparse { indices, symbols, table } => {
                for (&i, &s) in indices.iter().zip(symbols) {
                    acc[i as usize] += table[s as usize];
                }
            }
        }
        Ok(())
    }
}

/// PS-side decoding interface: the server is generic over this, so both
/// the static [`Compressor`] (tests, direct harnesses) and the
/// closed-loop [`CompressionPipeline`] (the round loop) can feed it.
pub trait PacketDecoder {
    fn decompress_accumulate(
        &self,
        packet: &Packet,
        acc: &mut [f32],
    ) -> Result<()>;
}

impl PacketDecoder for Compressor {
    fn decompress_accumulate(
        &self,
        packet: &Packet,
        acc: &mut [f32],
    ) -> Result<()> {
        Compressor::decompress_accumulate(self, packet, acc)
    }
}

impl PacketDecoder for CompressionPipeline {
    fn decompress_accumulate(
        &self,
        packet: &Packet,
        acc: &mut [f32],
    ) -> Result<()> {
        CompressionPipeline::decompress_accumulate(self, packet, acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rcq::LengthModel;

    fn gaussian_grad(n: usize, mu: f32, sigma: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g, mu, sigma);
        g
    }

    fn rcfed_scheme() -> CompressionScheme {
        CompressionScheme::RcFed {
            bits: 3,
            lambda: 0.05,
            length_model: LengthModel::Huffman,
        }
    }

    #[test]
    fn controller_labels_are_stable() {
        assert_eq!(RateTarget::Off.label(), "off");
        assert_eq!(
            RateTarget::Track { bits_per_coord: 2.5, adapt_every: 4 }.label(),
            "rt2.5w4"
        );
        assert_eq!(
            RateTarget::Joint { total_bpc: 4.0, split: 0.5, adapt_every: 4 }
                .label(),
            "jt4s0.5w4"
        );
    }

    #[test]
    fn joint_budget_splits_both_directions() {
        let jt =
            RateTarget::Joint { total_bpc: 4.0, split: 0.625, adapt_every: 2 };
        assert!(jt.is_on());
        assert_eq!(jt.track_params(), Some((2.5, 2)));
        let (down, w) = jt.down_params().unwrap();
        assert!((down - 1.5).abs() < 1e-12);
        assert_eq!(w, 2);
        assert!(jt.validate(&rcfed_scheme()).is_ok());
        assert!(jt.validate(&CompressionScheme::Fp32).is_err());
        for bad in [
            RateTarget::Joint { total_bpc: 4.0, split: 1.0, adapt_every: 2 },
            RateTarget::Joint { total_bpc: 4.0, split: 0.0, adapt_every: 2 },
            RateTarget::Joint { total_bpc: 0.0, split: 0.5, adapt_every: 2 },
            RateTarget::Joint { total_bpc: 4.0, split: 0.5, adapt_every: 0 },
            RateTarget::Joint {
                total_bpc: f64::NAN,
                split: 0.5,
                adapt_every: 2,
            },
        ] {
            assert!(bad.validate(&rcfed_scheme()).is_err(), "{bad:?}");
        }
        // only Joint exposes a downlink share
        assert!(RateTarget::Off.down_params().is_none());
        assert!(RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 }
            .down_params()
            .is_none());
        // the pipeline treats Joint exactly like Track at the split target
        let pipe = CompressionPipeline::design(
            rcfed_scheme(),
            WireCoder::Huffman,
            jt,
        )
        .unwrap();
        assert!(pipe.is_adaptive());
    }

    #[test]
    fn off_pipeline_is_bit_identical_to_static_compressor() {
        // the acceptance bar: RateTarget::Off must reproduce the static
        // Compressor packet for packet, byte for byte
        for scheme in [
            rcfed_scheme(),
            CompressionScheme::Lloyd { bits: 3 },
            CompressionScheme::Qsgd { bits: 3 },
            CompressionScheme::Fp32,
        ] {
            let stat =
                Compressor::design(scheme, WireCoder::Huffman).unwrap();
            let pipe = CompressionPipeline::design(
                scheme, WireCoder::Huffman, RateTarget::Off)
            .unwrap();
            assert!(!pipe.is_adaptive());
            let g = gaussian_grad(4096, 0.01, 0.02, 71);
            // QSGD draws randomness: identical seeds on both sides
            let mut r1 = Rng::new(72);
            let mut r2 = Rng::new(72);
            let p1 = stat.compress(1, 5, &g, &mut r1).unwrap();
            let p2 = pipe.compress(1, 5, &g, &mut r2).unwrap();
            assert_eq!(p1.to_bytes(), p2.to_bytes(), "{scheme:?}");
            assert_eq!(p1.total_bits(), p2.total_bits());
            // the stats pass is skipped entirely
            assert!(pipe.grad_sample(&g).is_empty());
            let mut a1 = vec![0f32; g.len()];
            let mut a2 = vec![0f32; g.len()];
            stat.decompress_accumulate(&p1, &mut a1).unwrap();
            pipe.decompress_accumulate(&p2, &mut a2).unwrap();
            assert_eq!(a1, a2);
        }
    }

    #[test]
    fn rate_target_validation() {
        let track = RateTarget::Track { bits_per_coord: 2.0, adapt_every: 4 };
        assert!(track.validate(&rcfed_scheme()).is_ok());
        assert!(track
            .validate(&CompressionScheme::Lloyd { bits: 3 })
            .is_err());
        assert!(RateTarget::Track { bits_per_coord: 0.0, adapt_every: 4 }
            .validate(&rcfed_scheme())
            .is_err());
        assert!(RateTarget::Track { bits_per_coord: 2.0, adapt_every: 0 }
            .validate(&rcfed_scheme())
            .is_err());
        assert!(RateTarget::Off
            .validate(&CompressionScheme::Fp32)
            .is_ok());
        assert!(CompressionPipeline::design(
            CompressionScheme::Fp32,
            WireCoder::Huffman,
            track
        )
        .is_err());
    }

    #[test]
    fn adaptive_packets_carry_version_and_reject_stale() {
        let target = RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 };
        let mut pipe = CompressionPipeline::design(
            rcfed_scheme(), WireCoder::Huffman, target)
        .unwrap();
        let g = gaussian_grad(8192, 0.0, 0.5, 73);
        let mut rng = Rng::new(74);
        let v0 = pipe.compress(0, 0, &g, &mut rng).unwrap();
        assert_eq!(v0.side_info.len(), 3, "version word missing");
        assert_eq!(v0.side_info[2], 0.0);
        let mut acc = vec![0f32; g.len()];
        pipe.decompress_accumulate(&v0, &mut acc).unwrap();
        // drive one adaptation window by hand: samples + ledger movement
        let sample = pipe.grad_sample(&g);
        assert!(!sample.is_empty());
        // the hot-path variant reuses the packet's (μ, σ) bit-for-bit
        assert_eq!(sample, pipe.grad_sample_from(&g, &v0));
        pipe.observe_samples(&sample);
        pipe.observe_round(v0.total_bits(), v0.d as u64);
        match pipe.end_round(0).unwrap() {
            RoundAdaptation::Broadcast { bits_per_client } => {
                assert!(bits_per_client > 0,
                        "redesign must cost downlink bits");
            }
            other => panic!("expected a broadcast, got {other:?}"),
        }
        assert_eq!(pipe.version(), 1);
        // the old packet is now stale and must be rejected, not decoded
        let err = pipe.decompress_accumulate(&v0, &mut acc);
        assert!(err.is_err(), "stale version accepted");
        // fresh packets carry — and pass — the new version
        let v1 = pipe.compress(0, 1, &g, &mut rng).unwrap();
        assert_eq!(v1.side_info[2], 1.0);
        pipe.decompress_accumulate(&v1, &mut acc).unwrap();
    }

    // `dual_ascent_moves_lambda_toward_the_target` lives in
    // `tests/rate_controller.rs` (public API only).

    #[test]
    fn blackout_window_holds_lambda_and_keeps_accumulating() {
        // the guard at the top of the Track end_round: a window in which
        // nothing was transmitted (total channel blackout) must hold λ,
        // publish no codebook, and carry its samples into the next window
        let target = RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 };
        let mut pipe = CompressionPipeline::design(
            rcfed_scheme(), WireCoder::Huffman, target)
        .unwrap();
        let g = gaussian_grad(8192, 0.0, 1.0, 81);
        let sample = pipe.grad_sample(&g);
        assert!(!sample.is_empty());
        let lam0 = pipe.lambda();

        // window 1: samples observed, but zero ledger movement
        pipe.observe_samples(&sample);
        assert_eq!(pipe.end_round(0).unwrap(), RoundAdaptation::None);
        assert_eq!(pipe.lambda(), lam0, "blackout must hold λ");
        assert_eq!(pipe.version(), 0, "blackout must not publish");
        assert!(pipe.last_realized().is_nan());
        assert_eq!(pipe.samples.len(), sample.len(),
                   "blackout samples must keep accumulating");

        // the inverse blackout — ledger movement but no samples (every
        // sampled packet was rejected) — also holds
        let mut dry = CompressionPipeline::design(
            rcfed_scheme(), WireCoder::Huffman, target)
        .unwrap();
        dry.observe_round(1000, 500);
        assert_eq!(dry.end_round(0).unwrap(), RoundAdaptation::None);
        assert_eq!(dry.version(), 0);

        // window 2 transmits: adaptation fires and the design pdf spans
        // both windows' samples
        pipe.observe_samples(&sample);
        pipe.observe_round(4 * 8192, 8192);
        match pipe.end_round(1).unwrap() {
            RoundAdaptation::Broadcast { bits_per_client } => {
                assert!(bits_per_client > 0);
            }
            other => panic!("expected a broadcast, got {other:?}"),
        }
        assert_eq!(pipe.version(), 1);
        assert_eq!(pipe.moments.count(), 0, "window state must reset");
        assert!(pipe.lambda() > lam0, "realized ≫ target must raise λ");
    }

    // The σ = 0 constant-gradient regression lives in
    // `super::compressor::tests`; the transform × Track composition
    // scenario lives in `tests/error_feedback.rs` (public API only).

    /// `decode_body` + `accumulate_into` must be bitwise equal to the
    /// direct `decompress_accumulate` for every scheme family — dense
    /// codebook, raw-value fallbacks, sparse top-k, and the adaptive
    /// versioned path (including its stale-version reject).
    #[test]
    fn split_decode_is_bitwise_identical_to_direct() {
        let check = |pipe: &CompressionPipeline, pkt: &Packet, d: usize| {
            let mut direct = vec![0.25f32; d];
            pipe.decompress_accumulate(pkt, &mut direct).unwrap();
            let dp = pipe.decode_body(pkt).unwrap();
            assert_eq!(dp.dim(), d);
            let mut replay = vec![0.25f32; d];
            dp.accumulate_into(&mut replay).unwrap();
            let a: Vec<u32> = direct.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = replay.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b);
        };
        let g = gaussian_grad(4096, 0.01, 0.5, 91);
        // static pipelines across the kernel families
        for scheme in [
            rcfed_scheme(),
            CompressionScheme::Lloyd { bits: 3 },
            CompressionScheme::Qsgd { bits: 3 },
            CompressionScheme::Fp32,
            CompressionScheme::Sign,
        ] {
            let pipe = CompressionPipeline::design(
                scheme, WireCoder::Huffman, RateTarget::Off)
            .unwrap();
            let mut rng = Rng::new(92);
            let pkt = pipe.compress(0, 0, &g, &mut rng).unwrap();
            check(&pipe, &pkt, g.len());
        }
        // sparse top-k over a codebook kernel
        let sparse = Compressor::design_with_transform(
            rcfed_scheme(),
            WireCoder::Huffman,
            TransformCfg::topk(0.1),
        )
        .unwrap();
        let pipe = CompressionPipeline::from_compressor(sparse);
        let mut rng = Rng::new(93);
        let pkt = pipe.compress(0, 0, &g, &mut rng).unwrap();
        check(&pipe, &pkt, g.len());
        // adaptive versioned path: current version decodes, stale rejects
        let mut adaptive = CompressionPipeline::design(
            rcfed_scheme(),
            WireCoder::Huffman,
            RateTarget::Track { bits_per_coord: 2.0, adapt_every: 1 },
        )
        .unwrap();
        let v0 = adaptive.compress(0, 0, &g, &mut rng).unwrap();
        check(&adaptive, &v0, g.len());
        let sample = adaptive.grad_sample(&g);
        adaptive.observe_samples(&sample);
        adaptive.observe_round(v0.total_bits(), v0.d as u64);
        adaptive.end_round(0).unwrap();
        assert!(adaptive.decode_body(&v0).is_err(), "stale version");
        let v1 = adaptive.compress(0, 1, &g, &mut rng).unwrap();
        check(&adaptive, &v1, g.len());
    }
}
